#!/usr/bin/env bash
# CI gate: tier-1 tests + a quick benchmark smoke run.
#
#   bash scripts/ci.sh
#
# Dependency install is best-effort so the script also works in
# air-gapped containers that bake the toolchain into the image.
set -uo pipefail
cd "$(dirname "$0")/.."

pip install -r requirements.txt \
    || echo "ci: pip install failed; assuming preinstalled deps" >&2

set -e
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmark smoke (analytic, no roofline) =="
python -m benchmarks.run --quick --skip-roofline > /dev/null

echo "ci: OK"

#!/usr/bin/env bash
# CI gate: tier-1 tests + a quick benchmark smoke run.
#
#   bash scripts/ci.sh
#
# Dependency install is best-effort so the script also works in
# air-gapped containers that bake the toolchain into the image.
set -uo pipefail
cd "$(dirname "$0")/.."

pip install -r requirements.txt \
    || echo "ci: pip install failed; assuming preinstalled deps" >&2
# property-based modules importorskip on hypothesis — install it
# explicitly so the 4 property tests run in CI instead of skipping
pip install hypothesis \
    || echo "ci: hypothesis install failed; property tests will skip" >&2

set -e
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmark smoke (analytic, no roofline) =="
python -m benchmarks.run --quick --skip-roofline > /dev/null

# the machine-model cycles gate (benchmarks/roofline.py --smoke) and
# the simulator perf-trajectory gate (benchmarks/bench_sim.py --smoke)
# run as their own named CI jobs (machine-smoke / bench-smoke in
# ci.yml) so a drift failure is legible at a glance; run them here
# manually when iterating locally

echo "ci: OK"

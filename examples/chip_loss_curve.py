"""Chip-loss degradation curve on a mesh-of-chips system.

Compiles one workload onto an N-chip mesh, then knocks chips out one
at a time (``SystemConfig.degrade(failed_chips=...)``) and lets the
system partitioner re-plan on whatever survives.  The printed curve —
throughput vs failed-chip count, normalized to the healthy mesh — is
the graceful-degradation story: work is conserved (the re-plan covers
every layer), only the throughput and hop counts move.  On cheap links
the curve is flat-then-cliff — re-routing around a dead chip costs a
few hops' worth of cycles until the survivors no longer have the gmem
to hold the model at all, which the script reports as the final row.

    PYTHONPATH=src python examples/chip_loss_curve.py
    PYTHONPATH=src python examples/chip_loss_curve.py transformer \
        --chips 8 --fidelity trace
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro import flow
from repro.core.arch import default_chip
from repro.core.partition import InfeasibleModel
from repro.flow import CompileOptions
from repro.system import SystemConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("model", nargs="?", default="transformer")
    ap.add_argument("--chips", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--fidelity", default="analytic",
                    choices=("analytic", "trace"))
    args = ap.parse_args(argv)

    kw = {"res": 8, "c": 8} if args.model == "tiny_cnn" else None
    chip = default_chip()
    print(f"model={args.model}  mesh={args.chips} chips  "
          f"fidelity={args.fidelity}\n")
    hdr = (f"{'failed':>6} {'alive':>6} {'used':>5} {'cycles':>12} "
           f"{'samples/s':>10} {'vs healthy':>10}")
    print(hdr)
    print("-" * len(hdr))

    base_sps = None
    # fail chips starting at chip 1 — the low-index chips are the ones
    # the healthy plan occupies, so each loss forces a real re-plan
    # onto higher-index survivors with longer routes (chip 0, the
    # gmem-facing entry chip, stays alive)
    for n_fail in range(args.chips):
        failed = tuple(range(1, 1 + n_fail))
        sysc = SystemConfig.mesh(args.chips)
        if failed:
            sysc = sysc.degrade(failed_chips=failed)
        try:
            rep = flow.compile(args.model, chip, CompileOptions(
                fidelity=args.fidelity, batch=args.batch,
                workload_kw=kw, system=sysc)).evaluate()
        except InfeasibleModel as e:
            print(f"{n_fail:>6d} {args.chips - n_fail:>6d}   "
                  f"-- too few chips left: {e}")
            break
        if base_sps is None:
            base_sps = rep.throughput_sps
        print(f"{n_fail:>6d} {args.chips - n_fail:>6d} "
              f"{rep.n_chips:>5d} {rep.cycles:>12.1f} "
              f"{rep.throughput_sps:>10.1f} "
              f"{rep.throughput_sps / base_sps:>9.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())

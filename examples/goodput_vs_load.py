"""Goodput vs offered load under deadlines and load shedding.

Replays seeded Poisson traces at a ladder of arrival rates through the
serving simulator twice per rate: once unprotected (no deadline, no
queue cap — every token counts) and once in degraded-operation mode
(per-request deadline, bounded admission queue, retry-with-backoff).
Well below saturation the two are identical; past it, raw *throughput*
keeps climbing while *goodput* — tokens delivered within deadline —
collapses, and the shedding run trades a few rejected requests for a
far higher in-deadline fraction.  That crossover is the figure.

With matplotlib available, also writes ``results/goodput_vs_load.png``
(three curves: throughput, unprotected goodput, shedding goodput).

    PYTHONPATH=src python examples/goodput_vs_load.py
    PYTHONPATH=src python examples/goodput_vs_load.py \
        --rates 20000,60000,120000,300000 --deadline-ms 2
"""

import argparse
import sys
import warnings

sys.path.insert(0, "src")

from repro.serve import (ServeModelCfg, ServeSim, StepCostTable,
                         make_policy, poisson_trace)

# analytic prefill capacity for the default tiny config is ~90k req/s;
# the ladder deliberately crosses it
RATES = (20000.0, 50000.0, 90000.0, 150000.0, 300000.0)


def _run(table, trace, **kw):
    sim = ServeSim(table, make_policy("continuous", 8), **kw)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return sim.run(trace)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rates", default=",".join(str(int(r))
                                                for r in RATES))
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--deadline-ms", type=float, default=2.0)
    ap.add_argument("--max-queue", type=int, default=4)
    ap.add_argument("--max-retries", type=int, default=2)
    ap.add_argument("--no-plot", action="store_true")
    args = ap.parse_args(argv)

    table = StepCostTable(ServeModelCfg(), fidelity="analytic")
    deadline = args.deadline_ms / 1e3
    rates = [float(r) for r in args.rates.split(",")]

    hdr = (f"{'rate req/s':>10s} | {'tok/s':>9s} {'goodput':>9s} "
           f"{'shed-goodput':>12s} {'shed':>5s} {'timeo':>5s} "
           f"{'retry':>5s}")
    print(hdr)
    print("-" * len(hdr))
    rows = []
    for rate in rates:
        trace = poisson_trace(rate, args.requests, seed=args.seed)
        # deadline only: goodput of the unprotected system
        plain = _run(table, trace, deadline_s=deadline)
        # deadline + bounded queue + retries: graceful degradation
        shed = _run(table, trace, deadline_s=deadline,
                    max_queue=args.max_queue,
                    max_retries=args.max_retries,
                    retry_backoff_s=0.0005)
        rows.append((rate, plain["throughput_tok_s"],
                     plain["goodput_tok_s"], shed["goodput_tok_s"]))
        print(f"{rate:>10.0f} | {plain['throughput_tok_s']:>9.0f} "
              f"{plain['goodput_tok_s']:>9.0f} "
              f"{shed['goodput_tok_s']:>12.0f} "
              f"{shed['shed_requests']:>5d} "
              f"{shed['timeout_requests']:>5d} "
              f"{shed['retries']:>5d}")

    if not args.no_plot:
        try:
            import os

            import matplotlib
            matplotlib.use("Agg")
            import matplotlib.pyplot as plt
        except ImportError:
            print("\n(matplotlib not installed; table only)")
            return 0
        xs = [r[0] for r in rows]
        fig, ax = plt.subplots(figsize=(6, 4))
        ax.plot(xs, [r[1] for r in rows], "o-", label="throughput")
        ax.plot(xs, [r[2] for r in rows], "s--",
                label="goodput (no shedding)")
        ax.plot(xs, [r[3] for r in rows], "^-",
                label="goodput (shed + retry)")
        ax.set_xlabel("offered load (req/s)")
        ax.set_ylabel("tok/s")
        ax.set_title(f"goodput vs load "
                     f"(deadline {args.deadline_ms:g} ms)")
        ax.legend()
        ax.grid(alpha=0.3)
        os.makedirs("results", exist_ok=True)
        out = "results/goodput_vs_load.png"
        fig.savefig(out, dpi=120, bbox_inches="tight")
        print(f"\nwrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""End-to-end training driver example (deliverable b).

Trains an OLMoE-family model on the synthetic pipeline with
checkpointing + resume.  ``--full`` uses a ~100M-parameter config (for
real accelerators); the default fits a CPU smoke run.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import ARCHS, reduced
from repro.launch import train as train_mod


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config (needs a real accelerator)")
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.full:
        # ~100M params: 12 layers, d=768, same family as the target arch
        base = ARCHS[args.arch]
        cfg = dataclasses.replace(
            reduced(base, layers_per_kind=12, d_model=768, vocab=32000),
            name=base.name + "-100m", d_ff=3072)
        print(f"full config: {cfg.param_count() / 1e6:.0f}M params")
        argv = ["--arch", args.arch, "--steps", str(args.steps),
                "--batch", "16", "--seq", "1024"]
        # the driver rebuilds from ARCHS; inject our config
        train_mod.ARCHS = dict(train_mod.ARCHS, **{args.arch: cfg})
        return train_mod.main(argv + ["--ckpt-dir", args.ckpt_dir])
    return train_mod.main([
        "--arch", args.arch, "--reduced", "--steps", str(args.steps),
        "--batch", "8", "--seq", "128", "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50",
    ])


if __name__ == "__main__":
    raise SystemExit(main())

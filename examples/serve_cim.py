"""Arrival-rate vs p99 latency sweep on the CIM serving simulator.

Compiles one step-cost table, then replays seeded Poisson traces at a
ladder of offered loads under both batching policies.  The interesting
region is near saturation: static batching's head-of-line blocking
blows up p99 per-token latency while continuous (iteration-level)
batching degrades gracefully at the same throughput.

    PYTHONPATH=src python examples/serve_cim.py
    PYTHONPATH=src python examples/serve_cim.py --fidelity analytic
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.serve import (ServeModelCfg, ServeSim, StepCostTable,
                         make_policy, poisson_trace)

RATES = (1000.0, 2000.0, 5000.0, 10000.0, 15000.0, 20000.0)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fidelity",
                    choices=("analytic", "trace", "simulate"),
                    default="trace")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ServeModelCfg(n_layers=2, d_model=128, n_heads=4, vocab=256,
                        max_prompt=64, max_new=64)
    print(f"compiling step-cost table (fidelity={args.fidelity}) ...",
          flush=True)
    table = StepCostTable(cfg, fidelity=args.fidelity)

    hdr = (f"{'rate req/s':>10s} | {'policy':<11s} {'tok/s':>9s} "
           f"{'ttft p99 ms':>11s} {'tpot p99 us':>11s} "
           f"{'e2e p99 ms':>10s}")
    print(hdr)
    print("-" * len(hdr))
    for rate in RATES:
        trace = poisson_trace(rate, args.requests, seed=args.seed)
        for name in ("static", "continuous"):
            sim = ServeSim(table, make_policy(name, args.max_batch))
            m = sim.run(trace)
            print(f"{rate:>10.0f} | {name:<11s} "
                  f"{m['throughput_tok_s']:>9.0f} "
                  f"{m['ttft_s']['p99'] * 1e3:>11.3f} "
                  f"{m['tpot_s']['p99'] * 1e6:>11.1f} "
                  f"{m['e2e_s']['p99'] * 1e3:>10.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Prefill scheduling policies under an arrival-rate ladder.

Replays seeded Poisson traces through the array engine at a ladder of
offered loads under all three prefill policies.  The workload is
prefill-heavy (prompts 33..64 tokens, 2..8 generated) with a light
decode step, which is the regime where prefill scheduling matters:

* ``fifo``    — batch-1 prefill on a dedicated engine: simple, but at
  over-capacity the prefill queue grows without bound and p99 TTFT
  explodes;
* ``batched`` — groups up to ``--prefill-max-batch`` arrived requests
  per prefill launch (cost = base + per-seq from the fitted
  ``StepCostTable``), multiplying effective prefill capacity;
* ``chunked`` — Sarathi-style: prompt chunks are co-scheduled into
  decode iterations under a ``--chunk-tokens`` budget, so prefill
  rides the decode engine and TTFT stays flat past FIFO's saturation
  point.

    PYTHONPATH=src python examples/prefill_policies.py
    PYTHONPATH=src python examples/prefill_policies.py --requests 5000
"""

import argparse
import sys
import warnings

sys.path.insert(0, "src")

from repro.serve import (ServeModelCfg, ServeSim, StepCostTable,
                         make_policy, poisson_trace)

RATES = (2000.0, 5000.0, 8000.0, 11000.0)
POLICIES = ("fifo", "batched", "chunked")


def _table() -> StepCostTable:
    # Prefill-bound synthetic costs: prefill scales with the padded
    # bucket, decode is light and flat.  from_costs skips compilation
    # so the example runs in milliseconds.
    cfg = ServeModelCfg(max_prompt=64, max_new=8)
    pb = [1, 2, 4, 8, 16, 32, 64]
    db, b = [], 1
    while b < cfg.max_seq:
        db.append(b)
        b *= 2
    db.append(cfg.max_seq)
    return StepCostTable.from_costs(
        cfg,
        prefill_s={b: 2e-6 * b for b in pb},
        decode_base_s={b: 10e-6 for b in db},
        decode_per_seq_s={b: 1e-6 for b in db},
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=3000)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--prefill-max-batch", type=int, default=16)
    ap.add_argument("--chunk-tokens", type=int, default=64)
    ap.add_argument("--seed", type=int, default=11)
    args = ap.parse_args(argv)

    table = _table()
    hdr = (f"{'rate req/s':>10s} | {'prefill':<8s} {'tok/s':>9s} "
           f"{'ttft p50 ms':>11s} {'ttft p99 ms':>11s} "
           f"{'e2e p99 ms':>10s}")
    print(hdr)
    print("-" * len(hdr))
    for rate in RATES:
        trace = poisson_trace(rate, args.requests, seed=args.seed,
                              min_prompt=33, max_prompt=64,
                              min_new=2, max_new=8)
        for policy in POLICIES:
            sim = ServeSim(
                table, make_policy("continuous", args.max_batch),
                prefill_policy=policy,
                prefill_max_batch=args.prefill_max_batch,
                chunk_tokens=args.chunk_tokens,
            )
            with warnings.catch_warnings():
                # the upper rates are deliberately over capacity; the
                # saturation warning would fire once per cell
                warnings.simplefilter("ignore", RuntimeWarning)
                m = sim.run(trace)
            print(f"{rate:>10.0f} | {policy:<8s} "
                  f"{m['throughput_tok_s']:>9.0f} "
                  f"{m['ttft_s']['p50'] * 1e3:>11.3f} "
                  f"{m['ttft_s']['p99'] * 1e3:>11.3f} "
                  f"{m['e2e_s']['p99'] * 1e3:>10.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

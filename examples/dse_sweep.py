"""Design-space exploration example (paper §IV-C + future-work DSE).

Sweeps MG size x NoC flit x strategy for one workload with the analytic
model, then validates the Pareto-best point with the cycle-accurate
simulator — the paper's "systematic prototyping" workflow.

    PYTHONPATH=src python examples/dse_sweep.py [model]
"""

import sys

sys.path.insert(0, "src")

from repro.core import workloads
from repro.core.arch import default_chip
from repro.core.dse import SWEEP_FLIT, SWEEP_MG, evaluate
from repro.core.mapping import CostParams
from repro.core.partition import STRATEGIES


def main() -> int:
    model = sys.argv[1] if len(sys.argv) > 1 else "mobilenetv2"
    cg = workloads.build(model, res=112).condense()
    params = CostParams(batch=4)
    print(f"DSE over {model}: MG {SWEEP_MG} x flit {SWEEP_FLIT} x "
          f"{STRATEGIES}")
    best = None
    for strat in STRATEGIES:
        for mg in SWEEP_MG:
            for flit in SWEEP_FLIT:
                chip = default_chip(macros_per_group=mg, flit_bytes=flit)
                pt = evaluate(cg, chip, strat, params, simulate=False)
                edp = pt.cycles * pt.energy["total"]
                marker = ""
                if best is None or edp < best[0]:
                    best = (edp, strat, mg, flit)
                    marker = "  <- best EDP so far"
                print(f"  {strat:8s} MG={mg:2d} flit={flit:2d}: "
                      f"{pt.cycles:10.0f} cyc, "
                      f"{pt.energy['total'] / 1e6:7.2f} mJ{marker}")
    _, strat, mg, flit = best
    print(f"\nvalidating best point ({strat}, MG={mg}, flit={flit}B) "
          f"with the cycle-accurate simulator...")
    chip = default_chip(macros_per_group=mg, flit_bytes=flit)
    pt = evaluate(cg, chip, strat, params, simulate=True)
    print(f"  simulated: {pt.cycles:.0f} cycles, "
          f"{pt.energy['total'] / 1e6:.2f} mJ, "
          f"{pt.throughput_sps:.1f} samples/s @1GHz")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Design-space exploration example (paper §IV-C + future-work DSE).

Explores strategy x MG size x NoC flit for one workload on the
``repro.explore`` engine: a two-fidelity successive-halving pass screens
the whole grid with the analytic cost model (pool-parallel, cached),
promotes the top-K points to the cycle-accurate simulator, and prints
the cycles-vs-energy Pareto frontier — the paper's "systematic
prototyping" workflow.  Evaluation runs through the
:mod:`repro.flow` pipeline, whose pass-output cache lets an in-process
promotion reuse the partition computed during the analytic screen.

The same sweep is available without a script as
``python -m repro.explore sweep MODEL --top-k K``.

    PYTHONPATH=src python examples/dse_sweep.py [model] [--pool N]
        [--top-k K] [--full-space]
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.core.mapping import CostParams
from repro.core.partition import STRATEGIES
from repro.explore import (ExplorationEngine, by_edp, default_cache_dir,
                           default_space, frontier_report, mg_flit_space,
                           successive_halving)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("model", nargs="?", default="mobilenetv2")
    ap.add_argument("--pool", type=int, default=4,
                    help="worker processes for the screening sweep")
    ap.add_argument("--top-k", type=int, default=3,
                    help="survivors promoted to the simulator")
    ap.add_argument("--full-space", action="store_true",
                    help="explore the full 5-dimension space instead of "
                         "the Fig. 6 MG x flit grid")
    args = ap.parse_args()

    space = (default_space() if args.full_space
             else mg_flit_space((4, 8, 16), (8, 16),
                                strategies=STRATEGIES))
    eng = ExplorationEngine(args.model, res=112,
                            params=CostParams(batch=4), pool=args.pool,
                            cache=default_cache_dir())
    print(f"DSE over {args.model}: {space.describe()}")

    result, screened = successive_halving(eng, space, top_k=args.top_k,
                                          objective=by_edp)
    print(f"\nscreened {len(screened)} points with the analytic model "
          f"(cache: {eng.cache_stats()}), promoted {args.top_k} to the "
          f"cycle-accurate simulator")

    print("\nPareto frontier (cycles vs energy, analytic screen):")
    print(frontier_report(screened, axes=("cycles", "energy")))

    best = result.best
    p = best.point
    print(f"\nbest EDP after simulation: {p.strategy}, "
          f"MG={p.macros_per_group}, flit={p.flit_bytes}B "
          f"(cores={p.n_cores}, n_mg={p.n_macro_groups}, "
          f"lmem={p.local_mem_kb}KB)")
    print(f"  simulated: {best.cycles:.0f} cycles, "
          f"{best.energy_total / 1e6:.2f} mJ, "
          f"{best.throughput_sps:.1f} samples/s @1GHz")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

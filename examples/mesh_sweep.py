"""Mesh-of-chips scale-out sweep: pipeline vs tensor parallelism.

Compiles one workload onto 1 / 2 / 4 / 8-chip meshes through
``repro.system`` and prints, per mesh size and link tier, the
end-to-end batch latency, the inter-chip communication share, and the
throughput — the numbers behind the pipeline-vs-tensor crossover:

* **pipeline** stages pay one activation handoff per cut, so their
  comm cost is small and flat — but stage imbalance caps the speedup;
* **tensor** shards pay a collective per layer, so their comm cost
  grows with chip count — but the compute split is near-perfect.

Which wins flips with the link tier: on an interposer-class link the
collectives are cheap enough for tensor's better balance to pay off
earlier; on PCB/cable tiers pipeline holds on longer.

    PYTHONPATH=src python examples/mesh_sweep.py [model]
        [--chips 1,2,4,8] [--links interposer,pcb] [--fidelity trace]
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro import flow
from repro.core.arch import default_chip
from repro.flow import CompileOptions
from repro.system import PARALLEL_MODES, SystemConfig


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("model", nargs="?", default="transformer")
    ap.add_argument("--chips", default="1,2,4,8",
                    help="comma-separated mesh sizes")
    ap.add_argument("--links", default="interposer,pcb",
                    help="comma-separated link tiers")
    ap.add_argument("--fidelity", default="trace",
                    choices=("analytic", "trace"))
    args = ap.parse_args()
    chip = default_chip()
    sizes = [int(s) for s in args.chips.split(",")]
    links = args.links.split(",")

    print(f"model={args.model}  chip={chip.name}  "
          f"fidelity={args.fidelity}\n")
    hdr = (f"{'chips':>5} {'link':>10} {'mode':>8} {'cycles':>12} "
           f"{'comm':>10} {'comm%':>6} {'samples/s':>10}")
    print(hdr)
    print("-" * len(hdr))
    for n in sizes:
        for link in links:
            for mode in PARALLEL_MODES if n > 1 else ("pipeline",):
                try:
                    art = flow.compile(args.model, chip, CompileOptions(
                        fidelity=args.fidelity,
                        system=SystemConfig.mesh(n, link=link,
                                                 parallel=mode)))
                    rep = art.evaluate()
                    comm = getattr(rep, "comm_cycles", 0)
                    pct = 100.0 * comm / rep.cycles if rep.cycles else 0
                    print(f"{n:>5} {link:>10} {mode:>8} "
                          f"{rep.cycles:>12.0f} {comm:>10.0f} "
                          f"{pct:>5.1f}% {rep.throughput_sps:>10.1f}")
                except Exception as e:  # infeasible point, keep going
                    print(f"{n:>5} {link:>10} {mode:>8} "
                          f"{'—':>12} {type(e).__name__}: "
                          f"{str(e)[:50]}")
            if n == 1:
                break       # link tier is irrelevant on one chip
    print("\npipeline pays one handoff per cut (flat comm); tensor "
          "pays a collective\nper layer (comm grows with chips) but "
          "splits compute near-perfectly —\nthe crossover moves with "
          "the link tier.")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""LM serving example on the CIM serving simulator.

Replays a seeded Poisson trace against a compiled CIM step-cost table
and compares static vs continuous batching at the same offered load.
(The earlier revision of this example drove the JAX training-side
decode loop; serving now goes through ``repro.serve``, which prices
decode steps on the CIM fidelity ladder with incremental KV staging.)

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

sys.path.insert(0, "src")

from repro.serve import (ServeModelCfg, ServeSim, StepCostTable,
                         make_policy, poisson_trace)


def main() -> int:
    cfg = ServeModelCfg(n_layers=2, d_model=128, n_heads=4, vocab=256,
                        max_prompt=64, max_new=64)
    print("compiling step-cost table (fidelity=trace) ...", flush=True)
    table = StepCostTable(cfg, fidelity="trace")
    trace = poisson_trace(rate=5000.0, n=200, seed=0)
    for name in ("static", "continuous"):
        sim = ServeSim(table, make_policy(name, max_batch=8))
        m = sim.run(trace)
        print(f"{name:<11s} tok/s={m['throughput_tok_s']:9.0f}  "
              f"ttft p99={m['ttft_s']['p99'] * 1e3:6.2f}ms  "
              f"tpot p99={m['tpot_s']['p99'] * 1e6:7.1f}us  "
              f"e2e p99={m['e2e_s']['p99'] * 1e3:6.2f}ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

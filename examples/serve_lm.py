"""Batched-request serving example (deliverable b).

Serves three architecture families — dense+SWA ring cache, pure-SSM
constant state, MoE expert-parallel — through the same decode path.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_mod


def main() -> int:
    for arch, gen in [("h2o-danube-3-4b", 16), ("mamba2-780m", 16),
                      ("olmoe-1b-7b", 16)]:
        print(f"\n=== {arch} ===")
        rc = serve_mod.main(["--arch", arch, "--reduced", "--batch", "4",
                             "--prompt-len", "24", "--gen", str(gen)])
        if rc:
            return rc
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

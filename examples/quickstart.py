"""CIMFlow quickstart: a small CNN through the whole stack in ~30 s.

    graph -> repro.flow.compile (condense -> Alg.1 DP partition ->
    OP-level mapping -> ISA codegen passes) -> Artifact.evaluate on the
    analytic / cycle-accurate / functional backends -> oracle check

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys

import numpy as np

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from repro import flow
from repro.core import ref, workloads
from repro.core.arch import default_chip
from repro.core.mapping import CostParams
from repro.core.partition import STRATEGIES
from repro.flow import CompileOptions


def main() -> int:
    rng = np.random.default_rng(0)
    # 1. model + hardware -----------------------------------------------------
    graph = workloads.tiny_cnn(res=8, c=8)
    print(graph.summary())
    cg = graph.condense()
    chip = default_chip(n_cores=8, mesh_cols=4)
    print(chip.describe())

    # 2. the paper's three compilation strategies ------------------------------
    # one options record per strategy; the analytic backend scores the
    # partition without generating any ISA
    opts = CompileOptions(params=CostParams(batch=2), batch=2)
    arts = {s: flow.compile(cg, chip, opts, strategy=s)
            for s in STRATEGIES}
    for s, art in arts.items():
        rep = art.evaluate("analytic")
        print(f"  {s:8s}: {rep.cycles:8.0f} cycles "
              f"({art.partition.n_stages} stages)")

    # 3. compile the DP plan to ISA programs ----------------------------------
    # weights: random int8 in the im2col matrix layout
    weights, biases = {}, {}
    for g in cg:
        if g.anchor is None:
            continue
        op = graph.ops[g.anchor]
        if op.kind == "conv":
            k = op.attrs["k"]
            cin = graph.ops[op.inputs[0]].out_shape[-1]
            ker = rng.integers(-6, 7, (k, k, cin, op.gemm_n), np.int8)
            weights[g.idx] = ref.conv_weight_matrix(ker)
        elif op.kind == "linear":
            weights[g.idx] = rng.integers(-6, 7, (g.gemm_k, g.gemm_n),
                                          dtype=np.int8)
        if any(graph.ops[i].kind == "bias" for i in g.op_ids):
            biases[g.idx] = rng.integers(-40, 40, g.gemm_n, np.int32)
    inputs = rng.integers(-8, 8, (2, 8, 8, 3)).astype(np.int8)
    qp = ref.auto_quant(cg, weights, biases, inputs)
    # fidelity="func": the codegen pass runs eagerly (and is cached —
    # note the partition pass comes back from the pipeline cache)
    art = flow.compile(cg, chip, opts, strategy="dp", quant=qp,
                       strict_lmem=True, fidelity="func")
    print(art.describe())
    model = art.model
    print(f"compiled: {model.total_instrs} instructions across "
          f"{len(model.stages)} stage programs")

    # 4. functional simulation, checked against the INT8 oracle ---------------
    img = art.build_gmem_image(weights, biases, inputs)
    rep = art.evaluate("func", gmem_image=img)
    oracle = ref.run_reference(cg, weights, biases, qp, inputs)
    last = len(cg) - 1
    for s in range(2):
        addr, nb = art.output_addr(last, s)
        got = rep.sim.gmem[addr - 0x10000000: addr - 0x10000000 + nb]
        assert np.array_equal(got, oracle[last][s].reshape(-1)), s
    print("functional ISS output == numpy INT8 oracle  [OK]")

    # 5. performance + energy report -------------------------------------------
    print(f"simulated: {rep.sim.summary()}")
    bd = rep.energy
    top = sorted((k, v) for k, v in bd.items() if k != "total")
    print("energy breakdown:",
          ", ".join(f"{k}={100 * v / bd['total']:.0f}%" for k, v in top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

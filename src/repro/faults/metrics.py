"""Accuracy-degradation metrics over oracle outputs.

All metrics compare two ``{gid: int8 array}`` output dicts (or two raw
arrays) of identical shapes — typically the fault-free oracle run
against a faulty one — and reduce to plain floats, so degradation
curves serialize straight into benchmark goldens.
"""

from __future__ import annotations

from typing import Dict, Union

import numpy as np

__all__ = ["bit_error_rate", "top1_agreement", "top1_delta"]

_Outputs = Union[np.ndarray, Dict[int, np.ndarray]]


def _pairs(ref: _Outputs, got: _Outputs):
    if isinstance(ref, dict) != isinstance(got, dict):
        raise TypeError("compare two output dicts or two arrays, "
                        "not a mix")
    if isinstance(ref, dict):
        if sorted(ref) != sorted(got):
            raise ValueError(f"output keys differ: {sorted(ref)} vs "
                             f"{sorted(got)}")
        for gid in sorted(ref):
            yield np.asarray(ref[gid]), np.asarray(got[gid])
    else:
        yield np.asarray(ref), np.asarray(got)


def bit_error_rate(ref: _Outputs, got: _Outputs) -> float:
    """Fraction of output *bits* that differ (0.0 = bit-identical)."""
    wrong = 0
    total = 0
    for a, b in _pairs(ref, got):
        if a.shape != b.shape:
            raise ValueError(f"shape mismatch {a.shape} vs {b.shape}")
        x = np.bitwise_xor(a.view(np.uint8), b.view(np.uint8))
        wrong += int(np.unpackbits(x.reshape(-1)).sum())
        total += x.size * 8
    return wrong / total if total else 0.0


def top1_agreement(ref: np.ndarray, got: np.ndarray) -> float:
    """Fraction of samples whose argmax class is unchanged.

    Takes the final ``(batch, ...)`` output maps; everything after the
    batch axis is flattened into one logit vector per sample.
    """
    a = np.asarray(ref).reshape(ref.shape[0], -1)
    b = np.asarray(got).reshape(got.shape[0], -1)
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch {a.shape} vs {b.shape}")
    return float(np.mean(a.argmax(axis=1) == b.argmax(axis=1)))


def top1_delta(ref: np.ndarray, got: np.ndarray) -> float:
    """Fraction of samples whose argmax class *changed* (1 - agreement)."""
    return 1.0 - top1_agreement(ref, got)

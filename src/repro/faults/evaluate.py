"""Fault-rate degradation curves on the numpy oracle.

One call = one workload swept across stuck-at fault rates: resolve a
:class:`~repro.faults.model.FaultModel` per rate, corrupt the weights,
re-run the oracle and score bit-error rate / top-1 agreement against
the fault-free outputs.  Deterministic end to end (fixed seed), so the
resulting curve is golden-able — ``benchmarks/bench_faults.py`` pins
exactly this path.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Any, Dict, List, Optional, Sequence

from .metrics import bit_error_rate, top1_agreement
from .model import FaultModel, resolve_faults

__all__ = ["degradation_curve"]


def degradation_curve(cg: Any, chip: Any, rates: Sequence[float],
                      batch: int = 4, seed: int = 0,
                      base: Optional[FaultModel] = None
                      ) -> List[Dict[str, float]]:
    """BER / top-1 agreement of a condensed graph per stuck-at rate.

    ``base`` carries the non-``rate`` fault knobs (transient rate,
    seed); per sweep step only ``rate`` changes.  Returns one row per
    rate: ``{"rate", "n_stuck", "ber", "top1_agreement"}``.
    """
    from ..core import ref

    weights, biases, inputs = ref.random_init(cg, batch=batch, seed=seed)
    quant = ref.auto_quant(cg, weights, biases, inputs)
    clean = ref.run_reference(cg, weights, biases, quant, inputs)
    final_gid = max(clean)
    rows: List[Dict[str, float]] = []
    fm0 = base if base is not None else FaultModel(seed=seed)
    for rate in rates:
        fm = replace(fm0, rate=float(rate))
        fs = resolve_faults(weights, chip, fm)
        faulty = ref.run_reference(cg, weights, biases, quant, inputs,
                                   faults=fs)
        rows.append({
            "rate": float(rate),
            "n_stuck": float(fs.n_stuck),
            "ber": bit_error_rate(clean, faulty),
            "top1_agreement": top1_agreement(clean[final_gid],
                                             faulty[final_gid]),
        })
    return rows

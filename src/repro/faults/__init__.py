"""Seeded fault injection and resilience evaluation (``repro.faults``).

Reliability is a first-class design axis for digital CIM: the compute
arrays suffer stuck-at and transient bit faults, global memory takes
soft errors, and pod-scale meshes lose whole chips and links.  This
package makes all of that *measurable* with the same determinism
guarantees as the rest of the framework:

* :class:`FaultModel` — one frozen, seeded description of every fault
  process (CIM stuck-at rate, transient accumulator flips, gmem word
  flips, failed mesh chips/links).  Identical configs resolve to
  bit-identical fault sets on every run and every backend.
* :class:`FaultSet` — the resolved *logical* faults of one workload:
  per-MG-tile stuck-at masks over each group's ``(K, N)`` weight
  matrix plus deterministic per-``(group, sample)`` transient flips.
  Hooked into the numpy oracle (``ref.run_reference(faults=...)``)
  and, through corrupted weights/gmem images, the functional ISS and
  the ``func:pallas`` backend.
* :class:`PhysicalCimFaults` — the *physical* view: stuck bits pinned
  to ``(core, macro group)`` array coordinates, applied by the
  functional ISS when ``CIM_LOAD`` latches weights into a faulty
  array (``Simulator(..., faults=...)``).
* :func:`bit_error_rate` / :func:`top1_delta` — accuracy-degradation
  metrics over oracle outputs.
* :func:`degradation_curve` — BER / top-1 agreement of a workload
  across a fault-rate sweep.
* :func:`residual_rate` — first-order effectiveness of the mitigation
  hardware (ECC / row sparing / TMR) priced by
  :class:`repro.core.arch.ProtectionConfig`.
"""

from .metrics import bit_error_rate, top1_agreement, top1_delta
from .model import (FaultModel, FaultSet, PhysicalCimFaults, corrupt_gmem,
                    residual_rate, resolve_faults)
from .evaluate import degradation_curve

__all__ = [
    "FaultModel", "FaultSet", "PhysicalCimFaults",
    "resolve_faults", "corrupt_gmem", "residual_rate",
    "bit_error_rate", "top1_agreement", "top1_delta",
    "degradation_curve",
]

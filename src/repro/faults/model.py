"""Fault models and their deterministic resolution to fault sets.

Determinism contract
--------------------

Every random draw in this module flows from a
:class:`numpy.random.SeedSequence` keyed by ``(model.seed, namespace,
coordinates...)`` — no global RNG state, no draw-order coupling between
groups, tiles or samples.  Two consequences the tests pin:

* the same :class:`FaultModel` resolves to bit-identical fault sets on
  every run, every process and every backend;
* resolving group 7's faults never changes group 3's (each tile owns
  an independent stream), so fault sets are stable under workload
  slicing — the property mesh failover relies on.

Logical vs physical faults
--------------------------

:class:`FaultSet` describes faults in *weight-matrix space*: stuck
bits at ``(k, n, bit)`` coordinates of each group's ``(K, N)`` int8
weight matrix, drawn per MG-sized tile (``macro.rows`` x
``group_n_out``).  Applying the same set to the oracle's weights and
to the weights a gmem image is built from makes the numpy oracle, the
Pallas oracle and the functional ISS agree bit-exactly on the
*corrupted* outputs — which is what makes accuracy-degradation numbers
trustworthy across fidelities.

:class:`PhysicalCimFaults` describes faults in *array space*: stuck
bits pinned to a physical ``(core, macro group)`` array.  The
functional ISS applies them when ``CIM_LOAD`` latches rows into the
array, so whatever logical tile the compiler happened to place there
gets corrupted — the hardware-eye view, independent of mapping.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Tuple

import numpy as np

__all__ = ["FaultModel", "FaultSet", "PhysicalCimFaults",
           "resolve_faults", "corrupt_gmem", "residual_rate"]

# SeedSequence namespaces: keep the per-purpose streams disjoint even
# when coordinate tuples collide (e.g. gid 0 / core 0).
_NS_STUCK = 1
_NS_TRANSIENT = 2
_NS_GMEM = 3
_NS_PHYSICAL = 4


def _rng(*key: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence(list(key)))


@dataclass(frozen=True)
class FaultModel:
    """One frozen, seeded description of every fault process.

    ``rate`` is the headline knob — the per-bit stuck-at fault
    probability in the CIM weight arrays — so ``FaultModel(rate=0)``
    (the default) is an exact no-op everywhere.  ``transient_rate``
    flips accumulator bits per MVM evaluation; ``gmem_rate`` flips one
    bit per affected 32-bit global-memory word.  ``failed_chips`` /
    ``failed_links`` name dead mesh slots / inter-chip links for
    system-level failover (see :mod:`repro.system`).
    """

    rate: float = 0.0            # stuck-at, per CIM weight bit
    transient_rate: float = 0.0  # per accumulator bit per MVM
    gmem_rate: float = 0.0       # per 32-bit gmem word
    seed: int = 0
    failed_chips: Tuple[int, ...] = ()
    failed_links: Tuple[Tuple[int, int], ...] = ()

    def __post_init__(self) -> None:
        for f in ("rate", "transient_rate", "gmem_rate"):
            v = getattr(self, f)
            if not (0.0 <= v <= 1.0) or not math.isfinite(v):
                raise ValueError(f"{f} must be in [0, 1], got {v!r}")
        if not (isinstance(self.seed, int) and self.seed >= 0):
            raise ValueError(f"seed must be a non-negative int, "
                             f"got {self.seed!r}")
        object.__setattr__(self, "failed_chips",
                           tuple(sorted(int(c) for c in self.failed_chips)))
        object.__setattr__(
            self, "failed_links",
            tuple(sorted(tuple(sorted((int(a), int(b))))
                         for a, b in self.failed_links)))

    @property
    def is_null(self) -> bool:
        """True when the model injects nothing and fails nothing."""
        return (self.rate == 0.0 and self.transient_rate == 0.0
                and self.gmem_rate == 0.0 and not self.failed_chips
                and not self.failed_links)

    def to_dict(self) -> Dict[str, Any]:
        return {"rate": self.rate, "transient_rate": self.transient_rate,
                "gmem_rate": self.gmem_rate, "seed": self.seed,
                "failed_chips": list(self.failed_chips),
                "failed_links": [list(l) for l in self.failed_links]}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "FaultModel":
        return cls(rate=float(d.get("rate", 0.0)),
                   transient_rate=float(d.get("transient_rate", 0.0)),
                   gmem_rate=float(d.get("gmem_rate", 0.0)),
                   seed=int(d.get("seed", 0)),
                   failed_chips=tuple(d.get("failed_chips", ())),
                   failed_links=tuple(tuple(l) for l in
                                      d.get("failed_links", ())))

    def mitigated(self, chip: Any) -> "FaultModel":
        """The residual model after the chip's protection hardware.

        Reads :class:`repro.core.arch.ProtectionConfig` off the chip
        and scales the stuck-at / transient rates by
        :func:`residual_rate` — the "how much protection is worth it at
        fault rate X" half of a DSE sweep (the cost half lives on the
        :class:`~repro.core.machine.MachineModel`).
        """
        import dataclasses
        p = chip.core.cim.protection
        return dataclasses.replace(
            self,
            rate=residual_rate(self.rate, p, chip.core.cim.macro),
            transient_rate=residual_rate(self.transient_rate, p,
                                         chip.core.cim.macro,
                                         transient=True))


def residual_rate(rate: float, protection: Any, macro: Any,
                  transient: bool = False) -> float:
    """First-order residual fault rate after mitigation hardware.

    * **TMR** votes three copies: a bit survives unless >= 2 copies
      fault — residual ``3p^2 - 2p^3``.
    * **ECC** (SECDED over 72-bit words) corrects any single error: a
      bit stays wrong only if another bit of its word also faulted —
      residual ``p * (1 - (1-p)^71)``.
    * **Row sparing** remaps faulty rows to ``spare_rows`` spares per
      macro: residual scales by the fraction of expected faulty rows
      the spares cannot cover.  Spares hold *weights*, so they do not
      reduce transient (datapath) faults.

    These are independence-assuming closed forms — good enough to rank
    protection levels in a sweep, not a reliability sign-off.
    """
    p = float(rate)
    if p <= 0.0:
        return 0.0
    if protection.tmr:
        p = 3.0 * p * p - 2.0 * p ** 3
    if protection.ecc:
        p = p * (1.0 - (1.0 - p) ** 71)
    if protection.spare_rows > 0 and not transient:
        row_bits = macro.cols            # bits per row per macro
        p_row = 1.0 - (1.0 - p) ** row_bits
        expected_bad = macro.rows * p_row
        if expected_bad > 0:
            p *= max(0.0, 1.0 - protection.spare_rows / expected_bad)
    return min(1.0, max(0.0, p))


def _stuck_masks(shape: Tuple[int, int], tile_k: int, tile_n: int,
                 rate: float, seed_key: Tuple[int, ...]
                 ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Per-tile stuck-at draw over an int8 matrix of ``shape``.

    Returns ``(or_mask, and_mask, n_faults)`` uint8 masks: stuck-at-1
    bits set in ``or_mask``, stuck-at-0 bits cleared in ``and_mask``.
    Each MG-sized tile draws from its own SeedSequence stream, so the
    set is independent of traversal order and of the other tiles.
    """
    K, N = shape
    or_mask = np.zeros(shape, dtype=np.uint8)
    and_mask = np.full(shape, 0xFF, dtype=np.uint8)
    n_faults = 0
    for ti in range((K + tile_k - 1) // tile_k):
        for tj in range((N + tile_n - 1) // tile_n):
            kk = min(tile_k, K - ti * tile_k)
            nn = min(tile_n, N - tj * tile_n)
            bits = kk * nn * 8
            rng = _rng(*seed_key, ti, tj)
            cnt = int(rng.binomial(bits, rate))
            if cnt == 0:
                continue
            pos = rng.choice(bits, size=cnt, replace=False)
            val = rng.integers(0, 2, size=cnt, dtype=np.uint8)
            k = ti * tile_k + pos // (nn * 8)
            r = pos % (nn * 8)
            n = tj * tile_n + r // 8
            bit = (r % 8).astype(np.uint8)
            m = (np.uint8(1) << bit).astype(np.uint8)
            one = val.astype(bool)
            np.bitwise_or.at(or_mask, (k[one], n[one]), m[one])
            np.bitwise_and.at(and_mask, (k[~one], n[~one]),
                              np.bitwise_not(m[~one]))
            n_faults += cnt
    return or_mask, and_mask, n_faults


def _apply_masks(w: np.ndarray, or_mask: np.ndarray,
                 and_mask: np.ndarray) -> np.ndarray:
    """Stuck-at corruption of an int8 array (returns a copy)."""
    u = np.ascontiguousarray(w, dtype=np.int8).view(np.uint8)
    return ((u | or_mask) & and_mask).view(np.int8)


@dataclass
class FaultSet:
    """The resolved logical faults of one workload (see module docs)."""

    model: FaultModel
    # gid -> (or_mask, and_mask) uint8, same shape as the weight matrix
    stuck: Dict[int, Tuple[np.ndarray, np.ndarray]] = field(
        default_factory=dict)
    counts: Dict[int, int] = field(default_factory=dict)

    @property
    def n_stuck(self) -> int:
        return sum(self.counts.values())

    # -- weight-space corruption --------------------------------------------

    def corrupt_weight_matrix(self, gid: int, w: np.ndarray) -> np.ndarray:
        masks = self.stuck.get(gid)
        if masks is None:
            return w
        if masks[0].shape != w.shape:
            raise ValueError(
                f"fault set for group {gid} was resolved for shape "
                f"{masks[0].shape}, got weights of shape {w.shape}")
        return _apply_masks(w, *masks)

    def corrupt_weights(self, weights: Dict[int, np.ndarray]
                        ) -> Dict[int, np.ndarray]:
        """Corrupted copy of a ``{gid: (K, N) int8}`` weight dict."""
        return {gid: self.corrupt_weight_matrix(gid, w)
                for gid, w in weights.items()}

    # -- transient accumulator flips ----------------------------------------

    def corrupt_acc(self, acc: np.ndarray, gid: int,
                    sample: int) -> np.ndarray:
        """Transient bit flips in one MVM's int32 accumulator.

        Keyed by ``(seed, gid, sample)``: re-running the same sample
        reproduces the same flips, and samples/groups are independent.
        """
        if self.model.transient_rate <= 0.0:
            return acc
        rng = _rng(self.model.seed, _NS_TRANSIENT, gid, sample)
        bits = acc.size * 32
        cnt = int(rng.binomial(bits, self.model.transient_rate))
        if cnt == 0:
            return acc
        pos = rng.choice(bits, size=cnt, replace=False)
        out = np.ascontiguousarray(acc, dtype=np.int32).copy()
        u = out.view(np.uint32).reshape(-1)
        flip = (np.uint32(1) << (pos % 32).astype(np.uint32))
        np.bitwise_xor.at(u, pos // 32, flip)
        return out.reshape(acc.shape)


def resolve_faults(weights: Dict[int, np.ndarray], chip: Any,
                   model: FaultModel) -> FaultSet:
    """Resolve a :class:`FaultModel` against a workload's weights.

    Tiles each group's ``(K, N)`` matrix into MG-sized tiles
    (``macro.rows`` x ``group_n_out`` of ``chip``) and draws stuck-at
    faults per tile.  ``model.rate == 0`` resolves to an empty set —
    every downstream hook is then an exact no-op.
    """
    fs = FaultSet(model=model)
    if model.rate <= 0.0:
        return fs
    cim = chip.core.cim
    tile_k, tile_n = cim.macro.rows, cim.group_n_out
    for gid in sorted(weights):
        w = weights[gid]
        if w.ndim != 2:
            raise ValueError(f"group {gid}: weights must be (K, N), "
                             f"got shape {w.shape}")
        or_mask, and_mask, cnt = _stuck_masks(
            w.shape, tile_k, tile_n, model.rate,
            (model.seed, _NS_STUCK, gid))
        if cnt:
            fs.stuck[gid] = (or_mask, and_mask)
            fs.counts[gid] = cnt
    return fs


def corrupt_gmem(image: np.ndarray, model: FaultModel) -> np.ndarray:
    """Single-bit flips in a fraction ``model.gmem_rate`` of the
    image's 32-bit words (returns a corrupted int8 copy)."""
    out = np.ascontiguousarray(image, dtype=np.int8).copy()
    if model.gmem_rate <= 0.0:
        return out
    n_words = out.size // 4
    if n_words == 0:
        return out
    rng = _rng(model.seed, _NS_GMEM)
    cnt = int(rng.binomial(n_words, model.gmem_rate))
    if cnt == 0:
        return out
    widx = rng.choice(n_words, size=cnt, replace=False)
    bit = rng.integers(0, 32, size=cnt).astype(np.uint32)
    u = out[:n_words * 4].view(np.uint32)
    np.bitwise_xor.at(u, widx, np.uint32(1) << bit)
    return out


class PhysicalCimFaults:
    """Stuck-at faults pinned to physical ``(core, macro group)`` arrays.

    The functional ISS calls :meth:`corrupt_loaded` when ``CIM_LOAD``
    latches ``(rows, n_len)`` weights into an array: the top-left
    window of that array's stuck-bit masks corrupts whatever the
    compiler placed there.  Masks are drawn lazily per ``(core, mg)``
    from independent SeedSequence streams and cached, so repeated
    loads into the same array see the same stuck bits — the defining
    property of a stuck-at fault.
    """

    def __init__(self, chip: Any, model: FaultModel) -> None:
        self.chip = chip
        self.model = model
        cim = chip.core.cim
        self._shape = (cim.macro.rows, cim.group_n_out)
        self._masks: Dict[Tuple[int, int],
                          Optional[Tuple[np.ndarray, np.ndarray]]] = {}

    def _masks_for(self, core_id: int, mg: int
                   ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        key = (core_id, mg)
        if key not in self._masks:
            if self.model.rate <= 0.0:
                self._masks[key] = None
            else:
                or_mask, and_mask, cnt = _stuck_masks(
                    self._shape, self._shape[0], self._shape[1],
                    self.model.rate,
                    (self.model.seed, _NS_PHYSICAL, core_id, mg))
                self._masks[key] = (or_mask, and_mask) if cnt else None
        return self._masks[key]

    def corrupt_loaded(self, core_id: int, mg: int,
                       w: np.ndarray) -> np.ndarray:
        masks = self._masks_for(core_id, mg)
        if masks is None:
            return w
        rows, n_len = w.shape
        return _apply_masks(w, masks[0][:rows, :n_len],
                            masks[1][:rows, :n_len])

"""Bit-serial digital-CIM MVM as a Pallas TPU kernel.

Hardware adaptation (DESIGN.md §2): a digital CIM macro computes
``y = Σ_b 2^b · (x_b · W)`` over *activation bit-planes* with shift-add
accumulation — multiplications decompose into bit-wise AND-popcount rows,
which is exactly a {0,1}-matrix multiply.  On TPU we express the same
arithmetic as ``act_bits`` MXU matmuls over bit-planes with INT32
shift-add accumulation, tiled for VMEM:

* grid ``(M/bm, N/bn, K/bk)`` — K innermost ("arbitrary" semantics), with
  an INT32 VMEM accumulator scratch carried across K steps;
* per step: slice the int8 activation tile, peel ``act_bits`` bit-planes
  (two's complement: the MSB plane enters negatively), one
  ``dot_general(plane_i8, w_i8) -> int32`` per plane on the MXU,
  shift-added into the accumulator;
* block shapes default to MXU-aligned multiples of 128 (the ``ops``
  wrapper zero-pads ragged shapes — exact for integer arithmetic).

This kernel is the *semantics* path: bit-exact with the CIMFlow
functional simulator's macro model and the pure-jnp oracle in
:mod:`repro.kernels.ref`.  The *performance* path (`int8_matmul` in
:mod:`repro.kernels.ops`) issues one direct int8 MXU matmul; both return
identical INT32 results, and the ratio of their costs (``act_bits`` : 1)
is precisely the bit-serial beat count the cycle-accurate simulator
charges per CIM pass.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["bitserial_mvm_kernel", "bitserial_mvm_pallas"]

# renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def bitserial_mvm_kernel(x_ref, w_ref, o_ref, acc_ref, *, act_bits: int,
                         k_steps: int, signed: bool) -> None:
    """One (bm, bn) output tile; accumulates over the K grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                # (bm, bk) int8
    w = w_ref[...]                                # (bk, bn) int8
    # two's-complement bit peel on the unsigned reinterpretation
    xu = x.astype(jnp.uint8).astype(jnp.int32)
    acc = acc_ref[...]
    for b in range(act_bits):
        plane = ((xu >> b) & 1).astype(jnp.int8)  # {0,1} bit-plane
        term = jax.lax.dot_general(
            plane, w,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        if signed and b == act_bits - 1:
            acc = acc - (term << b)               # MSB is negative
        else:
            acc = acc + (term << b)
    acc_ref[...] = acc

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def bitserial_mvm_pallas(x: jax.Array, w: jax.Array, *, act_bits: int = 8,
                         block_m: int = 128, block_n: int = 128,
                         block_k: int = 128, signed: bool = True,
                         interpret: bool = False) -> jax.Array:
    """``(M, K) int8 @ (K, N) int8 -> (M, N) int32`` via bit-serial planes.

    Shapes must be multiples of the block sizes — use
    :func:`repro.kernels.ops.cim_mvm` for automatic padding.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shape ({m},{k})x({k},{n}) not divisible by blocks "
        f"({block_m},{block_n},{block_k})")
    k_steps = k // block_k
    grid = (m // block_m, n // block_n, k_steps)
    kernel = functools.partial(bitserial_mvm_kernel, act_bits=act_bits,
                               k_steps=k_steps, signed=signed)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_k, block_n), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)

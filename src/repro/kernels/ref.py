"""Pure-jnp oracles for the CIM kernels.

Digital CIM is *exact* integer arithmetic: the bit-serial decomposition
must reproduce a plain INT32 matmul bit-for-bit.  These references define
the contract the Pallas kernels (and the CIMFlow functional simulator's
macro model) are tested against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["mvm_ref", "bitserial_mvm_ref", "quantized_linear_ref",
           "requant_ref"]


def mvm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """INT32 ground truth: ``(M,K) int8 @ (K,N) int8 -> (M,N) int32``."""
    return jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def bitserial_mvm_ref(x: jax.Array, w: jax.Array, act_bits: int = 8,
                      signed: bool = True) -> jax.Array:
    """Bit-plane decomposition in plain jnp (mirrors the macro model)."""
    xu = x.astype(jnp.uint8).astype(jnp.int32)
    acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.int32)
    w32 = w.astype(jnp.int32)
    for b in range(act_bits):
        plane = ((xu >> b) & 1).astype(jnp.int32)
        term = plane @ w32
        acc = acc - (term << b) if (signed and b == act_bits - 1) \
            else acc + (term << b)
    return acc


def requant_ref(acc: jax.Array, scale: int, shift: int,
                div: int = 1) -> jax.Array:
    """Fixed-point requant, identical to the ISS / compiled semantics."""
    den = div << shift
    q = (acc.astype(jnp.int64) * scale + (den >> 1)) // den
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def quantized_linear_ref(x: jax.Array, w_int8: jax.Array, w_scale,
                         act_scale) -> jax.Array:
    """Fake-quant linear: float in/out, INT8 CIM arithmetic inside."""
    xq = jnp.clip(jnp.round(x / act_scale), -128, 127).astype(jnp.int8)
    acc = mvm_ref(xq, w_int8)
    return acc.astype(jnp.float32) * (act_scale * w_scale)

"""Jit'd public wrappers around the CIM kernels.

* :func:`cim_mvm` — bit-serial Pallas kernel with automatic zero-padding
  to MXU-aligned blocks (exact for integer arithmetic).
* :func:`int8_matmul` — the direct single-pass INT8 MXU path (the
  *performance* path; bit-identical to :func:`cim_mvm`).
* :func:`quantized_linear` — float-in/float-out linear with INT8 CIM
  arithmetic inside and a straight-through-estimator custom VJP, used by
  the framework's quantization-aware training / INT8 serving path.

On CPU (this container) the Pallas kernel runs in ``interpret=True``;
on TPU it compiles natively.  ``interpret=None`` auto-detects.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from .bitserial_mvm import bitserial_mvm_pallas
from .ref import mvm_ref

__all__ = ["cim_mvm", "int8_matmul", "quantized_linear", "pad_to"]

_INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"


@functools.lru_cache(maxsize=None)
def _auto_interpret() -> bool:
    """Whether Pallas kernels should run in interpret mode by default.

    ``REPRO_PALLAS_INTERPRET=0/1`` overrides the backend probe (e.g. to
    force interpret mode on a TPU host, or assert native compilation).
    Memoized — ``jax.default_backend()`` initializes the platform
    backend, which is milliseconds per call; tests monkeypatching the
    env var must ``_auto_interpret.cache_clear()``.
    """
    env = os.environ.get(_INTERPRET_ENV)
    if env is not None and env.strip() != "":
        return env.strip().lower() not in ("0", "false", "no", "off")
    return jax.default_backend() != "tpu"


def pad_to(a: jax.Array, mults) -> jax.Array:
    """Zero-pad each dim of ``a`` up to a multiple of ``mults``."""
    pads = []
    for dim, mult in zip(a.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if any(p[1] for p in pads):
        a = jnp.pad(a, pads)
    return a


@functools.partial(jax.jit, static_argnames=("act_bits", "block_m",
                                             "block_n", "block_k",
                                             "signed", "interpret"))
def cim_mvm(x: jax.Array, w: jax.Array, *, act_bits: int = 8,
            block_m: int = 128, block_n: int = 128, block_k: int = 128,
            signed: bool = True,
            interpret: Optional[bool] = None) -> jax.Array:
    """Bit-serial CIM MVM, ragged shapes welcome: int8 x int8 -> int32."""
    if interpret is None:
        interpret = _auto_interpret()
    m, k = x.shape
    _, n = w.shape
    xp = pad_to(x.astype(jnp.int8), (block_m, block_k))
    wp = pad_to(w.astype(jnp.int8), (block_k, block_n))
    out = bitserial_mvm_pallas(xp, wp, act_bits=act_bits, block_m=block_m,
                               block_n=block_n, block_k=block_k,
                               signed=signed, interpret=interpret)
    return out[:m, :n]


@jax.jit
def int8_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Direct INT8 MXU matmul (performance path, bit-identical)."""
    return mvm_ref(x.astype(jnp.int8), w.astype(jnp.int8))


# ---------------------------------------------------------------------------
# Fake-quant linear with straight-through estimator
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def quantized_linear(x: jax.Array, w_int8: jax.Array, scales,
                     use_pallas: bool = False) -> jax.Array:
    """``y = dequant(int8(x) @ w_int8)``; float32 in/out.

    ``scales = (act_scale, w_scale)`` — per-tensor symmetric.  Backward is
    the straight-through estimator on a dequantized weight view, so the
    op drops into a standard training loop.
    """
    act_scale, w_scale = scales
    xq = jnp.clip(jnp.round(x / act_scale), -128, 127).astype(jnp.int8)
    if use_pallas:
        acc = cim_mvm(xq, w_int8)
    else:
        acc = int8_matmul(xq, w_int8)
    return acc.astype(jnp.float32) * (act_scale * w_scale)


def _ql_fwd(x, w_int8, scales, use_pallas):
    y = quantized_linear(x, w_int8, scales, use_pallas)
    return y, (x, w_int8, scales)


def _ql_bwd(use_pallas, res, g):
    x, w_int8, (act_scale, w_scale) = res
    w_deq = w_int8.astype(jnp.float32) * w_scale
    # straight-through: d/dx ignores the quantizer's staircase
    dx = g @ w_deq.T
    dw = x.T @ g / w_scale          # gradient w.r.t. the int8 weight view
    return dx, dw, (jnp.zeros(()), jnp.zeros(()))


quantized_linear.defvjp(_ql_fwd, _ql_bwd)

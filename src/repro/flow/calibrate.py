"""``flow.calibrate``: fit per-unit correction factors from a handful
of simulator runs.

The analytic cost model and the trace replay share the simulator's
:class:`~repro.core.machine.MachineModel`, but they still idealize
effects only per-instruction stepping sees (in-order issue stalls, link
back-pressure, padding-edge gather work).  This harness closes the
residual *systematically* instead of hand-tuning constants:

1. compile each calibration workload and run the perf-mode simulator
   (ground truth) plus the target cheap fidelity;
2. fit per-unit factors as the ratio of simulator unit-busy cycles to
   the cheap model's per-unit cycle estimates (CIM / vector / NoC);
3. fit a residual ``makespan`` factor as the geometric-mean ratio of
   simulator cycles to the unit-calibrated cheap-model cycles.

The result is a :class:`~repro.core.machine.Calibration` that rides on
``CompileOptions.calibration`` (and therefore on the machine model via
``machine_for(chip, calib)``): the analytic and trace backends apply it
at evaluation time, the partition search and pass cache stay
calibration-free, and :mod:`repro.explore`'s successive halving screens
with simulator-faithful rankings.
"""

from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from ..core.arch import ChipConfig
from ..core.machine import Calibration
from ..core.mapping import CostParams
from ..core.partition import PartitionResult
from .options import CompileOptions

__all__ = ["CalibrationRow", "CalibrationReport", "calibrate",
           "analytic_unit_cycles", "calibration_dir", "save_calibration",
           "load_calibration", "list_calibrations"]

# Named calibration presets: ``flow.calibrate(..., save="name")`` writes
# ``results/calibrations/<name>.json`` and
# ``CompileOptions(calibration="name")`` loads it back — so a fit paid
# once (a handful of simulator runs) rides along to later sessions,
# benchmark drivers and explore sweeps by name.
ENV_CALIB_DIR = "REPRO_CALIB_DIR"
# anchored to the repo root (like the committed bench goldens), not the
# CWD — presets must resolve no matter where the process was launched
DEFAULT_CALIB_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "results", "calibrations")


def calibration_dir(directory: Optional[str] = None) -> str:
    return (directory or os.environ.get(ENV_CALIB_DIR)
            or DEFAULT_CALIB_DIR)


def _preset_path(name: str, directory: Optional[str] = None) -> str:
    if name.endswith(".json") or os.sep in name:
        return name                     # explicit path passes through
    return os.path.join(calibration_dir(directory), f"{name}.json")


def save_calibration(calib: Calibration, name: str,
                     directory: Optional[str] = None,
                     meta: Optional[Dict[str, Any]] = None) -> str:
    """Persist a fitted :class:`Calibration` as a named preset."""
    path = _preset_path(name, directory)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    doc = {"name": os.path.splitext(os.path.basename(path))[0],
           "calibration": calib.to_dict()}
    if meta:
        doc.update(meta)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def load_calibration(name: str,
                     directory: Optional[str] = None) -> Calibration:
    """Load a named preset (or an explicit ``*.json`` path)."""
    path = _preset_path(name, directory)
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        have = list_calibrations(directory)
        hint = (", ".join(have) if have
                else "none — fit one with flow.calibrate(..., save=name)")
        raise FileNotFoundError(
            f"no calibration preset {name!r} at {path} "
            f"(available: {hint})") from None
    return Calibration.from_dict(doc["calibration"])


def list_calibrations(directory: Optional[str] = None) -> List[str]:
    d = calibration_dir(directory)
    try:
        return sorted(os.path.splitext(f)[0] for f in os.listdir(d)
                      if f.endswith(".json"))
    except FileNotFoundError:
        return []


def analytic_unit_cycles(res: PartitionResult,
                         batch: int) -> Dict[str, float]:
    """Per-unit busy-cycle totals implied by the analytic components.

    ``compute``/``vector`` are per-sample per-replica-core figures, so
    total unit busy multiplies by batch and the replica's core count;
    ``comm`` is a per-replica port figure (gmem streams occupy the NoC
    unit in the simulator, so both comm shares map to ``noc``).
    """
    tot = {"cim": 0.0, "vector": 0.0, "noc": 0.0}
    for sp in res.stages:
        for a in sp.allocs:
            tot["cim"] += a.compute * batch * a.cores * a.dup
            tot["vector"] += a.vector * batch * a.cores * a.dup
            tot["noc"] += a.comm * batch * a.dup
    return tot


@dataclass
class CalibrationRow:
    """One calibration workload's before/after agreement.

    Carries the full simulator payload so callers (e.g.
    ``ExplorationEngine.calibrate``) can reuse the ground-truth run —
    it cost seconds — instead of re-simulating the same point later.
    """

    workload: str
    sim_cycles: float
    base_cycles: float             # cheap fidelity, uncalibrated
    calibrated_cycles: float = 0.0
    sim_energy: Optional[Dict[str, float]] = None
    sim_throughput_sps: float = 0.0
    sim_wall_s: float = 0.0

    @property
    def base_ratio(self) -> float:
        return self.sim_cycles / max(self.base_cycles, 1e-12)

    @property
    def calibrated_ratio(self) -> float:
        return self.sim_cycles / max(self.calibrated_cycles, 1e-12)


@dataclass
class CalibrationReport:
    """Fit result + per-workload agreement before/after."""

    calibration: Calibration
    fidelity: str
    rows: List[CalibrationRow] = field(default_factory=list)

    def max_ratio(self, calibrated: bool = True) -> float:
        """Worst-case |log-ratio| band, as a multiplicative factor."""
        ratios = [(r.calibrated_ratio if calibrated else r.base_ratio)
                  for r in self.rows]
        if not ratios:
            return 1.0
        return max(max(r, 1.0 / r) for r in ratios)

    def describe(self) -> str:
        lines = [f"{self.fidelity} {self.calibration.describe()}"]
        for r in self.rows:
            lines.append(
                f"  {r.workload:24s} sim={r.sim_cycles:12.0f} "
                f"{self.fidelity}={r.base_cycles:12.0f} "
                f"(x{r.base_ratio:6.2f}) calibrated="
                f"{r.calibrated_cycles:12.0f} (x{r.calibrated_ratio:5.2f})")
        lines.append(f"  band: x{self.max_ratio(False):.2f} -> "
                     f"x{self.max_ratio(True):.2f}")
        return "\n".join(lines)


Workload = Union[str, Tuple[str, Dict[str, Any]], Any]


def _norm_workload(w: Workload) -> Tuple[Any, Dict[str, Any], str]:
    if isinstance(w, str):
        return w, {}, w
    if isinstance(w, tuple):
        name, kw = w
        label = name + "".join(f"@{k}={v}" for k, v in sorted(kw.items()))
        return name, dict(kw), label
    return w, {}, getattr(w, "name", type(w).__name__)


def _geomean(xs: Sequence[float]) -> float:
    xs = [x for x in xs if x > 0 and math.isfinite(x)]
    if not xs:
        return 1.0
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def calibrate(workloads: Sequence[Workload], chip: ChipConfig,
              strategy: str = "dp",
              params: Optional[CostParams] = None,
              batch: Optional[int] = None,
              fidelity: str = "analytic",
              pipeline: Any = None,
              save: Optional[str] = None) -> CalibrationReport:
    """Fit a :class:`Calibration` for ``fidelity`` on ``chip``.

    ``workloads`` is a handful of calibration models — names,
    ``(name, workload_kw)`` pairs, or graph objects.  Each one costs a
    perf-mode simulator run (seconds); everything else is cheap.  Use
    small geometries (``res=64``/``112``) — per-unit ratios transfer to
    the full-size models because the *mechanism* (im2col gather cost,
    handoff serialization) is geometry-independent.

    ``save`` persists the fit as a named preset
    (``results/calibrations/<save>.json``; see :func:`save_calibration`)
    that ``CompileOptions(calibration="<save>")`` and
    ``ExplorationEngine(calibration="<save>")`` load by name.
    """
    if fidelity not in ("analytic", "trace"):
        raise ValueError(f"calibrate fits 'analytic' or 'trace', "
                         f"got {fidelity!r}")
    from . import compile as flow_compile       # late: avoid cycle
    params = params or CostParams(batch=4)

    arts = []
    rows: List[CalibrationRow] = []
    sim_busy = {"cim": 0.0, "vector": 0.0, "noc": 0.0}
    model_busy = {"cim": 0.0, "vector": 0.0, "noc": 0.0}
    for w in workloads:
        workload, kw, label = _norm_workload(w)
        opts = CompileOptions(strategy=strategy, params=params,
                              batch=batch, workload_kw=kw or None)
        art = flow_compile(workload, chip, opts, pipeline=pipeline)
        sim = art.evaluate("simulate")
        base = art.evaluate(fidelity)
        if fidelity == "analytic":
            unit = analytic_unit_cycles(art.partition,
                                        opts.resolved_batch())
            for u in sim_busy:
                sim_busy[u] += sim.sim.unit_busy.get(u, 0.0)
                model_busy[u] += unit.get(u, 0.0)
        arts.append((art, label))
        rows.append(CalibrationRow(workload=label,
                                   sim_cycles=sim.cycles,
                                   base_cycles=base.cycles,
                                   sim_energy=dict(sim.energy),
                                   sim_throughput_sps=sim.throughput_sps,
                                   sim_wall_s=sim.wall_s))

    if fidelity == "analytic":
        factors = {u: (sim_busy[u] / model_busy[u]) if model_busy[u] > 0
                   else 1.0 for u in sim_busy}
        unit_calib = Calibration(cim=factors["cim"],
                                 vector=factors["vector"],
                                 noc=factors["noc"], gmem=factors["noc"])
    else:
        # trace already charges machine-model unit costs per replayed
        # event; its residual is serialization-shaped, so a makespan-only
        # fit is more robust than re-scaling units it got right
        unit_calib = Calibration()

    # residual serialization: re-evaluate with unit factors only, then
    # absorb what per-unit scaling cannot explain into ``makespan``
    resid = []
    partial = []
    for (art, label), row in zip(arts, rows):
        rep = art.replace_options(calibration=unit_calib) \
            .evaluate(fidelity)
        partial.append(rep.cycles)
        resid.append(row.sim_cycles / max(rep.cycles, 1e-12))
    calib = unit_calib.scaled(makespan=_geomean(resid))
    for row, cyc in zip(rows, partial):
        row.calibrated_cycles = cyc * calib.makespan
    report = CalibrationReport(calibration=calib, fidelity=fidelity,
                               rows=rows)
    if save:
        save_calibration(
            calib, save,
            meta={"fidelity": fidelity, "chip": chip.name,
                  "strategy": strategy,
                  "workloads": [r.workload for r in rows],
                  "band": round(report.max_ratio(True), 4)})
    return report

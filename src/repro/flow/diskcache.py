"""On-disk pass-output cache for the :mod:`repro.flow` pipeline.

The in-process LRU gives cross-fidelity partition reuse, but pool
workers (``repro.explore`` fans sweeps over a ``multiprocessing`` pool)
each start with a cold cache and re-partition their own misses.  This
cache persists pass outputs across processes using the same
content-addressing discipline as :mod:`repro.explore.cache`: entries
are sharded by key prefix (``<root>/ab/<key>.pkl``) and written
atomically (tmp + rename), so concurrent workers never observe torn
files and overlapping sweeps share partitions for free.

Payloads are pickles (pass outputs are ``CondensedGraph`` /
``PartitionResult`` objects, not JSON-shaped); a corrupt or
version-skewed entry is treated as a miss and overwritten.  Point every
process at the same directory via ``Pipeline(disk_cache=...)`` or the
``REPRO_FLOW_CACHE`` environment variable (which
:func:`repro.flow.default_pipeline` honors — that is how pool workers
inherit it).
"""

from __future__ import annotations

import bisect
import os
import pickle
import tempfile
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["PassDiskCache", "ENV_VAR"]

ENV_VAR = "REPRO_FLOW_CACHE"


class PassDiskCache:
    """Sharded pickle cache keyed by the pipeline chain digest.

    Carries the same eviction discipline as
    :class:`repro.explore.cache.ResultCache`: nothing ages out
    automatically, but :meth:`prune` drops entries older than
    ``max_age_days`` (file mtime) and then the oldest beyond
    ``max_entries`` — safe to run alongside live sweeps (``put`` is
    atomic, readers treat vanished files as misses).
    """

    def __init__(self, root: str,
                 max_age_days: Optional[float] = None,
                 max_entries: Optional[int] = None) -> None:
        self.root = root
        self.max_age_days = max_age_days
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.corrupt = 0    # unreadable entries dropped by get()

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key[:2], key + ".pkl")

    def get(self, key: str) -> Tuple[bool, Any]:
        path = self._path(key)
        try:
            with open(path, "rb") as f:
                out = pickle.load(f)
        except FileNotFoundError:
            self.misses += 1                  # plain miss: stay quiet
            return False, None
        except (OSError, pickle.UnpicklingError, EOFError,
                AttributeError, ImportError, ValueError,
                IndexError) as e:
            # the file exists but cannot be loaded: truncated by a
            # crash mid-copy, bit-rotted, or pickled against an older
            # class layout.  Drop it so the recompute can repopulate
            # the slot (put() is atomic, so we never tear a good entry)
            # and say so once — a silently swallowed corruption that
            # recurs every run is a debugging tarpit.
            warnings.warn(
                f"flow disk cache: dropping unreadable entry {path} "
                f"({type(e).__name__}: {e}); it will be recomputed",
                RuntimeWarning, stacklevel=2)
            try:
                os.unlink(path)
            except OSError:
                pass
            self.corrupt += 1
            self.misses += 1
            return False, None
        self.hits += 1
        return True, out

    def put(self, key: str, value: Any) -> None:
        path = self._path(key)
        sdir = os.path.dirname(path)
        for _ in range(8):
            os.makedirs(sdir, exist_ok=True)
            try:
                fd, tmp = tempfile.mkstemp(dir=sdir, suffix=".tmp")
                break
            except FileNotFoundError:
                continue    # concurrent prune rmdir'd the empty shard
        else:
            raise OSError(f"cache shard {sdir} keeps vanishing "
                          f"(concurrent prune?)")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(value, f, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _entries(self) -> List[Tuple[float, str]]:
        """All entry files as sorted ``(mtime, path)``, oldest first."""
        out: List[Tuple[float, str]] = []
        if not os.path.isdir(self.root):
            return out
        for shard in os.listdir(self.root):
            sdir = os.path.join(self.root, shard)
            try:
                names = os.listdir(sdir)
            except (NotADirectoryError, FileNotFoundError):
                continue
            for f in names:
                if not f.endswith(".pkl"):
                    continue
                path = os.path.join(sdir, f)
                try:
                    out.append((os.path.getmtime(path), path))
                except OSError:
                    continue          # concurrently pruned
        out.sort()
        return out

    def prune(self, max_age_days: Optional[float] = None,
              max_entries: Optional[int] = None,
              now: Optional[float] = None) -> int:
        """Evict by age then by count; returns how many were removed.

        Limits default to the construction-time ones; ``None`` disables
        that criterion.  ``now`` is injectable for tests.
        """
        max_age_days = (self.max_age_days if max_age_days is None
                        else max_age_days)
        max_entries = (self.max_entries if max_entries is None
                       else max_entries)
        entries = self._entries()
        now = time.time() if now is None else now
        doomed: List[str] = []
        if max_age_days is not None:
            cutoff = now - max_age_days * 86400.0
            i = bisect.bisect_left(entries, (cutoff,))
            doomed.extend(p for _, p in entries[:i])
            entries = entries[i:]
        if max_entries is not None and len(entries) > max_entries:
            extra = len(entries) - max_entries
            doomed.extend(p for _, p in entries[:extra])
        removed = 0
        for path in doomed:
            try:
                os.unlink(path)
                removed += 1
            except OSError:
                pass
        for shard in os.listdir(self.root) if os.path.isdir(self.root) \
                else ():
            sdir = os.path.join(self.root, shard)
            if os.path.isdir(sdir) and not os.listdir(sdir):
                try:
                    os.rmdir(sdir)
                except OSError:
                    pass
        return removed

    def __contains__(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def __len__(self) -> int:
        n = 0
        if not os.path.isdir(self.root):
            return 0
        for shard in os.listdir(self.root):
            sdir = os.path.join(self.root, shard)
            try:
                n += sum(1 for f in os.listdir(sdir)
                         if f.endswith(".pkl"))
            except (NotADirectoryError, FileNotFoundError):
                continue
        return n

    def clear(self) -> int:
        n = 0
        if not os.path.isdir(self.root):
            return 0
        for shard in os.listdir(self.root):
            sdir = os.path.join(self.root, shard)
            try:
                names = os.listdir(sdir)
            except (NotADirectoryError, FileNotFoundError):
                continue
            for f in names:
                if f.endswith(".pkl"):
                    try:
                        os.unlink(os.path.join(sdir, f))
                        n += 1
                    except OSError:
                        pass
            try:
                os.rmdir(sdir)
            except OSError:
                pass
        return n

    @property
    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "corrupt": self.corrupt}

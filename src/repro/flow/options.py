"""Compile options for the :mod:`repro.flow` pipeline.

:class:`CompileOptions` is the single declarative knob bundle of the
pass-based compiler: strategy, batch, quantization, local-memory
strictness, target fidelity and the analytic cost-model parameters.  It
is frozen (safe to share across threads/pool workers) and knows how to
render any *subset* of itself into a canonical JSON fragment — the
pass-output cache keys each pipeline pass by exactly the option fields
it declares in ``Pass.depends``, so a re-compile that only changes
``fidelity`` reuses the already-computed partition.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

from ..core.codegen import QuantParams
from ..core.machine import Calibration
from ..core.mapping import CostParams

__all__ = ["CompileOptions", "FIDELITIES"]

# The fidelity ladder: "analytic" = closed-form cost model (no
# codegen); "trace" = StagePlan replay at unit/transfer granularity;
# "simulate" = perf-mode cycle-accurate run; "func" = functional ISS
# (bit-exact data semantics).
FIDELITIES = ("analytic", "trace", "simulate", "func")


@dataclass(frozen=True)
class CompileOptions:
    """Everything that determines a compile's outcome, in one record.

    ``batch=None`` falls back to ``params.batch`` (the legacy
    ``compile_model`` convention).  ``quant`` maps group index to
    :class:`~repro.core.codegen.QuantParams`; it is normalized to a
    sorted tuple so options hash/compare structurally.
    """

    strategy: str = "dp"
    batch: Optional[int] = None
    quant: Optional[Mapping[int, QuantParams]] = None
    strict_lmem: bool = False
    fidelity: str = "analytic"
    params: CostParams = field(default_factory=CostParams)
    workload_kw: Optional[Mapping[str, Any]] = None   # for str workloads
    dump_dir: Optional[str] = None    # per-pass JSON IR dumps (debugging)
    # per-unit correction factors applied by the analytic and trace
    # backends at evaluation time (fit via repro.flow.calibrate); the
    # partition search itself stays uncalibrated and cache-shared.
    # A string names a saved preset (results/calibrations/<name>.json,
    # written by flow.calibrate(..., save=name)) and is resolved to the
    # Calibration it holds at construction time.
    calibration: Union[Calibration, str, None] = None
    # Multi-chip scale-out: a repro.system.SystemConfig routes the
    # compile through the system-level partitioner (``system:pipeline``
    # / ``system:tensor`` passes) and makes ``flow.compile`` return a
    # SystemArtifact stitching per-chip artifacts over inter-chip
    # links.  ``None`` (default) is the classic single-chip path.
    system: Optional[Any] = None

    def __post_init__(self) -> None:
        if self.fidelity not in FIDELITIES:
            raise ValueError(f"fidelity must be one of {FIDELITIES}, "
                             f"got {self.fidelity!r}")
        if self.system is not None and (
                not hasattr(self.system, "to_dict")
                or not hasattr(self.system, "n_chips")):
            raise TypeError(
                f"system must be a repro.system.SystemConfig, got "
                f"{type(self.system).__name__}")
        if isinstance(self.calibration, str):
            from .calibrate import load_calibration    # late: cycle
            object.__setattr__(self, "calibration",
                               load_calibration(self.calibration))
        if self.batch is not None and self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")
        if self.quant is not None and not isinstance(self.quant, tuple):
            object.__setattr__(
                self, "quant",
                tuple(sorted((int(k), v) for k, v in
                             dict(self.quant).items())))
        if self.workload_kw is not None \
                and not isinstance(self.workload_kw, tuple):
            object.__setattr__(
                self, "workload_kw",
                tuple(sorted(dict(self.workload_kw).items())))

    # -- derived -------------------------------------------------------------

    def resolved_batch(self) -> int:
        return self.batch if self.batch is not None else self.params.batch

    def quant_dict(self) -> Dict[int, QuantParams]:
        return dict(self.quant) if self.quant else {}

    def workload_kw_dict(self) -> Dict[str, Any]:
        return dict(self.workload_kw) if self.workload_kw else {}

    def replace(self, **kw: Any) -> "CompileOptions":
        return dataclasses.replace(self, **kw)

    # -- cache keying ---------------------------------------------------------

    def subset_key(self, fields: Sequence[str]) -> str:
        """Canonical JSON of the named option fields only.

        This is the "options-prefix" a pass contributes to its cache
        key: a partition pass depends on ``("strategy", "params")``, so
        two compiles differing only in ``fidelity`` / ``quant`` /
        ``strict_lmem`` share its cached output.
        """
        desc: Dict[str, Any] = {}
        for f in sorted(fields):
            v = getattr(self, f)
            if f == "params":
                v = dataclasses.asdict(v)
            elif f == "calibration":
                v = v.to_dict() if v is not None else None
            elif f == "quant":
                v = [[gid, qp.scale, qp.shift]
                     for gid, qp in (v or ())]
            elif f == "workload_kw":
                v = [list(kv) for kv in (v or ())]
            elif f == "system":
                v = v.to_dict() if v is not None else None
            desc[f] = v
        return json.dumps(desc, sort_keys=True, separators=(",", ":"))

    def describe(self) -> str:
        return (f"CompileOptions(strategy={self.strategy!r}, "
                f"batch={self.resolved_batch()}, "
                f"fidelity={self.fidelity!r}, "
                f"strict_lmem={self.strict_lmem}, "
                f"quant={'yes' if self.quant else 'default'})")

"""Evaluation backends for :class:`repro.flow.Artifact`.

A :class:`Backend` turns a compiled artifact into an
:class:`EvalReport` — the one result shape shared by every fidelity:

* :class:`AnalyticBackend` — the mapping cost model's stage latencies
  and energy-event ledger (no codegen; fast screening fidelity).
* :class:`TraceBackend` — replays each StagePlan at unit/transfer
  granularity on :class:`repro.core.trace.TraceEngine` (no codegen, no
  per-instruction stepping; the middle rung of the fidelity ladder).
* :class:`SimulatorBackend` — runs the per-core ISA streams on the
  cycle-accurate simulator (``mode="perf"``) or the functional ISS
  (``mode="func"``, which additionally needs a ``gmem_image``).

All three price energy through the shared
:class:`~repro.core.machine.MachineModel`; the analytic and trace
backends additionally honor ``CompileOptions.calibration`` (fit via
:func:`repro.flow.calibrate`).

Backends resolve by name through :data:`BACKENDS` (``"analytic"``,
``"trace"``, ``"simulate"``/``"perf"``, ``"func"``), so
``artifact.evaluate(backend="simulate")`` and custom registered
backends compose without touching callers.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, Optional, Union

import numpy as np

from ..core.machine import machine_for
from ..core.simulator import ENGINES, SimReport, Simulator
from ..core.trace import TraceEngine, TraceReport

__all__ = ["EvalReport", "Backend", "AnalyticBackend", "TraceBackend",
           "SimulatorBackend", "PallasFuncBackend", "BACKENDS",
           "resolve_backend", "register_backend",
           "backend_for_fidelity"]


@dataclass
class EvalReport:
    """One artifact evaluation, identical shape across fidelities."""

    backend: str                   # resolved backend name
    cycles: float
    energy: Dict[str, float]       # nJ breakdown, incl. "total"
    throughput_sps: float          # samples/s at the chip clock
    batch: int
    wall_s: float = 0.0
    sim: Optional[SimReport] = None     # simulator backends only
    trace: Optional[TraceReport] = None  # trace backend only
    outputs: Optional[Dict[int, np.ndarray]] = None  # func oracles only

    @property
    def energy_total(self) -> float:
        return self.energy.get("total", 0.0)

    @property
    def edp(self) -> float:
        return self.cycles * self.energy_total

    def summary(self) -> str:
        return (f"[{self.backend}] {self.cycles:.0f} cycles, "
                f"{self.energy_total / 1e6:.3f} mJ, "
                f"{self.throughput_sps:.1f} samples/s "
                f"(batch={self.batch})")


def _throughput(chip: Any, cycles: float, batch: int) -> float:
    if cycles <= 0:
        return 0.0
    return batch / (cycles / (chip.clock_ghz * 1e9))


class Backend:
    """Evaluation backend protocol: ``evaluate(artifact) -> EvalReport``."""

    name: str = "backend"
    requires_model: bool = False

    def evaluate(self, artifact: Any, **kw: Any) -> EvalReport:
        raise NotImplementedError


class AnalyticBackend(Backend):
    """The mapping cost model — no ISA, no simulator."""

    name = "analytic"
    requires_model = False

    def evaluate(self, artifact: Any, **kw: Any) -> EvalReport:
        if kw:
            raise TypeError(f"analytic backend takes no extra "
                            f"arguments, got {sorted(kw)}")
        t0 = time.perf_counter()
        res = artifact.partition
        batch = artifact.options.resolved_batch()
        calib = artifact.options.calibration
        cycles = float(res.latency_cycles(batch, calib))
        energy = dict(machine_for(artifact.chip).price_events(
            res.energy_events(batch, calib)))
        return EvalReport(
            backend=self.name, cycles=cycles, energy=energy,
            throughput_sps=_throughput(artifact.chip, cycles, batch),
            batch=batch, wall_s=time.perf_counter() - t0)


class TraceBackend(Backend):
    """StagePlan replay on the shared machine model (middle fidelity)."""

    name = "trace"
    requires_model = False

    def evaluate(self, artifact: Any, **kw: Any) -> EvalReport:
        if kw:
            raise TypeError(f"trace backend takes no extra arguments, "
                            f"got {sorted(kw)}")
        t0 = time.perf_counter()
        batch = artifact.options.resolved_batch()
        engine = TraceEngine(artifact.chip,
                             artifact.options.calibration)
        rep = engine.run(artifact.partition, batch)
        return EvalReport(
            backend=self.name, cycles=float(rep.cycles),
            energy=dict(rep.energy()),
            throughput_sps=_throughput(artifact.chip, rep.cycles, batch),
            batch=batch, wall_s=time.perf_counter() - t0, trace=rep)


class SimulatorBackend(Backend):
    """Cycle-accurate (``perf``) / functional ISS (``func``) execution.

    ``engine`` selects the perf-mode execution path: ``"auto"``
    (default) replays pre-decoded basic blocks on the vectorized engine
    and falls back to the scalar interpreter for programs outside its
    static subset; ``"scalar"`` forces the interpreter, ``"vector"``
    forbids the fallback.  Both paths are cycle- and event-identical
    (pinned by ``tests/test_vectorsim.py``); an ``engine=...`` keyword
    on ``evaluate`` overrides per call.
    """

    requires_model = True

    def __init__(self, mode: str = "perf", name: Optional[str] = None,
                 engine: str = "auto") -> None:
        if mode not in ("perf", "func"):
            raise ValueError(f"mode must be 'perf' or 'func', "
                             f"got {mode!r}")
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, "
                             f"got {engine!r}")
        self.mode = mode
        self.engine = engine
        self.name = name or ("simulate" if mode == "perf" else "func")

    def evaluate(self, artifact: Any,
                 gmem_image: Optional[np.ndarray] = None,
                 engine: Optional[str] = None,
                 faults: Optional[Any] = None,
                 **kw: Any) -> EvalReport:
        if kw:
            raise TypeError(f"simulator backend takes only gmem_image, "
                            f"engine and faults, got {sorted(kw)}")
        t0 = time.perf_counter()
        model = artifact.ensure_model()
        # pass the engine through unchanged: Simulator itself rejects
        # func+vector and unknown engines, so an explicit override is
        # never silently ignored.  ``faults`` (functional mode) is a
        # repro.faults.PhysicalCimFaults injecting stuck bits at
        # CIM_LOAD time.
        sim = Simulator(artifact.chip, model.isa, mode=self.mode,
                        engine=engine or self.engine, faults=faults)
        rep = sim.run_model(model, gmem_image=gmem_image)
        batch = model.batch
        return EvalReport(
            backend=self.name, cycles=float(rep.cycles),
            energy=dict(rep.energy()),
            throughput_sps=_throughput(artifact.chip, rep.cycles, batch),
            batch=batch, wall_s=time.perf_counter() - t0, sim=rep)


class PallasFuncBackend(Backend):
    """Functional oracle with the MVMs on the Pallas bit-serial kernel.

    Forward-passes the artifact's condensed graph through
    :func:`repro.core.ref.run_reference`, executing every INT8 matmul
    on :func:`repro.kernels.ops.cim_mvm` — the bit-serial bit-plane
    decomposition a digital CIM macro performs, as a Pallas kernel
    (interpret mode on CPU, native on TPU; see
    ``REPRO_PALLAS_INTERPRET``).  With ``check=True`` (default) the
    pure-numpy oracle runs alongside and every group output is asserted
    bit-equal, so one evaluation validates the kernel's integer
    semantics at full-model scale — feasible where the per-instruction
    functional ISS is not (e.g. resnet18 at 224x224).

    ``weights``/``biases``/``inputs``/``quant`` default to
    :func:`repro.core.ref.random_init` + ``auto_quant`` draws, making
    ``artifact.evaluate("func:pallas")`` self-contained.
    """

    name = "func:pallas"
    requires_model = False

    def evaluate(self, artifact: Any, weights: Any = None,
                 biases: Any = None, inputs: Any = None,
                 quant: Any = None, check: bool = True,
                 seed: int = 0, faults: Any = None,
                 **kw: Any) -> EvalReport:
        if kw:
            raise TypeError(f"func:pallas backend takes weights/biases/"
                            f"inputs/quant/check/seed/faults, "
                            f"got {sorted(kw)}")
        from ..core import ref
        t0 = time.perf_counter()
        cg = artifact.cg
        if weights is None:
            if biases is not None or inputs is not None:
                raise TypeError("pass weights+biases+inputs together "
                                "or none of them")
            batch = artifact.options.resolved_batch()
            weights, biases, inputs = ref.random_init(cg, batch=batch,
                                                      seed=seed)
        else:
            batch = int(inputs.shape[0])
        if quant is None:
            quant = ref.auto_quant(cg, weights, biases, inputs)
        # ``faults`` (a repro.faults.FaultSet) corrupts both oracles
        # identically, so the bit-equality check stays meaningful on
        # faulty runs — it validates the kernel, not the fault model
        outs = ref.run_reference(cg, weights, biases, quant, inputs,
                                 matmul=_pallas_matmul, faults=faults)
        if check:
            want = ref.run_reference(cg, weights, biases, quant, inputs,
                                     faults=faults)
            for gid, arr in want.items():
                got = outs[gid]
                if got.shape != arr.shape or not np.array_equal(got, arr):
                    raise AssertionError(
                        f"func:pallas mismatch on group {gid}: pallas "
                        f"oracle != numpy oracle "
                        f"(shapes {got.shape} vs {arr.shape})")
        # a functional-validation pass carries no timing claim
        return EvalReport(backend=self.name, cycles=0.0,
                          energy={"total": 0.0}, throughput_sps=0.0,
                          batch=batch,
                          wall_s=time.perf_counter() - t0, outputs=outs)


def _pallas_matmul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """INT32-valued (int8-ranged) ``a @ b`` on the bit-serial kernel."""
    import jax.numpy as jnp

    from ..kernels.ops import cim_mvm
    return np.asarray(cim_mvm(jnp.asarray(a, jnp.int8),
                              jnp.asarray(b, jnp.int8)))


BACKENDS: Dict[str, Backend] = {}


def register_backend(b: Backend, *aliases: str,
                     replace: bool = False) -> Backend:
    for key in (b.name,) + aliases:
        if key in BACKENDS and not replace:
            raise ValueError(f"backend {key!r} already registered")
        BACKENDS[key] = b
    return b


register_backend(AnalyticBackend())
register_backend(TraceBackend())
register_backend(SimulatorBackend("perf"), "perf")
register_backend(SimulatorBackend("func"))
register_backend(PallasFuncBackend())


def resolve_backend(backend: Union[str, Backend, None],
                    fidelity: str = "analytic") -> Backend:
    """Name | instance | None (-> the fidelity's default backend)."""
    if backend is None:
        backend = backend_for_fidelity(fidelity)
    if isinstance(backend, str):
        try:
            return BACKENDS[backend]
        except KeyError:
            raise KeyError(f"unknown backend {backend!r}; registered: "
                           f"{sorted(BACKENDS)}") from None
    if isinstance(backend, Backend):
        return backend
    raise TypeError(f"backend must be a name or Backend instance, "
                    f"got {type(backend).__name__}")


def backend_for_fidelity(fidelity: str) -> str:
    """CompileOptions.fidelity -> default backend name."""
    return {"analytic": "analytic", "trace": "trace",
            "simulate": "simulate", "func": "func"}[fidelity]

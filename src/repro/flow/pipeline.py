"""The :mod:`repro.flow` pipeline: ``compile(workload, chip, options)``.

Replaces the ad-hoc ``partition() -> compile_model() -> Simulator()``
call chain with one stable entry point:

    art = repro.flow.compile("resnet18", chip,
                             CompileOptions(strategy="dp",
                                            workload_kw={"res": 112}))
    report = art.evaluate(backend="analytic")      # or "simulate"/"func"

The pipeline is a chain of registered passes (condense ->
``partition:<strategy>`` -> codegen-on-demand), each instrumented with
wall time and a one-line IR summary (``Artifact.describe()``), and each
memoized in an LRU cache keyed by ``(workload, chip, options-prefix)``
— only the option fields a pass declares in ``depends`` enter its key.
A re-compile at a different *fidelity* therefore reuses the
already-computed ``PartitionResult`` instead of re-partitioning, which
is what makes cross-fidelity promotions (analytic screen -> simulator
validation) cheap.
"""

from __future__ import annotations

import hashlib
import json
import os
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.arch import ChipConfig
from ..core.codegen import CompiledModel
from ..core.graph import CondensedGraph, Graph
from ..core.partition import PartitionResult
from .backends import Backend, EvalReport, resolve_backend
from .diskcache import ENV_VAR as _CACHE_ENV
from .diskcache import PassDiskCache
from .options import CompileOptions
from .passes import (CodegenPass, Pass, PassRecord, PipelineContext,
                     get_pass, partition_pass_name)

__all__ = ["Artifact", "Pipeline", "compile", "compile_many",
           "default_pipeline", "workload_fingerprint"]


def workload_fingerprint(workload: Any) -> str:
    """Structural identity of a workload for pass-cache keying.

    Named workloads key by name (geometry lives in ``workload_kw``,
    which the condense pass declares as a dependency); graph objects key
    by a digest of their op (or group) structure, so two separately
    built but identical graphs share cache entries.
    """
    if isinstance(workload, str):
        return f"name:{workload}"

    def op_desc(g: Graph) -> list:
        return [(op.idx, op.name, op.kind, tuple(op.inputs),
                 tuple(op.out_shape), sorted(op.attrs.items()),
                 op.gemm_m, op.gemm_k, op.gemm_n, op.groups)
                for op in g.ops]

    if isinstance(workload, Graph):
        desc: Any = op_desc(workload)
        kind = "graph"
    elif isinstance(workload, CondensedGraph):
        # group geometry always enters the digest: two condensed graphs
        # over the same source but with different group records (e.g.
        # tensor-parallel shards) must not share cache entries
        desc = (op_desc(workload.source)
                if workload.source is not None else None,
                [(g.idx, g.name, tuple(g.preds), g.gemm_m, g.gemm_k,
                  g.gemm_n, g.groups, g.macs, g.weight_bytes,
                  g.in_bytes, g.out_bytes,
                  sorted(g.vector_work.items()))
                 for g in workload])
        kind = "cg"
    else:
        raise TypeError(f"workload must be a name, Graph or "
                        f"CondensedGraph, got {type(workload).__name__}")
    blob = repr((workload.name, desc)).encode()
    return f"{kind}:{hashlib.sha256(blob).hexdigest()}"


def _chip_fingerprint(chip: ChipConfig) -> str:
    d = chip.to_dict()
    d.pop("name", None)          # labels are cosmetic
    blob = json.dumps(d, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


# ---------------------------------------------------------------------------
# Artifact
# ---------------------------------------------------------------------------


@dataclass
class Artifact:
    """The result of :func:`compile`: partitioned (and, on demand,
    fully code-generated) model plus the instrumented pass trace."""

    workload: Any
    chip: ChipConfig
    options: CompileOptions
    cg: CondensedGraph
    partition: PartitionResult
    trace: List[PassRecord] = field(default_factory=list)
    _pipeline: Optional["Pipeline"] = None
    _chain_key: str = ""         # cache-key prefix up to the partition
    _model: Optional[CompiledModel] = None

    # -- lazy codegen ---------------------------------------------------------

    @property
    def model(self) -> Optional[CompiledModel]:
        """The compiled ISA streams, or ``None`` before codegen ran."""
        return self._model

    def ensure_model(self) -> CompiledModel:
        """Run (or fetch from cache) the codegen pass."""
        if self._model is None:
            ctx = PipelineContext(workload=self.workload, chip=self.chip,
                                  options=self.options, cg=self.cg,
                                  partition=self.partition)
            pipe = self._pipeline or default_pipeline()
            out, rec, _ = pipe._run_pass(get_pass("codegen"), ctx,
                                         self._chain_key)
            self._model = out
            self.trace.append(rec)
        return self._model

    # -- evaluation -----------------------------------------------------------

    def evaluate(self, backend: Union[str, Backend, None] = None,
                 **kw: Any) -> EvalReport:
        """Score this artifact on a backend (default: the one matching
        ``options.fidelity``)."""
        b = resolve_backend(backend, self.options.fidelity)
        return b.evaluate(self, **kw)

    # -- conveniences ---------------------------------------------------------

    def replace_options(self, **kw: Any) -> "Artifact":
        """This artifact under tweaked *evaluation* options — the
        compiled partition is shared, nothing re-runs.  Fields that
        feed codegen (``batch``, ``quant``, ``strict_lmem``) drop the
        cached model so ``ensure_model`` re-lowers on demand; fields
        that determine the partition itself (``strategy``, ``params``,
        ``workload_kw``) cannot be swapped under a finished compile —
        re-run :func:`compile` for those."""
        import dataclasses as _dc
        stale = {"strategy", "params", "workload_kw"} & set(kw)
        if stale:
            raise ValueError(
                f"{sorted(stale)} change the partition; recompile via "
                f"flow.compile(...) instead of replace_options")
        opts = self.options.replace(**kw)
        keep_model = self._model if not (
            {"batch", "quant", "strict_lmem"} & set(kw)) else None
        return _dc.replace(self, options=opts, trace=list(self.trace),
                           _model=keep_model)

    def build_gmem_image(self, weights, biases, inputs) -> np.ndarray:
        return self.ensure_model().build_gmem_image(weights, biases,
                                                    inputs)

    def output_addr(self, gid: int, sample: int) -> Tuple[int, int]:
        return self.ensure_model().output_addr(gid, sample)

    @property
    def total_instrs(self) -> int:
        return self.ensure_model().total_instrs

    def pass_record(self, name: str) -> Optional[PassRecord]:
        """Latest trace record for a pass (``"partition"`` matches the
        strategy-qualified partition pass)."""
        for rec in reversed(self.trace):
            if rec.name == name or (name == "partition"
                                    and rec.name.startswith("partition:")):
                return rec
        return None

    def describe(self) -> str:
        head = (f"flow artifact: '{self.cg.name}' on "
                f"'{self.chip.name}' — {self.options.describe()}")
        return "\n".join([head] + [r.describe() for r in self.trace])


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------


class Pipeline:
    """Pass runner with a two-tier (LRU + optional disk) output cache.

    One pipeline's cache is shared across all its ``compile()`` calls;
    the module-level :func:`default_pipeline` gives every caller in a
    process cross-fidelity partition reuse for free.  ``cache_size=0``
    disables caching.  The default cap is sized for full design-space
    sweeps (~1k chips x strategies; cached ``PartitionResult`` objects
    are a few KB each — codegen outputs are never cached) so an
    analytic screen's partitions survive until the simulator
    promotion.

    ``disk_cache`` (a directory path or :class:`PassDiskCache`) adds a
    persistent tier below the LRU, shared *across processes*: explore's
    pool workers — and tomorrow's re-run of the same sweep — skip
    re-partitioning anything any process already partitioned.
    """

    def __init__(self, cache_size: int = 8192,
                 disk_cache: Union[str, PassDiskCache, None] = None
                 ) -> None:
        self.cache_size = int(cache_size)
        self._cache: "OrderedDict[str, Any]" = OrderedDict()
        if isinstance(disk_cache, str):
            disk_cache = PassDiskCache(disk_cache)
        self.disk = disk_cache
        self.hits = 0
        self.misses = 0

    # -- cache ----------------------------------------------------------------

    def _cache_get(self, key: str) -> Tuple[bool, Any]:
        if key in self._cache:
            self._cache.move_to_end(key)
            self.hits += 1
            return True, self._cache[key]
        if self.disk is not None:
            ok, out = self.disk.get(key)
            if ok:
                self.hits += 1
                self._mem_put(key, out)     # promote to the hot tier
                return True, out
        self.misses += 1
        return False, None

    def _mem_put(self, key: str, value: Any) -> None:
        if self.cache_size <= 0:
            return
        self._cache[key] = value
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

    def _cache_put(self, key: str, value: Any) -> None:
        self._mem_put(key, value)
        if self.disk is not None:
            self.disk.put(key, value)

    def cache_info(self) -> Dict[str, int]:
        info = {"entries": len(self._cache), "hits": self.hits,
                "misses": self.misses}
        if self.disk is not None:
            info["disk_hits"] = self.disk.hits
            info["disk_misses"] = self.disk.misses
        return info

    def clear_cache(self) -> None:
        self._cache.clear()

    # -- pass execution -------------------------------------------------------

    def _run_pass(self, p: Pass, ctx: PipelineContext,
                  prev_key: str) -> Tuple[Any, PassRecord, str]:
        import time
        subset = ctx.options.subset_key(p.depends)
        key = hashlib.sha256(
            f"{prev_key}|{p.name}|{subset}".encode()).hexdigest()
        t0 = time.perf_counter()
        cached, out = (self._cache_get(key) if p.cacheable
                       else (False, None))
        if not cached:
            out = p.run(ctx)
            if p.cacheable:
                self._cache_put(key, out)
        dump_path = None
        if ctx.options.dump_dir:      # dump cache hits too — the dump
            dump_path = p.write_dump(out, ctx.options.dump_dir, key)
            # dir may differ from (or postdate) the run that filled
            # the cache
        p.apply(ctx, out)
        rec = PassRecord(name=p.name,
                         wall_s=time.perf_counter() - t0,
                         cached=cached, summary=p.summarize(out),
                         key=key[:16], dump_path=dump_path)
        return out, rec, key

    # -- compilation ----------------------------------------------------------

    def compile(self, workload: Any, chip: ChipConfig,
                options: Optional[CompileOptions] = None,
                **kw: Any) -> Artifact:
        """Compile ``workload`` for ``chip`` under ``options``.

        Extra keyword arguments are folded into the options
        (``compile(cg, chip, strategy="dp", batch=2)``).  Codegen runs
        eagerly for simulator fidelities and lazily (on
        ``Artifact.ensure_model`` / a simulator backend) otherwise.
        """
        return self.compile_many(workload, [chip], options, **kw)[0]

    def compile_many(self, workload: Any, chips: Sequence[ChipConfig],
                     options: Optional[CompileOptions] = None,
                     **kw: Any) -> List[Artifact]:
        """Compile one workload against N chips in a single invocation.

        The condense pass runs (or cache-hits) exactly once; only the
        chip-dependent partition pass repeats per candidate.  This is
        the batched analytic-evaluation path of arch sweeps: the shared
        machine model is O(1) to derive per chip, so the marginal cost
        of an extra candidate is one partition.
        """
        if options is None:
            options = CompileOptions(**kw)
        elif kw:
            options = options.replace(**kw)

        if options.system is not None:
            return [self._compile_system(workload, chip, options)
                    for chip in chips]

        try:
            part_pass = get_pass(partition_pass_name(options.strategy))
        except KeyError:
            raise KeyError(
                f"unknown strategy {options.strategy!r}: no "
                f"{partition_pass_name(options.strategy)!r} pass "
                f"registered") from None

        # condense is chip-independent: keying it on the workload alone
        # lets one cache entry serve every chip in an arch sweep.  The
        # chip fingerprint enters the chain between condense and the
        # (chip-dependent) partition/codegen passes.
        base = hashlib.sha256(
            workload_fingerprint(workload).encode()).hexdigest()
        ctx0 = PipelineContext(workload=workload,
                               chip=chips[0] if chips else None,
                               options=options)
        _, cond_rec, cond_key = self._run_pass(get_pass("condense"),
                                               ctx0, base)

        arts: List[Artifact] = []
        for chip in chips:
            ctx = PipelineContext(workload=workload, chip=chip,
                                  options=options, cg=ctx0.cg)
            key = hashlib.sha256(
                f"{cond_key}|chip:{_chip_fingerprint(chip)}"
                .encode()).hexdigest()
            _, rec, key = self._run_pass(part_pass, ctx, key)
            art = Artifact(workload=workload, chip=chip,
                           options=options, cg=ctx.cg,
                           partition=ctx.partition,
                           trace=[cond_rec, rec],
                           _pipeline=self, _chain_key=key)
            # only the simulator fidelities need ISA streams; analytic
            # and trace evaluate straight off the partition
            if options.fidelity in ("simulate", "func"):
                art.ensure_model()
            arts.append(art)
        return arts


    # -- multi-chip (repro.system) --------------------------------------------

    def _compile_system(self, workload: Any, chip: ChipConfig,
                        options: CompileOptions) -> Any:
        """The ``options.system`` path: condense once, run the
        ``system:<mode>`` partition pass, then compile each chip slice
        through the ordinary single-chip pipeline (``system=None``) —
        a 1x1 mesh therefore produces an inner artifact bit-identical
        to the classic path.  Returns a
        :class:`repro.system.SystemArtifact`.
        """
        # imported lazily: repro.system imports repro.flow at module
        # level, so flow -> system must stay function-local
        from ..system import SystemArtifact
        from ..system.passes import system_pass_name

        sysc = options.system
        if sysc.parallel == "tensor" and sysc.n_chips > 1 \
                and options.fidelity in ("simulate", "func"):
            raise ValueError(
                "tensor-parallel plans support analytic/trace fidelity "
                "only (shards are group-level scaled condensed graphs "
                "with no per-shard ISA streams); use "
                "parallel='pipeline' for simulator fidelities")

        base = hashlib.sha256(
            workload_fingerprint(workload).encode()).hexdigest()
        ctx0 = PipelineContext(workload=workload, chip=chip,
                               options=options)
        _, cond_rec, cond_key = self._run_pass(get_pass("condense"),
                                               ctx0, base)
        ctx = PipelineContext(workload=workload, chip=chip,
                              options=options, cg=ctx0.cg)
        key = hashlib.sha256(
            f"{cond_key}|chip:{_chip_fingerprint(chip)}"
            .encode()).hexdigest()
        plan, rec, key = self._run_pass(
            get_pass(system_pass_name(sysc.parallel)), ctx, key)

        inner = options.replace(system=None)
        arts = [self.compile(sl.workload if sl.workload is not None
                             else workload, chip, inner)
                for sl in plan.slices]
        return SystemArtifact(workload=workload, chip=chip,
                              options=options, cg=ctx.cg, plan=plan,
                              chips=arts, trace=[cond_rec, rec])


_DEFAULT_PIPELINE: Optional[Pipeline] = None


def default_pipeline() -> Pipeline:
    """The process-wide pipeline (shared pass-output cache).

    When the ``REPRO_FLOW_CACHE`` environment variable names a
    directory, the pipeline also persists pass outputs there —
    processes pointed at the same directory (e.g. explore pool
    workers) share partitions.  Set before first use; the pipeline is
    created once per process.
    """
    global _DEFAULT_PIPELINE
    if _DEFAULT_PIPELINE is None:
        _DEFAULT_PIPELINE = Pipeline(
            disk_cache=os.environ.get(_CACHE_ENV) or None)
    return _DEFAULT_PIPELINE


def compile(workload: Any, chip: ChipConfig,
            options: Optional[CompileOptions] = None, *,
            pipeline: Optional[Pipeline] = None,
            **kw: Any) -> Artifact:
    """The stable compile entry point (see :class:`Pipeline.compile`).

    Uses the process-wide default pipeline unless one is given, so
    successive compiles of the same (workload, chip, options-prefix)
    hit the pass cache.
    """
    return (pipeline or default_pipeline()).compile(workload, chip,
                                                    options, **kw)


def compile_many(workload: Any, chips: Sequence[ChipConfig],
                 options: Optional[CompileOptions] = None, *,
                 pipeline: Optional[Pipeline] = None,
                 **kw: Any) -> List[Artifact]:
    """Batched compile: one workload, N candidate chips, one condense
    (see :meth:`Pipeline.compile_many`)."""
    return (pipeline or default_pipeline()).compile_many(
        workload, chips, options, **kw)

"""The pass layer of the :mod:`repro.flow` pipeline.

A :class:`Pass` is one named, instrumented compilation step.  Passes
declare which :class:`~repro.flow.options.CompileOptions` fields they
depend on (``depends``) — the pipeline caches each pass's output keyed
by ``(workload, chip, options-prefix)`` where the prefix is the union of
``depends`` along the pass chain, so changing an option a pass never
reads (e.g. ``fidelity``) reuses its cached output.

The registry makes strategies pluggable: every partition strategy is
registered as ``partition:<name>``; registering a new
:class:`PartitionPass` (or any custom pass) under a fresh name makes it
reachable through ``CompileOptions(strategy=...)`` without touching any
caller.  The stock passes wrap the internal implementations in
:mod:`repro.core.partition` and :mod:`repro.core.codegen`.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..core.codegen import CompiledModel, _compile_model
from ..core.graph import CondensedGraph, Graph
from ..core.partition import STRATEGIES, PartitionResult, _partition
from ..core import workloads
from .options import CompileOptions

__all__ = [
    "Pass", "PassRecord", "PipelineContext", "PASS_REGISTRY",
    "register_pass", "get_pass", "partition_pass_name",
    "CondensePass", "PartitionPass", "CodegenPass",
]


@dataclass
class PassRecord:
    """Instrumentation for one pass execution (or cache hit)."""

    name: str
    wall_s: float
    cached: bool
    summary: str
    key: str = ""                    # pipeline cache key (digest)
    dump_path: Optional[str] = None  # where the JSON IR dump landed

    def describe(self) -> str:
        src = "cache" if self.cached else f"{self.wall_s * 1e3:8.1f} ms"
        line = f"  {self.name:<18s} [{src:>10s}]  {self.summary}"
        if self.dump_path:
            line += f"  -> {self.dump_path}"
        return line


@dataclass
class PipelineContext:
    """Mutable state threaded through the pass chain."""

    workload: Any                    # str | Graph | CondensedGraph
    chip: Any                        # ChipConfig
    options: CompileOptions
    cg: Optional[CondensedGraph] = None
    partition: Optional[PartitionResult] = None
    model: Optional[CompiledModel] = None
    extras: Dict[str, Any] = field(default_factory=dict)


class Pass:
    """Base class for pipeline passes.

    Subclasses set ``name`` (registry key), ``depends`` (the
    ``CompileOptions`` fields feeding this pass's cache key) and
    implement :meth:`run`.  ``summarize`` yields the one-line IR summary
    recorded in the pass trace; ``dump`` optionally returns a
    JSON-serializable IR snapshot written when ``options.dump_dir`` is
    set.
    """

    name: str = "pass"
    depends: Tuple[str, ...] = ()
    # False keeps this pass's output out of the pipeline LRU (e.g.
    # codegen: full ISA streams are large, and the Artifact already
    # holds its own model — caching would pin up to cache_size of them)
    cacheable: bool = True

    def run(self, ctx: PipelineContext) -> Any:
        raise NotImplementedError

    def apply(self, ctx: PipelineContext, out: Any) -> None:
        """Store the (possibly cached) output back into the context."""

    def summarize(self, out: Any) -> str:
        return type(out).__name__

    def dump(self, out: Any) -> Optional[Dict[str, Any]]:
        return None

    def write_dump(self, out: Any, dump_dir: str, key: str) -> \
            Optional[str]:
        doc = self.dump(out)
        if doc is None:
            return None
        os.makedirs(dump_dir, exist_ok=True)
        safe = self.name.replace(":", "_")
        path = os.path.join(dump_dir, f"{safe}-{key[:12]}.json")
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True, default=str)
        return path


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


PASS_REGISTRY: Dict[str, Pass] = {}


def register_pass(p: Pass, replace: bool = False) -> Pass:
    """Register a pass instance under its ``name``."""
    if p.name in PASS_REGISTRY and not replace:
        raise ValueError(f"pass {p.name!r} already registered "
                         f"(pass replace=True to override)")
    PASS_REGISTRY[p.name] = p
    return p


def get_pass(name: str) -> Pass:
    try:
        return PASS_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown pass {name!r}; registered: "
            f"{sorted(PASS_REGISTRY)}") from None


def partition_pass_name(strategy: str) -> str:
    return f"partition:{strategy}"


# ---------------------------------------------------------------------------
# Stock passes
# ---------------------------------------------------------------------------


class CondensePass(Pass):
    """workload (name | Graph | CondensedGraph) -> CondensedGraph."""

    name = "condense"
    depends = ("workload_kw",)

    def run(self, ctx: PipelineContext) -> CondensedGraph:
        w = ctx.workload
        if isinstance(w, str):
            w = workloads.build(w, **ctx.options.workload_kw_dict())
        if isinstance(w, Graph):
            return w.condense()
        if isinstance(w, CondensedGraph):
            return w
        raise TypeError(
            f"workload must be a name, Graph or CondensedGraph, "
            f"got {type(w).__name__}")

    def apply(self, ctx: PipelineContext, out: CondensedGraph) -> None:
        ctx.cg = out

    def summarize(self, out: CondensedGraph) -> str:
        return out.summary()

    def dump(self, out: CondensedGraph) -> Dict[str, Any]:
        return {
            "name": out.name,
            "groups": [{
                "idx": g.idx, "name": g.name, "preds": list(g.preds),
                "gemm": [g.gemm_m, g.gemm_k, g.gemm_n],
                "weight_bytes": g.weight_bytes, "macs": g.macs,
                "in_bytes": g.in_bytes, "out_bytes": g.out_bytes,
            } for g in out],
        }


class PartitionPass(Pass):
    """CondensedGraph -> PartitionResult for one strategy.

    One instance per strategy lives in the registry under
    ``partition:<strategy>``; the pipeline picks the instance matching
    ``options.strategy``, so registering a new strategy pass makes it
    available to every caller with no signature change.
    """

    depends = ("strategy", "params")

    def __init__(self, strategy: str,
                 fn: Optional[Callable[..., PartitionResult]] = None
                 ) -> None:
        self.strategy = strategy
        self.name = partition_pass_name(strategy)
        self._fn = fn

    def run(self, ctx: PipelineContext) -> PartitionResult:
        if self._fn is not None:
            return self._fn(ctx.cg, ctx.chip, ctx.options.params)
        return _partition(ctx.cg, ctx.chip, self.strategy,
                          ctx.options.params)

    def apply(self, ctx: PipelineContext, out: PartitionResult) -> None:
        ctx.partition = out

    def summarize(self, out: PartitionResult) -> str:
        return (f"{out.n_stages} stages, "
                f"{out.latency_cycles():.0f} analytic cycles")

    def dump(self, out: PartitionResult) -> Dict[str, Any]:
        return {
            "strategy": out.strategy,
            "n_stages": out.n_stages,
            "latency_cycles": out.latency_cycles(),
            "stages": [{
                "gids": list(s.gids),
                "latency_cycles": s.latency_cycles(),
            } for s in out.stages],
        }


class CodegenPass(Pass):
    """PartitionResult -> CompiledModel (per-core ISA streams)."""

    name = "codegen"
    depends = ("batch", "quant", "strict_lmem")
    cacheable = False

    def run(self, ctx: PipelineContext) -> CompiledModel:
        o = ctx.options
        return _compile_model(ctx.partition, batch=o.resolved_batch(),
                              quant=o.quant_dict() or None,
                              strict_lmem=o.strict_lmem)

    def apply(self, ctx: PipelineContext, out: CompiledModel) -> None:
        ctx.model = out

    def summarize(self, out: CompiledModel) -> str:
        return (f"{out.total_instrs} instrs across "
                f"{len(out.stages)} stage programs (batch={out.batch})")

    def dump(self, out: CompiledModel) -> Dict[str, Any]:
        histo: Dict[str, int] = {}
        for st in out.stages:
            for prog in st.programs.values():
                for ins in prog:
                    histo[ins.op] = histo.get(ins.op, 0) + 1
        return {
            "batch": out.batch,
            "total_instrs": out.total_instrs,
            "gmem_bytes": out.layout.size,
            "instr_histogram": dict(sorted(histo.items())),
            "stage_instrs": [s.total_instrs for s in out.stages],
        }


register_pass(CondensePass())
register_pass(CodegenPass())
for _s in STRATEGIES:
    register_pass(PartitionPass(_s))

"""``repro.flow`` — the pass-based compiler pipeline (user-facing API).

CIMFlow's integrated-workflow claim, as an API: one declarative entry
point bridging compilation and evaluation, with pluggable passes and
backends::

    from repro import flow
    from repro.flow import CompileOptions

    art = flow.compile("resnet18", chip,
                       CompileOptions(strategy="dp", batch=4,
                                      workload_kw={"res": 112}))
    print(art.describe())                 # instrumented pass trace
    fast = art.evaluate("analytic")       # cost model
    true = art.evaluate("simulate")       # cycle-accurate (lazy codegen)

* :class:`CompileOptions` — strategy / batch / quant / strict_lmem /
  fidelity in one frozen record.
* :class:`Pass` + :func:`register_pass` — partition strategies and
  future optimizations plug in as ``partition:<name>`` passes without
  touching callers; every pass is timed, summarized, and optionally
  JSON-dumped (``dump_dir``).
* :class:`Pipeline` — runs the pass chain behind an LRU output cache
  keyed by ``(workload, chip, options-prefix)``; re-compiling at a new
  fidelity reuses the cached ``PartitionResult``.
* :class:`Backend` + :func:`register_backend` — the analytic cost model
  and the cycle-accurate / functional simulator behind one
  ``Artifact.evaluate(backend=...)`` surface.

The legacy free functions (``repro.core.partition.partition``,
``repro.core.codegen.compile_model``) remain as deprecated shims over
the same internals.
"""

from ..core.machine import Calibration, MachineModel, machine_for
from .backends import (BACKENDS, AnalyticBackend, Backend, EvalReport,
                       PallasFuncBackend, SimulatorBackend, TraceBackend,
                       backend_for_fidelity, register_backend,
                       resolve_backend)
from .calibrate import (CalibrationReport, CalibrationRow, calibrate,
                        calibration_dir, list_calibrations,
                        load_calibration, save_calibration)
from .diskcache import PassDiskCache
from .options import FIDELITIES, CompileOptions
from .passes import (PASS_REGISTRY, CodegenPass, CondensePass, Pass,
                     PartitionPass, PassRecord, PipelineContext,
                     get_pass, partition_pass_name, register_pass)
from .pipeline import (Artifact, Pipeline, compile, compile_many,
                       default_pipeline, workload_fingerprint)

__all__ = [
    "compile", "compile_many", "CompileOptions", "FIDELITIES",
    "Artifact", "Pipeline", "default_pipeline", "workload_fingerprint",
    "Pass", "PassRecord", "PipelineContext", "PASS_REGISTRY",
    "register_pass", "get_pass", "partition_pass_name",
    "CondensePass", "PartitionPass", "CodegenPass",
    "Backend", "EvalReport", "AnalyticBackend", "TraceBackend",
    "SimulatorBackend", "PallasFuncBackend", "BACKENDS",
    "register_backend",
    "resolve_backend", "backend_for_fidelity",
    "calibrate", "CalibrationReport", "CalibrationRow",
    "calibration_dir", "save_calibration", "load_calibration",
    "list_calibrations",
    "Calibration", "MachineModel", "machine_for", "PassDiskCache",
]

"""Expert-parallel MoE via ``shard_map`` + ``lax.ragged_dot``.

The dense one-hot dispatch in :mod:`.layers` materializes ``(T, E, f)``
activations — fine for smoke tests, impossible for 64–256-expert models
(DeepSeek-V3 train_4k would need ~10^14 elements).  This module is the
production path:

* Activations enter **replicated over the 'model' axis** (standard TP).
  Every model-rank computes the identical router decision, then handles
  only the (token, choice) pairs routed to *its* expert shard — total
  work across ranks is exactly ``T x top_k`` expert applications, no
  duplication, and the only collective is the same ``psum`` a dense
  TP MLP would issue.
* Per rank: local choices are packed into a fixed ``capacity`` buffer
  (scatter with drop semantics — standard capacity-factor token
  dropping), **sorted by local expert id**, and run through
  ``lax.ragged_dot`` segment matmuls (MXU-dense per expert, no padding
  waste); results scatter back through the inverse permutation and
  combine with router gates.
* Fully differentiable (ragged_dot has a transpose rule; permutations
  are gather/scatter).

An alternative all-to-all dispatch with sequence-sharded activations is
evaluated in EXPERIMENTS.md §Perf as a hillclimb candidate.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from . import layers as L
from .analysis_flags import FLAGS as _AFLAGS

__all__ = ["moe_ep_apply_local", "EP_AXIS"]

Params = Dict[str, Any]

EP_AXIS = "model"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def moe_ep_apply_local(cfg: ArchConfig, p: Params, x: jax.Array,
                       axis: str = EP_AXIS,
                       data_axes: Tuple[str, ...] = ()
                       ) -> Tuple[jax.Array, jax.Array]:
    """Per-device body (call inside shard_map).

    ``x`` (B_loc, S, d) is replicated over ``axis``; expert weights
    ``p['wi'|'wg'|'wo']`` are sharded over ``axis`` on the expert dim
    (shapes here are the *local* (E_loc, ...) shards).  Router weights
    and the shared expert are replicated.
    Returns (output contribution already psum'ed over ``axis``, aux loss).
    """
    m = cfg.moe
    tp = lax.axis_size(axis)
    rank = lax.axis_index(axis)
    e_loc = p["wi"].shape[0]
    b, s, d = x.shape
    t = b * s
    k = m.experts_per_tok
    xf = x.reshape(t, d)

    logits = (xf @ p["router"]).astype(jnp.float32)         # (T, E)
    if m.router_score == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    vals, idx = lax.top_k(scores, k)                        # (T, k)
    gates = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)

    # ---- pack this rank's (token, choice) hits into a capacity buffer ----
    flat_e = idx.reshape(-1)                                # (T*k,)
    flat_g = gates.reshape(-1).astype(x.dtype)
    tok = jnp.arange(t * k, dtype=jnp.int32) // k
    mine = (flat_e // e_loc) == rank
    eloc = flat_e % e_loc
    cap = _round_up(max(int(m.capacity_factor * t * k / tp), 8), 8)
    pos = jnp.cumsum(mine.astype(jnp.int32)) - 1            # slot per hit
    slot = jnp.where(mine & (pos < cap), pos, cap)          # cap == drop
    buf = jnp.zeros((cap, d), x.dtype).at[slot].set(
        xf[tok], mode="drop")
    buf_e = jnp.full((cap,), e_loc, jnp.int32).at[slot].set(
        eloc, mode="drop")

    # ---- sort by local expert, ragged segment matmuls --------------------
    order = jnp.argsort(buf_e)                              # stable
    xs = buf[order]
    if _AFLAGS["balanced_moe"]:
        # cost-probe path: XLA prices ragged_dot as dense over all E_loc
        # groups; the balanced batched matmul prices the ideal-balance
        # FLOPs exactly (see models/analysis_flags.py)
        cpe = max(cap // e_loc, 1)
        rows = cpe * e_loc
        xs_p = (jnp.pad(xs, ((0, rows - cap), (0, 0)))
                if rows > cap else xs[:rows])
        xb = xs_p.reshape(e_loc, cpe, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, p["wg"])) \
            * jnp.einsum("ecd,edf->ecf", xb, p["wi"])
        y = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(-1, d)
        y = (y[:cap] if rows > cap
             else jnp.pad(y, ((0, cap - y.shape[0]), (0, 0))))
    else:
        gs = jnp.bincount(buf_e, length=e_loc + 1)[:e_loc] \
            .astype(jnp.int32)
        h = jax.nn.silu(lax.ragged_dot(xs, p["wg"], gs)) \
            * lax.ragged_dot(xs, p["wi"], gs)
        y = lax.ragged_dot(h, p["wo"], gs)                  # (cap, d)
    y_unsorted = jnp.zeros_like(y).at[order].set(y)

    # ---- combine: gate-weighted scatter-add back to tokens ---------------
    contrib = jnp.where((slot < cap)[:, None],
                        y_unsorted[jnp.minimum(slot, cap - 1)], 0.0)
    out = jnp.zeros((t, d), x.dtype).at[tok].add(
        contrib * flat_g[:, None])
    out = lax.psum(out, axis)

    # shared expert(s): replicated compute, outside the psum
    if "shared" in p:
        out = out + L.mlp_apply(cfg, p["shared"], xf)

    # aux load-balance loss: identical on model ranks (invarying there),
    # averaged over the data axes where it genuinely varies
    me = jnp.mean(jax.nn.one_hot(idx[..., 0], m.n_experts), axis=0)
    pe = jnp.mean(jax.nn.softmax(logits, -1), axis=0)
    aux = m.n_experts * jnp.sum(me * pe)
    if data_axes:
        d_axes = tuple(data_axes)
        aux = lax.psum(aux, d_axes) / lax.psum(jnp.ones(()), d_axes)
    return out.reshape(b, s, d), aux

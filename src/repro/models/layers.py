"""Shared neural building blocks (pure-functional JAX, explicit params).

Everything is a (init, apply) pair over plain dicts of arrays — no
framework dependency.  Attention supports GQA, causal/sliding-window
masks, KV caches, cross-attention, MLA (DeepSeek latent attention), and a
blockwise *flash-style* path (online softmax over KV chunks via
``lax.scan``) that keeps long-context prefill memory O(S·block) instead
of O(S²).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig, MlaConfig
from .analysis_flags import FLAGS as _AFLAGS

__all__ = [
    "dense_init", "rmsnorm", "layernorm", "norm_init", "apply_norm",
    "rope_tables", "apply_rope", "attention_init", "attention_apply",
    "attention_decode", "mla_init", "mla_apply", "mla_decode",
    "mlp_init", "mlp_apply", "moe_init", "moe_apply", "flash_attention",
]

Params = Dict[str, Any]

# Use the flash path once the KV length exceeds this.
FLASH_THRESHOLD = 2048
FLASH_BLOCK_Q = 512
FLASH_BLOCK_K = 1024


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(cfg: ArchConfig, d: int, dtype) -> Params:
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    return {"w": jnp.ones((d,), dtype)}


def rmsnorm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm(x, w, b, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps) * w.astype(jnp.float32) \
        + b.astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(cfg: ArchConfig, p: Params, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_tables(positions: jax.Array, dim: int,
                theta: float) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for given positions; dim = rotary dimension."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv     # (..., dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D) rotated pairwise; cos/sin: (S, D/2)."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    c = cos[..., None, :].astype(x.dtype)       # broadcast over heads
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA / SWA / cross) with flash path
# ---------------------------------------------------------------------------


def attention_init(cfg: ArchConfig, key, dtype,
                   cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype),
    }


def _gqa_scores_ctx(q, k, v, mask_fn, q_pos0: int):
    """Naive path: q (B,Sq,KV,G,D), k/v (B,Sk,KV,D)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k) * scale
    sq, sk = q.shape[1], k.shape[1]
    qi = q_pos0 + jnp.arange(sq)[:, None]
    ki = jnp.arange(sk)[None, :]
    scores = jnp.where(mask_fn(qi, ki), scores.astype(jnp.float32),
                       -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def flash_attention(q, k, v, mask_fn, q_pos0: int = 0,
                    block_q: int = FLASH_BLOCK_Q,
                    block_k: int = FLASH_BLOCK_K):
    """Blockwise online-softmax attention (memory O(S·block)).

    q: (B, Sq, KV, G, D); k, v: (B, Sk, KV, D).  ``mask_fn(qi, ki)`` is a
    boolean predicate on absolute positions.  Implemented as a scan over
    KV blocks inside a scan over Q blocks — this is the paper-agnostic
    "beyond-paper" optimization that makes prefill_32k/long-context cells
    tractable (see EXPERIMENTS.md §Perf).
    """
    b, sq, kv, g, d = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]                    # may differ from d (MLA)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = -(-sq // block_q)
    nk = -(-sk // block_k)
    pad_q = nq * block_q - sq
    pad_k = nk * block_k - sk
    scale = 1.0 / math.sqrt(d)

    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    qb = qp.reshape(b, nq, block_q, kv, g, d).transpose(1, 0, 2, 3, 4, 5)
    kb = kp.reshape(b, nk, block_k, kv, d).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(b, nk, block_k, kv, dv).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk                       # qblk (B,bq,KV,G,D)

        def kv_step(carry, ki_kvb):
            m, l, acc = carry
            ki, kblk, vblk = ki_kvb
            s = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk) * scale
            qpos = q_pos0 + qi * block_q + jnp.arange(block_q)[:, None]
            kpos = (ki * block_k + jnp.arange(block_k))[None, :]
            valid = mask_fn(qpos, kpos) & (kpos < sk)
            s = jnp.where(valid, s.astype(jnp.float32), -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(qblk.dtype),
                            vblk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kv, g, block_q), jnp.float32)
        a0 = jnp.zeros((b, kv, g, block_q, dv), qblk.dtype)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return None, out.transpose(0, 3, 1, 2, 4)   # (B,bq,KV,G,D)

    _, blocks = lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * block_q, kv,
                                                     g, dv)
    return out[:, :sq]


def _mask_fn(cfg: ArchConfig, causal: bool):
    win = cfg.sliding_window

    def fn(qi, ki):
        ok = jnp.ones(jnp.broadcast_shapes(qi.shape, ki.shape), bool)
        if causal:
            ok &= ki <= qi
        if win is not None:
            ok &= ki > qi - win
        return ok

    return fn


def attention_apply(cfg: ArchConfig, p: Params, x, *, causal: bool = True,
                    kv_src: Optional[jax.Array] = None,
                    positions: Optional[jax.Array] = None,
                    use_rope: bool = True) -> jax.Array:
    """Full-sequence attention (training / prefill / encoder / cross)."""
    b, s, d = x.shape
    hd, h, kvh = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    g = h // kvh
    src = x if kv_src is None else kv_src
    sk = src.shape[1]
    q = (x @ p["wq"]).reshape(b, s, kvh, g, hd)
    k = (src @ p["wk"]).reshape(b, sk, kvh, hd)
    v = (src @ p["wv"]).reshape(b, sk, kvh, hd)
    if use_rope:
        qpos = positions if positions is not None else jnp.arange(s)
        cos_q, sin_q = rope_tables(qpos, hd, cfg.rope_theta)
        cos_k, sin_k = rope_tables(jnp.arange(sk), hd, cfg.rope_theta)
        q = apply_rope(q.reshape(b, s, kvh * g, hd), cos_q, sin_q) \
            .reshape(b, s, kvh, g, hd)
        k = apply_rope(k, cos_k, sin_k)
    mfn = _mask_fn(cfg, causal and kv_src is None)
    q, k, v, unshard = _maybe_seq_parallel(q, k, v)
    if sk > FLASH_THRESHOLD and not _AFLAGS["naive_attention"]:
        ctx = flash_attention(q, k, v, mfn)
    else:
        ctx = _gqa_scores_ctx(q, k, v, mfn, 0)
    ctx = unshard(ctx)
    return ctx.reshape(b, s, h * hd) @ p["wo"]


def _maybe_seq_parallel(q, k, v):
    """§Perf knob: reshard attention sequence-wise over the model axis.

    The head_dim fallback sharding psums every (S, S) score tile — an
    S²-scaling collective.  Sequence sharding costs one S-linear
    all-to-all each way instead: q is sharded on its seq dim, k/v are
    replicated over 'model', each chip computes full-head attention for
    its sequence slice.
    """
    from ..launch import meshctx, tuning
    ctx = meshctx.current()
    if not tuning.FLAGS["attn_seq_parallel"] or ctx is None:
        return q, k, v, lambda c: c
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh, dp, mp = ctx.mesh, ctx.data_axes, ctx.model_axis
    if q.shape[1] % mesh.shape[mp]:
        return q, k, v, lambda c: c          # seq not divisible: keep
    ns = lambda spec: NamedSharding(mesh, spec)      # noqa: E731
    q = lax.with_sharding_constraint(
        q, ns(P(dp, mp, None, None, None)))
    k = lax.with_sharding_constraint(k, ns(P(dp, None, None, None)))
    v = lax.with_sharding_constraint(v, ns(P(dp, None, None, None)))

    def unshard(c):
        # back to head-sharded layout for the row-parallel wo matmul
        return lax.with_sharding_constraint(
            c, ns(P(dp, None, None, None, mp)))

    return q, k, v, unshard


def _kv_store(x, store_dtype):
    """§Perf int8_kv_cache knob: symmetric INT8 (fixed 1/64 scale
    stand-in; production calibrates per head via repro.quant)."""
    if store_dtype == jnp.int8:
        return jnp.clip(jnp.round(x.astype(jnp.float32) * 64.0),
                        -127, 127).astype(jnp.int8)
    return x.astype(store_dtype)


def _kv_load(c, compute_dtype):
    if c.dtype == jnp.int8:
        return c.astype(compute_dtype) * jnp.asarray(1.0 / 64,
                                                     compute_dtype)
    return c.astype(compute_dtype)


def attention_decode(cfg: ArchConfig, p: Params, x, cache: Params,
                     pos: jax.Array) -> Tuple[jax.Array, Params]:
    """Single-token decode with a (possibly ring-buffered) KV cache.

    ``cache = {"k": (B, S_cache, KV, D), "v": ..., }``; ``pos`` is the
    absolute position of the incoming token (scalar int32).  For
    sliding-window archs the cache holds only ``window`` slots and is
    written ring-wise — long_500k memory stays O(window).
    """
    b, one, d = x.shape
    hd, h, kvh = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    g = h // kvh
    s_cache = cache["k"].shape[1]
    q = (x @ p["wq"]).reshape(b, 1, kvh, g, hd)
    k = (x @ p["wk"]).reshape(b, 1, kvh, hd)
    v = (x @ p["wv"]).reshape(b, 1, kvh, hd)
    cos, sin = rope_tables(pos[None], hd, cfg.rope_theta)
    q = apply_rope(q.reshape(b, 1, h, hd), cos, sin).reshape(
        b, 1, kvh, g, hd)
    k = apply_rope(k, cos, sin)
    slot = pos % s_cache                      # ring index (== pos if full)
    ck = lax.dynamic_update_slice(cache["k"],
                                  _kv_store(k, cache["k"].dtype),
                                  (0, slot, 0, 0))
    cv = lax.dynamic_update_slice(cache["v"],
                                  _kv_store(v, cache["v"].dtype),
                                  (0, slot, 0, 0))
    # absolute position of each cache slot under ring addressing
    idx = jnp.arange(s_cache)
    wraps = (pos // s_cache) * s_cache
    abs_pos = jnp.where(idx <= slot, wraps + idx, wraps - s_cache + idx)
    valid = (abs_pos >= 0) & (abs_pos <= pos)
    if cfg.sliding_window is not None:
        valid &= abs_pos > pos - cfg.sliding_window
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q,
                        _kv_load(ck, q.dtype)) * scale
    scores = jnp.where(valid[None, None, None, None, :],
                       scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", probs, _kv_load(cv, q.dtype))
    out = ctx.reshape(b, 1, h * hd) @ p["wo"]
    return out, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_init(cfg: ArchConfig, key, dtype) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora_rank, dtype),
        "wq_b": dense_init(ks[1], m.q_lora_rank, h * qk, dtype),
        "wkv_a": dense_init(ks[2], d,
                            m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "wkv_b": dense_init(ks[3], m.kv_lora_rank,
                            h * (m.qk_nope_head_dim + m.v_head_dim),
                            dtype),
        "wo": dense_init(ks[4], h * m.v_head_dim, d, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
    }


def _mla_qkv(cfg: ArchConfig, p: Params, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q = rmsnorm(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv = x @ p["wkv_a"]
    c_kv = rmsnorm(kv[..., :m.kv_lora_rank], p["kv_norm"])
    k_rope = kv[..., m.kv_lora_rank:].reshape(b, s, 1, dr)
    cos, sin = rope_tables(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand(cfg: ArchConfig, p: Params, c_kv):
    m = cfg.mla
    b, s, _ = c_kv.shape
    h = cfg.n_heads
    dn, dv = m.qk_nope_head_dim, m.v_head_dim
    kv = (c_kv @ p["wkv_b"]).reshape(b, s, h, dn + dv)
    return kv[..., :dn], kv[..., dn:]


def mla_apply(cfg: ArchConfig, p: Params, x, *,
              positions: Optional[jax.Array] = None) -> jax.Array:
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    pos = positions if positions is not None else jnp.arange(s)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(cfg, p, x, pos)
    k_nope, v = _mla_expand(cfg, p, c_kv)
    # fold into the generic GQA shapes: kv-heads == n_heads here
    q = jnp.concatenate([q_nope, q_rope], -1)[:, :, :, None, :] \
        .transpose(0, 1, 2, 3, 4)                  # (B,S,H,1,dn+dr)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (b, s, h,
                                                   k_rope.shape[-1]))],
                        -1)
    q = q.reshape(b, s, h, 1, -1)
    mfn = _mask_fn(cfg, True)
    if s > FLASH_THRESHOLD and not _AFLAGS["naive_attention"]:
        ctx = flash_attention(q, k, v, mfn)
    else:
        ctx = _gqa_scores_ctx(q, k, v, mfn, 0)
    return ctx.reshape(b, s, h * m.v_head_dim) @ p["wo"]


def mla_decode(cfg: ArchConfig, p: Params, x, cache: Params,
               pos: jax.Array) -> Tuple[jax.Array, Params]:
    """Latent-cache decode in the **absorbed** formulation.

    The up-projections fold into the query/context sides —
    ``q^T (W_uk c) = (W_uk^T q)^T c`` and ``Σ_s p_s (W_uv c_s) =
    W_uv (Σ_s p_s c_s)`` — so attention runs entirely in the 512-dim
    latent space and nothing of size (B, S, H, d) ever materializes.
    This is the MLA memory/bandwidth win the cache exists for.
    """
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(cfg, p, x, pos[None])
    cc = lax.dynamic_update_slice(cache["c_kv"],
                                  c_kv.astype(cache["c_kv"].dtype),
                                  (0, pos, 0))
    cr = lax.dynamic_update_slice(cache["k_rope"],
                                  k_rope.astype(cache["k_rope"].dtype),
                                  (0, pos, 0, 0))
    w_kv = p["wkv_b"].reshape(m.kv_lora_rank, h, dn + dv)
    w_k, w_v = w_kv[..., :dn], w_kv[..., dn:]
    # absorb W_uk into the query; scores in latent space
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0], w_k)
    lat = cc.astype(x.dtype)                       # (B, S, 512)
    rope = cr.astype(x.dtype)[:, :, 0]             # (B, S, dr)
    scale = 1.0 / math.sqrt(dn + dr)
    scores = (jnp.einsum("bhl,bsl->bhs", q_lat, lat)
              + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0], rope)) * scale
    valid = jnp.arange(lat.shape[1]) <= pos
    scores = jnp.where(valid[None, None, :], scores.astype(jnp.float32),
                       -1e30)
    probs = jax.nn.softmax(scores, -1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhs,bsl->bhl", probs, lat)
    ctx = jnp.einsum("bhl,lhv->bhv", ctx_lat, w_v)
    out = ctx.reshape(b, 1, h * dv) @ p["wo"]
    return out, {"c_kv": cc, "k_rope": cr}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(cfg: ArchConfig, key, dtype, d_ff: Optional[int] = None
             ) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {"wi": dense_init(ks[0], d, f, dtype),
                "wg": dense_init(ks[1], d, f, dtype),
                "wo": dense_init(ks[2], f, d, dtype)}
    return {"wi": dense_init(ks[0], d, f, dtype),
            "wo": dense_init(ks[2], f, d, dtype)}


def mlp_apply(cfg: ArchConfig, p: Params, x) -> jax.Array:
    if cfg.act == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]


# ---------------------------------------------------------------------------
# Mixture of Experts (dense one-hot dispatch — TPU-friendly, static shapes)
# ---------------------------------------------------------------------------


def moe_init(cfg: ArchConfig, key, dtype) -> Params:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    sf = m.shared_d_ff or m.d_ff

    def ex(key, n, fin, fout):
        return (jax.random.normal(key, (n, fin, fout), jnp.float32)
                / math.sqrt(fin)).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, m.n_experts, dtype),
        "wi": ex(ks[1], m.n_experts, d, m.d_ff),
        "wg": ex(ks[2], m.n_experts, d, m.d_ff),
        "wo": ex(ks[3], m.n_experts, m.d_ff, d),
    }
    if m.n_shared_experts:
        p["shared"] = mlp_init(cfg, ks[4], dtype,
                               d_ff=sf * m.n_shared_experts)
    return p


def moe_apply(cfg: ArchConfig, p: Params, x) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_load_balance_loss).

    Under an active mesh context this dispatches to the expert-parallel
    shard_map path (:mod:`repro.models.moe_ep`); otherwise it uses the
    dense one-hot reference dispatch (smoke-test scale only — the dense
    path materializes ``(T, E, f)``).
    """
    from ..launch import meshctx
    ctx = meshctx.current()
    if ctx is not None:
        from .moe_ep import moe_ep_apply_local
        from jax.sharding import PartitionSpec as P
        dp = ctx.data_axes
        mp = ctx.model_axis
        espec = P(mp, None, None)
        in_specs = (P(dp, None, None),
                    {"router": P(), "wi": espec, "wg": espec,
                     "wo": espec,
                     **({"shared": P()} if "shared" in p else {})})
        fn = jax.shard_map(
            lambda xx, pp: moe_ep_apply_local(cfg, pp, xx, axis=mp,
                                              data_axes=dp),
            mesh=ctx.mesh, in_specs=in_specs,
            out_specs=(P(dp, None, None), P()))
        return fn(x, p)
    m = cfg.moe
    b, s, d = x.shape
    logits = (x @ p["router"]).astype(jnp.float32)      # (B,S,E)
    if m.router_score == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    vals, idx = lax.top_k(scores, m.experts_per_tok)
    gates = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    # combine weights (B,S,E): scatter the top-k gates
    comb = jnp.sum(jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32)
                   * gates[..., None], axis=2)          # (B,S,E)
    comb = comb.astype(x.dtype)
    h = jnp.einsum("bsd,edf->bsef", x, p["wg"])
    hi = jnp.einsum("bsd,edf->bsef", x, p["wi"])
    act = jax.nn.silu(h) * hi
    y = jnp.einsum("bsef,efd->bsed", act, p["wo"])
    out = jnp.einsum("bsed,bse->bsd", y, comb)
    if "shared" in p:
        out = out + mlp_apply(cfg, p["shared"], x)
    # Switch-style load-balance aux loss
    me = jnp.mean(jax.nn.one_hot(idx[..., 0], m.n_experts), axis=(0, 1))
    pe = jnp.mean(jax.nn.softmax(logits, -1), axis=(0, 1))
    aux = m.n_experts * jnp.sum(me * pe)
    return out, aux

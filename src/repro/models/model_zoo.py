"""Convenience layer over the unified model: init + dummy batches."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import transformer as T

__all__ = ["init", "dummy_batch", "batch_spec"]


def init(cfg: ArchConfig, seed: int = 0):
    return T.init_params(cfg, jax.random.PRNGKey(seed))


def dummy_batch(cfg: ArchConfig, batch: int, seq: int,
                seed: int = 1) -> Dict[str, jax.Array]:
    """Concrete random batch (smoke tests / examples)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    out: Dict[str, jax.Array] = {
        "tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab,
                                     jnp.int32),
    }
    if cfg.encoder_layers:
        out["frames"] = jax.random.normal(
            k2, (batch, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.02
    if cfg.vision_tokens:
        out["patches"] = jax.random.normal(
            k3, (batch, cfg.vision_tokens, cfg.d_model),
            jnp.float32) * 0.02
    return out


def batch_spec(cfg: ArchConfig, batch: int,
               seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    out = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.encoder_layers:
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.vision_tokens:
        out["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision_tokens, cfg.d_model), jnp.float32)
    return out

"""Mamba-2 (SSD — state-space duality) block in pure JAX.

Implements the chunked SSD algorithm (arXiv:2405.21060): the sequence is
split into chunks; within a chunk the quadratic dual form runs on the MXU
(einsums over ``(Q, Q)`` decay-masked scores), and a ``lax.scan`` carries
the ``(d_state, head_dim)`` recurrent state across chunks.  Single-token
decode is the constant-memory recurrence — this is what makes
``long_500k`` tractable for the SSM/hybrid architectures.

Layer I/O matches an attention block (``(B, S, d_model) -> same``), so
hybrid stacks interleave freely.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from .layers import dense_init, rmsnorm

__all__ = ["ssm_init", "ssm_apply", "ssm_decode", "ssm_state_init"]

Params = Dict[str, Any]


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, n_heads, conv_dim


def ssm_init(cfg: ArchConfig, key, dtype) -> Params:
    s, d_in, nh, conv_dim = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + nh
    return {
        "in_proj": dense_init(ks[0], d, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim),
                                     jnp.float32)
                   / math.sqrt(s.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[2], d_in, d, dtype),
    }


def _split_proj(cfg: ArchConfig, zxbcdt):
    s, d_in, nh, _ = _dims(cfg)
    g = s.n_groups
    z, x, B, C, dt = jnp.split(
        zxbcdt,
        [d_in, 2 * d_in, 2 * d_in + g * s.d_state,
         2 * d_in + 2 * g * s.d_state], axis=-1)
    return z, x, B, C, dt


def _causal_conv(x, w, b):
    """Depth-wise causal conv1d: x (B,S,C), w (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):                      # tiny static unroll (K=4)
        out = out + xp[:, i:i + x.shape[1]] * w[i]
    return jax.nn.silu(out + b)


def ssm_apply(cfg: ArchConfig, p: Params, u: jax.Array) -> jax.Array:
    """Full-sequence SSD (training / prefill)."""
    s, d_in, nh, conv_dim = _dims(cfg)
    bsz, S, _ = u.shape
    Q = min(s.chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by SSD chunk {Q}"
    nc = S // Q
    g = s.n_groups
    hp = s.head_dim

    z, x, B, C, dt_raw = _split_proj(cfg, u @ p["in_proj"])
    xbc = _causal_conv(jnp.concatenate([x, B, C], -1), p["conv_w"],
                       p["conv_b"])
    x, B, C = jnp.split(xbc, [d_in, d_in + g * s.d_state], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                # (nh,)
    x = x.reshape(bsz, nc, Q, nh, hp)
    B = B.reshape(bsz, nc, Q, g, s.d_state)
    C = C.reshape(bsz, nc, Q, g, s.d_state)
    dt = dt.reshape(bsz, nc, Q, nh)
    hpg = nh // g                                           # heads per group
    dA = dt * A                                             # (b,c,Q,nh)
    cum = jnp.cumsum(dA, axis=2)                            # (b,c,Q,nh)

    # ---- intra-chunk (dual quadratic form) --------------------------------
    # L[i,j] = exp(cum_i - cum_j) for i >= j; mask BEFORE exp so masked
    # entries are exp(-inf) = 0 with zero gradient (no inf*0 NaNs)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (b,c,Q,Q,nh)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -jnp.inf))
    # scores[i,j] = (C_i . B_j) * L[i,j] * dt_j
    CB = jnp.einsum("bcqgn,bcsgn->bcqsg", C, B)             # (b,c,Q,Q,g)
    CB = jnp.repeat(CB, hpg, axis=-1)                       # (b,c,Q,Q,nh)
    W = CB * L * dt[:, :, None, :, :]
    y_diag = jnp.einsum("bcqsh,bcshp->bcqhp",
                        W.astype(u.dtype), x)

    # ---- chunk summary states ---------------------------------------------
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)            # (b,c,Q,nh)
    Bh = jnp.repeat(B, hpg, axis=-2).reshape(bsz, nc, Q, nh, s.d_state)
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp",
                        (decay_end * dt).astype(u.dtype), Bh, x)

    # ---- inter-chunk recurrence -------------------------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (b,c,nh)

    def step(h, inp):
        dec, st = inp                                       # (b,nh), (b,nh,n,p)
        h_new = h * dec[..., None, None].astype(h.dtype) + st
        return h_new, h                                     # emit h_{c-1}

    h0 = jnp.zeros((bsz, nh, s.d_state, hp), u.dtype)
    _, h_prev = lax.scan(step, h0,
                         (chunk_decay.transpose(1, 0, 2),
                          states.transpose(1, 0, 2, 3, 4)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                # (b,c,nh,n,p)

    Ch = jnp.repeat(C, hpg, axis=-2).reshape(bsz, nc, Q, nh, s.d_state)
    y_off = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", Ch, h_prev,
                       jnp.exp(cum).astype(u.dtype))

    y = (y_diag + y_off
         + x * p["D"][..., None].astype(u.dtype))
    y = y.reshape(bsz, S, d_in)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["out_proj"]


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def ssm_state_init(cfg: ArchConfig, batch: int, dtype) -> Params:
    s, d_in, nh, conv_dim = _dims(cfg)
    return {
        "h": jnp.zeros((batch, nh, s.d_state, s.head_dim), dtype),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
    }


def ssm_decode(cfg: ArchConfig, p: Params, u: jax.Array,
               state: Params) -> Tuple[jax.Array, Params]:
    """One-token recurrence: u (B, 1, d)."""
    s, d_in, nh, conv_dim = _dims(cfg)
    bsz = u.shape[0]
    g, hp = s.n_groups, s.head_dim
    hpg = nh // g

    z, x, B, C, dt_raw = _split_proj(cfg, u @ p["in_proj"])
    xbc = jnp.concatenate([x, B, C], -1)                    # (B,1,conv)
    window = jnp.concatenate([state["conv"],
                              xbc.astype(state["conv"].dtype)], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(u.dtype),
                          p["conv_w"]) + p["conv_b"]
    xbc1 = jax.nn.silu(conv_out)[:, None, :]
    x, B, C = jnp.split(xbc1, [d_in, d_in + g * s.d_state], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                    # (B,nh)
    x = x.reshape(bsz, nh, hp)
    Bh = jnp.repeat(B.reshape(bsz, g, s.d_state), hpg, axis=1)
    Ch = jnp.repeat(C.reshape(bsz, g, s.d_state), hpg, axis=1)
    h = state["h"].astype(jnp.float32)
    h = h * dA[..., None, None] \
        + (dt[..., None, None] * Bh[..., :, None]
           * x[..., None, :].astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), h)
    y = y + p["D"][..., None] * x.astype(jnp.float32)
    y = y.reshape(bsz, 1, d_in).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    out = y @ p["out_proj"]
    new_state = {"h": h.astype(state["h"].dtype),
                 "conv": window[:, 1:].astype(state["conv"].dtype)}
    return out, new_state

"""Unified decoder-LM / encoder-decoder model covering all ten assigned
architectures.

One parameterized implementation:

* ``block_pattern`` interleaves sublayers per scan block — ``"A"`` (dense
  transformers), ``"M"`` (pure Mamba-2), ``"MMMMMMMA"`` (Jamba's 1:7
  hybrid) — and ``lax.scan`` runs over stacked block params so the HLO is
  O(1) in depth (critical for dry-run compile times at 61-72 layers).
* FFN per sublayer is dense MLP or MoE (``moe_stride`` alternates them,
  Jamba-style); attention is GQA, sliding-window, or MLA per config.
* ``encoder_layers > 0`` adds a bidirectional encoder + cross-attention
  (Whisper); the audio frontend is a stub — ``input_specs`` feeds
  precomputed frame embeddings.
* ``vision_tokens > 0`` prepends projected patch embeddings (LLaVA-style
  anyres stub) to the token embeddings.
* Decode paths maintain per-block KV caches (ring-buffered under sliding
  windows), MLA latent caches, or SSD recurrent states.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from . import layers as L
from . import ssm as S
from .analysis_flags import FLAGS as _AFLAGS

__all__ = ["init_params", "forward", "loss_fn", "init_decode_state",
           "decode_step", "prefill", "cache_len_for"]

Params = Dict[str, Any]


def _dt(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype), jnp.dtype(cfg.compute_dtype)


def cast_params(cfg: ArchConfig, params: Params) -> Params:
    """Mixed precision: master params stay in ``param_dtype``; matrices
    are cast to ``compute_dtype`` at use.  1-D params (norm scales, SSM
    A/D/dt) remain full precision for numerical stability."""
    _, cdtype = _dt(cfg)

    def cast(a):
        if not hasattr(a, "ndim") or a.ndim < 2:
            return a
        if a.dtype == jnp.int8:
            # §Perf int8_weights knob: INT8 storage, dequant at use
            # (fixed 1/128 scale stand-in; serving calibrates per tensor
            # via repro.quant)
            return a.astype(cdtype) * jnp.asarray(1.0 / 128, cdtype)
        if jnp.issubdtype(a.dtype, jnp.floating):
            return a.astype(cdtype)
        return a

    return jax.tree.map(cast, params)


def _use_moe(cfg: ArchConfig, sub_idx: int) -> bool:
    if cfg.moe is None:
        return False
    stride = getattr(cfg.moe, "moe_stride", 1)
    return sub_idx % max(stride, 1) == 0


def _has_ffn(cfg: ArchConfig, ch: str) -> bool:
    if cfg.family == "ssm":
        return False                    # Mamba-2 blocks are self-contained
    return True


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(cfg: ArchConfig, key, pdtype, cross: bool) -> Params:
    p: Params = {}
    keys = jax.random.split(key, 4 * len(cfg.block_pattern) + 2)
    ki = iter(keys)
    for i, ch in enumerate(cfg.block_pattern):
        p[f"norm{i}"] = L.norm_init(cfg, cfg.d_model, pdtype)
        if ch == "A":
            if cfg.mla is not None:
                p[f"attn{i}"] = L.mla_init(cfg, next(ki), pdtype)
            else:
                p[f"attn{i}"] = L.attention_init(cfg, next(ki), pdtype)
            if cross:
                p[f"xnorm{i}"] = L.norm_init(cfg, cfg.d_model, pdtype)
                p[f"xattn{i}"] = L.attention_init(cfg, next(ki), pdtype,
                                                  cross=True)
        else:
            p[f"ssm{i}"] = S.ssm_init(cfg, next(ki), pdtype)
        if _has_ffn(cfg, ch):
            p[f"fnorm{i}"] = L.norm_init(cfg, cfg.d_model, pdtype)
            if _use_moe(cfg, i):
                p[f"moe{i}"] = L.moe_init(cfg, next(ki), pdtype)
            else:
                p[f"mlp{i}"] = L.mlp_init(cfg, next(ki), pdtype)
    return p


def init_params(cfg: ArchConfig, key) -> Params:
    pdtype, _ = _dt(cfg)
    k_embed, k_blocks, k_head, k_enc, k_mtp, k_vis = \
        jax.random.split(key, 6)
    p: Params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(pdtype),
        "final_norm": L.norm_init(cfg, cfg.d_model, pdtype),
    }
    cross = cfg.encoder_layers > 0
    block_keys = jax.random.split(k_blocks, cfg.n_blocks)
    p["blocks"] = jax.vmap(
        lambda k: _init_block(cfg, k, pdtype, cross))(block_keys)
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab, pdtype)
    if cross:
        enc_cfg = cfg
        enc_keys = jax.random.split(k_enc, cfg.encoder_layers + 1)
        p["enc_blocks"] = jax.vmap(
            lambda k: {
                "norm0": L.norm_init(cfg, cfg.d_model, pdtype),
                "attn0": L.attention_init(cfg, k, pdtype),
                "fnorm0": L.norm_init(cfg, cfg.d_model, pdtype),
                "mlp0": L.mlp_init(cfg, jax.random.fold_in(k, 1), pdtype),
            })(enc_keys[:-1])
        p["enc_norm"] = L.norm_init(cfg, cfg.d_model, pdtype)
    if cfg.vision_tokens:
        p["vis_proj"] = L.dense_init(k_vis, cfg.d_model, cfg.d_model,
                                     pdtype)
    if cfg.mtp:
        km1, km2 = jax.random.split(k_mtp)
        p["mtp"] = {
            "norm": L.norm_init(cfg, cfg.d_model, pdtype),
            "proj": L.dense_init(km1, 2 * cfg.d_model, cfg.d_model,
                                 pdtype),
        }
    return p


# ---------------------------------------------------------------------------
# Block application (full sequence)
# ---------------------------------------------------------------------------


def _block_apply(cfg: ArchConfig, bp: Params, x, enc=None,
                 positions=None):
    aux = jnp.zeros((), jnp.float32)
    for i, ch in enumerate(cfg.block_pattern):
        h = L.apply_norm(cfg, bp[f"norm{i}"], x)
        if ch == "A":
            if cfg.mla is not None:
                x = x + L.mla_apply(cfg, bp[f"attn{i}"], h,
                                    positions=positions)
            else:
                x = x + L.attention_apply(cfg, bp[f"attn{i}"], h,
                                          causal=True,
                                          positions=positions)
            if enc is not None:
                hx = L.apply_norm(cfg, bp[f"xnorm{i}"], x)
                x = x + L.attention_apply(cfg, bp[f"xattn{i}"], hx,
                                          causal=False, kv_src=enc,
                                          use_rope=False)
        else:
            x = x + S.ssm_apply(cfg, bp[f"ssm{i}"], h)
        if _has_ffn(cfg, ch):
            hf = L.apply_norm(cfg, bp[f"fnorm{i}"], x)
            if _use_moe(cfg, i):
                y, a = L.moe_apply(cfg, bp[f"moe{i}"], hf)
                x = x + y
                aux = aux + a
            else:
                x = x + L.mlp_apply(cfg, bp[f"mlp{i}"], hf)
    return x, aux


def _run_encoder(cfg: ArchConfig, params: Params, frames):
    """Whisper-style encoder over precomputed frame embeddings."""
    _, cdtype = _dt(cfg)
    x = frames.astype(cdtype)
    # sinusoidal positions
    s = x.shape[1]
    pos = jnp.arange(s)[:, None]
    dim = jnp.arange(cfg.d_model // 2)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / cfg.d_model)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
    x = x + pe.astype(cdtype)

    def enc_block(x, bp):
        h = L.apply_norm(cfg, bp["norm0"], x)
        x = x + L.attention_apply(cfg, bp["attn0"], h, causal=False,
                                  use_rope=False)
        hf = L.apply_norm(cfg, bp["fnorm0"], x)
        return x + L.mlp_apply(cfg, bp["mlp0"], hf), None

    x, _ = lax.scan(enc_block, x, params["enc_blocks"],
                    unroll=_AFLAGS["scan_unroll"])
    return L.apply_norm(cfg, params["enc_norm"], x)


def _embed_inputs(cfg: ArchConfig, params: Params, batch: Dict) -> Tuple:
    _, cdtype = _dt(cfg)
    x = params["embed"][batch["tokens"]].astype(cdtype)
    if cfg.vision_tokens:
        vis = batch["patches"].astype(cdtype) @ params["vis_proj"]
        x = jnp.concatenate([vis, x], axis=1)
    enc = None
    if cfg.encoder_layers:
        enc = _run_encoder(cfg, params, batch["frames"])
    return x, enc


def _remat_policy():
    from ..launch import tuning
    if tuning.FLAGS["remat_policy"] == "dots":
        return jax.checkpoint_policies.dots_saveable
    return jax.checkpoint_policies.nothing_saveable


def forward(cfg: ArchConfig, params: Params, batch: Dict,
            remat: bool = True) -> jax.Array:
    """Logits over the (text) token positions."""
    params = cast_params(cfg, params)
    x, enc = _embed_inputs(cfg, params, batch)

    def body(x, bp):
        y, aux = _block_apply(cfg, bp, x, enc=enc)
        return y, aux

    if remat:
        body = jax.checkpoint(body, policy=_remat_policy())
    x, auxs = lax.scan(body, x, params["blocks"],
                       unroll=_AFLAGS["scan_unroll"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    if cfg.vision_tokens:
        x = x[:, cfg.vision_tokens:]
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = x @ head.astype(x.dtype)
    return logits


def loss_fn(cfg: ArchConfig, params: Params, batch: Dict) -> jax.Array:
    """Next-token cross-entropy (+ MoE aux + MTP when configured)."""
    params = cast_params(cfg, params)
    x, enc = _embed_inputs(cfg, params, batch)

    def body(x, bp):
        return _block_apply(cfg, bp, x, enc=enc)

    body_r = jax.checkpoint(body, policy=_remat_policy())
    x, auxs = lax.scan(body_r, x, params["blocks"],
                       unroll=_AFLAGS["scan_unroll"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    if cfg.vision_tokens:
        x = x[:, cfg.vision_tokens:]
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)

    tokens = batch["tokens"]
    labels = batch.get("labels", tokens)

    def xent(h, lab):
        logits = (h @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, lab[..., None], -1)[..., 0]
        return logz - gold

    loss = xent(x[:, :-1], labels[:, 1:]).mean()
    if cfg.mtp:
        # multi-token prediction: predict t+2 from (h_t, emb_{t+1})
        _, cdtype = _dt(cfg)
        emb_next = params["embed"][tokens[:, 1:-1]].astype(cdtype)
        h = L.apply_norm(cfg, params["mtp"]["norm"], x[:, :-2])
        h2 = jnp.concatenate([h, emb_next], -1) @ params["mtp"]["proj"]
        loss = loss + 0.3 * xent(h2, labels[:, 2:]).mean()
    if cfg.moe is not None:
        loss = loss + 0.01 * jnp.sum(auxs)
    return loss


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def cache_len_for(cfg: ArchConfig, seq_len: int) -> int:
    """KV slots needed for a context of ``seq_len`` (ring under SWA)."""
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_decode_state(cfg: ArchConfig, params: Params, batch: int,
                      seq_len: int,
                      enc: Optional[jax.Array] = None) -> Params:
    """Pre-allocated per-block caches + position counter."""
    from ..launch import tuning
    _, cdtype = _dt(cfg)
    kv_dtype = (jnp.int8 if tuning.FLAGS["int8_kv_cache"]
                else cdtype)
    s_cache = cache_len_for(cfg, seq_len)
    nb = cfg.n_blocks
    caches: Params = {}
    for i, ch in enumerate(cfg.block_pattern):
        if ch == "A":
            if cfg.mla is not None:
                m = cfg.mla
                caches[f"attn{i}"] = {
                    "c_kv": jnp.zeros((nb, batch, s_cache,
                                       m.kv_lora_rank), cdtype),
                    "k_rope": jnp.zeros((nb, batch, s_cache, 1,
                                         m.qk_rope_head_dim), cdtype),
                }
            else:
                caches[f"attn{i}"] = {
                    "k": jnp.zeros((nb, batch, s_cache, cfg.n_kv_heads,
                                    cfg.hd), kv_dtype),
                    "v": jnp.zeros((nb, batch, s_cache, cfg.n_kv_heads,
                                    cfg.hd), kv_dtype),
                }
        else:
            st = S.ssm_state_init(cfg, batch, cdtype)
            caches[f"ssm{i}"] = jax.tree.map(
                lambda a: jnp.zeros((nb,) + a.shape, a.dtype), st)
    state = {"caches": caches, "pos": jnp.zeros((), jnp.int32)}
    if enc is not None:
        state["enc"] = enc
    return state


def decode_step(cfg: ArchConfig, params: Params, state: Params,
                token: jax.Array) -> Tuple[jax.Array, Params]:
    """One decode step: token (B, 1) int32 -> (logits (B, vocab), state)."""
    params = cast_params(cfg, params)
    _, cdtype = _dt(cfg)
    x = params["embed"][token].astype(cdtype)
    pos = state["pos"]
    enc = state.get("enc")

    def body(x, scanned):
        bp, cache = scanned
        new_cache = {}
        for i, ch in enumerate(cfg.block_pattern):
            h = L.apply_norm(cfg, bp[f"norm{i}"], x)
            if ch == "A":
                if cfg.mla is not None:
                    y, nc = L.mla_decode(cfg, bp[f"attn{i}"], h,
                                         cache[f"attn{i}"], pos)
                else:
                    y, nc = L.attention_decode(cfg, bp[f"attn{i}"], h,
                                               cache[f"attn{i}"], pos)
                x = x + y
                new_cache[f"attn{i}"] = nc
                if enc is not None:
                    hx = L.apply_norm(cfg, bp[f"xnorm{i}"], x)
                    x = x + L.attention_apply(cfg, bp[f"xattn{i}"], hx,
                                              causal=False, kv_src=enc,
                                              use_rope=False)
            else:
                y, ns = S.ssm_decode(cfg, bp[f"ssm{i}"], h,
                                     cache[f"ssm{i}"])
                x = x + y
                new_cache[f"ssm{i}"] = ns
            if _has_ffn(cfg, ch):
                hf = L.apply_norm(cfg, bp[f"fnorm{i}"], x)
                if _use_moe(cfg, i):
                    y, _ = L.moe_apply(cfg, bp[f"moe{i}"], hf)
                    x = x + y
                else:
                    x = x + L.mlp_apply(cfg, bp[f"mlp{i}"], hf)
        return x, new_cache

    x, new_caches = lax.scan(body, x, (params["blocks"], state["caches"]),
                             unroll=_AFLAGS["scan_unroll"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = (x[:, 0] @ head).astype(jnp.float32)
    new_state = dict(state)
    new_state["caches"] = new_caches
    new_state["pos"] = pos + 1
    return logits, new_state


def prefill(cfg: ArchConfig, params: Params, batch: Dict,
            seq_len: Optional[int] = None) -> Tuple[jax.Array, Params]:
    """Run the full prompt, returning last-token logits + decode state.

    Implemented as forward for logits; caches are filled by scanning
    decode steps in tests (small) — production prefill-with-cache-export
    lowers the full-sequence path and writes caches per block.
    """
    logits = forward(cfg, params, batch, remat=False)
    return logits[:, -1], None

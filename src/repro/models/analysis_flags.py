"""Cost-probe mode for the roofline analysis.

XLA's ``cost_analysis()`` counts a ``while``-loop body **once** regardless
of trip count (verified empirically — see EXPERIMENTS.md §Roofline
methodology), so lowering the full model under-reports FLOPs/bytes by
~n_layers.  The dry-run therefore compiles **depth-1 and depth-2 probe
variants with fully-unrolled scans** and reconstructs step totals as
``X(1) + (n_blocks - 1) · (X(2) - X(1))``.

Probe mode additionally switches:

* flash attention -> the naive masked-softmax path (its inner block scans
  would otherwise be undercounted the same way; FLOP counts are identical,
  HBM bytes become an S² *upper bound*, noted in the tables);
* EP MoE ragged_dot -> a balanced equal-capacity batched matmul
  (XLA prices ragged_dot as dense over all groups — E_loc x overcount;
  the balanced probe prices exactly the ideal-load-balance FLOPs).
"""

from __future__ import annotations

import contextlib

FLAGS = {
    "naive_attention": False,
    "balanced_moe": False,
    "scan_unroll": 1,
}


@contextlib.contextmanager
def probe_mode(unroll: int, naive_attention: bool = True):
    """``naive_attention=True`` -> exact FLOP counts (S² bytes upper
    bound); ``False`` -> flash path kept, bytes/collectives measured with
    the flash inner scans counted once (the dry-run adds the analytic
    flash streaming traffic back — see launch/analysis.flash_addons)."""
    prev = dict(FLAGS)
    FLAGS.update(naive_attention=naive_attention, balanced_moe=True,
                 scan_unroll=unroll)
    try:
        yield
    finally:
        FLAGS.update(prev)

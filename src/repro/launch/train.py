"""Training driver: data -> sharded train step -> checkpoints, with the
fault-tolerance substrate wired in.

Runs anywhere: ``--reduced`` trains the smoke-scale config on CPU;
on a pod the same driver builds the production mesh.  Demonstrates:

* deterministic resumable data (stream state in the checkpoint),
* async atomic checkpointing + crash-safe restore,
* straggler detection over per-step timings,
* elastic re-mesh planning on simulated node loss (``--simulate-loss``).

Example::

    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \
        --reduced --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs import ARCHS, STANDARD_SHAPES, ShapeConfig, reduced
from repro.data import SyntheticStream
from repro.launch import meshctx, sharding, steps
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.launch.sharding import usable_data_axes
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import StragglerDetector, plan_remesh
from repro.checkpoint import CheckpointManager


def local_mesh():
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--simulate-loss", type=int, default=0,
                    help="simulate N chips lost at mid-run (re-mesh demo)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else local_mesh())
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    adamw = AdamWConfig()
    dp = usable_data_axes(mesh, args.batch)

    with meshctx.use_mesh(mesh, data_axes=dp):
        step_fn, _ = steps.make_train_step(
            cfg, mesh, shape, adamw, lr_peak=args.lr,
            warmup=max(2, args.steps // 10), total_steps=args.steps)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params, adamw)

        start = 0
        stream_state = {"step": 0, "seed": 0}
        mgr: Optional[CheckpointManager] = None
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir, keep=3)
            restored = mgr.restore({"params": params, "opt": opt})
            if restored[0] is not None:
                start, tree, meta = restored
                params, opt = tree["params"], tree["opt"]
                stream_state = meta.get("stream", stream_state)
                print(f"[resume] from step {start}")

        stream = SyntheticStream.restore(cfg, args.batch, args.seq,
                                         stream_state)
        straggler = StragglerDetector()
        t_hist = []
        import jax.numpy as jnp
        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
            t0 = time.time()
            params, opt, metrics = step_fn(params, opt, batch,
                                           jnp.int32(step))
            loss = float(metrics["loss"])
            dt = time.time() - t0
            t_hist.append(dt)
            flagged = straggler.record_step({"host0": dt})
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt * 1e3:.0f} ms"
                      + (f" stragglers={flagged}" if flagged else ""))
            if mgr and step and step % args.ckpt_every == 0:
                mgr.save(step, {"params": params, "opt": opt},
                         metadata={"stream": stream.state_dict(),
                                   "step": step})
            if args.simulate_loss and step == args.steps // 2:
                survivors = mesh.size - args.simulate_loss
                plan = plan_remesh(
                    survivors, model_parallel=mesh.shape["model"],
                    target_data_parallel=int(np.prod(
                        [mesh.shape[a] for a in dp])) if dp else 1)
                print(f"[elastic] lost {args.simulate_loss} chips -> "
                      f"mesh {plan.mesh_shape}, grad_accum x"
                      f"{plan.grad_accum} ({plan.reason}); restart from "
                      f"latest checkpoint would resume step "
                      f"{mgr.latest_step() if mgr else 'n/a'}")
        if mgr:
            mgr.save(args.steps, {"params": params, "opt": opt},
                     metadata={"stream": stream.state_dict(),
                               "step": args.steps}, blocking=True)
        stream.close()
        print(f"done: {args.steps - start} steps, "
              f"median {np.median(t_hist) * 1e3:.0f} ms/step")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Performance-tuning knobs for the §Perf hillclimb.

Each flag is one hypothesis from EXPERIMENTS.md §Perf; the dry-run probes
re-measure the roofline terms with a knob flipped, and the before/after
goes into the log.  Flags default to the paper-faithful baseline.

* ``attn_seq_parallel`` — replace the head_dim-fallback attention sharding
  (whose score psum scales with S²) by sequence-sharded attention: q/k/v
  are resharded seq-wise (an S-linear all-to-all), attention computes with
  full heads per chip on its sequence slice, and the context reshards
  back for the row-parallel output projection.
* ``fsdp_params`` — ZeRO-3-style: parameters (and their optimizer
  moments) shard over the data axis too; XLA inserts per-layer
  all-gathers / reduce-scatters.  Trades collective time for the capacity
  wall (671B-class configs cannot hold replicated-over-data params).
* ``int8_weights`` — store 2-D+ weights INT8 with per-tensor scales,
  dequantizing at use (the paper's digital-CIM INT8 inference story
  applied to decode bandwidth).
* ``int8_kv_cache`` — INT8 KV cache with dequant-at-attention.
"""

from __future__ import annotations

import contextlib

FLAGS = {
    "attn_seq_parallel": False,
    "fsdp_params": False,
    "int8_weights": False,
    "int8_kv_cache": False,
    "remat_policy": "nothing",      # nothing | dots
}


@contextlib.contextmanager
def tuned(**kw):
    prev = dict(FLAGS)
    FLAGS.update(kw)
    try:
        yield
    finally:
        FLAGS.update(prev)

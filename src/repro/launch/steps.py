"""Jitted, sharded train / prefill / decode steps.

``make_*_step`` return ``(fn, in_shardings, out_shardings, abstract
inputs)`` so the same builders serve the real drivers *and* the dry-run
(``fn.lower(*specs).compile()``).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig
from ..models import model_zoo, transformer as T
from ..optim import AdamWConfig, adamw_init, adamw_update, cosine_warmup
from . import meshctx, sharding, tuning
from .mesh import MODEL_AXIS, data_axes_of
from .sharding import usable_data_axes

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "abstract_params", "abstract_opt_state", "abstract_state"]


def abstract_params(cfg: ArchConfig):
    """ShapeDtypeStructs of the parameter tree (no allocation).

    Under the ``int8_weights`` tuning knob, 2-D+ float leaves become
    INT8 storage (dequantized at use by ``transformer.cast_params``)."""
    tree = jax.eval_shape(lambda: T.init_params(
        cfg, jax.random.PRNGKey(0)))
    if tuning.FLAGS["int8_weights"]:
        def q(s):
            if s.ndim >= 2 and jnp.issubdtype(s.dtype, jnp.floating):
                return jax.ShapeDtypeStruct(s.shape, jnp.int8)
            return s
        tree = jax.tree.map(q, tree)
    return tree


def abstract_opt_state(cfg: ArchConfig, adamw: AdamWConfig):
    params = abstract_params(cfg)
    return jax.eval_shape(lambda: adamw_init(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params),
        adamw))


def abstract_state(cfg: ArchConfig, batch: int, seq: int):
    def build():
        enc = (jnp.zeros((batch, cfg.encoder_seq, cfg.d_model),
                         jnp.dtype(cfg.compute_dtype))
               if cfg.encoder_layers else None)
        return T.init_decode_state(cfg, {}, batch, seq, enc=enc)
    return jax.eval_shape(build)


def _sds(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, mesh: Mesh,
                    shape: ShapeConfig,
                    adamw: AdamWConfig = AdamWConfig(),
                    lr_peak: float = 3e-4, warmup: int = 200,
                    total_steps: int = 10_000):
    """Returns (jitted step, abstract (params, opt, batch, step))."""
    pspecs = sharding.param_specs(cfg, mesh)
    if tuning.FLAGS["fsdp_params"]:
        pspecs = sharding.fsdp_specs(pspecs, abstract_params(cfg), mesh)
    ospecs = sharding.opt_state_specs(pspecs)
    bspecs = sharding.batch_specs(cfg, mesh, shape.global_batch)
    dp = usable_data_axes(mesh, shape.global_batch)

    def train_step(params, opt_state, batch, step):
        with_ctx = functools.partial(T.loss_fn, cfg)
        loss, grads = jax.value_and_grad(with_ctx)(params, batch)
        lr = cosine_warmup(step, peak=lr_peak, warmup=warmup,
                           total=total_steps)
        new_p, new_o, metrics = adamw_update(grads, opt_state, params,
                                             lr, adamw)
        metrics = dict(metrics, loss=loss, lr=lr)
        return new_p, new_o, metrics

    ns = lambda t: sharding.named(mesh, t)           # noqa: E731
    jitted = jax.jit(
        train_step,
        in_shardings=(ns(pspecs), ns(ospecs), ns(bspecs),
                      NamedSharding(mesh, P())),
        out_shardings=(ns(pspecs), ns(ospecs),
                       NamedSharding(mesh, P())),
        donate_argnums=(0, 1),
    )
    abstract = (
        abstract_params(cfg),
        abstract_opt_state(cfg, adamw),
        _sds(model_zoo.batch_spec(cfg, shape.global_batch,
                                  shape.seq_len)),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    return jitted, abstract


# ---------------------------------------------------------------------------
# Serve: prefill + decode
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ArchConfig, mesh: Mesh, shape: ShapeConfig):
    """Full-sequence prefill lowering to last-token logits.

    The vocabulary projection applies to the final position only, so the
    (B, S, V) logits tensor never materializes at 32k context.
    """
    pspecs = sharding.param_specs(cfg, mesh)
    if tuning.FLAGS["fsdp_params"]:
        pspecs = sharding.fsdp_specs(pspecs, abstract_params(cfg), mesh)
    bspecs = sharding.batch_specs(cfg, mesh, shape.global_batch)
    dp = usable_data_axes(mesh, shape.global_batch)

    def prefill_step(params, batch):
        params = T.cast_params(cfg, params)
        x, enc = T._embed_inputs(cfg, params, batch)

        def body(x, bp):
            return T._block_apply(cfg, bp, x, enc=enc)

        body = jax.checkpoint(body, policy=T._remat_policy())
        x, _ = jax.lax.scan(body, x, params["blocks"],
                            unroll=T._AFLAGS["scan_unroll"])
        x = T.L.apply_norm(cfg, params["final_norm"], x)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"]).astype(x.dtype)
        return (x[:, -1:] @ head)[:, 0].astype(jnp.float32)

    jitted = jax.jit(
        prefill_step,
        in_shardings=(sharding.named(mesh, pspecs),
                      sharding.named(mesh, bspecs)),
        out_shardings=NamedSharding(mesh, P(dp, None)),
    )
    abstract = (abstract_params(cfg),
                _sds(model_zoo.batch_spec(cfg, shape.global_batch,
                                          shape.seq_len)))
    return jitted, abstract


def make_decode_step(cfg: ArchConfig, mesh: Mesh, shape: ShapeConfig):
    """One new token against a pre-allocated ``seq_len`` KV cache/state."""
    pspecs = sharding.param_specs(cfg, mesh)
    if tuning.FLAGS["fsdp_params"]:
        pspecs = sharding.fsdp_specs(pspecs, abstract_params(cfg), mesh)
    sspecs = sharding.decode_state_specs(cfg, mesh, shape.global_batch)
    dp = usable_data_axes(mesh, shape.global_batch)

    def decode(params, state, token):
        return T.decode_step(cfg, params, state, token)

    jitted = jax.jit(
        decode,
        in_shardings=(sharding.named(mesh, pspecs),
                      sharding.named(mesh, sspecs),
                      NamedSharding(mesh, P(dp, None))),
        out_shardings=(NamedSharding(mesh, P(dp, None)),
                       sharding.named(mesh, sspecs)),
        donate_argnums=(1,),
    )
    abstract = (
        abstract_params(cfg),
        abstract_state(cfg, shape.global_batch, shape.seq_len),
        jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
    )
    return jitted, abstract

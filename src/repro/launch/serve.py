"""Serving driver: batched prefill + token-by-token decode.

``--reduced`` serves the smoke-scale config on CPU; the same driver
builds the production mesh on a pod.  Decode uses the pre-allocated
(ring-buffered under SWA) caches, MLA latent caches, or SSD states —
whatever the architecture calls for.

Example::

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
        --reduced --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, ShapeConfig, reduced
from repro.data import make_batch
from repro.launch import meshctx, steps
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.launch.sharding import usable_data_axes
from repro.models import transformer as T


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg)
    n = len(jax.devices())
    mesh = (make_production_mesh() if args.production_mesh
            else make_mesh((n, 1), ("data", "model")))
    total = args.prompt_len + args.gen
    dp = usable_data_axes(mesh, args.batch)

    with meshctx.use_mesh(mesh, data_axes=dp):
        params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
        shape = ShapeConfig("cli", total, args.batch, "decode")
        decode_fn, _ = steps.make_decode_step(cfg, mesh, shape)

        batch = {k: jnp.asarray(v) for k, v in make_batch(
            cfg, args.batch, args.prompt_len, seed=args.seed,
            step=0).items()}
        enc = (T._run_encoder(cfg, T.cast_params(cfg, params),
                              batch["frames"])
               if cfg.encoder_layers else None)
        state = T.init_decode_state(cfg, params, args.batch, total,
                                    enc=enc)

        # prefill by stepping the prompt through the decode path (fills
        # caches exactly; a fused full-sequence prefill-with-cache-export
        # is the production fast path, measured in the dry-run cells)
        t0 = time.time()
        logits = None
        for t in range(args.prompt_len):
            logits, state = decode_fn(params, state,
                                      batch["tokens"][:, t:t + 1])
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        key = jax.random.PRNGKey(args.seed + 1)
        out_tokens = []
        t1 = time.time()
        for t in range(args.gen):
            key, sub = jax.random.split(key)
            if args.temperature > 0:
                nxt = jax.random.categorical(
                    sub, logits / args.temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = nxt[:, None].astype(jnp.int32)
            out_tokens.append(np.asarray(nxt))
            logits, state = decode_fn(params, state, nxt)
        jax.block_until_ready(logits)
        t_gen = time.time() - t1

    gen = np.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill:.2f}s  decode: {t_gen:.2f}s "
          f"({args.batch * args.gen / t_gen:.1f} tok/s)")
    print("sample token ids:", gen[0][:16].tolist())
    assert np.isfinite(np.asarray(logits)).all(), "non-finite logits"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Compiled-artifact analysis: collective bytes, roofline terms.

``cost_analysis()`` gives HLO FLOPs/bytes but not collective traffic, so
we parse the optimized HLO text and sum result-buffer sizes per
collective kind (DESIGN.md §Roofline).  Hardware constants target
TPU v5e-class chips per the assignment: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s per ICI link.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

__all__ = ["HW", "collective_bytes", "roofline_terms", "RooflineTerms",
           "model_flops"]


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # B/s per chip
    ici_bw: float = 50e9                # B/s per link
    ici_links: int = 4                  # usable mesh links per chip
    hbm_bytes: float = 16e9             # capacity per chip


DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")

# result shape like  bf16[16,4096,448]{2,1,0:T(8,128)(2,1)}
_SHAPE_RE = re.compile(r"([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?|pred)"
                       r"\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(",
    re.M)


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        nbytes = DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * nbytes
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Result-buffer bytes per collective kind (``-start`` ops only are
    counted once; ``-done`` carries no new payload)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for m in _OP_RE.finditer(hlo_text):
        shape_text, kind = m.group(1), m.group(2)
        if "-done" in m.group(0):
            continue
        out[kind] += _shape_bytes(shape_text)
        out["count"] += 1
    return out


@dataclass
class RooflineTerms:
    """Per-chip roofline terms in seconds + supporting numbers."""

    flops: float                 # HLO flops per chip (per step)
    hbm_bytes: float             # HLO bytes accessed per chip
    coll_link_bytes: float       # bytes crossing one ICI link
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    collectives: Dict[str, int] = field(default_factory=dict)

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_link_bytes": self.coll_link_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "collectives": self.collectives,
        }


def roofline_terms(cost: Dict, coll: Dict[str, int],
                   hw: HW = HW(),
                   extra_link_bytes: float = 0.0) -> RooflineTerms:
    """Three-term roofline from per-chip cost analysis + collectives.

    Link-byte model per chip (ring algorithms on a 2-D torus):
      all-reduce R result     -> 2R bytes through the busiest link
      all-gather R result     -> R
      reduce-scatter R result -> R x (n-1) ≈ its input ≈ R·n ... counted
                                 via result x 1 (conservative lower bound)
      all-to-all / permute R  -> R
    divided by the ``ici_links`` a chip can drive concurrently.
    """
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    link_bytes = (2.0 * coll.get("all-reduce", 0)
                  + 1.0 * coll.get("all-gather", 0)
                  + 1.0 * coll.get("reduce-scatter", 0)
                  + 1.0 * coll.get("all-to-all", 0)
                  + 1.0 * coll.get("collective-permute", 0))
    link_bytes = link_bytes / hw.ici_links + extra_link_bytes
    compute_s = flops / hw.peak_flops
    memory_s = hbm / hw.hbm_bw
    coll_s = link_bytes / hw.ici_bw
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", coll_s), key=lambda kv: kv[1])[0]
    return RooflineTerms(flops=flops, hbm_bytes=hbm,
                         coll_link_bytes=link_bytes,
                         compute_s=compute_s, memory_s=memory_s,
                         collective_s=coll_s, dominant=dom,
                         collectives=dict(coll))


def flash_addons(cfg, shape, n_chips: int, tp: int,
                 head_choice: str,
                 block_q: int = 512) -> Tuple[float, float]:
    """(extra HBM bytes, extra ICI link bytes) per chip per step for the
    blockwise-attention inner scans, which the cost probes count once.

    HBM: every query block streams the full K/V (window-clipped under
    SWA) — the defining flash traffic.  ICI: when attention falls back to
    head_dim sharding (heads % tp != 0), every score tile is psum'ed over
    the model axis; that S²-proportional collective is a baseline finding
    addressed in §Perf.  Training multiplies by ~4 (fwd + remat fwd +
    2x bwd).
    """
    seq = shape.seq_len
    if shape.kind not in ("train", "prefill") or seq <= 2048:
        return 0.0, 0.0
    n_attn = cfg.n_blocks * cfg.block_pattern.count("A")
    if n_attn == 0:
        return 0.0, 0.0
    dp = max(n_chips // tp, 1)
    b_loc = max(shape.global_batch // dp, 1)
    if cfg.mla is not None:
        kvh, hd = cfg.n_heads, (cfg.mla.qk_nope_head_dim
                                + cfg.mla.qk_rope_head_dim)
    else:
        kvh, hd = cfg.n_kv_heads, cfg.hd
    heads = cfg.n_heads
    if head_choice == "heads":
        kvh_loc, hd_loc, h_loc = max(kvh // tp, 1), hd, heads // tp
    elif head_choice == "head_dim":
        kvh_loc, hd_loc, h_loc = kvh, hd // tp, heads
    else:
        kvh_loc, hd_loc, h_loc = kvh, hd, heads
    nq = -(-seq // block_q)
    if head_choice == "sequence":
        # seq-parallel attention: full heads per chip, 1/tp of the query
        # blocks, full K/V streamed; the S-linear all-to-alls are real
        # per-layer collectives the probes measure directly
        kvh_loc, hd_loc, h_loc = kvh, hd, heads
        nq = max(nq // tp, 1)
    kv_span = min(seq, (cfg.sliding_window or seq) + block_q)
    passes = 4.0 if shape.kind == "train" else 1.0
    # HBM: per q-block read of K+V (bf16) across all attention layers
    hbm = passes * n_attn * b_loc * nq * kv_span * kvh_loc * hd_loc \
        * 2 * 2.0
    # ICI: head_dim sharding psums every (block_q x block_k) score tile
    link = 0.0
    if head_choice == "head_dim" and tp > 1:
        tiles = nq * (-(-kv_span // 1024))          # nk per q block
        tile_bytes = b_loc * kvh_loc * (heads // max(kvh, 1)) \
            * block_q * 1024 * 4.0
        link = passes * n_attn * tiles * tile_bytes * 2.0 / 4.0
    return hbm, link


def model_flops(cfg, shape, n_chips: int) -> float:
    """MODEL_FLOPS per chip per step: 6·N·D for training (N = active
    params), 2·N·D for prefill, 2·N per decoded token."""
    n_active = _active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind in ("train", "prefill")
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens / n_chips


def _active_params(cfg) -> float:
    """Params touched per token (MoE: top-k of the routed experts)."""
    total = cfg.param_count()
    if cfg.moe is None:
        return float(total)
    m = cfg.moe
    # subtract inactive routed-expert params
    per_expert = (3 if cfg.act == "swiglu" else 2) * cfg.d_model * m.d_ff
    n_moe_layers = sum(1 for _ in range(cfg.n_blocks)
                       for i, ch in enumerate(cfg.block_pattern)
                       if cfg.family != "ssm"
                       and i % max(m.moe_stride, 1) == 0)
    inactive = n_moe_layers * (m.n_experts - m.experts_per_tok) \
        * per_expert
    return float(total - max(inactive, 0))

"""Per-architecture sharding rules (PartitionSpec trees).

Weight sharding is Megatron-style tensor parallelism over the ``model``
axis (column-parallel up-projections, row-parallel down-projections,
expert-sharded MoE, vocab-sharded embeddings) with a **divisibility
fallback**: any dimension the 16-way axis does not divide falls back to
the next candidate (e.g. attention shards heads when ``H % tp == 0``,
else head_dim, else replicates) — so every assigned architecture
compiles on the fixed production mesh without padding its published
hyper-parameters.  The fallback decisions are logged into the spec tree
and surface in EXPERIMENTS.md §Dry-run.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from .mesh import MODEL_AXIS, data_axes_of

__all__ = ["param_specs", "batch_specs", "decode_state_specs",
           "opt_state_specs", "named", "head_sharding_choice"]


def _tp(mesh: Mesh) -> int:
    return mesh.shape[MODEL_AXIS]


def head_sharding_choice(cfg: ArchConfig, mesh: Mesh) -> str:
    """heads | head_dim | replicated — the attention fallback chain."""
    tp = _tp(mesh)
    n_heads = cfg.n_heads
    kvh = cfg.n_kv_heads
    if cfg.mla is not None:
        return "heads" if n_heads % tp == 0 else (
            "head_dim" if cfg.mla.v_head_dim % tp == 0 else "replicated")
    if n_heads % tp == 0 and kvh % tp == 0:
        return "heads"
    if cfg.hd % tp == 0:
        return "head_dim"
    return "replicated"


def _col(tp: int, dim: int) -> P:
    """Column-parallel (shard the output dim) when divisible."""
    return P(None, MODEL_AXIS) if dim % tp == 0 else P(None, None)


def _row(tp: int, dim: int) -> P:
    return P(MODEL_AXIS, None) if dim % tp == 0 else P(None, None)


def param_specs(cfg: ArchConfig, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching ``transformer.init_params``."""
    tp = _tp(mesh)
    d, hd = cfg.d_model, cfg.hd

    def block_specs() -> Dict[str, Any]:
        bs: Dict[str, Any] = {}
        for i, ch in enumerate(cfg.block_pattern):
            bs[f"norm{i}"] = _norm()
            if ch == "A":
                if cfg.mla is not None:
                    m = cfg.mla
                    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                    bs[f"attn{i}"] = {
                        "wq_a": P(None, None),
                        "wq_b": _col(tp, cfg.n_heads * qk),
                        "wkv_a": P(None, None),
                        "wkv_b": _col(tp, cfg.n_heads
                                      * (m.qk_nope_head_dim
                                         + m.v_head_dim)),
                        "wo": _row(tp, cfg.n_heads * m.v_head_dim),
                        "q_norm": P(None),
                        "kv_norm": P(None),
                    }
                else:
                    bs[f"attn{i}"] = {
                        "wq": _col(tp, cfg.n_heads * hd),
                        "wk": _col(tp, cfg.n_kv_heads * hd),
                        "wv": _col(tp, cfg.n_kv_heads * hd),
                        "wo": _row(tp, cfg.n_heads * hd),
                    }
                if cfg.encoder_layers:
                    bs[f"xnorm{i}"] = _norm()
                    bs[f"xattn{i}"] = dict(bs[f"attn{i}"])
            else:
                s = cfg.ssm
                d_in = s.expand * d
                nh = d_in // s.head_dim
                proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + nh
                conv_dim = d_in + 2 * s.n_groups * s.d_state
                bs[f"ssm{i}"] = {
                    "in_proj": _col(tp, proj_out),
                    "conv_w": P(None, MODEL_AXIS)
                    if conv_dim % tp == 0 else P(None, None),
                    "conv_b": P(None),
                    "A_log": P(None), "D": P(None), "dt_bias": P(None),
                    "norm_w": P(None),
                    "out_proj": _row(tp, d_in),
                }
            if f"mlp{i}" in _ffn_keys(cfg, i) or \
                    f"moe{i}" in _ffn_keys(cfg, i):
                bs[f"fnorm{i}"] = _norm()
                if _ffn_keys(cfg, i) == {f"moe{i}"}:
                    m = cfg.moe
                    espec = P(MODEL_AXIS, None, None) \
                        if m.n_experts % tp == 0 else P(None, None, None)
                    moe_spec: Dict[str, Any] = {
                        "router": P(None, None),
                        "wi": espec, "wg": espec, "wo": espec,
                    }
                    if m.n_shared_experts:
                        moe_spec["shared"] = _mlp_spec(
                            cfg, tp,
                            (m.shared_d_ff or m.d_ff)
                            * m.n_shared_experts)
                    bs[f"moe{i}"] = moe_spec
                else:
                    bs[f"mlp{i}"] = _mlp_spec(cfg, tp, cfg.d_ff)
        return bs

    def _norm():
        return ({"w": P(None), "b": P(None)} if cfg.norm == "layernorm"
                else {"w": P(None)})

    def _mlp_spec(cfg, tp, f):
        sp = {"wi": _col(tp, f), "wo": _row(tp, f)}
        if cfg.act == "swiglu":
            sp["wg"] = _col(tp, f)
        return sp

    def _ffn_keys(cfg, i):
        if cfg.family == "ssm":
            return set()
        if cfg.moe is not None and i % max(cfg.moe.moe_stride, 1) == 0:
            return {f"moe{i}"}
        return {f"mlp{i}"}

    # embeddings: vocab-sharded when divisible, else d_model, else full
    if cfg.vocab % tp == 0:
        embed = P(MODEL_AXIS, None)
    elif d % tp == 0:
        embed = P(None, MODEL_AXIS)
    else:
        embed = P(None, None)

    specs: Dict[str, Any] = {
        "embed": embed,
        "final_norm": _norm(),
        # stacked block params get a leading None for the scan dim
        "blocks": jax.tree.map(lambda s: P(*((None,) + tuple(s))),
                               block_specs(),
                               is_leaf=lambda x: isinstance(x, P)),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = _col(tp, cfg.vocab)
    if cfg.encoder_layers:
        enc = {
            "norm0": _norm(),
            "attn0": {"wq": _col(tp, cfg.n_heads * hd),
                      "wk": _col(tp, cfg.n_kv_heads * hd),
                      "wv": _col(tp, cfg.n_kv_heads * hd),
                      "wo": _row(tp, cfg.n_heads * hd)},
            "fnorm0": _norm(),
            "mlp0": _mlp_spec(cfg, tp, cfg.d_ff),
        }
        specs["enc_blocks"] = jax.tree.map(
            lambda s: P(*((None,) + tuple(s))), enc,
            is_leaf=lambda x: isinstance(x, P))
        specs["enc_norm"] = _norm()
    if cfg.vision_tokens:
        specs["vis_proj"] = P(None, None)
    if cfg.mtp:
        specs["mtp"] = {"norm": _norm(), "proj": P(None, None)}
    return specs


def usable_data_axes(mesh: Mesh, batch: Optional[int]
                     ) -> Tuple[str, ...]:
    """Data axes whose product divides the batch (else drop axes from the
    left: long_500k's single request replicates over the batch axes)."""
    dp = data_axes_of(mesh)
    if batch is None:
        return dp
    while dp and batch % int(np.prod([mesh.shape[a] for a in dp])):
        dp = dp[1:]
    return dp


def batch_specs(cfg: ArchConfig, mesh: Mesh,
                batch: Optional[int] = None) -> Dict[str, P]:
    dp = usable_data_axes(mesh, batch)
    out = {"tokens": P(dp, None)}
    if cfg.encoder_layers:
        out["frames"] = P(dp, None, None)
    if cfg.vision_tokens:
        out["patches"] = P(dp, None, None)
    return out


def decode_state_specs(cfg: ArchConfig, mesh: Mesh,
                       batch: Optional[int] = None) -> Dict[str, Any]:
    """Specs for ``transformer.init_decode_state`` pytrees."""
    dp = usable_data_axes(mesh, batch)
    tp = _tp(mesh)
    choice = head_sharding_choice(cfg, mesh)
    if cfg.mla is not None:
        attn_spec = {"c_kv": P(None, dp, None, None),
                     "k_rope": P(None, dp, None, None, None)}
    elif choice == "heads":
        attn_spec = {"k": P(None, dp, None, MODEL_AXIS, None),
                     "v": P(None, dp, None, MODEL_AXIS, None)}
    elif choice == "head_dim":
        attn_spec = {"k": P(None, dp, None, None, MODEL_AXIS),
                     "v": P(None, dp, None, None, MODEL_AXIS)}
    else:
        attn_spec = {"k": P(None, dp, None, None, None),
                     "v": P(None, dp, None, None, None)}
    caches: Dict[str, Any] = {}
    for i, ch in enumerate(cfg.block_pattern):
        if ch == "A":
            caches[f"attn{i}"] = attn_spec
        else:
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            nh = d_in // s.head_dim
            caches[f"ssm{i}"] = {
                "h": P(None, dp, MODEL_AXIS if nh % tp == 0 else None,
                       None, None),
                "conv": P(None, dp, None, None),
            }
    out = {"caches": caches, "pos": P()}
    if cfg.encoder_layers:
        out["enc"] = P(dp, None, None)
    return out


def opt_state_specs(pspecs: Any) -> Dict[str, Any]:
    """AdamW state mirrors the parameter sharding."""
    return {"m": pspecs, "v": pspecs, "step": P()}


def fsdp_specs(specs: Any, abstract_params: Any, mesh: Mesh) -> Any:
    """§Perf knob (ZeRO-3-style): additionally shard each parameter's
    largest still-replicated dimension over the data axis.  XLA inserts
    the per-layer all-gathers / grad reduce-scatters; capacity drops by
    ~the data-axis size."""
    daxes = data_axes_of(mesh)
    if not daxes:
        return specs
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))

    def up(spec, p):
        dims = p.shape
        if len(dims) < 2:
            return spec
        best = None
        for i, ax in enumerate(tuple(spec) + (None,) * (len(dims)
                                                        - len(spec))):
            if ax is None and dims[i] % dsize == 0:
                if best is None or dims[i] > dims[best]:
                    best = i
        if best is None:
            return spec
        new = list(tuple(spec) + (None,) * (len(dims) - len(spec)))
        new[best] = daxes if len(daxes) > 1 else daxes[0]
        return P(*new)

    return jax.tree.map(up, specs, abstract_params,
                        is_leaf=lambda x: isinstance(x, P))


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))

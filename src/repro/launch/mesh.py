"""Production mesh construction.

A function, not a module constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

try:                              # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:               # jax 0.4.x: meshes are Auto-only
    AxisType = None

__all__ = ["make_production_mesh", "make_mesh", "data_axes_of",
           "MODEL_AXIS"]

MODEL_AXIS = "model"


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips).

    Batch shards over ("pod", "data"); weights/experts/vocab over
    "model".  The dry-run proves both lower + compile for every
    (architecture x input shape).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def data_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)

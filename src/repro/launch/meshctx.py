"""Ambient mesh context.

The model layer is mesh-agnostic; the launcher activates a mesh context
so layers that have a distributed implementation (MoE expert parallelism)
can pick it up without threading mesh objects through every call.
"""

from __future__ import annotations

import contextlib
from typing import Optional, Tuple

from jax.sharding import Mesh

__all__ = ["MeshCtx", "set_mesh", "current", "use_mesh"]


class MeshCtx:
    def __init__(self, mesh: Mesh, data_axes: Tuple[str, ...],
                 model_axis: str = "model") -> None:
        self.mesh = mesh
        self.data_axes = data_axes
        self.model_axis = model_axis


_CURRENT: Optional[MeshCtx] = None


def set_mesh(mesh: Optional[Mesh],
             data_axes: Tuple[str, ...] = ("data",),
             model_axis: str = "model") -> None:
    global _CURRENT
    _CURRENT = None if mesh is None else MeshCtx(mesh, data_axes,
                                                 model_axis)


def current() -> Optional[MeshCtx]:
    return _CURRENT


@contextlib.contextmanager
def use_mesh(mesh: Mesh, data_axes: Tuple[str, ...] = ("data",),
             model_axis: str = "model"):
    global _CURRENT
    prev = _CURRENT
    set_mesh(mesh, data_axes, model_axis)
    try:
        yield
    finally:
        _CURRENT = prev

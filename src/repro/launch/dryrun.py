import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512"
                           ).strip()

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell, build the production mesh
(16x16 single-pod / 2x16x16 two-pod), lower the appropriate step
(train_step for train shapes, prefill/decode for serving shapes) with
its in/out shardings, ``.compile()`` it, and record:

* ``compiled.memory_analysis()``  — per-chip argument/output/temp bytes
  (proves the cell fits, or quantifies by how much it doesn't);
* ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline;
* collective payload bytes parsed from the optimized HLO;
* lower/compile wall time.

Results accumulate in a JSON cache (one entry per cell x mesh) that the
roofline benchmark and EXPERIMENTS.md tables read.

Usage::

    python -m repro.launch.dryrun --arch phi3-medium-14b --shape train_4k
    python -m repro.launch.dryrun --all [--multipod] [--out FILE]
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCHS, STANDARD_SHAPES, cell_skip_reason
from repro.configs.base import depth_variant
from repro.launch import analysis, meshctx, steps
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import usable_data_axes
from repro.models import analysis_flags

DEFAULT_OUT = "results/dryrun.json"


def _build_step(cfg, shape, mesh):
    if shape.kind == "train":
        return steps.make_train_step(cfg, mesh, shape)
    if shape.kind == "prefill":
        return steps.make_prefill_step(cfg, mesh, shape)
    return steps.make_decode_step(cfg, mesh, shape)


def _cost_of(compiled) -> Dict:
    cost = compiled.cost_analysis() or {}
    out = {k: float(v) for k, v in cost.items()
           if isinstance(v, (int, float))
           and k in ("flops", "bytes accessed", "transcendentals")}
    out["collectives"] = analysis.collective_bytes(compiled.as_text())
    return out


def probe_corrected(cfg, shape, mesh, dp) -> Dict:
    """Reconstruct true per-step cost: XLA counts while bodies once, so
    compile fully-unrolled depth-1/-2 variants and extrapolate
    ``X(1) + (n_blocks - 1)(X(2) - X(1))``.

    Two probe flavors (models/analysis_flags): naive attention for exact
    FLOPs; flash-path for bytes + collectives, with the flash streaming
    traffic (counted once by XLA) added back analytically
    (analysis.flash_addons).
    """
    from repro.launch.mesh import MODEL_AXIS
    from repro.launch.sharding import head_sharding_choice

    def run_probe(naive: bool) -> Dict[int, Dict]:
        out = {}
        for k in (1, 2):
            cfg_k = depth_variant(cfg, k)
            with analysis_flags.probe_mode(unroll=k,
                                           naive_attention=naive), \
                    meshctx.use_mesh(mesh, data_axes=dp):
                fn, abstract = _build_step(cfg_k, shape, mesh)
                out[k] = _cost_of(fn.lower(*abstract).compile())
        return out

    nb = cfg.n_blocks

    def extrap(probes, key):
        x1, x2 = probes[1].get(key, 0.0), probes[2].get(key, 0.0)
        return max(x1 + (nb - 1) * (x2 - x1), 0.0)

    pa = run_probe(naive=True)           # exact FLOPs
    pb = run_probe(naive=False)          # flash bytes + collectives
    coll = {}
    for kind in pb[1]["collectives"]:
        c1 = pb[1]["collectives"][kind]
        c2 = pb[2]["collectives"][kind]
        coll[kind] = max(0, int(c1 + (nb - 1) * (c2 - c1)))

    tp = mesh.shape[MODEL_AXIS]
    from repro.launch import tuning
    if tuning.FLAGS["attn_seq_parallel"]:
        choice = "sequence"
    else:
        choice = head_sharding_choice(cfg, mesh)
    extra_hbm, extra_link = analysis.flash_addons(
        cfg, shape, mesh.size, tp, choice)
    return {
        "flops": extrap(pa, "flops"),
        "bytes accessed": extrap(pb, "bytes accessed") + extra_hbm,
        "collectives": coll,
        "flash_extra_hbm": extra_hbm,
        "flash_extra_link": extra_link,
        "head_sharding": choice,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             hw: analysis.HW = analysis.HW()) -> Dict:
    cfg = ARCHS[arch]
    shape = STANDARD_SHAPES[shape_name]
    skip = cell_skip_reason(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    rec: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": "x".join(str(s) for s in mesh.devices.shape),
                 "n_chips": n_chips, "kind": shape.kind}
    t0 = time.time()
    dp = usable_data_axes(mesh, shape.global_batch)
    with meshctx.use_mesh(mesh, data_axes=dp):
        fn, abstract = _build_step(cfg, shape, mesh)
        lowered = fn.lower(*abstract)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    if mem is not None:
        rec["memory"] = {
            "argument_gib": getattr(mem, "argument_size_in_bytes", 0)
            / 2**30,
            "output_gib": getattr(mem, "output_size_in_bytes", 0) / 2**30,
            "temp_gib": getattr(mem, "temp_size_in_bytes", 0) / 2**30,
            "alias_gib": getattr(mem, "alias_size_in_bytes", 0) / 2**30,
        }
        live = (rec["memory"]["argument_gib"] + rec["memory"]["output_gib"]
                + rec["memory"]["temp_gib"]
                - rec["memory"]["alias_gib"])
        rec["memory"]["live_gib"] = live
        rec["memory"]["fits_16g"] = bool(live <= hw.hbm_bytes / 2**30)
    cost = compiled.cost_analysis() or {}
    rec["cost_raw"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and
                       k in ("flops", "bytes accessed",
                             "transcendentals")}
    rec["collectives_raw"] = analysis.collective_bytes(compiled.as_text())

    # corrected per-step cost from the unrolled depth probes (bounded:
    # pathological probe compiles degrade to raw uncorrected numbers)
    import signal

    def _alarm(signum, frame):
        raise TimeoutError("probe compile budget exceeded")

    t2 = time.time()
    old = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(int(os.environ.get("PROBE_TIMEOUT_S", "900")))
    try:
        probe = probe_corrected(cfg, shape, mesh, dp)
    except TimeoutError:
        probe = None
        rec["note"] = ("probe-corrected roofline omitted: probe compile "
                       "exceeded budget; raw (while-body-once) numbers "
                       "reported")
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)
    rec["probe_s"] = round(time.time() - t2, 1)
    if probe is not None:
        rec["cost"] = {"flops": probe["flops"],
                       "bytes accessed": probe["bytes accessed"]}
        rec["collectives"] = probe["collectives"]
        rec["head_sharding"] = probe["head_sharding"]
        rec["flash_extra"] = {"hbm": probe["flash_extra_hbm"],
                              "link": probe["flash_extra_link"]}
        extra_link = probe["flash_extra_link"]
    else:
        rec["cost"] = dict(rec["cost_raw"])
        rec["collectives"] = dict(rec["collectives_raw"])
        extra_link = 0.0
    terms = analysis.roofline_terms(
        rec["cost"], rec["collectives"], hw,
        extra_link_bytes=extra_link)
    rec["roofline"] = terms.as_dict()
    mf = analysis.model_flops(cfg, shape, n_chips)
    rec["model_flops"] = mf
    rec["useful_flops_frac"] = (mf / terms.flops) if terms.flops else None
    rec["status"] = "ok"
    return rec


def _load(path: str) -> Dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def _save(path: str, data: Dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true",
                    help="2x16x16 two-pod mesh (default single-pod 16x16)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--force", action="store_true",
                    help="recompute cached cells")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(STANDARD_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multipod]
    if not (args.all or args.arch or args.shape):
        ap.error("pass --all or --arch/--shape")

    results = _load(args.out)
    failures = 0
    for multi in meshes:
        for a in archs:
            for s in shapes:
                key = f"{a}|{s}|{'2pod' if multi else '1pod'}"
                if key in results and not args.force \
                        and results[key].get("status") in ("ok", "skipped"):
                    print(f"[cached] {key}")
                    continue
                print(f"[run] {key} ...", flush=True)
                try:
                    rec = run_cell(a, s, multi)
                except Exception as e:           # noqa: BLE001
                    rec = {"arch": a, "shape": s, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    failures += 1
                results[key] = rec
                _save(args.out, results)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']} "
                             f"compute={r['compute_s']:.3g}s "
                             f"mem={r['memory_s']:.3g}s "
                             f"coll={r['collective_s']:.3g}s "
                             f"(lower {rec['lower_s']}s, "
                             f"compile {rec['compile_s']}s)")
                print(f"  -> {status}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Synthetic-but-structured token stream (deterministic, resumable)."""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from ..configs.base import ArchConfig

__all__ = ["make_batch", "SyntheticStream"]


def _rng_for(seed: int, step: int, shard: int) -> np.random.Generator:
    # Philox counter-style determinism: independent of visit order
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))


def make_batch(cfg: ArchConfig, batch: int, seq: int, *, seed: int,
               step: int, shard: int = 0,
               n_shards: int = 1) -> Dict[str, np.ndarray]:
    """One (host-)shard of the global batch for a given step.

    Tokens follow a Zipfian-ish distribution with short-range structure
    (repeated n-grams) so losses behave like language data rather than
    white noise.
    """
    rng = _rng_for(seed, step, shard)
    b = batch // n_shards
    zipf = rng.zipf(1.3, size=(b, seq)).astype(np.int64)
    tokens = (zipf % (cfg.vocab - 2)) + 1
    # inject short-range structure: repeat the previous token with p=0.15
    rep = rng.random((b, seq)) < 0.15
    tokens[:, 1:] = np.where(rep[:, 1:], tokens[:, :-1], tokens[:, 1:])
    out: Dict[str, np.ndarray] = {"tokens": tokens.astype(np.int32)}
    if cfg.encoder_layers:
        out["frames"] = rng.standard_normal(
            (b, cfg.encoder_seq, cfg.d_model)).astype(np.float32) * 0.02
    if cfg.vision_tokens:
        out["patches"] = rng.standard_normal(
            (b, cfg.vision_tokens, cfg.d_model)).astype(np.float32) * 0.02
    return out


class SyntheticStream:
    """Resumable iterator with a background prefetch thread."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, *,
                 seed: int = 0, start_step: int = 0, shard: int = 0,
                 n_shards: int = 1, prefetch: int = 2) -> None:
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.step = start_step
        self.shard = shard
        self.n_shards = n_shards
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._next_produce = start_step
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self) -> None:
        while not self._stop.is_set():
            b = make_batch(self.cfg, self.batch, self.seq, seed=self.seed,
                           step=self._next_produce, shard=self.shard,
                           n_shards=self.n_shards)
            self._q.put((self._next_produce, b))
            self._next_produce += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, b = self._q.get()
        self.step = step + 1
        return b

    # -- checkpoint integration ----------------------------------------------

    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.seed}

    @classmethod
    def restore(cls, cfg: ArchConfig, batch: int, seq: int,
                state: Dict[str, int], **kw) -> "SyntheticStream":
        return cls(cfg, batch, seq, seed=state["seed"],
                   start_step=state["step"], **kw)

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

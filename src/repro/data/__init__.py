"""Deterministic synthetic data pipeline.

Batches are a pure function of ``(seed, step, shard)`` — restart from a
checkpointed step index reproduces the exact stream (the fault-tolerance
story depends on this).  Host-side numpy generation, double-buffered
prefetch thread, per-modality extras (frames / patches) matching each
architecture's ``input_specs``.
"""

from .pipeline import SyntheticStream, make_batch

__all__ = ["SyntheticStream", "make_batch"]

"""Symmetric INT8 quantization."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["QTensor", "quantize_tensor", "dequantize", "quantize_tree",
           "fake_quant"]


@dataclass
class QTensor:
    q: jax.Array            # int8
    scale: jax.Array        # () or (channels,)
    axis: Optional[int]     # channel axis, None = per-tensor

    @property
    def shape(self):
        return self.q.shape


def quantize_tensor(x: jax.Array, axis: Optional[int] = None) -> QTensor:
    """Symmetric int8: scale = max|x| / 127 (per tensor or per channel)."""
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        red = tuple(i for i in range(x.ndim) if i != axis)
        amax = jnp.max(jnp.abs(x), axis=red, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
    return QTensor(q=q, scale=jnp.squeeze(scale) if axis is None
                   else scale, axis=axis)


def dequantize(t: QTensor) -> jax.Array:
    s = t.scale
    if t.axis is not None and s.ndim != t.q.ndim:
        shape = [1] * t.q.ndim
        shape[t.axis] = -1
        s = s.reshape(shape)
    return t.q.astype(jnp.float32) * s


def fake_quant(x: jax.Array, axis: Optional[int] = None) -> jax.Array:
    """Straight-through quantize-dequantize (QAT forward)."""
    y = dequantize(quantize_tensor(x, axis))
    return x + jax.lax.stop_gradient(y - x)


def quantize_tree(params: Any, axis: Optional[int] = None):
    """Quantize every float leaf of a pytree; ints pass through."""
    def q(x):
        if jnp.issubdtype(x.dtype, jnp.floating) and x.ndim >= 2:
            return quantize_tensor(x, axis)
        return x
    return jax.tree.map(q, params)

"""INT8 quantization utilities (paper §IV-A: weights/activations INT8).

Bridges the JAX models to the CIM arithmetic model: symmetric per-tensor
or per-channel weight quantization, activation calibration, and a
drop-in quantized linear (backed by the bit-serial Pallas kernel or the
direct INT8 MXU path) for QAT / INT8 serving.
"""

from .quantize import (QTensor, dequantize, fake_quant, quantize_tensor,
                       quantize_tree)

__all__ = ["QTensor", "quantize_tensor", "quantize_tree", "dequantize",
           "fake_quant"]

"""Sharded-friendly functional optimizer (AdamW) + schedules.

Plain pytree-in/pytree-out so it composes with ``jax.jit`` shardings:
optimizer state mirrors the parameter tree (ZeRO-style sharding of the
state falls out of giving it the same PartitionSpecs as the params, or
data-axis specs for fully sharded states).  ``moment_dtype`` lets huge
models keep moments in bf16 (recorded in DESIGN.md — the 671B config
cannot hold fp32 moments on a 256-chip pod).
"""

from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .schedule import cosine_warmup

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "cosine_warmup"]

"""AdamW with global-norm clipping and configurable moment dtype."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"       # bf16 for memory-bound configs


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)        # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, state, params, lr: jax.Array,
                 cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state["step"] + 1
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm}

"""Learning-rate schedules (pure functions of the step)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_warmup"]


def cosine_warmup(step, *, peak: float, warmup: int, total: int,
                  floor: float = 0.1):
    """Linear warmup to ``peak`` then cosine decay to ``floor * peak``."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = floor * peak + (1 - floor) * peak * 0.5 \
        * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, cos)

"""Straggler detection over per-host step timings.

Robust z-score (median / MAD) across hosts within a step window: a host
whose step time persistently exceeds ``median + k * MAD`` is flagged.
Mitigation hooks: the launcher can demote the host (elastic re-mesh) or
enable gradient-skip for it (documented in launch/train.py).
"""

from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Dict, List

__all__ = ["StragglerDetector"]


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


@dataclass
class StragglerDetector:
    k: float = 4.0                 # MAD multiplier
    window: int = 16               # steps of history per host
    min_hits: int = 3              # consecutive flags before reporting
    _hist: Dict[str, deque] = field(
        default_factory=lambda: defaultdict(lambda: deque(maxlen=16)))
    _hits: Dict[str, int] = field(default_factory=lambda: defaultdict(int))

    def record_step(self, times: Dict[str, float]) -> List[str]:
        """Feed one step's per-host durations; returns flagged hosts."""
        med = _median(list(times.values()))
        mad = _median([abs(t - med) for t in times.values()]) or 1e-9
        flagged = []
        for host, t in times.items():
            self._hist[host].append(t)
            z = (t - med) / (1.4826 * mad)
            if z > self.k:
                self._hits[host] += 1
            else:
                self._hits[host] = 0
            if self._hits[host] >= self.min_hits:
                flagged.append(host)
        return flagged

    def chronic(self) -> List[str]:
        return [h for h, c in self._hits.items() if c >= self.min_hits]

"""Distributed-runtime substrate: failure detection, elastic re-meshing,
straggler mitigation.  All components are device-free and CPU-testable;
the launcher wires them to real heartbeats / step timings."""

from .fault import FailureDetector, HeartbeatRegistry
from .elastic import ElasticPlan, plan_remesh
from .straggler import StragglerDetector

__all__ = ["FailureDetector", "HeartbeatRegistry", "ElasticPlan",
           "plan_remesh", "StragglerDetector"]

"""Heartbeat-based failure detection.

Hosts publish monotonic heartbeats; the detector flags nodes whose last
beat is older than ``timeout``.  φ-accrual-lite: the timeout adapts to
the observed inter-beat distribution (mean + k·std), so slow-but-alive
networks do not trigger false evictions.
"""

from __future__ import annotations

import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

__all__ = ["HeartbeatRegistry", "FailureDetector"]


class HeartbeatRegistry:
    """Last-seen timestamps + inter-arrival history per node."""

    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 history: int = 32) -> None:
        self._clock = clock
        self._last: Dict[str, float] = {}
        self._gaps: Dict[str, deque] = defaultdict(
            lambda: deque(maxlen=history))

    def beat(self, node: str) -> None:
        now = self._clock()
        if node in self._last:
            self._gaps[node].append(now - self._last[node])
        self._last[node] = now

    def nodes(self) -> List[str]:
        return sorted(self._last)

    def age(self, node: str) -> float:
        return self._clock() - self._last[node]

    def gap_stats(self, node: str):
        g = self._gaps[node]
        if not g:
            return None
        mean = sum(g) / len(g)
        var = sum((x - mean) ** 2 for x in g) / len(g)
        return mean, var ** 0.5


@dataclass
class FailureDetector:
    """Flags nodes as failed when heartbeat age exceeds the adaptive
    threshold ``max(min_timeout, mean + k * std)``."""

    registry: HeartbeatRegistry
    min_timeout: float = 10.0
    k: float = 6.0
    on_failure: Optional[Callable[[str], None]] = None
    _failed: Set[str] = field(default_factory=set)

    def check(self) -> List[str]:
        newly = []
        for node in self.registry.nodes():
            if node in self._failed:
                continue
            stats = self.registry.gap_stats(node)
            thresh = self.min_timeout
            if stats is not None:
                mean, std = stats
                thresh = max(self.min_timeout, mean + self.k * std)
            if self.registry.age(node) > thresh:
                self._failed.add(node)
                newly.append(node)
                if self.on_failure:
                    self.on_failure(node)
        return newly

    @property
    def failed(self) -> Set[str]:
        return set(self._failed)

    def alive(self) -> List[str]:
        return [n for n in self.registry.nodes()
                if n not in self._failed]

    def revive(self, node: str) -> None:
        """Node rejoined after elastic scale-up."""
        self._failed.discard(node)

"""Elastic re-meshing after node loss / join.

Given the surviving chip count and the model's parallelism needs, pick a
new ``(pod, data, model)`` mesh and the training adjustments (gradient-
accumulation factor to preserve global batch).  The model axis is kept at
its configured size whenever the survivor count allows — re-sharding the
model axis means re-partitioning weights, which is far more expensive
than shrinking the data axis.

This mirrors the CIMFlow planner's capacity logic (a chip's HBM must hold
its parameter + optimizer-state shard); `repro.core.planner` supplies the
per-arch byte estimates.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

__all__ = ["ElasticPlan", "plan_remesh"]


@dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]          # (data, model) or (pod, data, model)
    axis_names: Tuple[str, ...]
    chips_used: int
    chips_idle: int
    grad_accum: int                      # to preserve the global batch
    reason: str


def _divisors_desc(n: int) -> List[int]:
    return [d for d in range(n, 0, -1) if n % d == 0]


def plan_remesh(surviving_chips: int, *, model_parallel: int,
                target_data_parallel: int,
                min_model_parallel: Optional[int] = None) -> ElasticPlan:
    """Largest usable (data x model) grid from the survivors.

    Keeps ``model_parallel`` if possible; otherwise falls back to the
    largest power-of-two model axis >= ``min_model_parallel`` that still
    fits.  Idle chips (remainder) become hot spares.
    """
    min_mp = min_model_parallel or model_parallel
    best: Optional[ElasticPlan] = None
    mp = model_parallel
    while mp >= 1:
        if mp >= min_mp and surviving_chips >= mp:
            dp = surviving_chips // mp
            used = dp * mp
            accum = max(1, math.ceil(target_data_parallel / dp))
            plan = ElasticPlan(
                mesh_shape=(dp, mp), axis_names=("data", "model"),
                chips_used=used, chips_idle=surviving_chips - used,
                grad_accum=accum,
                reason=(f"kept model axis {mp}" if mp == model_parallel
                        else f"shrunk model axis {model_parallel}->{mp}"))
            if best is None or plan.chips_used > best.chips_used:
                best = plan
            if mp == model_parallel:
                break                      # prefer the configured axis
        mp //= 2
    if best is None:
        raise ValueError(
            f"{surviving_chips} chips cannot host model_parallel>="
            f"{min_mp}")
    return best

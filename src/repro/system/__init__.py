"""repro.system — mesh-of-chips scale-out above the single-chip stack.

One chip is the unit of everything below this package; here a
:class:`SystemConfig` arranges identical chips in a 2D mesh joined by
an inter-chip link tier (priced, like every other timing rule, by
:class:`repro.core.machine.MachineModel`), and the system partitioners
split one workload across the mesh:

* ``pipeline`` — contiguous stage ranges per chip, cut-crossing
  activations as SEND/RECV link transfers (full fidelity ladder,
  including bit-exact func mode via :meth:`SystemArtifact.run_func`);
* ``tensor`` — per-group weight sharding with ring collectives
  (analytic + trace fidelities).

Entry point: ``repro.flow.compile(workload, chip, system=cfg)`` — the
``system=`` keyword routes through the ``system:<mode>`` passes and
returns a :class:`SystemArtifact`.  Importing this package registers
those passes.
"""

from .artifact import FuncRunResult, SystemArtifact
from .config import PARALLEL_MODES, SystemConfig
from .evaluate import SystemReport, evaluate_plan
from .partition import (ChipSlice, Collective, SystemPlan,
                        SystemPlanError, Transfer, shard_tensor,
                        split_pipeline)
from . import passes as _passes            # noqa: F401  (registers passes)

__all__ = [
    "SystemConfig", "PARALLEL_MODES",
    "SystemPlan", "ChipSlice", "Transfer", "Collective",
    "SystemPlanError", "split_pipeline", "shard_tensor",
    "SystemArtifact", "FuncRunResult",
    "SystemReport", "evaluate_plan",
]

"""System-level structural description: a 2D mesh of chips.

:class:`SystemConfig` is to the *system* what
:class:`~repro.core.arch.ChipConfig` is to one chip: pure structure —
how many chips, how they are arranged, which inter-chip link tier ties
them together, and how many of each chip's global-memory ports are
reserved for off-chip ("boundary") traffic.  Every timing/energy rule
for those links lives in :class:`~repro.core.machine.InterChipLink` /
the :class:`~repro.core.machine.MachineModel` accessors — this module
deliberately contains no constants of its own.

Pipeline-parallel plans place consecutive stages on consecutive chips
of a *snake* ordering of the mesh, so adjacent stages are one hop
apart; transfers between non-adjacent stages pay the Manhattan
distance between their chips' mesh coordinates.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, Iterable, Mapping, Tuple, Union

from ..core.machine import InterChipLink, link_tier

__all__ = ["SystemConfig", "PARALLEL_MODES"]

PARALLEL_MODES = ("pipeline", "tensor")


@dataclass(frozen=True)
class SystemConfig:
    """A mesh of identical chips plus the link tier joining them.

    ``parallel`` selects the system-level partitioner: ``pipeline``
    (contiguous stage ranges per chip, SEND/RECV at the cuts) or
    ``tensor`` (every MVM group sharded across all chips, collectives
    at shard boundaries).  ``boundary_ports`` caps how many of a chip's
    gmem ports an inter-chip transfer may drain through — the
    contention model of :meth:`MachineModel.interchip_transfer_cycles`.

    ``failed_chips`` / ``failed_links`` mark dead mesh slots / directed
    link pairs (stored as sorted slot pairs): the partitioners place
    work on the surviving slots only and :meth:`hops` routes around the
    failures (BFS over the live grid).  Both default empty — a
    fault-free config is bit-identical to one predating the fields, in
    behaviour *and* in :meth:`to_dict` (so cached plans keep their
    keys).
    """

    chips_x: int = 1
    chips_y: int = 1
    link: Union[InterChipLink, str] = "pcb"
    boundary_ports: int = 2
    parallel: str = "pipeline"
    failed_chips: Tuple[int, ...] = ()
    failed_links: Tuple[Tuple[int, int], ...] = ()

    def __post_init__(self) -> None:
        if self.chips_x < 1 or self.chips_y < 1:
            raise ValueError(f"mesh dims must be >= 1, got "
                             f"{self.chips_x}x{self.chips_y}")
        if isinstance(self.link, str):
            object.__setattr__(self, "link", link_tier(self.link))
        if not isinstance(self.link, InterChipLink):
            raise TypeError(f"link must be an InterChipLink or tier "
                            f"name, got {type(self.link).__name__}")
        if self.boundary_ports < 1:
            raise ValueError("boundary_ports must be >= 1")
        if self.parallel not in PARALLEL_MODES:
            raise ValueError(f"parallel must be one of {PARALLEL_MODES},"
                             f" got {self.parallel!r}")
        n = self.chips_x * self.chips_y
        fc = tuple(sorted({int(c) for c in self.failed_chips}))
        fl = tuple(sorted({tuple(sorted((int(a), int(b))))
                           for a, b in self.failed_links}))
        for c in fc:
            if not 0 <= c < n:
                raise ValueError(f"failed chip slot {c} out of range "
                                 f"0..{n - 1}")
        for a, b in fl:
            if not (0 <= a < n and 0 <= b < n) or a == b:
                raise ValueError(f"failed link ({a}, {b}) is not a "
                                 f"pair of distinct slots in 0..{n - 1}")
        if len(fc) >= n:
            raise ValueError("all chips failed — nothing left to plan on")
        object.__setattr__(self, "failed_chips", fc)
        object.__setattr__(self, "failed_links", fl)

    # -- derived -----------------------------------------------------------

    @property
    def n_chips(self) -> int:
        return self.chips_x * self.chips_y

    def coord(self, slot: int) -> Tuple[int, int]:
        """Mesh (row, col) of logical chip ``slot`` in snake order —
        slot ``k`` and ``k+1`` are always mesh neighbours."""
        if not 0 <= slot < self.n_chips:
            raise IndexError(f"chip slot {slot} out of range "
                             f"0..{self.n_chips - 1}")
        row, r = divmod(slot, self.chips_x)
        col = r if row % 2 == 0 else self.chips_x - 1 - r
        return row, col

    def hops(self, a: int, b: int) -> int:
        """Hop distance between two logical chip slots.

        Fault-free meshes use the closed-form Manhattan distance.
        With failures present, the distance is a BFS over the
        surviving grid (failed chips cannot route through, failed
        links are cut); an unreachable pair raises — the mesh has
        partitioned and no plan can span it.
        """
        ra, ca = self.coord(a)
        rb, cb = self.coord(b)
        if not self.failed_chips and not self.failed_links:
            return abs(ra - rb) + abs(ca - cb)
        if a in self.failed_chips or b in self.failed_chips:
            raise ValueError(f"hops({a}, {b}): endpoint is a failed chip")
        if a == b:
            return 0
        dead_links = set(self.failed_links)
        dead = set(self.failed_chips)
        dist = {a: 0}
        q = deque([a])
        while q:
            s = q.popleft()
            for t in self._grid_neighbors(s):
                if t in dist or t in dead:
                    continue
                if tuple(sorted((s, t))) in dead_links:
                    continue
                dist[t] = dist[s] + 1
                if t == b:
                    return dist[t]
                q.append(t)
        raise ValueError(
            f"hops({a}, {b}): mesh partitioned by failures "
            f"(chips {self.failed_chips}, links {self.failed_links})")

    def _slot_at(self, row: int, col: int) -> int:
        """Inverse of :meth:`coord` (snake ordering)."""
        r = col if row % 2 == 0 else self.chips_x - 1 - col
        return row * self.chips_x + r

    def _grid_neighbors(self, slot: int) -> Iterable[int]:
        r, c = self.coord(slot)
        for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            rr, cc = r + dr, c + dc
            if 0 <= rr < self.chips_y and 0 <= cc < self.chips_x:
                yield self._slot_at(rr, cc)

    @property
    def alive_slots(self) -> Tuple[int, ...]:
        """Surviving chip slots in snake order — the slots the
        partitioners place work on."""
        dead = set(self.failed_chips)
        return tuple(s for s in range(self.n_chips) if s not in dead)

    @property
    def n_alive(self) -> int:
        return self.n_chips - len(self.failed_chips)

    def degrade(self, failed_chips: Iterable[int] = (),
                failed_links: Iterable[Tuple[int, int]] = ()
                ) -> "SystemConfig":
        """This config with additional failures folded in (union with
        any already present) — the mesh-failover entry point used by
        :class:`repro.faults.FaultModel`-driven sweeps."""
        return dataclasses.replace(
            self,
            failed_chips=self.failed_chips + tuple(failed_chips),
            failed_links=self.failed_links + tuple(
                tuple(l) for l in failed_links))

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "chips_x": self.chips_x, "chips_y": self.chips_y,
            "link": self.link.to_dict(),
            "boundary_ports": self.boundary_ports,
            "parallel": self.parallel}
        # only serialized when present: a fault-free config's dict (and
        # hence every derived cache key) is byte-identical to the
        # pre-failover format
        if self.failed_chips:
            out["failed_chips"] = list(self.failed_chips)
        if self.failed_links:
            out["failed_links"] = [list(l) for l in self.failed_links]
        return out

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SystemConfig":
        link = d.get("link", "pcb")
        if isinstance(link, Mapping):
            link = InterChipLink.from_dict(link)
        return cls(chips_x=int(d.get("chips_x", 1)),
                   chips_y=int(d.get("chips_y", 1)), link=link,
                   boundary_ports=int(d.get("boundary_ports", 2)),
                   parallel=str(d.get("parallel", "pipeline")),
                   failed_chips=tuple(d.get("failed_chips", ())),
                   failed_links=tuple(tuple(l) for l in
                                      d.get("failed_links", ())))

    @classmethod
    def mesh(cls, n_chips: int, **kw: Any) -> "SystemConfig":
        """The squarest mesh holding ``n_chips`` (4 -> 2x2, 8 -> 2x4)."""
        n = int(n_chips)
        if n < 1:
            raise ValueError(f"n_chips must be >= 1, got {n_chips}")
        best = 1
        for c in range(1, int(n ** 0.5) + 1):
            if n % c == 0:
                best = c
        return cls(chips_x=n // best, chips_y=best, **kw)

    def describe(self) -> str:
        s = (f"system {self.chips_x}x{self.chips_y} chips, "
             f"{self.parallel}-parallel, link '{self.link.name}' "
             f"({self.link.bytes_per_cycle:g} B/cyc, "
             f"{self.link.hop_cycles} cyc/hop), "
             f"{self.boundary_ports} boundary ports")
        if self.failed_chips or self.failed_links:
            s += (f" [degraded: {len(self.failed_chips)} chip(s), "
                  f"{len(self.failed_links)} link(s) failed]")
        return s

"""System-level structural description: a 2D mesh of chips.

:class:`SystemConfig` is to the *system* what
:class:`~repro.core.arch.ChipConfig` is to one chip: pure structure —
how many chips, how they are arranged, which inter-chip link tier ties
them together, and how many of each chip's global-memory ports are
reserved for off-chip ("boundary") traffic.  Every timing/energy rule
for those links lives in :class:`~repro.core.machine.InterChipLink` /
the :class:`~repro.core.machine.MachineModel` accessors — this module
deliberately contains no constants of its own.

Pipeline-parallel plans place consecutive stages on consecutive chips
of a *snake* ordering of the mesh, so adjacent stages are one hop
apart; transfers between non-adjacent stages pay the Manhattan
distance between their chips' mesh coordinates.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Mapping, Tuple, Union

from ..core.machine import InterChipLink, link_tier

__all__ = ["SystemConfig", "PARALLEL_MODES"]

PARALLEL_MODES = ("pipeline", "tensor")


@dataclass(frozen=True)
class SystemConfig:
    """A mesh of identical chips plus the link tier joining them.

    ``parallel`` selects the system-level partitioner: ``pipeline``
    (contiguous stage ranges per chip, SEND/RECV at the cuts) or
    ``tensor`` (every MVM group sharded across all chips, collectives
    at shard boundaries).  ``boundary_ports`` caps how many of a chip's
    gmem ports an inter-chip transfer may drain through — the
    contention model of :meth:`MachineModel.interchip_transfer_cycles`.
    """

    chips_x: int = 1
    chips_y: int = 1
    link: Union[InterChipLink, str] = "pcb"
    boundary_ports: int = 2
    parallel: str = "pipeline"

    def __post_init__(self) -> None:
        if self.chips_x < 1 or self.chips_y < 1:
            raise ValueError(f"mesh dims must be >= 1, got "
                             f"{self.chips_x}x{self.chips_y}")
        if isinstance(self.link, str):
            object.__setattr__(self, "link", link_tier(self.link))
        if not isinstance(self.link, InterChipLink):
            raise TypeError(f"link must be an InterChipLink or tier "
                            f"name, got {type(self.link).__name__}")
        if self.boundary_ports < 1:
            raise ValueError("boundary_ports must be >= 1")
        if self.parallel not in PARALLEL_MODES:
            raise ValueError(f"parallel must be one of {PARALLEL_MODES},"
                             f" got {self.parallel!r}")

    # -- derived -----------------------------------------------------------

    @property
    def n_chips(self) -> int:
        return self.chips_x * self.chips_y

    def coord(self, slot: int) -> Tuple[int, int]:
        """Mesh (row, col) of logical chip ``slot`` in snake order —
        slot ``k`` and ``k+1`` are always mesh neighbours."""
        if not 0 <= slot < self.n_chips:
            raise IndexError(f"chip slot {slot} out of range "
                             f"0..{self.n_chips - 1}")
        row, r = divmod(slot, self.chips_x)
        col = r if row % 2 == 0 else self.chips_x - 1 - r
        return row, col

    def hops(self, a: int, b: int) -> int:
        """Manhattan distance between two logical chip slots."""
        ra, ca = self.coord(a)
        rb, cb = self.coord(b)
        return abs(ra - rb) + abs(ca - cb)

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {"chips_x": self.chips_x, "chips_y": self.chips_y,
                "link": self.link.to_dict(),
                "boundary_ports": self.boundary_ports,
                "parallel": self.parallel}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SystemConfig":
        link = d.get("link", "pcb")
        if isinstance(link, Mapping):
            link = InterChipLink.from_dict(link)
        return cls(chips_x=int(d.get("chips_x", 1)),
                   chips_y=int(d.get("chips_y", 1)), link=link,
                   boundary_ports=int(d.get("boundary_ports", 2)),
                   parallel=str(d.get("parallel", "pipeline")))

    @classmethod
    def mesh(cls, n_chips: int, **kw: Any) -> "SystemConfig":
        """The squarest mesh holding ``n_chips`` (4 -> 2x2, 8 -> 2x4)."""
        n = int(n_chips)
        if n < 1:
            raise ValueError(f"n_chips must be >= 1, got {n_chips}")
        best = 1
        for c in range(1, int(n ** 0.5) + 1):
            if n % c == 0:
                best = c
        return cls(chips_x=n // best, chips_y=best, **kw)

    def describe(self) -> str:
        return (f"system {self.chips_x}x{self.chips_y} chips, "
                f"{self.parallel}-parallel, link '{self.link.name}' "
                f"({self.link.bytes_per_cycle:g} B/cyc, "
                f"{self.link.hop_cycles} cyc/hop), "
                f"{self.boundary_ports} boundary ports")

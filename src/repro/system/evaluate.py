"""Multi-chip plan evaluation: stitch per-chip reports over the links.

One :class:`SystemReport` (an :class:`~repro.flow.backends.EvalReport`
subclass, so every existing consumer — serve, explore, benchmarks —
reads it unchanged) per evaluation:

* **pipeline mode** — ``cycles`` is the *fill* makespan of one batch
  through all chips (per-chip latencies + every cut transfer, priced
  gmem-port-contended on the configured link tier), while
  ``throughput_sps`` reflects pipelined steady state: the bottleneck
  chip's latency plus its incident transfers.  At trace fidelity the
  per-chip :class:`~repro.core.trace.TraceReport` replays are stitched
  (:meth:`TraceReport.stitch`) into one system-level trace.
* **tensor mode** — chips run the same stage sequence on shards, so
  ``cycles`` is the slowest chip plus the per-group collectives
  (ring all-gather / all-reduce, see
  :meth:`MachineModel.interchip_collective_cycles`).

Energy is the per-chip breakdown summed key-wise plus an ``interchip``
category priced from the plan's total link traffic at the tier's
pJ/byte — single-chip reports keep their exact historical shape (no
new zero-valued keys).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from ..core.machine import machine_for
from ..core.trace import TraceReport
from ..flow.backends import EvalReport, _throughput
from .partition import SystemPlan

__all__ = ["SystemReport", "evaluate_plan"]


@dataclass
class SystemReport(EvalReport):
    """One multi-chip evaluation (EvalReport shape + system extras)."""

    mode: str = "pipeline"
    n_chips: int = 1
    comm_cycles: float = 0.0           # inter-chip transfer/collective
    bottleneck_cycles: float = 0.0     # steady-state pipeline interval
    per_chip: List[EvalReport] = field(default_factory=list)
    # degraded-mode accounting: chips/links the plan routed around.
    # ``throughput_sps`` above IS the degraded throughput when these
    # are nonzero — chip-loss degradation curves read it directly.
    n_failed_chips: int = 0
    n_failed_links: int = 0

    @property
    def degraded(self) -> bool:
        return self.n_failed_chips > 0 or self.n_failed_links > 0

    def summary(self) -> str:
        s = (f"[{self.backend}/{self.mode}x{self.n_chips}] "
             f"{self.cycles:.0f} cycles "
             f"({self.comm_cycles:.0f} inter-chip), "
             f"{self.energy_total / 1e6:.3f} mJ, "
             f"{self.throughput_sps:.1f} samples/s "
             f"(batch={self.batch})")
        if self.degraded:
            s += (f" [degraded: -{self.n_failed_chips} chips, "
                  f"-{self.n_failed_links} links]")
        return s


def _merge_energy(reports: List[EvalReport],
                  interchip_nj: float) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for r in reports:
        for k, v in r.energy.items():
            if k == "total":
                continue
            out[k] = out.get(k, 0.0) + v
    if interchip_nj > 0:
        out["interchip"] = out.get("interchip", 0.0) + interchip_nj
    out["total"] = sum(out.values())
    return out


def evaluate_plan(plan: SystemPlan, chip: Any, reports: List[EvalReport],
                  batch: int, calibration: Any = None,
                  backend_name: str = "analytic") -> SystemReport:
    """Stitch per-chip backend reports into one system report."""
    t0 = time.perf_counter()
    sys = plan.system
    m = machine_for(chip, calibration)
    link, ports = sys.link, sys.boundary_ports
    n = plan.n_chips

    if plan.mode == "pipeline":
        incident = [0.0] * n
        comm = 0.0
        for t in plan.transfers:
            cyc = m.interchip_transfer_cycles(
                t.nbytes * batch, link, hops=t.hops, ports=ports)
            comm += cyc
            incident[t.src_chip] += cyc
            incident[t.dst_chip] += cyc
        cycles = sum(r.cycles for r in reports) + comm
        bottleneck = max(r.cycles + incident[i]
                         for i, r in enumerate(reports))
    else:                                      # tensor
        # collectives ring over the *participating* chips (== the mesh
        # size on a healthy system, fewer under failover)
        comm = sum(m.interchip_collective_cycles(
            c.nbytes * batch, link, n, kind=c.kind,
            ports=ports) for c in plan.collectives)
        cycles = max(r.cycles for r in reports) + comm
        bottleneck = cycles

    interchip_nj = m.interchip_energy_nj(plan.transfer_bytes(batch),
                                         link)
    stitched: Optional[TraceReport] = None
    if plan.mode == "pipeline" and all(r.trace is not None
                                       for r in reports):
        stitched = TraceReport.stitch([r.trace for r in reports],
                                      link_cycles=comm)
    return SystemReport(
        backend=backend_name, cycles=float(cycles),
        energy=_merge_energy(reports, interchip_nj),
        throughput_sps=_throughput(chip, bottleneck, batch),
        batch=batch, wall_s=time.perf_counter() - t0, trace=stitched,
        mode=plan.mode, n_chips=n, comm_cycles=float(comm),
        bottleneck_cycles=float(bottleneck), per_chip=list(reports),
        n_failed_chips=len(sys.failed_chips),
        n_failed_links=len(sys.failed_links))

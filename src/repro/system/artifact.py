"""The multi-chip compile artifact: per-chip artifacts + the plan.

``flow.compile(workload, chip, system=SystemConfig(...))`` returns a
:class:`SystemArtifact` instead of a plain
:class:`~repro.flow.pipeline.Artifact` — same ``evaluate`` /
``replace_options`` / ``describe`` surface, so serve and explore
consume it with no caller change.  Each chip slice is a *real*
single-chip artifact (full pass cache, full fidelity ladder); this
module only stitches.

Func mode is the one fidelity that cannot be a per-chip black box —
chips exchange activations — so it lives here as
:meth:`SystemArtifact.run_func`: chips execute **sequentially** on the
functional ISS, each cut-crossing output harvested from the producer
chip's gmem and concatenated into the consumer chip's input region.
The result is bit-exact with the single-chip oracle
(``repro.core.ref.run_reference`` on the unsplit graph) because every
slice is a verbatim op-copy sub-graph and the wire carries the exact
int8 blob codegen spilled (``force_boundary`` guarantees the spill).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple, Union

import numpy as np

from ..core.arch import ChipConfig
from ..core.codegen import GMEM_BASE, QuantParams, _compile_model
from ..core.graph import CondensedGraph
from ..core.simulator import SimReport, Simulator
from ..flow.backends import Backend, resolve_backend
from ..flow.options import CompileOptions
from ..flow.passes import PassRecord
from ..flow.pipeline import Artifact
from .config import SystemConfig
from .evaluate import SystemReport, evaluate_plan
from .partition import SystemPlan

__all__ = ["SystemArtifact", "FuncRunResult"]


@dataclass
class FuncRunResult:
    """One functional multi-chip run: harvested boundary blobs.

    ``outputs[gid]`` is the int8 ``(batch, nbytes)`` gmem blob of a
    harvested global group (every cut-transfer producer plus the
    final group); compare ``final`` against the single-chip oracle.
    """

    outputs: Dict[int, np.ndarray]
    final_gid: int
    reports: List[SimReport] = field(default_factory=list)

    @property
    def final(self) -> np.ndarray:
        return self.outputs[self.final_gid]


@dataclass
class SystemArtifact:
    """A compiled multi-chip plan (drop-in for :class:`Artifact`)."""

    workload: Any
    chip: ChipConfig                 # the per-mesh-slot chip (identical)
    options: CompileOptions          # carries .system (the mesh)
    cg: CondensedGraph               # full, unsplit condensed graph
    plan: SystemPlan
    chips: List[Artifact]            # index = logical chip slot
    trace: List[PassRecord] = field(default_factory=list)

    # -- derived --------------------------------------------------------------

    @property
    def system(self) -> SystemConfig:
        return self.plan.system

    @property
    def n_chips(self) -> int:
        return self.plan.n_chips

    @property
    def mode(self) -> str:
        return self.plan.mode

    # -- evaluation -----------------------------------------------------------

    def evaluate(self, backend: Union[str, Backend, None] = None,
                 **kw: Any) -> SystemReport:
        """Evaluate every chip slice and stitch over the links.

        Pipeline mode supports the analytic, trace and perf-simulator
        backends; tensor mode the analytic and trace backends (shards
        are group-level scaled condensed graphs — there is no per-shard
        ISA stream to step).  Functional execution needs the
        cross-chip data plane: use :meth:`run_func`.
        """
        b = resolve_backend(backend, self.options.fidelity)
        if b.name == "func":
            raise ValueError(
                "func fidelity on a multi-chip plan needs the "
                "cross-chip data plane; call SystemArtifact.run_func")
        if self.mode == "tensor" and getattr(b, "requires_model", False):
            raise ValueError(
                f"tensor-parallel plans evaluate at analytic/trace "
                f"fidelity only (backend {b.name!r} needs ISA "
                f"streams); use parallel='pipeline' for simulation")
        reports = [a.evaluate(b, **kw) for a in self.chips]
        return evaluate_plan(self.plan, self.chip, reports,
                             batch=self.options.resolved_batch(),
                             calibration=self.options.calibration,
                             backend_name=b.name)

    # -- functional execution -------------------------------------------------

    def run_func(self, weights: Mapping[int, np.ndarray],
                 biases: Optional[Mapping[int, np.ndarray]],
                 inputs: Any,
                 quant: Optional[Mapping[int, QuantParams]] = None
                 ) -> FuncRunResult:
        """Run the plan on the functional ISS, chip by chip.

        ``weights`` / ``biases`` / ``quant`` are keyed by **global**
        group id exactly as for the single-chip harness
        (``ref.make_weights`` / ``ref.auto_quant`` on the full graph);
        ``inputs`` is the full graph's input batch — one
        ``(batch, ...)`` array for single-input graphs, or a mapping
        ``{input_op_idx: (batch, ...)}`` for multi-input graphs.
        """
        if self.mode != "pipeline":
            raise ValueError("run_func supports pipeline-parallel "
                             "plans only (tensor shards have no "
                             "per-chip ISA streams)")
        src = self.cg.source
        if src is None:
            raise ValueError("run_func needs a source graph")
        input_ops = [op.idx for op in src.ops if op.kind == "input"]
        if isinstance(inputs, Mapping):
            inp = {int(k): np.asarray(v) for k, v in inputs.items()}
        else:
            if len(input_ops) != 1:
                raise ValueError(
                    f"'{self.cg.name}' has {len(input_ops)} graph "
                    f"inputs; pass a {{input_op_idx: array}} mapping")
            inp = {input_ops[0]: np.asarray(inputs)}
        batch = next(iter(inp.values())).shape[0]

        needed = {t.gid for t in self.plan.transfers}
        final_gid = len(self.cg) - 1
        needed.add(final_gid)

        biases = biases or {}
        quant = quant or {}
        values: Dict[int, List[np.ndarray]] = {}
        reports: List[SimReport] = []
        for sl, art in zip(self.plan.slices, self.chips):
            local_of = {gid: k for k, gid in enumerate(sl.gids)}
            w_l = {local_of[g]: weights[g] for g in sl.gids
                   if g in weights}
            b_l = {local_of[g]: biases[g] for g in sl.gids
                   if g in biases}
            q_l = {local_of[g]: quant[g] for g in sl.gids
                   if g in quant}
            force = {local_of[g] for g in needed if g in local_of}
            model = _compile_model(
                art.partition, batch=batch, quant=q_l or None,
                strict_lmem=art.options.strict_lmem,
                force_boundary=force)

            srcs = sl.input_srcs or tuple(
                ("input", i) for i in input_ops)
            rows: List[np.ndarray] = []
            for s in range(batch):
                parts = [
                    np.ascontiguousarray(
                        inp[ref][s], dtype=np.int8).reshape(-1)
                    if kind == "input" else values[ref][s]
                    for kind, ref in srcs]
                rows.append(np.concatenate(parts) if parts
                            else np.zeros(0, dtype=np.int8))
            img = model.build_gmem_image(w_l, b_l, np.stack(rows))

            sim = Simulator(self.chip, model.isa, mode="func")
            rep = sim.run_model(model, gmem_image=img)
            reports.append(rep)
            for g in needed:
                if g not in local_of:
                    continue
                vals = []
                for s in range(batch):
                    addr, nb = model.output_addr(local_of[g], s)
                    off = addr - GMEM_BASE
                    vals.append(rep.gmem[off:off + nb].copy())
                values[g] = vals
        outputs = {g: np.stack(v) for g, v in values.items()}
        return FuncRunResult(outputs=outputs, final_gid=final_gid,
                             reports=reports)

    # -- conveniences ---------------------------------------------------------

    def replace_options(self, **kw: Any) -> "SystemArtifact":
        """This plan under tweaked *evaluation* options (fidelity,
        calibration, batch, ...).  Anything that would change the plan
        or the per-chip partitions — ``strategy``, ``params``,
        ``workload_kw``, ``system`` — needs a fresh ``flow.compile``.

        Note: ``batch`` here rescales stitching and per-chip
        evaluation, but the system plan's capacity check was made at
        compile-time batch.
        """
        import dataclasses as _dc
        stale = {"strategy", "params", "workload_kw", "system"} & set(kw)
        if stale:
            raise ValueError(
                f"{sorted(stale)} change the system plan; recompile "
                f"via flow.compile(...) instead of replace_options")
        return _dc.replace(
            self, options=self.options.replace(**kw),
            chips=[a.replace_options(**kw) for a in self.chips],
            trace=list(self.trace))

    def pass_record(self, name: str) -> Optional[PassRecord]:
        for rec in reversed(self.trace):
            if rec.name == name or (
                    name == "system"
                    and rec.name.startswith("system:")):
                return rec
        return None

    def describe(self) -> str:
        head = (f"system artifact: '{self.cg.name}' on "
                f"{self.system.chips_x}x{self.system.chips_y} x "
                f"'{self.chip.name}' — {self.options.describe()}")
        lines = [head] + [r.describe() for r in self.trace]
        lines.append(self.plan.describe())
        return "\n".join(lines)

"""System-level partitioners: split one condensed graph across chips.

Two strategies, registered as ``system:pipeline`` / ``system:tensor``
passes on the :mod:`repro.flow` registry:

* **pipeline** — the condensed graph's groups are cut into contiguous
  ranges, one per chip, balanced by a compute proxy (MACs + vector
  element-ops) under the per-chip gmem capacity rule
  (:func:`repro.core.mapping.gmem_footprint_bytes`).  Each range is
  *re-materialized* as a real sub-:class:`~repro.core.graph.Graph`
  (cut-crossing tensors become graph inputs), so a chip's slice runs
  the whole single-chip fidelity ladder unchanged — including bit-exact
  func mode.  Cut-crossing activations are priced as inter-chip
  SEND/RECV transfers (gmem-port-contended, see
  :meth:`MachineModel.interchip_transfer_cycles`).

* **tensor** — every MVM group is sharded across *all* chips along the
  best available axis (attention heads -> ``groups``; else ``gemm_n``
  column split -> concat/all-gather; else ``gemm_m`` row split ->
  all-gather; else ``gemm_k`` reduction split -> all-reduce of int32
  partials), with exact integer splits so total MACs are conserved to
  the bit.  Per-chip shards are group-level scaled condensed graphs
  over the shared source, evaluated at the analytic and trace
  fidelities; vector-only groups are replicated (their compute is
  counted per chip, their *unique* work once — see
  :meth:`SystemPlan.total_macs`).

The splitters are pure functions of ``(cg, chip, system)`` and their
outputs are picklable, so the flow pass cache memoizes plans across
processes like any other pass output.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from ..core.arch import ChipConfig
from ..core.graph import CondensedGraph, Graph, Group, Op
from ..core.mapping import gmem_footprint_bytes
from ..core.partition import InfeasibleModel
from .config import SystemConfig

__all__ = ["SystemPlan", "ChipSlice", "Transfer", "Collective",
           "SystemPlanError", "split_pipeline", "shard_tensor"]


class SystemPlanError(RuntimeError):
    """The graph cannot be split the requested way (structural, not
    capacity — capacity failures raise
    :class:`~repro.core.partition.InfeasibleModel`)."""


@dataclass(frozen=True)
class Transfer:
    """One cut-crossing activation tensor (pipeline mode), per sample."""

    gid: int            # global producer group id
    src_chip: int
    dst_chip: int
    nbytes: int         # per-sample payload
    hops: int           # mesh Manhattan distance


@dataclass(frozen=True)
class Collective:
    """One per-group shard-boundary collective (tensor mode)."""

    gid: int            # global group id
    kind: str           # "allgather" | "allreduce"
    nbytes: int         # full per-sample payload moved by the collective


@dataclass
class ChipSlice:
    """One chip's share of the plan.

    ``workload`` is what that chip compiles: a sub-``Graph`` (pipeline
    mode), a scaled ``CondensedGraph`` (tensor mode), or ``None``
    meaning "the original workload, unchanged" (the 1-chip degenerate
    case — this is what makes a 1x1 mesh bit-identical to the
    single-chip path).  ``input_srcs`` maps the sub-graph's input ops,
    in op order, back to their origin: ``("input", op_idx)`` for an
    original graph input, ``("group", gid)`` for a cut-crossing
    producer group — the func-mode stitcher feeds each chip from this.

    ``chip_id`` is the *logical* slice index (0..n-1, what transfers
    and per-chip reports index); ``slot`` is the *physical* mesh slot
    the slice landed on.  They coincide on a healthy mesh and diverge
    under failover, when slices skip failed slots (``-1`` = legacy
    plan, read it as ``chip_id``).
    """

    chip_id: int
    gids: Tuple[int, ...]               # global group ids on this chip
    workload: Any = None                # Graph | CondensedGraph | None
    input_srcs: Tuple[Tuple[str, int], ...] = ()
    macs: int = 0                       # unique MACs charged to this slice
    out_bytes: int = 0                  # unique boundary bytes charged
    weight_bytes: int = 0               # resident (non-dynamic) weights
    slot: int = -1                      # physical mesh slot (-1 = chip_id)

    @property
    def mesh_slot(self) -> int:
        return self.chip_id if self.slot < 0 else self.slot


@dataclass
class SystemPlan:
    """A multi-chip execution plan over one condensed graph."""

    mode: str                           # "pipeline" | "tensor"
    system: SystemConfig
    cg: CondensedGraph                  # the full, unsplit graph
    slices: List[ChipSlice]
    transfers: Tuple[Transfer, ...] = ()
    collectives: Tuple[Collective, ...] = ()

    @property
    def n_chips(self) -> int:
        return len(self.slices)

    def total_macs(self) -> int:
        """Unique MACs across the plan — must equal ``cg.total_macs``
        (the conservation invariant; replicated groups count once)."""
        return sum(s.macs for s in self.slices)

    def total_out_bytes(self) -> int:
        """Unique boundary-activation bytes across the plan."""
        return sum(s.out_bytes for s in self.slices)

    def transfer_bytes(self, batch: int = 1) -> int:
        """Total inter-chip payload per batch (pipeline transfers +
        collective ring traffic)."""
        b = max(1, int(batch))
        total = sum(t.nbytes for t in self.transfers) * b
        c = max(1, len(self.slices))     # participating (surviving) chips
        for col in self.collectives:
            steps = (c - 1) * (2 if col.kind == "allreduce" else 1)
            total += steps * (col.nbytes // max(c, 1)) * b
        return total

    def describe(self) -> str:
        lines = [f"system plan [{self.mode}] '{self.cg.name}' on "
                 f"{self.system.chips_x}x{self.system.chips_y} chips "
                 f"('{self.system.link.name}' links)"]
        for s in self.slices:
            lines.append(
                f"  chip {s.chip_id}: {len(s.gids)} groups, "
                f"{s.macs / 1e6:.1f} MMACs, "
                f"{s.weight_bytes / 1e6:.2f} MB weights")
        if self.transfers:
            nb = sum(t.nbytes for t in self.transfers)
            lines.append(f"  {len(self.transfers)} cut transfers, "
                         f"{nb / 1e3:.1f} KB/sample")
        if self.collectives:
            lines.append(f"  {len(self.collectives)} collectives")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def _group_cost(g: Group) -> float:
    """Load-balance proxy: MAC work + vector element-ops."""
    return float(g.macs + g.vector_elems)


def _prop_slice(total: int, parts: Sequence[int]) -> List[int]:
    """Split ``total`` proportionally to ``parts`` with exact integer
    conservation (cumulative flooring telescopes to ``total``)."""
    whole = sum(parts)
    if whole <= 0:
        return [0] * len(parts)
    out, cum, prev = [], 0, 0
    for p in parts:
        cum += p
        now = total * cum // whole
        out.append(now - prev)
        prev = now
    return out


def _even_parts(n: int, c: int) -> List[int]:
    """``n`` split into ``c`` near-equal integer parts (first parts get
    the remainder), exactly conserving the sum."""
    q, r = divmod(n, c)
    return [q + (1 if i < r else 0) for i in range(c)]


# ---------------------------------------------------------------------------
# Pipeline-parallel splitter
# ---------------------------------------------------------------------------


def split_pipeline(cg: CondensedGraph, chip: ChipConfig,
                   system: SystemConfig) -> SystemPlan:
    """Cut ``cg`` into contiguous per-chip stage ranges.

    Cuts are chosen by DP minimizing the max per-chip compute proxy,
    subject to (a) the per-chip gmem capacity rule and (b) structural
    validity: a tensor crossing a cut must be its producer group's
    final output op (that is the blob codegen spills to gmem and the
    stitcher can forward).  Raises
    :class:`~repro.core.partition.InfeasibleModel` when no split at
    this chip count satisfies capacity.
    """
    G = len(cg.groups)
    if G == 0:
        raise SystemPlanError(f"'{cg.name}': empty condensed graph")
    # failover: plan over the surviving mesh slots only; logical slice
    # c lands on physical slot avail[c] (identity on a healthy mesh)
    avail = system.alive_slots
    n = min(len(avail), G)
    cap = chip.global_mem_bytes

    # -- structural cut validity ------------------------------------------
    # An op-level edge (op s in group p) -> (consumer in group q > p)
    # invalidates every cut j in [p, q-1] unless s is p's output op.
    valid_cut = [True] * G          # valid_cut[j]: may cut after group j
    if cg.source is not None:
        owner: Dict[int, int] = {i: g.idx for g in cg for i in g.op_ids}
        last_op = {g.idx: g.op_ids[-1] for g in cg}
        for g in cg:
            for i in g.op_ids:
                for s in cg.source.ops[i].inputs:
                    p = owner.get(s)
                    if p is None or p == g.idx or s == last_op[p]:
                        continue
                    for j in range(p, g.idx):
                        valid_cut[j] = False
    elif n > 1:
        raise SystemPlanError(
            f"'{cg.name}': pipeline split needs a source graph "
            f"(got a group-only condensed graph)")

    cost = [_group_cost(g) for g in cg]
    pref = [0.0]
    for c in cost:
        pref.append(pref[-1] + c)

    def range_cost(lo: int, hi: int) -> float:
        return pref[hi] - pref[lo]

    def feasible(lo: int, hi: int) -> bool:
        return gmem_footprint_bytes(cg.groups[lo:hi]) <= cap

    # -- DP: minimize the max range cost over exactly n valid ranges ------
    INF = float("inf")
    best = [[INF] * (G + 1) for _ in range(n + 1)]
    back = [[-1] * (G + 1) for _ in range(n + 1)]
    best[0][0] = 0.0
    for k in range(1, n + 1):
        for hi in range(k, G + 1):
            if hi < G and not valid_cut[hi - 1]:
                continue
            for lo in range(k - 1, hi):
                if best[k - 1][lo] == INF or not feasible(lo, hi):
                    continue
                v = max(best[k - 1][lo], range_cost(lo, hi))
                if v < best[k][hi]:
                    best[k][hi] = v
                    back[k][hi] = lo
    # fewer ranges than chips is allowed (graphs with sparse valid
    # cuts — e.g. residual blocks — may not support n non-empty
    # ranges): take the best feasible chip count <= n
    n_used = min((k for k in range(1, n + 1) if best[k][G] < INF),
                 key=lambda k: (best[k][G], k), default=0)
    if n_used == 0:
        need = _min_chips(cg, chip)
        raise InfeasibleModel(
            f"'{cg.name}' does not fit {n} chip(s) of "
            f"{cap / 1e6:.0f} MB gmem each "
            f"({gmem_footprint_bytes(cg.groups) / 1e6:.1f} "
            f"MB resident weights; needs >= {need} chips)")
    n = n_used

    bounds: List[int] = [G]
    k, hi = n, G
    while k > 0:
        lo = back[k][hi]
        bounds.append(lo)
        k, hi = k - 1, lo
    bounds.reverse()                # [0, c1, c2, ..., G]

    # -- materialize slices -----------------------------------------------
    slices: List[ChipSlice] = []
    chip_of: Dict[int, int] = {}
    for c in range(n):
        lo, hi = bounds[c], bounds[c + 1]
        gids = tuple(range(lo, hi))
        for gid in gids:
            chip_of[gid] = c
        sub, srcs = ((None, ()) if n == 1
                     else _slice_graph(cg, lo, hi))
        grp = cg.groups[lo:hi]
        slices.append(ChipSlice(
            chip_id=c, gids=gids, workload=sub, input_srcs=srcs,
            macs=sum(g.macs for g in grp),
            out_bytes=sum(g.out_bytes for g in grp),
            weight_bytes=sum(g.weight_bytes for g in grp
                             if g.weight_source != "dynamic"),
            slot=avail[c]))

    # -- cut-crossing transfers (deduped per producer, destination) ------
    transfers: List[Transfer] = []
    if n > 1 and cg.source is not None:
        seen: Set[Tuple[int, int]] = set()
        for g in cg:
            for i in g.op_ids:
                for s in cg.source.ops[i].inputs:
                    p = owner.get(s)
                    if p is None or chip_of[p] == chip_of[g.idx]:
                        continue
                    key = (p, chip_of[g.idx])
                    if key in seen:
                        continue
                    seen.add(key)
                    op = cg.source.ops[last_op[p]]
                    transfers.append(Transfer(
                        gid=p, src_chip=chip_of[p],
                        dst_chip=chip_of[g.idx],
                        nbytes=op.out_elems * op.act_bits // 8,
                        hops=system.hops(avail[chip_of[p]],
                                         avail[chip_of[g.idx]])))
    transfers.sort(key=lambda t: (t.src_chip, t.dst_chip, t.gid))
    return SystemPlan(mode="pipeline", system=system, cg=cg,
                      slices=slices, transfers=tuple(transfers))


def _min_chips(cg: CondensedGraph, chip: ChipConfig) -> int:
    """Lower-bound chip count: greedy first-fit over group ranges."""
    cap = chip.global_mem_bytes
    chips, lo = 1, 0
    for hi in range(1, len(cg.groups) + 1):
        if gmem_footprint_bytes(cg.groups[lo:hi]) > cap:
            if hi - 1 == lo:        # one group alone exceeds a chip
                return len(cg.groups) + 1
            chips += 1
            lo = hi - 1
    return chips


def _slice_graph(cg: CondensedGraph, lo: int,
                 hi: int) -> Tuple[Graph, Tuple[Tuple[str, int], ...]]:
    """Rebuild groups ``[lo, hi)`` as a standalone Graph.

    External tensors (original graph inputs and cut-crossing producer
    outputs) become input ops, created at first use so op order stays
    topological; the per-op geometry is copied verbatim, so the slice
    re-condenses to groups identical to the originals (asserted by the
    caller's conservation tests).
    """
    src = cg.source
    assert src is not None
    owner = {i: g.idx for g in cg for i in g.op_ids}
    last_op = {g.idx: g.op_ids[-1] for g in cg}
    member = [i for g in cg.groups[lo:hi] for i in g.op_ids]
    member.sort()
    inside = set(member)
    sub = Graph(f"{src.name}.pp{lo}_{hi}")
    remap: Dict[int, int] = {}
    srcs: List[Tuple[str, int]] = []
    for i in member:
        op = src.ops[i]
        for s in op.inputs:
            if s in inside or s in remap:
                continue
            sop = src.ops[s]
            if sop.kind != "input":
                p = owner[s]
                if s != last_op[p]:
                    raise SystemPlanError(
                        f"cut crosses a non-terminal tensor of group "
                        f"{p} ('{cg[p].name}' op {sop.name}); invalid "
                        f"cut placement")
                srcs.append(("group", p))
            else:
                srcs.append(("input", s))
            remap[s] = sub.input(f"in.{sop.name}",
                                 tuple(sop.out_shape))
        remap[i] = sub.add(Op(
            name=op.name, kind=op.kind,
            inputs=tuple(remap[s] for s in op.inputs),
            out_shape=tuple(op.out_shape), attrs=dict(op.attrs),
            gemm_m=op.gemm_m, gemm_k=op.gemm_k, gemm_n=op.gemm_n,
            groups=op.groups, weight_bits=op.weight_bits,
            act_bits=op.act_bits))
    return sub, tuple(srcs)


# ---------------------------------------------------------------------------
# Tensor-parallel sharder
# ---------------------------------------------------------------------------


def shard_tensor(cg: CondensedGraph, chip: ChipConfig,
                 system: SystemConfig) -> SystemPlan:
    """Shard every MVM group across all chips of the mesh.

    Axis choice per group (first match wins): attention heads
    (``groups`` divisible by the chip count), output columns
    (``gemm_n``), output rows (``gemm_m``), reduction (``gemm_k``,
    int32-partial all-reduce).  Unshardable groups are replicated.
    Splits are exact-integer, so ``plan.total_macs() == cg.total_macs``
    always holds.

    Under failover the shard count is the number of *surviving* chips
    — the same workload simply re-shards wider per chip.
    """
    avail = system.alive_slots
    C = len(avail)
    per_chip: List[List[Group]] = [[] for _ in range(C)]
    slice_macs = [0] * C
    slice_out = [0] * C
    slice_w = [0] * C
    collectives: List[Collective] = []

    for g in cg:
        shards, col = _shard_group(g, C)
        for c in range(C):
            sg = shards[c]
            per_chip[c].append(sg)
            if sg.weight_source != "dynamic":
                slice_w[c] += sg.weight_bytes
        if col is not None:
            collectives.append(col)
            for c in range(C):
                slice_macs[c] += shards[c].macs
            if col.kind == "allreduce":    # output replicated post-reduce
                slice_out[0] += g.out_bytes
            else:
                for c in range(C):
                    slice_out[c] += shards[c].out_bytes
        else:                              # replicated: unique work once
            slice_macs[0] += g.macs
            slice_out[0] += g.out_bytes

    cap = chip.global_mem_bytes
    for c in range(C):
        fp = gmem_footprint_bytes(per_chip[c])
        if fp > cap:
            raise InfeasibleModel(
                f"'{cg.name}' tensor shard {c}/{C} needs "
                f"{fp / 1e6:.1f} MB gmem (> {cap / 1e6:.0f} MB); "
                f"use more chips")

    slices = [ChipSlice(
        chip_id=c, gids=tuple(g.idx for g in cg),
        workload=CondensedGraph(f"{cg.name}.tp{c}of{C}", per_chip[c],
                                source=cg.source),
        macs=slice_macs[c], out_bytes=slice_out[c],
        weight_bytes=slice_w[c], slot=avail[c]) for c in range(C)]
    return SystemPlan(mode="tensor", system=system, cg=cg,
                      slices=slices, collectives=tuple(collectives))


def _shard_group(g: Group,
                 C: int) -> Tuple[List[Group], Optional[Collective]]:
    """One group's per-chip shard records + its boundary collective."""
    if C == 1:
        return [g], None
    if g.anchor is None or g.macs == 0:
        return [dataclasses.replace(g) for _ in range(C)], None

    if g.groups >= C and g.groups % C == 0:          # attention heads
        parts = _even_parts(g.groups, C)
        shards = _scaled(g, parts, groups=True)
        return shards, Collective(g.idx, "allgather", g.out_bytes)
    if g.gemm_n >= C:                                # output columns
        parts = _even_parts(g.gemm_n, C)
        shards = _scaled(g, parts, n=True)
        return shards, Collective(g.idx, "allgather", g.out_bytes)
    if g.gemm_m >= C:                                # output rows
        parts = _even_parts(g.gemm_m, C)
        shards = _scaled(g, parts, m=True)
        return shards, Collective(g.idx, "allgather", g.out_bytes)
    if g.gemm_k >= C:                                # reduction split
        parts = _even_parts(g.gemm_k, C)
        shards = _scaled(g, parts, k=True)
        # int32 partial sums ride the ring: 4x the int8 payload
        return shards, Collective(g.idx, "allreduce", 4 * g.out_bytes)
    return [dataclasses.replace(g) for _ in range(C)], None


def _scaled(g: Group, parts: Sequence[int], groups: bool = False,
            n: bool = False, m: bool = False,
            k: bool = False) -> List[Group]:
    """Per-chip scaled copies of ``g`` along one shard axis, with
    exact-integer conservation of MACs / weight / boundary bytes."""
    macs = _prop_slice(g.macs, parts)
    out: List[Group] = []
    w = (_prop_slice(g.weight_bytes, parts) if not m
         else [g.weight_bytes] * len(parts))       # M-shard: full weights
    ob = (_prop_slice(g.out_bytes, parts) if not k
          else [g.out_bytes] * len(parts))         # K-shard: full partials
    ib = (_prop_slice(g.in_bytes, parts) if (m or k)
          else [g.in_bytes] * len(parts))          # N/head: full input
    vw = {cls: _prop_slice(e, parts)
          for cls, e in g.vector_work.items()}
    for c, p in enumerate(parts):
        out.append(dataclasses.replace(
            g,
            groups=p if groups else g.groups,
            gemm_n=p if n else g.gemm_n,
            gemm_m=p if m else g.gemm_m,
            gemm_k=p if k else g.gemm_k,
            macs=macs[c], weight_bytes=w[c], out_bytes=ob[c],
            in_bytes=ib[c],
            vector_work={cls: v[c] for cls, v in vw.items()}))
    return out

"""System-level partition passes on the :mod:`repro.flow` registry.

``system:pipeline`` / ``system:tensor`` sit between the shared
``condense`` pass and the per-chip single-chip pipelines: they turn one
condensed graph plus a :class:`~repro.system.config.SystemConfig` into
a :class:`~repro.system.partition.SystemPlan`.  Like every other pass
the output is memoized by ``(workload, chip, options-prefix)`` through
the flow pass cache (including the ``REPRO_FLOW_CACHE`` disk tier), so
repeated multi-chip sweeps re-plan nothing.
"""

from __future__ import annotations

from typing import Any, Dict

from ..flow.passes import Pass, PipelineContext, register_pass
from .config import PARALLEL_MODES
from .partition import SystemPlan, shard_tensor, split_pipeline

__all__ = ["SystemPartitionPass", "system_pass_name"]

_SPLITTERS = {"pipeline": split_pipeline, "tensor": shard_tensor}


def system_pass_name(mode: str) -> str:
    return f"system:{mode}"


class SystemPartitionPass(Pass):
    """CondensedGraph + SystemConfig -> SystemPlan (one mesh layout)."""

    depends = ("system",)

    def __init__(self, mode: str) -> None:
        if mode not in PARALLEL_MODES:
            raise ValueError(f"mode must be one of {PARALLEL_MODES}, "
                             f"got {mode!r}")
        self.mode = mode
        self.name = system_pass_name(mode)

    def run(self, ctx: PipelineContext) -> SystemPlan:
        return _SPLITTERS[self.mode](ctx.cg, ctx.chip,
                                     ctx.options.system)

    def apply(self, ctx: PipelineContext, out: SystemPlan) -> None:
        ctx.extras["system_plan"] = out

    def summarize(self, out: SystemPlan) -> str:
        extra = (f"{len(out.transfers)} transfers"
                 if out.mode == "pipeline"
                 else f"{len(out.collectives)} collectives")
        return (f"{out.n_chips} chips "
                f"({out.system.chips_x}x{out.system.chips_y} "
                f"'{out.system.link.name}'), {extra}")

    def dump(self, out: SystemPlan) -> Dict[str, Any]:
        return {
            "mode": out.mode,
            "system": out.system.to_dict(),
            "slices": [{
                "chip": s.chip_id, "gids": list(s.gids),
                "macs": s.macs, "weight_bytes": s.weight_bytes,
                "out_bytes": s.out_bytes,
            } for s in out.slices],
            "transfers": [{
                "gid": t.gid, "src": t.src_chip, "dst": t.dst_chip,
                "nbytes": t.nbytes, "hops": t.hops,
            } for t in out.transfers],
            "collectives": [{
                "gid": c.gid, "kind": c.kind, "nbytes": c.nbytes,
            } for c in out.collectives],
        }


for _m in PARALLEL_MODES:
    register_pass(SystemPartitionPass(_m))

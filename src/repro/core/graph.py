"""Computation-graph IR for the CIMFlow compiler.

The compiler front-end (paper §III-C, *CG-level optimization*) works on an
operator DAG derived from an ONNX-like model description:

1.  **Op DAG** — one node per operator, with the tensor/GEMM geometry the
    CIM mapping needs (im2col'd ``(M, K, N)`` for MVM-based ops).
2.  **Condensation** — MVM-based operators (conv / linear / matmul) are
    identified as *anchors*; adjacent non-MVM operators (bias, BN, activation,
    pooling, element-wise adds, SE-scaling...) are grouped with them, giving a
    condensed CG whose nodes are :class:`Group` s.
3.  **Linearization** — a dependency-preserving topological order of groups,
    the substrate for the DP-based partitioning (Alg. 1).

Shapes are batch-free: feature maps are ``(H, W, C)``, vectors ``(C,)``.
The ``gemm_*`` fields describe one *sample*; batching is applied by the cost
model / simulator.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "Op",
    "Graph",
    "Group",
    "CondensedGraph",
    "MVM_KINDS",
    "WEIGHT_STATIC",
    "WEIGHT_STREAMED",
    "WEIGHT_DYNAMIC",
    "WEIGHT_SOURCES",
]


class GraphError(ValueError):
    pass


# Operator kinds that anchor a CIM group (executed on the CIM unit).
MVM_KINDS = {"conv", "dwconv", "linear", "matmul"}

# Weight-source abstraction, threaded through every layer of the stack:
#
# * ``static``   — CIM-resident weights, preloaded from global memory in
#   the stage prologue (the classic CNN case);
# * ``streamed`` — weights exceed the allocated MG slots and are
#   re-loaded from global memory in multiple *rounds* per sample (a
#   *mapping* outcome, discovered at op-level planning, never a graph
#   property);
# * ``dynamic``  — the weights are a predecessor operator's activations
#   (attention Q·Kᵀ / P·V matmuls), written into macro groups at
#   runtime from local memory, once per sample.
WEIGHT_STATIC = "static"
WEIGHT_STREAMED = "streamed"
WEIGHT_DYNAMIC = "dynamic"
WEIGHT_SOURCES = (WEIGHT_STATIC, WEIGHT_STREAMED, WEIGHT_DYNAMIC)

# Vector-unit kinds and their per-element cost class (see VectorUnitConfig).
VECTOR_KINDS = {
    "bias": "alu", "bn": "mul", "relu": "alu", "relu6": "alu",
    "silu": "special", "gelu": "special", "sigmoid": "special",
    "swish": "special", "tanh": "special", "softmax": "special",
    "add": "alu", "mul": "mul", "maxpool": "alu", "avgpool": "alu",
    "globalpool": "alu", "quant": "mul", "dequant": "mul",
    "layernorm": "special", "rmsnorm": "special", "concat": "alu",
    "pad": "alu", "flatten": "alu", "identity": "alu",
}


@dataclass
class Op:
    """A single operator node.

    ``out_shape`` is the batch-free output shape.  For MVM-based kinds the
    ``gemm_*`` triple is the im2col'd per-sample GEMM: ``M`` output
    positions, ``K`` reduction length, ``N`` output channels.  Depth-wise
    conv is modelled as ``groups=C`` small GEMMs: ``K = kh*kw`` and
    ``N = C`` — one output channel per group.  Its poor CIM row-utilization
    (``K`` ≪ macro rows) then *emerges* from the mapping rather than being
    special-cased.
    """

    name: str
    kind: str
    inputs: Tuple[int, ...] = ()
    out_shape: Tuple[int, ...] = ()
    attrs: Dict[str, Any] = field(default_factory=dict)
    # GEMM geometry for MVM kinds (per sample, post-im2col).
    gemm_m: int = 0
    gemm_k: int = 0
    gemm_n: int = 0
    groups: int = 1          # grouped conv / depthwise
    weight_bits: int = 8
    act_bits: int = 8
    idx: int = -1            # assigned on insertion

    # -- derived ------------------------------------------------------------

    @property
    def is_mvm(self) -> bool:
        return self.kind in MVM_KINDS

    @property
    def out_elems(self) -> int:
        return int(math.prod(self.out_shape)) if self.out_shape else 0

    @property
    def weight_elems(self) -> int:
        if not self.is_mvm:
            return 0
        return self.gemm_k * self.gemm_n * self.groups

    @property
    def weight_bytes(self) -> int:
        return self.weight_elems * self.weight_bits // 8

    @property
    def macs(self) -> int:
        if not self.is_mvm:
            return 0
        return self.gemm_m * self.gemm_k * self.gemm_n * self.groups

    @property
    def flops(self) -> int:
        return 2 * self.macs

    def __repr__(self) -> str:
        if self.is_mvm:
            return (f"Op({self.idx}:{self.name} {self.kind} "
                    f"M{self.gemm_m} K{self.gemm_k} N{self.gemm_n}"
                    f"{f' g{self.groups}' if self.groups > 1 else ''})")
        return f"Op({self.idx}:{self.name} {self.kind} {self.out_shape})"


class Graph:
    """An operator DAG under construction + analysis helpers."""

    def __init__(self, name: str = "model") -> None:
        self.name = name
        self.ops: List[Op] = []

    # -- construction ---------------------------------------------------------

    def add(self, op: Op) -> int:
        for i in op.inputs:
            if not 0 <= i < len(self.ops):
                raise GraphError(f"{op.name}: dangling input {i}")
        op.idx = len(self.ops)
        self.ops.append(op)
        return op.idx

    def input(self, name: str, shape: Tuple[int, ...]) -> int:
        return self.add(Op(name=name, kind="input", out_shape=shape))

    def conv(self, name: str, src: int, *, cout: int, k: int, stride: int = 1,
             padding: Optional[int] = None, groups: int = 1,
             act: Optional[str] = None, use_bn: bool = True) -> int:
        """Conv2D (+BN+activation fused as separate grouped ops)."""
        h, w, cin = self.ops[src].out_shape
        if padding is None:
            padding = k // 2
        ho = (h + 2 * padding - k) // stride + 1
        wo = (w + 2 * padding - k) // stride + 1
        if cin % groups or cout % groups:
            raise GraphError(f"{name}: groups {groups} !| {cin}->{cout}")
        kind = "dwconv" if groups == cin and groups == cout else "conv"
        i = self.add(Op(
            name=name, kind=kind, inputs=(src,), out_shape=(ho, wo, cout),
            gemm_m=ho * wo, gemm_k=(cin // groups) * k * k,
            gemm_n=cout // groups, groups=groups,
            attrs={"k": k, "stride": stride, "padding": padding}))
        if use_bn:
            i = self.add(Op(name=f"{name}.bn", kind="bn", inputs=(i,),
                            out_shape=(ho, wo, cout)))
        if act:
            i = self.add(Op(name=f"{name}.{act}", kind=act, inputs=(i,),
                            out_shape=(ho, wo, cout)))
        return i

    def linear(self, name: str, src: int, *, cout: int,
               act: Optional[str] = None, bias: bool = True) -> int:
        shp = self.ops[src].out_shape
        cin = shp[-1]
        m = int(math.prod(shp[:-1])) if len(shp) > 1 else 1
        out_shape = shp[:-1] + (cout,)
        i = self.add(Op(name=name, kind="linear", inputs=(src,),
                        out_shape=out_shape, gemm_m=m, gemm_k=cin,
                        gemm_n=cout))
        if bias:
            i = self.add(Op(name=f"{name}.bias", kind="bias", inputs=(i,),
                            out_shape=out_shape))
        if act:
            i = self.add(Op(name=f"{name}.{act}", kind=act, inputs=(i,),
                            out_shape=out_shape))
        return i

    def pool(self, name: str, src: int, *, k: int, stride: Optional[int] = None,
             kind: str = "maxpool", padding: int = 0) -> int:
        stride = stride or k
        h, w, c = self.ops[src].out_shape
        ho = (h + 2 * padding - k) // stride + 1
        wo = (w + 2 * padding - k) // stride + 1
        return self.add(Op(name=name, kind=kind, inputs=(src,),
                           out_shape=(ho, wo, c),
                           attrs={"k": k, "stride": stride,
                                  "padding": padding}))

    def globalpool(self, name: str, src: int) -> int:
        _, _, c = self.ops[src].out_shape
        return self.add(Op(name=name, kind="globalpool", inputs=(src,),
                           out_shape=(c,)))

    def eltwise(self, name: str, kind: str, a: int, b: int) -> int:
        sa, sb = self.ops[a].out_shape, self.ops[b].out_shape
        if sa != sb and math.prod(sa) != math.prod(sb):
            # allow broadcast (SE scaling: (C,) * (H,W,C))
            if sa[-1] != sb[-1]:
                raise GraphError(f"{name}: shape mismatch {sa} vs {sb}")
        out = sa if math.prod(sa) >= math.prod(sb) else sb
        return self.add(Op(name=name, kind=kind, inputs=(a, b),
                           out_shape=out))

    def unary(self, name: str, kind: str, src: int) -> int:
        return self.add(Op(name=name, kind=kind, inputs=(src,),
                           out_shape=self.ops[src].out_shape))

    # -- analysis -------------------------------------------------------------

    def consumers(self) -> List[List[int]]:
        outs: List[List[int]] = [[] for _ in self.ops]
        for op in self.ops:
            for i in op.inputs:
                outs[i].append(op.idx)
        return outs

    def topo_order(self) -> List[int]:
        # ops are appended post-order already; verify and return.
        for op in self.ops:
            for i in op.inputs:
                if i >= op.idx:
                    raise GraphError("graph not in topological insert order")
        return list(range(len(self.ops)))

    @property
    def total_weight_bytes(self) -> int:
        return sum(op.weight_bytes for op in self.ops)

    @property
    def total_macs(self) -> int:
        return sum(op.macs for op in self.ops)

    def summary(self) -> str:
        n_mvm = sum(1 for o in self.ops if o.is_mvm)
        return (f"graph '{self.name}': {len(self.ops)} ops ({n_mvm} MVM), "
                f"{self.total_weight_bytes / 1e6:.2f} MB weights, "
                f"{self.total_macs / 1e6:.1f} MMACs/sample")

    def condense(self) -> "CondensedGraph":
        return CondensedGraph.from_graph(self)


# ---------------------------------------------------------------------------
# Condensed graph (groups)
# ---------------------------------------------------------------------------


@dataclass
class Group:
    """A condensed CG node: one MVM anchor + its fused non-MVM neighbours.

    Quantities consumed by the mapping cost model:

    * ``gemm_m/k/n``, ``groups``  — the anchor GEMM (zero for anchor-less
      groups, e.g. a leading pool);
    * ``weight_bytes``            — CIM array footprint;
    * ``vector_work``             — per-sample vector-unit element-ops,
      split by latency class;
    * ``in_bytes`` / ``out_bytes``— activation traffic across the group
      boundary (per sample).
    """

    idx: int
    name: str
    op_ids: Tuple[int, ...]
    anchor: Optional[int]               # op id of the MVM anchor
    preds: Tuple[int, ...] = ()         # group indices
    gemm_m: int = 0
    gemm_k: int = 0
    gemm_n: int = 0
    groups: int = 1
    weight_bits: int = 8
    act_bits: int = 8
    weight_bytes: int = 0
    macs: int = 0
    vector_work: Dict[str, int] = field(default_factory=dict)
    in_bytes: int = 0
    out_bytes: int = 0
    # Graph-level weight source of the anchor: ``static`` (learned
    # weights in gmem) or ``dynamic`` (weights are a predecessor op's
    # activations).  ``streamed`` is a mapping outcome, never set here.
    weight_source: str = WEIGHT_STATIC
    transpose_weights: bool = False     # dynamic: W = producer outputᵀ
    # Append-only dynamic weights (KV-cached decode): across consecutive
    # samples the weight operand grows by exactly one producer row, so
    # the mapping/trace/codegen layers may price (and emit) an
    # incremental re-gather of just the appended row instead of
    # re-staging the whole buffer.  Set from ``attrs['kv_append']``.
    weight_incremental: bool = False

    @property
    def is_mvm(self) -> bool:
        return self.anchor is not None

    @property
    def dynamic_weights(self) -> bool:
        return self.weight_source == WEIGHT_DYNAMIC

    @property
    def vector_elems(self) -> int:
        return sum(self.vector_work.values())

    def __repr__(self) -> str:
        return (f"Group({self.idx}:{self.name} w={self.weight_bytes}B "
                f"macs={self.macs} out={self.out_bytes}B)")


class CondensedGraph:
    """Condensed CG: dependency-preserving sequence of groups (paper §III-C)."""

    def __init__(self, name: str, groups: List[Group],
                 source: Optional[Graph] = None) -> None:
        self.name = name
        self.groups = groups
        self.source = source
        self._check()

    def _check(self) -> None:
        for g in self.groups:
            for p in g.preds:
                if not 0 <= p < g.idx:
                    raise GraphError(
                        f"group {g.idx} has non-topological pred {p}")

    def __len__(self) -> int:
        return len(self.groups)

    def __iter__(self):
        return iter(self.groups)

    def __getitem__(self, i: int) -> Group:
        return self.groups[i]

    # -- dependency closures (Alg. 1 line 1) ---------------------------------

    def ancestor_masks(self) -> List[int]:
        """Per-group bitmask of its transitive predecessors (exclusive)."""
        masks = [0] * len(self.groups)
        for g in self.groups:
            m = 0
            for p in g.preds:
                m |= masks[p] | (1 << p)
            masks[g.idx] = m
        return masks

    @property
    def total_weight_bytes(self) -> int:
        return sum(g.weight_bytes for g in self.groups)

    @property
    def total_macs(self) -> int:
        return sum(g.macs for g in self.groups)

    def summary(self) -> str:
        return (f"condensed '{self.name}': {len(self.groups)} groups, "
                f"{self.total_weight_bytes / 1e6:.2f} MB weights, "
                f"{self.total_macs / 1e6:.1f} MMACs/sample")

    # -- construction ---------------------------------------------------------

    @staticmethod
    def from_graph(g: Graph) -> "CondensedGraph":
        """MVM-anchored condensation.

        Pass 1: assign every op to a group id — an MVM op starts a new group;
        a non-MVM op joins the group of its *latest* producer (adjacent
        grouping).  Ops preceding any MVM (stem pools etc.) join group of
        their producer or a fresh anchor-less group for graph inputs.
        Pass 2: renumber groups in topological order of first-op, collect
        geometry + boundary traffic.
        """
        n = len(g.ops)
        owner = [-1] * n
        groups_ops: List[List[int]] = []

        for op in g.ops:
            if op.kind == "input":
                owner[op.idx] = -1          # inputs belong to no group
                continue
            if op.is_mvm:
                owner[op.idx] = len(groups_ops)
                groups_ops.append([op.idx])
                continue
            # non-MVM: fuse into the latest producing group
            prod_groups = [owner[i] for i in op.inputs if owner[i] >= 0]
            if prod_groups:
                gid = max(prod_groups)
            else:
                gid = len(groups_ops)       # anchor-less stem group
                groups_ops.append([])
            owner[op.idx] = gid
            groups_ops[gid].append(op.idx)

        cons = g.consumers()
        # renumber non-empty groups in first-op order (already topological)
        renum = {gid: k for k, gid in enumerate(
            gid for gid, ops_ in enumerate(groups_ops) if ops_)}
        out: List[Group] = []
        for gid, op_ids in enumerate(groups_ops):
            if not op_ids:
                continue
            anchor = next((i for i in op_ids if g.ops[i].is_mvm), None)
            member = set(op_ids)
            preds: Set[int] = set()
            in_bytes = 0
            for i in op_ids:
                for s in g.ops[i].inputs:
                    so = owner[s]
                    if so == gid:
                        continue
                    if so >= 0:
                        preds.add(renum[so])
                    sop = g.ops[s]
                    in_bytes += sop.out_elems * sop.act_bits // 8
            out_bytes = 0
            for i in op_ids:
                if not cons[i] or any(c not in member for c in cons[i]):
                    op = g.ops[i]
                    out_bytes += op.out_elems * op.act_bits // 8
            vw: Dict[str, int] = {}
            for i in op_ids:
                op = g.ops[i]
                if op.is_mvm:
                    continue
                cls = _vec_class(op.kind)
                vw[cls] = vw.get(cls, 0) + op.out_elems
            a = g.ops[anchor] if anchor is not None else None
            out.append(Group(
                idx=renum[gid], name=g.ops[op_ids[0]].name,
                op_ids=tuple(op_ids), anchor=anchor,
                preds=tuple(sorted(preds)),
                gemm_m=a.gemm_m if a else 0, gemm_k=a.gemm_k if a else 0,
                gemm_n=a.gemm_n if a else 0, groups=a.groups if a else 1,
                weight_bits=a.weight_bits if a else 8,
                act_bits=a.act_bits if a else 8,
                weight_bytes=a.weight_bytes if a else 0,
                macs=a.macs if a else 0, vector_work=vw,
                in_bytes=in_bytes, out_bytes=out_bytes,
                weight_source=(WEIGHT_DYNAMIC
                               if a is not None
                               and a.attrs.get("dynamic_weights")
                               else WEIGHT_STATIC),
                transpose_weights=bool(
                    a.attrs.get("transpose_weights")) if a else False,
                weight_incremental=bool(
                    a.attrs.get("kv_append")) if a else False))
        return CondensedGraph(g.name, out, source=g)


def _vec_class(kind: str) -> str:
    c = VECTOR_KINDS.get(kind, "alu")
    return c

"""CG-level partitioning (paper §III-C, Alg. 1) and the §IV-B baselines.

The model is divided into **execution stages** to respect the digital-CIM
weight-capacity wall.  Stages execute sequentially (weights are reloaded per
stage); inside a stage, groups form an inter-operator pipeline across cores.

* :func:`dependency_closures` — Alg. 1 line 1: every *dependency closure*
  (predecessor-closed subset of the condensed CG) encoded as a bitmask.
* :func:`dp_partition` — Alg. 1's dynamic program over the closure lattice:
  ``dp[i] = min_{j ⊑ i} dp[j] + OptimalMapping(D_i \\ D_j, R)``.
* :func:`greedy_partition` — capacity-first partitioning in topological
  order; with ``generic`` mapping it is baseline (1) *generic inter-layer
  pipeline, no duplication*; with ``opportunistic`` mapping it is baseline
  (2), the CIM-MLC-style partition-then-duplicate scheme.
"""

from __future__ import annotations

import math
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .arch import ChipConfig
from .graph import CondensedGraph
from .mapping import (CostParams, StagePlan, generic_mapping, mg_tiles,
                      min_cores, needs_streaming, opportunistic_mapping,
                      optimal_mapping)

__all__ = [
    "PartitionResult", "dependency_closures", "dp_partition",
    "greedy_partition", "partition", "STRATEGIES", "ClosureExplosion",
]

Mapper = Callable[[CondensedGraph, Sequence[int], ChipConfig, CostParams],
                  Optional[StagePlan]]


class ClosureExplosion(RuntimeError):
    """Raised when the closure lattice exceeds the enumeration cap."""


class InfeasibleModel(RuntimeError):
    """No valid partition exists (some group cannot fit the chip at all)."""


# ---------------------------------------------------------------------------
# Result container
# ---------------------------------------------------------------------------


@dataclass
class PartitionResult:
    strategy: str
    stages: List[StagePlan]
    cg: CondensedGraph
    chip: ChipConfig
    params: CostParams

    def latency_cycles(self, batch: Optional[int] = None,
                       calib=None) -> float:
        return sum(s.latency_cycles(batch, calib) for s in self.stages)

    def latency_s(self, batch: Optional[int] = None) -> float:
        return self.latency_cycles(batch) / (self.chip.clock_ghz * 1e9)

    def throughput_sps(self, batch: Optional[int] = None) -> float:
        b = batch if batch is not None else self.params.batch
        return b / self.latency_s(b)

    def energy_events(self, batch: Optional[int] = None,
                      calib=None) -> Dict[str, float]:
        tot: Dict[str, float] = {}
        for s in self.stages:
            for k, v in s.energy_events(batch, calib).items():
                tot[k] = tot.get(k, 0.0) + v
        return tot

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def describe(self) -> str:
        head = (f"[{self.strategy}] {self.cg.name}: {self.n_stages} stages, "
                f"{self.latency_cycles():.0f} cycles "
                f"(batch={self.params.batch})")
        return "\n".join([head] + [s.describe() for s in self.stages])


# ---------------------------------------------------------------------------
# Dependency closures (Alg. 1, line 1)
# ---------------------------------------------------------------------------


def dependency_closures(cg: CondensedGraph, cap: int = 1 << 16) -> List[int]:
    """All predecessor-closed subsets of ``cg`` as bitmasks.

    BFS over the closure lattice: a closure ``m`` extends to ``m | 1<<v``
    for any node ``v ∉ m`` whose predecessors are all in ``m``.  Sorted by
    population count (then value) so the DP can scan subsets forward.
    Raises :class:`ClosureExplosion` beyond ``cap`` — callers fall back to
    topological-prefix closures.
    """
    n = len(cg)
    pred_mask = [0] * n
    for g in cg:
        for p in g.preds:
            pred_mask[g.idx] |= 1 << p
    seen = {0}
    frontier = [0]
    while frontier:
        m = frontier.pop()
        for v in range(n):
            bit = 1 << v
            if m & bit:
                continue
            if (pred_mask[v] & m) == pred_mask[v]:
                nm = m | bit
                if nm not in seen:
                    if len(seen) >= cap:
                        raise ClosureExplosion(
                            f"closure lattice of '{cg.name}' exceeds {cap}")
                    seen.add(nm)
                    frontier.append(nm)
    return sorted(seen, key=lambda m: (bin(m).count("1"), m))


def prefix_closures(cg: CondensedGraph) -> List[int]:
    """Fallback: topological prefixes only (always valid closures)."""
    masks = [0]
    m = 0
    for g in cg:
        m |= 1 << g.idx
        masks.append(m)
    return masks


def _bits(mask: int) -> List[int]:
    out = []
    i = 0
    while mask:
        if mask & 1:
            out.append(i)
        mask >>= 1
        i += 1
    return out


# ---------------------------------------------------------------------------
# Alg. 1: DP-based partitioning and mapping
# ---------------------------------------------------------------------------


def dp_partition(cg: CondensedGraph, chip: ChipConfig,
                 params: Optional[CostParams] = None,
                 mapper: Mapper = optimal_mapping,
                 closure_cap: int = 1 << 16) -> PartitionResult:
    """The paper's Alg. 1, including the state-compression bitmask encoding."""
    params = params or CostParams()
    try:
        D = dependency_closures(cg, cap=closure_cap)
    except ClosureExplosion:
        D = prefix_closures(cg)
    index = {m: i for i, m in enumerate(D)}
    full = (1 << len(cg)) - 1
    if full not in index:          # defensive; full set is always a closure
        D.append(full)
        index[full] = len(D) - 1

    INF = float("inf")
    dp = [INF] * len(D)
    prev = [-1] * len(D)
    plan: List[Optional[StagePlan]] = [None] * len(D)
    cache: Dict[int, Optional[StagePlan]] = {}

    def map_stage(stage_mask: int) -> Optional[StagePlan]:
        if stage_mask not in cache:
            cache[stage_mask] = mapper(cg, _bits(stage_mask), chip, params)
        return cache[stage_mask]

    for i, Di in enumerate(D):
        if Di == 0:
            dp[i] = 0.0
            continue
        for j, Dj in enumerate(D):
            if Dj == Di or (Di & Dj) != Dj:
                continue
            if dp[j] == INF:
                continue
            sp = map_stage(Di ^ Dj)            # D[i] - D[j] set difference
            if sp is None:
                continue
            cost = dp[j] + sp.latency_cycles()
            if cost < dp[i]:
                dp[i], prev[i], plan[i] = cost, j, sp

    fi = index[full]
    if dp[fi] == INF:
        raise InfeasibleModel(
            f"'{cg.name}' has no feasible partition on chip "
            f"'{chip.name}'")
    # ReconstructSolution
    stages: List[StagePlan] = []
    i = fi
    while prev[i] != -1:
        stages.append(plan[i])          # type: ignore[arg-type]
        i = prev[i]
    stages.reverse()
    return PartitionResult("dp", stages, cg, chip, params)


# ---------------------------------------------------------------------------
# Greedy capacity-first partitioning (baselines)
# ---------------------------------------------------------------------------


def greedy_partition(cg: CondensedGraph, chip: ChipConfig,
                     params: Optional[CostParams] = None,
                     mapper: Mapper = generic_mapping,
                     strategy: str = "generic") -> PartitionResult:
    """Pack groups into stages in topological order until capacity is hit."""
    params = params or CostParams()
    slots = chip.core.cim.n_macro_groups
    chip_tiles = chip.n_cores * slots
    stages: List[List[int]] = []
    cur: List[int] = []
    cur_tiles = 0
    cur_cores = 0
    for g in cg:
        t = mg_tiles(g, chip)
        c = min_cores(g, chip)
        # a weight-streaming group occupies the slots of the cores it
        # monopolizes, not its (larger) nominal tile count — it may
        # share a stage as long as the mapper can place the result
        eff = min(t, c * slots)
        if needs_streaming(g, chip) or t > chip_tiles:
            if cur and mapper(cg, cur + [g.idx], chip, params) is not None:
                cur.append(g.idx)
                cur_tiles += eff
                cur_cores += c
                continue
            if cur:
                stages.append(cur)
            stages.append([g.idx])
            cur, cur_tiles, cur_cores = [], 0, 0
            continue
        if cur and (cur_tiles + eff > chip_tiles
                    or cur_cores + c > chip.n_cores):
            stages.append(cur)
            cur, cur_tiles, cur_cores = [], 0, 0
        cur.append(g.idx)
        cur_tiles += eff
        cur_cores += c
    if cur:
        stages.append(cur)

    plans: List[StagePlan] = []
    for gids in stages:
        sp = mapper(cg, gids, chip, params)
        if sp is None:
            raise InfeasibleModel(
                f"greedy stage {gids} of '{cg.name}' unmappable")
        plans.append(sp)
    return PartitionResult(strategy, plans, cg, chip, params)


# ---------------------------------------------------------------------------
# Strategy registry (used by benchmarks / DSE)
# ---------------------------------------------------------------------------


def _partition(cg: CondensedGraph, chip: ChipConfig,
               strategy: str = "dp",
               params: Optional[CostParams] = None) -> PartitionResult:
    """Internal strategy dispatcher (the :mod:`repro.flow` pass bodies)."""
    if strategy == "dp":
        return dp_partition(cg, chip, params)
    if strategy == "generic":
        return greedy_partition(cg, chip, params, generic_mapping, "generic")
    if strategy == "cim-mlc":
        return greedy_partition(cg, chip, params, opportunistic_mapping,
                                "cim-mlc")
    raise KeyError(f"unknown strategy {strategy!r}")


def partition(cg: CondensedGraph, chip: ChipConfig,
              strategy: str = "dp",
              params: Optional[CostParams] = None) -> PartitionResult:
    """Deprecated free-function entry point.

    Use ``repro.flow.compile(cg, chip, CompileOptions(strategy=...))``
    — the pass-based pipeline adds per-pass instrumentation and caches
    partition outputs across fidelities.  This shim stays for existing
    callers and the golden equivalence tests.
    """
    warnings.warn(
        "repro.core.partition.partition() is deprecated; use "
        "repro.flow.compile(workload, chip, CompileOptions(strategy=...))",
        DeprecationWarning, stacklevel=2)
    return _partition(cg, chip, strategy, params)


STRATEGIES = ("generic", "cim-mlc", "dp")

"""JAX backend for the pre-decoded perf engine (``engine="jax"``).

:mod:`repro.core.vectorsim` already reduced perf-mode decode to a fixed
set of array passes over a stage's concatenated instruction columns —
segmented cumulative sums for G_Reg/S_Reg dataflow, a cumulative OR for
macro-group occupancy, and batched :class:`~repro.core.machine.
MachineModel` latency lookups.  This module re-expresses exactly those
passes in ``jax.numpy`` as **one jitted XLA program per decode-table
shape** and, crucially, makes the machine's timing constants a
*function argument* instead of baked-in Python attributes:

* ``Simulator(engine="jax")`` — single machine.  The device pass runs
  with donated input buffers and returns per-instruction latencies plus
  the resolved register/sreg/occupancy values; the host then assembles
  replay items with the *identical numpy expressions* the numpy engine
  uses (:func:`vectorsim._finish_decode`), so every reported number —
  cycles, stage_cycles, unit_busy, events, instrs — is bit-identical.
* :class:`FleetStageDecoder` — many machines.  The timing constants
  stack into a :class:`MachineTables` pytree and the same device pass is
  ``vmap``-ed over the machine axis: *one* XLA program evaluates a whole
  chunk of DSE points ("same program, different chip constants").  The
  dataflow half of the pass depends only on the instruction columns, so
  under ``vmap(in_axes=(0, None))`` XLA computes it once and batches
  only the latency arithmetic.

Bit-identity strategy: the device returns only *per-instruction* int64
values and float64 latencies computed with formulas mirrored
term-for-term from :class:`MachineModel`'s ``*_cycles_array`` methods
(int64 arithmetic, one final ``astype(float64)``, IEEE division) — every
*sum* (event ledgers, unit-busy, run prefix sums) happens on the host in
the shared numpy back half.  Inputs are padded to power-of-two buckets
to bound jit recompiles; all scans are prefix-safe, so padding appended
after the real rows never perturbs them and outputs are sliced back to
the true length.

Int semantics: everything runs under ``jax.experimental.enable_x64`` so
register arithmetic wraps in int64 exactly like the numpy engine.
Programs with control flow / scalar-ALU chains take the same
decode-time unroll path as the numpy engine; anything undecodable falls
back to the scalar interpreter per stage, unchanged.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .isa import Isa, Program
from .machine import MachineModel
from .vectorsim import (
    StageDecoder, DecodeUnsupported, _DecodedStage, _Prep, _finish_decode,
    replay_stage, _END, _K_VEC, _K_MVM, _K_WLOAD, _K_BCAST, _K_CONST,
    _K_SEND, _K_RECV, _K_GLD, _K_GST, _K_SYNC, _K_HALT,
    _S_VLEN, _S_VREP, _S_CHANNEL, _S_MASK_LO, _S_MASK_HI,
    _S_SEG_IN, _S_SEG_OUT, _S_NLEN, _I8_FLAG,
)

__all__ = ["JaxStageDecoder", "FleetStageDecoder", "MachineTables",
           "run_stage"]

# machine timing-constant layout (order is the device-call ABI)
_INT_KEYS = ("vector_lanes", "vector_alu_latency", "vector_mul_latency",
             "vector_special_latency", "mvm_interval_beats",
             "mvm_fill_beats")
_FLT_KEYS = ("scalar_alu_cycles", "scalar_ldst_cycles",
             "weight_load_rows_per_cycle", "link_bytes_per_cycle")

# instruction columns shipped to the device (plus op / starts)
_COL_NAMES = ("dst", "a", "imm", "sreg", "src", "len", "rows", "mg",
              "rep", "core", "size")

# tracked S_Reg timeline columns, in device order
_SREG_IDS = np.array([_S_VLEN, _S_VREP, _S_CHANNEL, _S_MASK_LO,
                      _S_MASK_HI, _S_SEG_IN, _S_SEG_OUT, _S_NLEN],
                     dtype=np.int64)
_SREG_KEYS = ("vlen", "vrep", "chan", "mask_lo", "mask_hi",
              "seg_in", "seg_out", "nlen")
_VLEN_COL = 0


class MachineTables:
    """Stacked timing constants — the ``vmap`` axis of a fleet.

    ``arrays`` is a tuple of ``(n_machines,)`` columns in
    ``_INT_KEYS + _FLT_KEYS`` order (int64 then float64), built from
    :meth:`MachineModel.timing_constants` so the batched latency
    arithmetic stays bit-identical to each machine's own accessors.
    """

    __slots__ = ("arrays", "n_machines")

    def __init__(self, arrays: Tuple[np.ndarray, ...]) -> None:
        self.arrays = arrays
        self.n_machines = int(arrays[0].shape[0])

    @classmethod
    def stack(cls, machines: List[MachineModel]) -> "MachineTables":
        rows = [m.timing_constants() for m in machines]
        arrays = tuple(
            np.array([r[k] for r in rows], dtype=np.int64)
            for k in _INT_KEYS
        ) + tuple(
            np.array([r[k] for r in rows], dtype=np.float64)
            for k in _FLT_KEYS
        )
        return cls(arrays)


def _scalar_row(tc: Dict[str, float]) -> Tuple[np.ndarray, ...]:
    """One machine's constants as 0-d arrays (the unbatched call)."""
    return tuple(np.int64(tc[k]) for k in _INT_KEYS) + \
        tuple(np.float64(tc[k]) for k in _FLT_KEYS)


def _latsel_table(dec: StageDecoder) -> np.ndarray:
    """Per-op constant-latency selector: 0 = none (boundary / batched
    kinds), 1 = literal 1.0, 2 = scalar-ALU, 3 = scalar-load/store —
    mirrors the ``const`` table in :class:`StageDecoder.__init__`."""
    t = np.zeros(dec.isa.n_ops, dtype=np.int32)
    t[dec.kind == _K_CONST] = 1
    for i in (dec.id_addi, dec.id_lui):
        if i >= 0:
            t[i] = 2
    for i in (dec.id_sld, dec.id_sst):
        if i >= 0:
            t[i] = 3
    return t


def _bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b <<= 1
    return b


# ---------------------------------------------------------------------------
# Device pass
# ---------------------------------------------------------------------------

_EXEC_CACHE: Dict[tuple, Tuple[Any, Any]] = {}


def _build_exec(kind_t: np.ndarray, vcls_t: np.ndarray,
                latsel_t: np.ndarray, ids: Tuple[int, ...],
                n_regs: int) -> Tuple[Any, Any]:
    """Compile the stage pass for one ISA-table fingerprint.

    Returns ``(single, fleet)`` where ``single(sc, cols)`` evaluates one
    machine (donated buffers) and ``fleet`` is the same function vmapped
    over the machine axis of ``sc``.  ``n_regs`` bounds the dense G_Reg
    timeline width (a power of two ≤ 32, from the stage's columns).
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    id_lui, id_addi, id_cfg, id_cfgr, id_setvl = ids
    kind_c = jnp.asarray(kind_t.astype(np.int32))
    vcls_c = jnp.asarray(vcls_t.astype(np.int32))
    latsel_c = jnp.asarray(latsel_t)
    sreg_ids = jnp.asarray(_SREG_IDS)

    def stage_pass(sc, cols):
        (lanes, v_alu, v_mul, v_special, ivl, fill,
         alu_f, ldst_f, wl_rate, link_bpc) = sc
        op = cols["op"]
        starts = cols["starts"]
        n = op.shape[0]
        idx = jnp.arange(n, dtype=jnp.int64)
        kind = kind_c[op]
        vcls = vcls_c[op]
        latsel = latsel_c[op]

        def excl_cummax(x):
            inc = lax.cummax(x, axis=0)
            pad = jnp.full_like(x[:1], -1)
            return jnp.concatenate([pad, inc[:-1]], axis=0)

        # ---- G_Reg dataflow: dense (n, n_regs) chain-cumsum ----------
        # column r tracks register r; reads gather the value written by
        # the last write strictly before the reader, within the reader's
        # program (``lastw >= starts`` — never another core's writes)
        is_lui = op == id_lui
        is_addi = op == id_addi
        dst, a_col, imm = cols["dst"], cols["a"], cols["imm"]
        wr = (is_lui | is_addi) & (dst != 0)
        regs = jnp.arange(n_regs, dtype=jnp.int64)
        w = wr[:, None] & (dst[:, None] == regs[None, :])
        base = jnp.where(is_lui, (imm & 0xFFFF) << 16, imm)
        lastw = excl_cummax(jnp.where(w, idx[:, None], -1))
        firstw = lastw < starts[:, None]
        reset = w & (is_lui[:, None] | (a_col[:, None] != regs[None, :])
                     | firstw)
        contrib = jnp.where(w, jnp.where(reset, base[:, None],
                                         imm[:, None]), 0)
        c = jnp.cumsum(contrib, axis=0)
        lastreset = lax.cummax(jnp.where(reset, idx[:, None], -1), axis=0)
        before = c - contrib                     # cumsum exclusive of row
        vals = c - jnp.take_along_axis(before, jnp.maximum(lastreset, 0),
                                       axis=0)
        vis = jnp.where(lastw >= starts[:, None],
                        jnp.take_along_axis(vals, jnp.maximum(lastw, 0),
                                            axis=0), 0)

        def greg_read(col):
            return jnp.take_along_axis(vis, col[:, None], axis=1)[:, 0]

        rd_src = greg_read(cols["src"])
        rd_core = greg_read(cols["core"])
        rd_size = greg_read(cols["size"])

        # ---- S_Reg timelines: dense (n, 8) last-write gather ---------
        is_cfg = op == id_cfg
        is_cfgr = op == id_cfgr
        is_setvl = op == id_setvl
        sreg = cols["sreg"]
        sw = (is_cfg | is_cfgr)[:, None] & (sreg[:, None]
                                            == sreg_ids[None, :])
        sw = sw.at[:, _VLEN_COL].set(sw[:, _VLEN_COL] | is_setvl)
        sval = jnp.where(is_cfgr, rd_src,
                         jnp.where(is_setvl, cols["len"], imm))
        slast = excl_cummax(jnp.where(sw, idx[:, None], -1))
        scur = jnp.where(
            slast >= starts[:, None],
            jnp.take_along_axis(jnp.broadcast_to(sval[:, None], sw.shape),
                                jnp.maximum(slast, 0), axis=0), 0)

        # ---- MG occupancy: segmented cumulative OR -------------------
        is_wl = kind == _K_WLOAD
        bits = jnp.where(is_wl, jnp.asarray(1, jnp.int64) << cols["mg"], 0)
        segfirst = idx == starts

        def _comb(xa, xb):
            v1, f1 = xa
            v2, f2 = xb
            return jnp.where(f2, v2, v1 | v2), f1 | f2

        occ_incl, _ = lax.associative_scan(_comb, (bits, segfirst))
        lwl = excl_cummax(jnp.where(is_wl, idx, -1))
        loaded = jnp.where(lwl >= starts,
                           occ_incl[jnp.maximum(lwl, 0)], 0)

        # ---- latencies (term-for-term MachineModel mirrors) ----------
        zero = jnp.asarray(0.0, jnp.float64)
        one = jnp.asarray(1.0, jnp.float64)
        consts = jnp.stack([zero, one,
                            jnp.asarray(alu_f, jnp.float64),
                            jnp.asarray(ldst_f, jnp.float64)])
        lat = consts[latsel]

        n_el = (jnp.maximum(scur[:, 0], 1)       # vlen
                * jnp.maximum(scur[:, 1], 1))    # vrep
        n_el = jnp.maximum(n_el, 1)
        beats = -(-n_el // lanes)                # ceil-div, exact int64
        vlat = jnp.where(vcls == 2, beats * v_special,
                         beats + jnp.where(vcls == 1, v_mul, v_alu)
                         ).astype(jnp.float64)
        lat = jnp.where(kind == _K_VEC, vlat, lat)
        lat = jnp.where(kind == _K_WLOAD,
                        cols["rows"].astype(jnp.float64) / wl_rate, lat)
        lat = jnp.where(kind == _K_MVM,
                        (cols["rep"] * ivl + fill).astype(jnp.float64),
                        lat)
        lat = jnp.where(kind == _K_BCAST,
                        jnp.maximum(one, rd_size.astype(jnp.float64)
                                    / link_bpc), lat)

        resolved = {"core": rd_core, "size": rd_size, "loaded": loaded}
        for k, key in enumerate(_SREG_KEYS):
            resolved[key] = scur[:, k]
        return lat, resolved

    single = jax.jit(stage_pass, donate_argnums=(1,))
    fleet = jax.jit(jax.vmap(stage_pass, in_axes=(0, None),
                             out_axes=(0, None)))
    return single, fleet


def _exec_for(dec: StageDecoder, n_regs: int) -> Tuple[Any, Any]:
    latsel = _latsel_table(dec)
    key = (dec.kind.tobytes(), dec.vcls.tobytes(), latsel.tobytes(),
           dec.id_lui, dec.id_addi, dec.id_cfg, dec.id_cfgr,
           dec.id_setvl, n_regs)
    got = _EXEC_CACHE.get(key)
    if got is None:
        got = _EXEC_CACHE[key] = _build_exec(
            dec.kind, dec.vcls, latsel,
            (dec.id_lui, dec.id_addi, dec.id_cfg, dec.id_cfgr,
             dec.id_setvl), n_regs)
    return got


# ---------------------------------------------------------------------------
# Host halves
# ---------------------------------------------------------------------------


def _reg_bucket(pr: _Prep) -> int:
    """Dense G_Reg width for this stage (power of two, ≤ 32).

    Raises :class:`DecodeUnsupported` when a register operand falls
    outside the architectural file — the caller then takes the scalar
    fallback, exactly like any other undecodable stage.
    """
    hi = 0
    for name in ("dst", "src", "core", "size"):
        c = pr.col(name)
        if c.size:
            lo_v, hi_v = int(c.min()), int(c.max())
            if lo_v < 0 or hi_v >= 32:
                raise DecodeUnsupported(
                    f"register operand {name}={lo_v if lo_v < 0 else hi_v}"
                    " outside G0..G31")
            hi = max(hi, hi_v)
    return _bucket(hi + 1, lo=8)


def _device_cols(pr: _Prep) -> Dict[str, np.ndarray]:
    """Pad the prep columns to the shape bucket (host-side numpy)."""
    n, nb = pr.n, _bucket(pr.n)

    def pad(x: np.ndarray, fill: int = 0) -> np.ndarray:
        x = x.astype(np.int64, copy=False)
        if nb == n:
            return x
        out = np.full(nb, fill, dtype=np.int64)
        out[:n] = x
        return out

    cols = {"op": pad(pr.op), "starts": pad(pr.starts, fill=n)}
    for name in _COL_NAMES:
        cols[name] = pad(pr.col(name))
    return cols


def _call_exec(fn: Any, sc: Tuple[np.ndarray, ...],
               cols: Dict[str, np.ndarray], n: int
               ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
    """Upload, run (under x64), download, and un-pad one device call."""
    import warnings

    import jax.numpy as jnp
    from jax.experimental import enable_x64
    with enable_x64(), warnings.catch_warnings():
        # donation is best-effort: a couple of int64 columns have no
        # matching output shape — harmless, not worth a user warning
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        dev_cols = {k: jnp.asarray(v) for k, v in cols.items()}
        dev_sc = tuple(jnp.asarray(x) for x in sc)
        lat, res = fn(dev_sc, dev_cols)
        lat = np.asarray(lat)
        res = {k: np.asarray(v)[:n] for k, v in res.items()}
    return lat[..., :n], res


def _finish_from_device(out: _DecodedStage, pr: _Prep, dec: StageDecoder,
                        m: MachineModel, lat: np.ndarray,
                        res: Dict[str, np.ndarray]) -> None:
    """Numpy back half: event ledgers, boundary items, replay plan.

    Every expression here is copied verbatim from the numpy engine's
    ``decode_stage`` (same dtypes, same `.sum()` order), so the totals
    are bit-identical given identical per-instruction inputs.
    """
    op, kind, col = pr.op, pr.kind, pr.col
    ev_tot = [0.0] * 4
    ev_cnt = [0] * 4

    # ---- S_LD / S_ST ledger traffic (4 B words) --------------------
    n_mem = int(((op == dec.id_sld) | (op == dec.id_sst)).sum())
    ev_tot[0] += 4.0 * n_mem
    ev_cnt[0] += n_mem

    # ---- vector ops ------------------------------------------------
    vpos = np.flatnonzero(kind == _K_VEC)
    if len(vpos):
        n_el = (np.maximum(res["vlen"][vpos], 1)
                * np.maximum(res["vrep"][vpos], 1))
        esz = np.where(col("flags")[vpos] & _I8_FLAG, 1, 4)
        ev_tot[0] += float((n_el * esz * 2).sum())
        ev_tot[3] += float(n_el.sum())
        ev_cnt[0] += len(vpos)
        ev_cnt[3] += len(vpos)

    # ---- CIM_LOAD --------------------------------------------------
    lpos = np.flatnonzero(kind == _K_WLOAD)
    if len(lpos):
        rows = col("rows")[lpos]
        nlen = np.maximum(res["nlen"][lpos], 1)
        wl = float((rows * nlen).sum())
        ev_tot[0] += wl
        ev_tot[1] += wl
        ev_cnt[0] += len(lpos)
        ev_cnt[1] += len(lpos)

    # ---- CIM_MVM ---------------------------------------------------
    mpos = np.flatnonzero(kind == _K_MVM)
    if len(mpos):
        rep = col("rep")[mpos]
        mask = ((res["mask_lo"][mpos] & 0xFFFF)
                | (res["mask_hi"][mpos] << 16))
        act = res["loaded"][mpos] & mask
        active = np.zeros(len(mpos), dtype=np.int64)
        for b in range(32):
            active += (act >> b) & 1
        ev_tot[2] += float((rep * active).sum() * m.macros_per_group)
        seg = res["seg_in"][mpos] + res["seg_out"][mpos]
        ev_tot[0] += float((rep * seg).sum())
        ev_cnt[0] += len(mpos)
        ev_cnt[2] += len(mpos)

    # ---- boundary items --------------------------------------------
    bitems: Dict[int, tuple] = {}
    for tag in (_K_SEND, _K_RECV):
        kpos = np.flatnonzero(kind == tag)
        for p, c, s, st in zip(kpos.tolist(),
                               res["core"][kpos].tolist(),
                               res["size"][kpos].tolist(),
                               res["chan"][kpos].tolist()):
            bitems[p] = (tag, c, s, st)
    for tag in (_K_GLD, _K_GST):
        kpos = np.flatnonzero(kind == tag)
        for p, s in zip(kpos.tolist(), res["size"][kpos].tolist()):
            bitems[p] = (tag, s)
    sync = np.flatnonzero(kind == _K_SYNC)
    for p, b in zip(sync.tolist(), col("barrier")[sync].tolist()):
        bitems[p] = (_K_SYNC, b)

    _finish_decode(out, pr, dec.unit[op], lat, bitems, ev_tot, ev_cnt)


# ---------------------------------------------------------------------------
# Decoders
# ---------------------------------------------------------------------------


class JaxStageDecoder:
    """Single-machine JAX decode: drop-in for :class:`StageDecoder`.

    Wraps a numpy :class:`StageDecoder` for the machine-independent prep
    (pack / dead-code / unroll split) and per-op tables, and replaces
    the dataflow + latency passes with the jitted device call.
    """

    def __init__(self, isa: Isa, m: MachineModel) -> None:
        self.isa = isa
        self.m = m
        self.npdec = StageDecoder(isa, m)
        self._sc = _scalar_row(m.timing_constants())

    def decode_stage(self, programs: Dict[int, Program]) -> _DecodedStage:
        out = _DecodedStage()
        pr = self.npdec._prep(programs)
        out.n_prog = pr.n_prog
        for cid in pr.empty:
            out.items[cid] = [(_END,)]
        for cid, prog in pr.unroll:
            self.npdec.unroll_decode(prog, cid, out)
        if not pr.cids:
            return out
        fn, _ = _exec_for(self.npdec, _reg_bucket(pr))
        lat, res = _call_exec(fn, self._sc, _device_cols(pr), pr.n)
        _finish_from_device(out, pr, self.npdec, self.m, lat, res)
        return out


class FleetStageDecoder:
    """Batched decode of one stage for a whole fleet of machines.

    One prep, one vmapped device call over the stacked
    :class:`MachineTables`, then one cheap numpy finish per machine —
    the replay plans are exactly what each machine's own
    ``Simulator(engine="jax")`` would build.
    """

    def __init__(self, isa: Isa, machines: List[MachineModel]) -> None:
        self.isa = isa
        self.machines = list(machines)
        self.npdecs = [StageDecoder(isa, m) for m in self.machines]
        self.tables = MachineTables.stack(self.machines)

    def prep(self, programs: Dict[int, Program]) -> _Prep:
        """Machine-independent front half (cacheable by the caller)."""
        return self.npdecs[0]._prep(programs)

    def decode_stage(self, programs: Dict[int, Program],
                     prep: Optional[_Prep] = None) -> List[_DecodedStage]:
        pr = prep if prep is not None else self.prep(programs)
        lat = res = None
        if pr.cids:
            _, fleet_fn = _exec_for(self.npdecs[0], _reg_bucket(pr))
            lat, res = _call_exec(fleet_fn, self.tables.arrays,
                                  _device_cols(pr), pr.n)
        outs: List[_DecodedStage] = []
        for i, (m, dec) in enumerate(zip(self.machines, self.npdecs)):
            out = _DecodedStage()
            out.n_prog = dict(pr.n_prog)
            for cid in pr.empty:
                out.items[cid] = [(_END,)]
            for cid, prog in pr.unroll:
                dec.unroll_decode(prog, cid, out)
            if pr.cids:
                _finish_from_device(out, pr, dec, m, lat[i], res)
            outs.append(out)
        return outs


def run_stage(sim: Any, sp: Any) -> Optional[Tuple[float, Dict[str, float],
                                                   Dict[str, float], int]]:
    """JAX-engine counterpart of :func:`vectorsim.run_stage`.

    Decode on device, replay with the shared
    :func:`vectorsim.replay_stage`; ``None`` when the stage is outside
    the decodable subset (scalar-interpreter fallback, as ever).
    """
    dec = getattr(sim, "_jdecoder", None)
    if dec is None or dec.isa is not sim.isa:
        dec = sim._jdecoder = JaxStageDecoder(sim.isa, sim.m)
    try:
        ds = dec.decode_stage(sp.programs)
    except DecodeUnsupported:
        return None
    return replay_stage(sim, sp, ds)

"""Reference INT8 oracle for compiled CIMFlow programs.

Pure-numpy forward pass with *bit-exact* semantics matching the code
generator + functional ISS contract:

* HWC activations, ``(ky, kx, c)`` im2col patch ordering
  (``(g, ky, kx)`` block-diagonal for depth-wise);
* INT32 accumulation, int32 bias, relu pre-quant (unless a residual
  add/scale follows — then int8 post-add);
* fixed-point requant ``clip((acc*scale + den/2) // den)`` with
  ``den = div << shift`` (``div`` folds the GAP mean);
* max-pool on int8 with zero-init windows (valid post-relu);
* saturating int8 residual adds / SE channel scaling;
* dynamic-weight matmuls (attention): the weight matrix is built from
  the weight-producer group's activations via
  :func:`repro.core.vecsem.dynamic_weight_matrix` — the same layout
  codegen's gather V_MOVs realize;
* fused ``softmax`` / ``layernorm`` / ``gelu`` through the shared
  integer semantics in :mod:`repro.core.vecsem`.

Also provides the weight-matrix builders tests use to generate gmem
images (`conv_weight_matrix`, `dwconv_weight_matrix`).
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from . import vecsem
from .codegen import QuantParams, _main_and_skip_preds, _weight_pred
from .graph import CondensedGraph, Graph
from .oplevel import Im2colSpec

__all__ = ["conv_weight_matrix", "dwconv_weight_matrix", "im2col",
           "quantize", "run_reference", "auto_quant", "random_init"]

# the INT8 x INT8 -> INT32 accumulator contraction; swappable so the
# same oracle can execute its MVMs on an accelerator kernel (see
# ``flow.backends.PallasFuncBackend``) while everything around the
# matmul stays pure numpy
MatmulFn = Callable[[np.ndarray, np.ndarray], np.ndarray]


def conv_weight_matrix(kernel: np.ndarray) -> np.ndarray:
    """(kh, kw, cin, cout) int8 kernel -> (kh*kw*cin, cout) matrix."""
    kh, kw, cin, cout = kernel.shape
    return kernel.reshape(kh * kw * cin, cout).astype(np.int8)


def dwconv_weight_matrix(kernel: np.ndarray) -> np.ndarray:
    """(kh, kw, C) depth-wise kernel -> block-diagonal (C*kh*kw, C)."""
    kh, kw, c = kernel.shape
    w = np.zeros((c * kh * kw, c), dtype=np.int8)
    for g in range(c):
        w[g * kh * kw:(g + 1) * kh * kw, g] = \
            kernel[:, :, g].reshape(-1)
    return w


def im2col(x: np.ndarray, kh: int, kw: int, stride: int, pad: int,
           depthwise: bool = False) -> np.ndarray:
    """HWC int8 map -> (ho*wo, K) patches; zero padding."""
    h, w, c = x.shape
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    xp = np.zeros((h + 2 * pad, w + 2 * pad, c), dtype=x.dtype)
    xp[pad:pad + h, pad:pad + w] = x
    out = np.zeros((ho * wo, kh * kw * c), dtype=x.dtype)
    for y in range(ho):
        for xx in range(wo):
            patch = xp[y * stride:y * stride + kh,
                       xx * stride:xx * stride + kw]   # (kh, kw, c)
            if depthwise:
                # (g, ky, kx) ordering
                out[y * wo + xx] = patch.transpose(2, 0, 1).reshape(-1)
            else:
                out[y * wo + xx] = patch.reshape(-1)
    return out


def quantize(acc: np.ndarray, q: QuantParams, div: int = 1) -> np.ndarray:
    den = div << q.shift
    v = (acc.astype(np.int64) * q.scale + (den >> 1)) // den
    return np.clip(v, -128, 127).astype(np.int8)


def _sat_add(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.clip(a.astype(np.int16) + b.astype(np.int16),
                   -128, 127).astype(np.int8)


def _sat_mul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.clip(a.astype(np.int32) * b.astype(np.int32),
                   -128, 127).astype(np.int8)


def _group_spec(cg: CondensedGraph, g) -> Optional[Tuple]:
    src = cg.source
    if src is None or g.anchor is None:
        return None
    op = src.ops[g.anchor]
    if op.kind not in ("conv", "dwconv"):
        return None
    h, w, cin = src.ops[op.inputs[0]].out_shape
    return (op.attrs["k"], op.attrs["stride"], op.attrs["padding"],
            op.kind == "dwconv")


def run_reference(cg: CondensedGraph, weights: Dict[int, np.ndarray],
                  biases: Dict[int, np.ndarray],
                  quant: Dict[int, QuantParams],
                  inputs: np.ndarray,
                  return_acc: bool = False,
                  matmul: Optional[MatmulFn] = None,
                  faults: Optional[Any] = None
                  ) -> Dict[int, np.ndarray]:
    """Forward-pass every sample; returns {gid: (batch, ...) int8 maps}
    (conv groups: (B, ho', wo', N) post-fusion; vector groups: (B, N)).

    ``matmul`` overrides the accumulator contraction
    ``(M, K) int32 x (K, N) int32 -> (M, N) int32`` (operand *values*
    always fit int8); the default is the numpy ``@``.

    ``faults`` is an optional :class:`repro.faults.FaultSet`: static
    weight matrices are stuck-at-corrupted before the contraction and
    the int32 accumulator takes deterministic per-``(group, sample)``
    transient flips after it.  ``None`` (and an empty set) leave the
    oracle bit-exactly unchanged.  Dynamic-weight (attention) matmuls
    build their matrix from activations at run time and carry no
    stored-weight faults.
    """
    mm: MatmulFn = matmul if matmul is not None else (
        lambda a, b: a @ b)
    src = cg.source
    assert src is not None, "reference needs the source graph"
    op_owner = {}
    for g in cg:
        for i in g.op_ids:
            op_owner[i] = g.idx
    B = inputs.shape[0]
    outs: Dict[int, np.ndarray] = {}
    accs: Dict[int, np.ndarray] = {}

    for g in cg:
        main, side = _main_and_skip_preds(cg, g, op_owner)
        wp = _weight_pred(cg, g, op_owner)
        spec = _group_spec(cg, g)
        q = quant[g.idx]
        res = []
        acc_dbg = []
        vops = _vops(cg, g)
        anchor_op = src.ops[g.anchor] if g.anchor is not None else None
        for s in range(B):
            x = inputs[s] if main is None else outs[main][s]
            if g.dynamic_weights:
                wbuf = inputs[s] if wp is None else outs[wp][s]
                W = vecsem.dynamic_weight_matrix(
                    wbuf, anchor_op.gemm_k, anchor_op.gemm_n,
                    anchor_op.groups,
                    bool(anchor_op.attrs.get("transpose_weights"))
                ).astype(np.int32)
            else:
                W = weights[g.idx]
                if faults is not None:
                    W = faults.corrupt_weight_matrix(g.idx, W)
                W = W.astype(np.int32)
            if spec is not None:
                k, stride, pad, dw = spec
                patches = im2col(x, k, k, stride, pad, dw).astype(np.int32)
                acc = mm(patches, W)
                anchor_op = src.ops[g.anchor]
                ho, wo, n = anchor_op.out_shape
            else:
                acc = mm(x.reshape(-1, W.shape[0]).astype(np.int32), W)
                ho, wo, n = 1, 1, W.shape[1]
            if faults is not None:
                acc = faults.corrupt_acc(acc, g.idx, s)
            acc_dbg.append(acc.copy())
            sv = (outs[side[0]][s] if side
                  else (inputs[s] if main is None else outs[main][s])) \
                if ("add" in vops or "mul" in vops) else None
            # process fused ops strictly in graph order
            i32 = True                    # still in the INT32 accumulator?
            y = None

            def leave_i32():
                nonlocal i32, y
                if i32:
                    z = quantize(acc, q)
                    y = (z.reshape(ho, wo, n) if spec is not None
                         else z.reshape(-1))
                    i32 = False

            for op in vops:
                if op == "bias":
                    acc = acc + biases[g.idx].astype(np.int32)[None, :]
                elif op == "relu":
                    if i32:
                        acc = np.maximum(acc, 0)
                    else:
                        y = np.maximum(y, 0)
                elif op in ("add", "mul"):
                    leave_i32()
                    if op == "mul":
                        y = _sat_mul(y, sv.reshape(
                            (1,) * (y.ndim - 1) + (-1,)))
                    else:
                        y = _sat_add(y, sv.reshape(y.shape))
                elif op == "maxpool":
                    leave_i32()
                    pk, ps, pp, pho, pwo = _pool_of(cg, g)
                    out = np.zeros((pho, pwo, n), dtype=np.int8)
                    for py in range(pho):
                        for px in range(pwo):
                            for jy in range(pk):
                                for jx in range(pk):
                                    iy = py * ps - pp + jy
                                    ix = px * ps - pp + jx
                                    if 0 <= iy < y.shape[0] and \
                                            0 <= ix < y.shape[1]:
                                        out[py, px] = np.maximum(
                                            out[py, px], y[iy, ix])
                    y = out
                elif op == "globalpool":
                    leave_i32()
                    m = y.reshape(-1, n)
                    tot = m.astype(np.int32).sum(axis=0)
                    y = quantize(tot, q, div=m.shape[0])
                elif op == "softmax":
                    # per head-row segment, matching codegen's VLEN
                    leave_i32()
                    seg = anchor_op.gemm_n if anchor_op is not None \
                        else y.shape[-1]
                    shp = y.shape
                    y = vecsem.softmax_i8(y.reshape(-1, seg)).reshape(shp)
                elif op == "layernorm":
                    leave_i32()
                    row = y.shape[-1]
                    if anchor_op is not None:
                        row = anchor_op.gemm_n * (
                            anchor_op.groups if anchor_op.groups > 1
                            else 1)
                    shp = y.shape
                    y = vecsem.layernorm_i8(
                        y.reshape(-1, row)).reshape(shp)
                elif op == "gelu":
                    leave_i32()
                    y = vecsem.gelu_i8(y)
                else:
                    raise NotImplementedError(
                        f"oracle: fused op {op!r} unsupported")
            leave_i32()
            res.append(y)
        outs[g.idx] = np.stack(res)
        if return_acc:
            accs[g.idx] = np.stack(acc_dbg)
    if return_acc:
        outs["acc"] = accs          # type: ignore[assignment]
    return outs


def _vops(cg: CondensedGraph, g) -> Tuple[str, ...]:
    src = cg.source
    out = []
    for i in g.op_ids:
        op = src.ops[i]
        if op.is_mvm or op.kind in ("bn", "flatten", "identity"):
            continue
        out.append(op.kind)
    return tuple(out)


def _pool_of(cg: CondensedGraph, g):
    src = cg.source
    for i in g.op_ids:
        op = src.ops[i]
        if op.kind == "maxpool":
            ho, wo, _ = op.out_shape
            return (op.attrs["k"], op.attrs["stride"],
                    op.attrs.get("padding", 0), ho, wo)
    return None


def _gap_of(cg: CondensedGraph, g) -> bool:
    src = cg.source
    return any(src.ops[i].kind == "globalpool" for i in g.op_ids)


def random_init(cg: CondensedGraph, batch: int = 1, seed: int = 0
                ) -> Tuple[Dict[int, np.ndarray],
                           Dict[int, np.ndarray], np.ndarray]:
    """Random int8 ``(weights, biases, inputs)`` for a condensed graph.

    Weights land in the ``(K_total, N_total)`` matrix layout codegen
    loads (conv kernels through :func:`conv_weight_matrix`, depth-wise
    through :func:`dwconv_weight_matrix`); values stay small so a few
    fused layers don't saturate before :func:`auto_quant` picks shifts.
    """
    src = cg.source
    assert src is not None, "random_init needs the source graph"
    rng = np.random.default_rng(seed)
    weights: Dict[int, np.ndarray] = {}
    biases: Dict[int, np.ndarray] = {}
    lo, hi = -6, 7
    for g in cg:
        if g.anchor is None:
            continue
        op = src.ops[g.anchor]
        if op.kind == "conv":
            k = op.attrs["k"]
            cin = src.ops[op.inputs[0]].out_shape[-1]
            ker = rng.integers(lo, hi, (k, k, cin, op.gemm_n),
                               dtype=np.int8)
            weights[g.idx] = conv_weight_matrix(ker)
        elif op.kind == "dwconv":
            k = op.attrs["k"]
            ker = rng.integers(lo, hi, (k, k, op.groups), dtype=np.int8)
            weights[g.idx] = dwconv_weight_matrix(ker)
        elif op.kind == "linear" and not g.dynamic_weights:
            weights[g.idx] = rng.integers(lo, hi, (g.gemm_k, g.gemm_n),
                                          dtype=np.int8)
        if "bias" in _vops(cg, g):
            biases[g.idx] = rng.integers(
                -40, 40, g.gemm_n * (g.groups if g.groups > 1 else 1)
            ).astype(np.int32)
    inputs = rng.integers(-8, 8, (batch,) + src.ops[0].out_shape
                          ).astype(np.int8)
    return weights, biases, inputs


def auto_quant(cg: CondensedGraph, weights: Dict[int, np.ndarray],
               biases: Dict[int, np.ndarray],
               inputs: np.ndarray) -> Dict[int, QuantParams]:
    """Pick per-group shifts that keep outputs in a healthy int8 range
    (fixed-point iteration of the oracle: downstream ranges depend on
    upstream quantization)."""
    qp = {g.idx: QuantParams(scale=1, shift=0) for g in cg}
    for _ in range(3):
        outs = run_reference(cg, weights, biases, qp, inputs,
                             return_acc=True)
        accs = outs["acc"]          # type: ignore[index]
        new = {}
        for g in cg:
            peak = max(1, int(np.abs(accs[g.idx]).max()))
            shift = (max(0, math.ceil(math.log2(peak / 100)))
                     if peak > 100 else 0)
            new[g.idx] = QuantParams(scale=1, shift=min(shift, 30))
        if new == qp:
            break
        qp = new
    return qp

"""Core mapping + analytic cost model (paper §III-C, ``OptimalMapping``).

Given a candidate partition *stage* (a set of condensed-CG groups) and the
hardware resources, this module decides

* how many MG-tiles each group needs (weight → macro allocation, organized
  along output channels; block-diagonal packing for grouped/depth-wise conv);
* how many cores each group occupies and its **duplication factor** — the
  paper's key lever: replicating an operator's weights across clusters of
  cores buys parallel throughput at the price of extra weight-load and
  input-multicast traffic;
* the resulting stage cost: weight-(re)load cycles + pipeline fill +
  steady-state interval per sample, plus an energy-event ledger.

Execution model (documented assumptions; the cycle-accurate simulator is the
ground truth, this model guides the DP search):

* Stages run **sequentially**: load stage weights, stream the whole batch
  through the stage's inter-operator pipeline, spill boundary activations to
  global memory, move on.  This is the capacity-wall execution the paper
  targets.
* Within a stage each group occupies its own cluster of cores (several small
  groups may share a core — their intervals then serialize).
* A replica processes one im2col input vector per ``act_bits`` beats
  (bit-serial), all its MG-tiles firing in parallel; ``dup`` replicas split
  ``gemm_m``.
* Input multicast: each extra replica re-receives ``alpha x in_bytes``
  (``alpha = 1`` — conservative full broadcast, matching the MG input
  broadcast organization).
* Oversized groups (weights exceed whole-chip MG capacity) execute in
  ``rounds`` with weight streaming.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .arch import ChipConfig
from .graph import CondensedGraph, Group
from .machine import Calibration, MachineModel, machine_for

__all__ = [
    "CostParams", "GroupAlloc", "StagePlan", "mg_tiles", "min_cores",
    "optimal_mapping", "generic_mapping", "opportunistic_mapping",
    "gmem_footprint_bytes",
]


def gmem_footprint_bytes(groups: "Iterable") -> int:
    """Resident global-memory footprint of a set of groups, per chip.

    Static and streamed weights live in gmem for the whole run (streamed
    groups re-fetch from there every round) — they are the *resident*
    term and the capacity wall.  Dynamic weights are activations and
    never materialize; boundary activations stream through gmem
    transiently (stage-sequential execution frees a blob once the
    consumer stage drains it) and are excluded.  The system-level
    partitioner uses this as the per-chip capacity rule — one chip's
    16 MB gmem is the wall that forces multi-chip plans.  The legacy
    single-chip path stays unguarded for backwards compatibility.
    """
    return sum(g.weight_bytes for g in groups
               if g.weight_source != "dynamic")


@dataclass(frozen=True)
class CostParams:
    """Knobs of the analytic cost model."""

    batch: int = 32                # samples streamed per stage
    # Duplication splits a group's work along its spatial/batch dimension:
    # each replica receives only its input slice, plus a halo overlap for
    # convolutions.  ``dup_halo`` is the per-extra-replica traffic overhead.
    dup_halo: float = 0.15
    max_dup: int = 64              # duplication search bound
    # Inter-operator pipelines stream at *row-chunk* granularity: a consumer
    # starts once its producer has emitted the few rows its kernel needs, so
    # the fill contribution of a spatial (gemm_m > 1) group is only a
    # fraction of its per-sample latency.  FC-like groups (gemm_m == 1)
    # contribute their full latency.
    pipeline_fill_frac: float = 0.1
    # static (leakage + clock-tree) power per core, as a fraction of one
    # core's peak dynamic power — makes latency savings show up as energy
    # savings, the dominant effect behind the paper's energy wins.
    static_frac: float = 0.35


# ---------------------------------------------------------------------------
# Geometry: group -> MG tiles
# ---------------------------------------------------------------------------


def mg_tiles(g: Group, chip: ChipConfig) -> int:
    """MG-tiles needed to hold one replica of the group's weights."""
    if not g.is_mvm or g.weight_bytes == 0 and g.macs == 0:
        return 0
    cim = chip.core.cim
    rows, n_out = cim.macro.rows, cim.group_n_out
    if g.groups == 1:
        tk = math.ceil(g.gemm_k / rows)
        tn = math.ceil(g.gemm_n / n_out)
        return tk * tn
    # grouped / depth-wise: block-diagonal packing.  Each MG pass computes
    # ``ch`` conv-groups: their input patches concatenated along rows,
    # each group's outputs on its own columns.
    ch = max(1, min(rows // max(g.gemm_k, 1), n_out // max(g.gemm_n, 1)))
    if g.gemm_k <= rows and g.gemm_n <= n_out:
        return math.ceil(g.groups / ch) * math.ceil(g.gemm_n / n_out)
    # giant grouped op (per-group K or N exceeds one MG): per-group tiling
    tk = math.ceil(g.gemm_k / rows)
    tn = math.ceil(g.gemm_n / n_out)
    return g.groups * tk * tn


def column_geometry(g: Group, chip: ChipConfig) -> Tuple[int, int]:
    """(n_columns, slots_per_column).

    A *column* is the set of k-tiles of one n-tile; its INT32 partial sums
    accumulate locally, so all its tiles must land on one core (mirrors
    :func:`repro.core.oplevel._n_tile_columns`).
    """
    cim = chip.core.cim
    rows, n_out = cim.macro.rows, cim.group_n_out
    if g.groups == 1:
        return (math.ceil(max(g.gemm_n, 1) / n_out),
                max(1, math.ceil(g.gemm_k / rows)))
    ch = max(1, min(rows // max(g.gemm_k, 1), n_out // max(g.gemm_n, 1)))
    if g.gemm_k > rows or g.gemm_n > n_out:
        return (g.groups * math.ceil(max(g.gemm_n, 1) / n_out),
                math.ceil(g.gemm_k / rows))
    return math.ceil(g.groups / ch), 1


def column_rows(g: Group, chip: ChipConfig) -> int:
    """Weight rows of one n-column (the CIM_LOAD row count a core pays
    per column when (re)writing its arrays — streamed/dynamic costing)."""
    cim = chip.core.cim
    rows, n_out = cim.macro.rows, cim.group_n_out
    if g.groups == 1 or g.gemm_k > rows or g.gemm_n > n_out:
        return max(g.gemm_k, 1)
    ch = max(1, min(rows // max(g.gemm_k, 1), n_out // max(g.gemm_n, 1)))
    return min(ch, g.groups) * g.gemm_k


def min_cores(g: Group, chip: ChipConfig) -> int:
    """Minimum cores to hold one replica (0 for anchor-less groups).

    Column-granular: all k-tiles of an n-column co-locate on one core, so
    a core hosts ``floor(slots / col_size)`` columns.  Groups whose column
    exceeds a core's slots (huge-K FC layers) stream in rounds instead.
    """
    t = mg_tiles(g, chip)
    if t == 0:
        return 1                   # still needs a core to run vector work
    slots = chip.core.cim.n_macro_groups
    ncol, colsz = column_geometry(g, chip)
    per_core = max(1, slots // colsz)
    return min(math.ceil(ncol / per_core), chip.n_cores)


# ---------------------------------------------------------------------------
# Allocation records
# ---------------------------------------------------------------------------


@dataclass
class GroupAlloc:
    """One group's placement within a stage."""

    gid: int
    tiles: int                 # MG tiles per replica
    cores: int                 # cores per replica
    dup: int                   # replicas
    rounds: int                # weight-streaming rounds (oversized groups)
    percore_slots: int         # MG slots needed on each allocated core
    boundary_in: bool          # inputs come from global memory
    # weight source of this allocation: "static" (gmem prologue),
    # "streamed" (gmem re-stream, ``rounds`` per sample) or "dynamic"
    # (a predecessor's activations, CIM-written every sample)
    weight_source: str = "static"
    col_slots: int = 1         # MG slots one n-column needs (placement)
    # per-sample cycle components (after duplication)
    compute: float = 0.0
    vector: float = 0.0
    comm: float = 0.0
    comm_gmem: float = 0.0     # gmem share of ``comm`` (boundary streams)
    fill_frac: float = 1.0     # chunked-pipelining fill fraction
    load_bytes: int = 0        # weight bytes fetched at stage start (x dup)

    @property
    def total_cores(self) -> int:
        return self.cores * self.dup

    def components(self, calib: Optional[Calibration] = None
                   ) -> Tuple[float, float, float]:
        """(compute, vector, comm) per-sample cycles, optionally scaled
        by per-unit calibration factors (``comm`` splits into its gmem
        and NoC shares so each takes its own factor)."""
        if calib is None or calib.is_identity:
            return self.compute, self.vector, self.comm
        noc_part = self.comm - self.comm_gmem
        return (self.compute * calib.cim,
                self.vector * calib.vector,
                self.comm_gmem * calib.gmem + noc_part * calib.noc)

    def interval_c(self, calib: Optional[Calibration] = None) -> float:
        return max(self.components(calib))

    def latency_c(self, calib: Optional[Calibration] = None) -> float:
        return sum(self.components(calib))

    def fill_c(self, calib: Optional[Calibration] = None) -> float:
        return self.latency_c(calib) * self.fill_frac

    @property
    def interval(self) -> float:
        return self.interval_c()

    @property
    def latency(self) -> float:
        return self.latency_c()

    @property
    def fill(self) -> float:
        """Pipeline-fill contribution (row-chunk streaming)."""
        return self.fill_c()


@dataclass
class StagePlan:
    """A mapped stage with its cost and energy-event ledger."""

    gids: Tuple[int, ...]
    allocs: List[GroupAlloc]
    chip: ChipConfig
    params: CostParams
    shared_cores: bool = False          # groups time-share cores
    bases: Optional[List[int]] = None   # base core per alloc (place_stage)

    # -- derived costs -------------------------------------------------------

    @property
    def machine(self) -> MachineModel:
        """The shared timing/energy model (uncalibrated; calibration is
        applied per evaluation via the ``calib`` arguments)."""
        return machine_for(self.chip)

    @property
    def cores_used(self) -> int:
        return min(self.chip.n_cores,
                   sum(a.total_cores for a in self.allocs))

    def interval_c(self, calib: Optional[Calibration] = None) -> float:
        """Steady-state cycles per sample."""
        if self.shared_cores:
            # groups serialize on shared cores: intervals add, scaled by
            # how over-subscribed the chip is.
            return sum(a.interval_c(calib) for a in self.allocs)
        return max((a.interval_c(calib) for a in self.allocs),
                   default=0.0)

    def fill_cycles(self, calib: Optional[Calibration] = None) -> float:
        """Latency of the first sample through the stage pipeline.

        Groups stream row-chunks to their successors, so spatial groups
        contribute only a fraction of their per-sample latency; the last
        group completes a full sample.
        """
        if not self.allocs:
            return 0.0
        return (sum(a.fill_c(calib) for a in self.allocs[:-1])
                + self.allocs[-1].latency_c(calib))

    def load_cycles_c(self, calib: Optional[Calibration] = None) -> float:
        """Weight (re)load at stage start (gmem stream + array write)."""
        m = self.machine
        total_bytes = sum(a.load_bytes for a in self.allocs)
        gmem = m.gmem_stream_cycles(total_bytes)
        # array row writes happen in parallel across cores; dynamic
        # groups have no prologue (their weights are written per sample
        # from a predecessor's activations — priced in the interval)
        per_core_tiles = max(
            (math.ceil(a.tiles / max(a.cores, 1)) * a.rounds
             for a in self.allocs if a.weight_source != "dynamic"),
            default=0)
        write = per_core_tiles * m.group_load_cycles()
        cycles = max(gmem, write)
        return cycles * calib.load if calib is not None else cycles

    @property
    def interval(self) -> float:
        return self.interval_c()

    @property
    def fill(self) -> float:
        return self.fill_cycles()

    @property
    def load_cycles(self) -> float:
        return self.load_cycles_c()

    def latency_cycles(self, batch: Optional[int] = None,
                       calib: Optional[Calibration] = None) -> float:
        b = batch if batch is not None else self.params.batch
        cycles = (self.load_cycles_c(calib) + self.fill_cycles(calib)
                  + max(0, b - 1) * self.interval_c(calib))
        if calib is not None:
            cycles *= calib.makespan
        return cycles

    # -- energy event ledger (consumed by core.energy) ------------------------

    def energy_events(self, batch: Optional[int] = None,
                      calib: Optional[Calibration] = None
                      ) -> Dict[str, float]:
        b = batch if batch is not None else self.params.batch
        chip = self.chip
        m = self.machine
        ev: Dict[str, float] = {
            "cim_macro_passes": 0.0, "cim_weight_load_bytes": 0.0,
            "vector_elems": 0.0, "noc_byte_hops": 0.0,
            "gmem_bytes": 0.0, "lmem_bytes": 0.0,
        }
        avg_hops = m.avg_hops
        for a in self.allocs:
            g = self._group(a.gid)
            # one pass activates `tiles` MGs = tiles*macros_per_group macros
            passes = g.gemm_m * b * a.tiles * m.macros_per_group
            ev["cim_macro_passes"] += passes
            if a.weight_source == "dynamic":
                if g.weight_incremental and a.rounds == 1:
                    # append-only cache: full staging once, then only
                    # the appended row's tiles re-write per sample
                    no = chip.core.cim.group_n_out
                    if g.transpose_weights:
                        incr_b = g.groups * g.gemm_k * min(g.gemm_n, no)
                    else:
                        incr_b = g.groups * g.gemm_n
                    ev["cim_weight_load_bytes"] += (
                        g.weight_bytes + incr_b * max(b - 1, 0)) * a.dup
                else:
                    # macro arrays rewritten from activations every
                    # sample
                    ev["cim_weight_load_bytes"] += g.weight_bytes \
                        * a.dup * b
            elif a.weight_source == "streamed":
                ev["cim_weight_load_bytes"] += a.load_bytes * b
            else:
                ev["cim_weight_load_bytes"] += a.load_bytes
            ev["vector_elems"] += g.vector_elems * b
            halo = self.params.dup_halo if (g.gemm_m > 1 and a.dup > 1) \
                else 0.0
            in_bytes = g.in_bytes
            if a.weight_source == "dynamic" and g.weight_incremental \
                    and a.rounds == 1:
                # the cache operand is part of in_bytes, but append-only
                # growth only moves the new row per steady-state sample
                row_b = (g.gemm_k if g.transpose_weights
                         else g.gemm_n) * g.groups
                in_bytes = max(in_bytes - g.weight_bytes, 0) + row_b
            in_traffic = in_bytes * (1 + halo * (a.dup - 1) / a.dup) * b
            if a.boundary_in:
                ev["gmem_bytes"] += in_traffic
            else:
                ev["noc_byte_hops"] += in_traffic * avg_hops
            ev["lmem_bytes"] += (g.in_bytes + g.out_bytes) * b
        # boundary outputs spill to gmem (approx: last groups of the stage)
        member = set(self.gids)
        for a in self.allocs:
            g = self._group(a.gid)
            if not any(s in member for s in self._consumers(g)):
                ev["gmem_bytes"] += g.out_bytes * b
        ev["static_core_cycles"] = (self.latency_cycles(b, calib)
                                    * chip.n_cores)
        return ev

    # -- plumbing -------------------------------------------------------------

    _groups_ref: Optional[CondensedGraph] = None

    def bind(self, cg: CondensedGraph) -> "StagePlan":
        self._groups_ref = cg
        return self

    def _group(self, gid: int) -> Group:
        assert self._groups_ref is not None, "StagePlan not bound to a CG"
        return self._groups_ref[gid]

    def _consumers(self, g: Group) -> List[int]:
        assert self._groups_ref is not None
        return [h.idx for h in self._groups_ref if g.idx in h.preds]

    def describe(self) -> str:
        rows = [f"stage{{{','.join(map(str, self.gids))}}} "
                f"cores={self.cores_used} interval={self.interval:.0f} "
                f"load={self.load_cycles:.0f}"]
        for a in self.allocs:
            rows.append(
                f"  g{a.gid}: tiles={a.tiles} cores={a.cores}x{a.dup}"
                f" cyc(c/v/m)={a.compute:.0f}/{a.vector:.0f}/{a.comm:.0f}")
        return "\n".join(rows)


# ---------------------------------------------------------------------------
# Per-group cycle components
# ---------------------------------------------------------------------------


def _alloc_group(g: Group, chip: ChipConfig, params: CostParams,
                 dup: int, boundary_in: bool) -> GroupAlloc:
    cim = chip.core.cim
    m = machine_for(chip)
    tiles = mg_tiles(g, chip)
    chip_tiles = chip.n_cores * cim.n_macro_groups
    eff_tiles = min(tiles, chip_tiles)
    cores = min_cores(g, chip)
    # weight-streaming rounds: per-core slot pressure at column
    # granularity.  Sized for the FULL slot range — when place_stage
    # later time-shares the core, the op-level plan cycles more rounds
    # through the smaller free range, so this is a (documented) lower
    # bound for co-resident streamers; trace/perf price the real count.
    if tiles:
        ncol, colsz = column_geometry(g, chip)
        slots_needed = math.ceil(ncol / cores) * colsz
        rounds = max(1, math.ceil(slots_needed / cim.n_macro_groups))
    else:
        ncol, colsz = 0, 1
        slots_needed = 0
        rounds = 1
    source = g.weight_source if (g.is_mvm and tiles) else "static"
    if source == "static" and rounds > 1:
        source = "streamed"

    m_per_rep = math.ceil(g.gemm_m / dup) if g.gemm_m else 0
    compute = (m_per_rep * m.mvm_interval_beats * rounds
               + m.mvm_fill_beats) if g.is_mvm else 0.0

    vector = g.vector_elems / (m.vector_lanes * max(cores, 1)) / dup if \
        g.vector_elems else 0.0

    # per-round CIM array (re)writes: streamed and dynamic weights are
    # written into macro groups *every sample*; a static group pays this
    # once in the stage prologue (load_cycles) instead.  (Lower bound:
    # the dynamic multi-round path additionally re-loads per m-chunk,
    # which only op-level planning can see — trace prices it exactly.)
    if source != "static":
        if source == "dynamic" and g.weight_incremental and rounds == 1:
            # append-only (KV-cache) steady state: only the tiles
            # covering the appended producer row re-stage — per head,
            # one column (row-granular tile rewrite of the head dim)
            # for Q·Kᵀ, one weight row for P·V.  O(1) in the cache
            # length; sample 0's full staging amortizes away (trace
            # prices it exactly).
            heads_pc = math.ceil(max(g.groups, 1) / max(cores, 1))
            if g.transpose_weights:
                compute += m.weight_load_cycles(heads_pc * g.gemm_k)
                vector += m.vector_cycles("mov", heads_pc * g.gemm_k)
            else:
                compute += m.weight_load_cycles(heads_pc)
                vector += m.vector_cycles("mov", heads_pc * g.gemm_n)
        else:
            rows_pc = math.ceil(ncol / cores) * column_rows(g, chip)
            compute += m.weight_load_cycles(rows_pc)
            if source == "dynamic":
                # gather-transpose staging of the producer's activations
                # into the CIM write layout (vector unit, per core)
                w_elems = g.gemm_k * g.gemm_n * g.groups
                vector += m.vector_cycles(
                    "mov", math.ceil(w_elems / max(cores, 1)))

    # Input delivery.  Replicas own disjoint spatial/batch slices: each
    # receives in_bytes/dup (+ conv halo) over its own mesh port, so the
    # per-sample comm interval scales down with duplication — this is the
    # communication side of the paper's duplicate-vs-communicate trade-off.
    halo = params.dup_halo if (g.gemm_m > 1 and dup > 1) else 0.0
    in_bytes = g.in_bytes
    if source == "dynamic" and g.weight_incremental and rounds == 1:
        # cache operand rides in in_bytes; append-only growth streams
        # one new row per steady-state sample, not the whole buffer
        row_b = (g.gemm_k if g.transpose_weights else g.gemm_n) * g.groups
        in_bytes = max(in_bytes - g.weight_bytes, 0) + row_b
    in_traffic = in_bytes * (1 + halo * (dup - 1) / dup)
    comm_gmem = 0.0
    if boundary_in:
        # gmem streams are a shared resource
        comm_gmem = m.gmem_stream_cycles(in_traffic)
        comm = comm_gmem
    else:
        comm = in_traffic / (m.link_bytes_per_cycle * dup)
        comm += m.router_hop_cycles * m.avg_hops
    # output delivery to the next group / gmem, likewise port-parallel
    comm += g.out_bytes / (m.link_bytes_per_cycle * dup)
    if source == "streamed":
        # multi-round groups re-fetch their weights from gmem per sample
        restream = m.gmem_stream_cycles(g.weight_bytes * dup)
        comm_gmem += restream
        comm += restream

    fill_frac = params.pipeline_fill_frac if g.gemm_m > 4 else 1.0
    return GroupAlloc(
        gid=g.idx, tiles=eff_tiles, cores=cores, dup=dup, rounds=rounds,
        percore_slots=min(slots_needed, cim.n_macro_groups),
        boundary_in=boundary_in, weight_source=source,
        col_slots=min(colsz, cim.n_macro_groups),
        compute=float(compute), vector=float(vector),
        comm=float(comm), comm_gmem=float(comm_gmem), fill_frac=fill_frac,
        # every replica fetches the full static weights once per stage
        # execution; dynamic weights never touch gmem (they arrive as a
        # predecessor's activations and are priced per sample above)
        load_bytes=0 if source == "dynamic" else g.weight_bytes * dup)


def place_stage(allocs: Sequence["GroupAlloc"],
                chip: ChipConfig) -> Optional[List[int]]:
    """First-fit placement of a stage's groups onto the core grid.

    Returns one base core per alloc (replicas occupy consecutive
    ``cores``-wide windows from there), such that no core's MG-slot
    occupancy exceeds the CIM unit — or ``None`` if no placement exists.
    Weight-streaming groups (rounds > 1) take every remaining slot of
    their window: they *prefer* an exclusive window (their round count
    was sized for the full slot range) but may time-share a core as
    long as one n-column's worth of slots is free — the op-level
    planner then cycles the rounds through the group's own slot range
    above its co-residents.  This is the single source of truth for
    stage feasibility: the cost model and the code generator both use
    it.
    """
    slots = chip.core.cim.n_macro_groups
    occ = [0] * chip.n_cores
    # place big groups first for tighter packing, but report in input order
    order = sorted(range(len(allocs)),
                   key=lambda i: -(allocs[i].total_cores * 1000
                                   + allocs[i].percore_slots))
    result = [0] * len(allocs)
    for i in order:
        a = allocs[i]
        need = min(a.total_cores, chip.n_cores)
        placed = False
        if a.rounds > 1:
            passes = ("exclusive", "shared")
        else:
            passes = ("additive",)
        for mode in passes:
            for base in range(0, chip.n_cores - need + 1):
                window = occ[base:base + need]
                # exact additive accounting: final per-core occupancy is
                # order-independent, so codegen (stage order) can never
                # overflow a placement validated here (size order)
                if mode == "exclusive":
                    ok = all(o == 0 for o in window)
                elif mode == "shared":
                    ok = all(o + a.col_slots <= slots for o in window)
                else:
                    ok = all(o + a.percore_slots <= slots for o in window)
                if ok:
                    for c in range(base, base + need):
                        occ[c] = slots if a.rounds > 1 \
                            else occ[c] + a.percore_slots
                    result[i] = base
                    placed = True
                    break
            if placed:
                break
        if not placed:
            return None
    return result


def needs_streaming(g: Group, chip: ChipConfig) -> bool:
    """Group's columns exceed its minimal allocation's slots -> it must
    re-stream weights every sample and monopolizes its stage."""
    if mg_tiles(g, chip) == 0:
        return False
    ncol, colsz = column_geometry(g, chip)
    cores = min_cores(g, chip)
    return math.ceil(ncol / cores) * colsz > chip.core.cim.n_macro_groups


def _stage_feasible(groups: Sequence[Group], chip: ChipConfig) -> bool:
    """A stage is feasible if its groups jointly fit the chip's MG
    capacity (time-sharing of cores allowed).  A weight-streaming group
    contributes the slots of the cores it monopolizes, not its (larger)
    nominal tile count — it may share a stage; :func:`place_stage` is
    the final arbiter."""
    slots = chip.core.cim.n_macro_groups
    chip_tiles = chip.n_cores * slots
    total = sum(min(mg_tiles(g, chip), min_cores(g, chip) * slots)
                for g in groups)
    return total <= chip_tiles or len(groups) == 1


# ---------------------------------------------------------------------------
# Mapping strategies
# ---------------------------------------------------------------------------


def _boundary_flags(groups: Sequence[Group], stage_set: set) -> Dict[int, bool]:
    flags = {}
    for g in groups:
        flags[g.idx] = (not g.preds) or any(p not in stage_set
                                            for p in g.preds)
    return flags


def generic_mapping(cg: CondensedGraph, gids: Sequence[int],
                    chip: ChipConfig, params: CostParams) -> Optional[StagePlan]:
    """Baseline 1 (§IV-B): inter-layer pipeline, **no duplication**."""
    groups = [cg[i] for i in gids]
    if not _stage_feasible(groups, chip):
        return None
    stage_set = set(gids)
    flags = _boundary_flags(groups, stage_set)
    allocs = [_alloc_group(g, chip, params, dup=1,
                           boundary_in=flags[g.idx]) for g in groups]
    bases = place_stage(allocs, chip)
    if bases is None:
        return None
    shared = sum(a.total_cores for a in allocs) > chip.n_cores
    return StagePlan(tuple(gids), allocs, chip, params,
                     shared_cores=shared, bases=bases).bind(cg)


def _improve_duplication(cg: CondensedGraph, allocs: List[GroupAlloc],
                         chip: ChipConfig, params: CostParams,
                         flags: Dict[int, bool]) -> List[GroupAlloc]:
    """Greedy duplication hillclimb: repeatedly replicate the bottleneck
    group while cores remain and the stage interval improves."""
    def used() -> int:
        return sum(a.total_cores for a in allocs)

    while True:
        free = chip.n_cores - used()
        if free <= 0:
            break
        # current bottleneck
        order = sorted(range(len(allocs)), key=lambda i: -allocs[i].interval)
        improved = False
        for i in order:
            a = allocs[i]
            g = cg[a.gid]
            # duplication splits gemm_m positions and/or batch samples
            dup_cap = min(params.max_dup, max(g.gemm_m, 1) * params.batch)
            if not g.is_mvm or a.dup >= dup_cap or a.rounds > 1:
                continue
            if a.cores > free:
                continue
            cand = _alloc_group(g, chip, params, dup=a.dup + 1,
                                boundary_in=flags[a.gid])
            if cand.interval < a.interval - 1e-9:
                trial = list(allocs)
                trial[i] = cand
                if place_stage(trial, chip) is None:
                    continue
                allocs[i] = cand
                improved = True
                break
        if not improved:
            break
    return allocs


def optimal_mapping(cg: CondensedGraph, gids: Sequence[int],
                    chip: ChipConfig, params: CostParams) -> Optional[StagePlan]:
    """The paper's ``OptimalMapping(stage, R)``: joint core allocation +
    weight duplication minimizing the stage's steady-state interval."""
    base = generic_mapping(cg, gids, chip, params)
    if base is None:
        return None
    if base.shared_cores:
        return base            # no spare cores to duplicate into
    stage_set = set(gids)
    flags = _boundary_flags([cg[i] for i in gids], stage_set)
    allocs = _improve_duplication(cg, list(base.allocs), chip, params, flags)
    bases = place_stage(allocs, chip)
    if bases is None:           # should not happen (hillclimb checked)
        return base
    return StagePlan(tuple(gids), allocs, chip, params,
                     bases=bases).bind(cg)


def opportunistic_mapping(cg: CondensedGraph, gids: Sequence[int],
                          chip: ChipConfig,
                          params: CostParams) -> Optional[StagePlan]:
    """Baseline 2 (§IV-B, CIM-MLC style): capacity-first partition given,
    then *opportunistic* duplication into whatever cores remain vacant.

    Identical duplication mechanics to :func:`optimal_mapping` — the
    difference is upstream: the partition was chosen greedily by capacity,
    not by the DP, so packed stages rarely have vacant cores.
    """
    return optimal_mapping(cg, gids, chip, params)

"""OP-level optimization (paper §III-C): virtual → physical mapping.

For every group of a mapped stage this module derives an :class:`OpSchedule`:

* **Virtual mapping** — the operator's loop nest is flattened to an ideal
  2-D weight layout ``(K = reduction, N = output channels)`` in a
  constraint-free space; convolutions go through the im2col transformation
  (HWC feature layout, ``(ky, kx, c)`` patch ordering — one contiguous
  ``kw*C`` segment per kernel row, which the code generator exploits to
  gather a whole patch row with a single strided ``V_MOV``).
* **Physical mapping** — the ideal layout is tiled to macro-group geometry:
  ``k``-tiles bounded by macro rows, ``n``-tiles by the MG's output width;
  grouped/depth-wise convolutions use block-diagonal packing (several conv
  groups share one MG pass, each on its own rows x columns block).  Tiles
  are assigned round-robin to the replica's cores, and the ``m`` dimension
  is chunked (one conv output row, or <= 511 positions — the CIM_MVM ``rep``
  field) against the local-memory segment budget.

The resulting schedule fixes every address-generation constant the code
generator needs; codegen then only emits instructions.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from .arch import ChipConfig
from .graph import (WEIGHT_DYNAMIC, WEIGHT_STATIC, WEIGHT_STREAMED,
                    CondensedGraph, Group, Op)
from .mapping import GroupAlloc, StagePlan

__all__ = ["Im2colSpec", "MgAssign", "ReplicaPlan", "OpSchedule",
           "plan_group", "plan_stage", "incremental_ops", "MAX_REP"]

MAX_REP = 511          # CIM_MVM imm10 repetition bound


@dataclass(frozen=True)
class Im2colSpec:
    """Conv geometry for the im2col gather (HWC layout)."""

    h: int
    w: int
    cin: int
    kh: int
    kw: int
    stride: int
    pad: int
    ho: int
    wo: int
    depthwise: bool = False

    @property
    def patch_len(self) -> int:
        """im2col row length: (ky, kx, c) ordering."""
        return self.kh * self.kw * self.cin


@dataclass(frozen=True)
class PoolSpec:
    """Fused pooling geometry (applies to the anchor's HWC output)."""

    kind: str          # maxpool | avgpool
    k: int
    stride: int
    pad: int
    ho: int            # pooled output rows
    wo: int            # pooled output cols


@dataclass(frozen=True)
class MgAssign:
    """One macro-group's share of the operator.

    All k-tiles of a given n-tile are co-located on one core (consecutive
    slots) so INT32 partial sums accumulate locally; when they exceed the
    core's *free* MG slots the surplus executes in later ``round`` s,
    cycling the group's own slot range (above any co-resident groups on
    a time-shared core) with weight re-streaming.

    ``source`` is the tile's weight source: ``static`` tiles load a
    gmem blob in the stage prologue, ``streamed`` tiles re-load per
    sample per round, ``dynamic`` tiles are gathered from a predecessor
    group's activations in local memory and CIM-written every sample.
    """

    core: int          # physical core id
    slot: int          # MG index within the core's CIM unit
    round: int         # weight-streaming round this tile executes in
    k_off: int         # input-vector offset (elements)
    k_len: int         # rows used
    n_off: int         # output-channel offset
    n_len: int         # output channels produced
    ch_off: int = 0    # block-diagonal packing: first conv group
    ch_cnt: int = 1    # conv groups packed into this MG
    source: str = WEIGHT_STATIC


@dataclass
class ReplicaPlan:
    """One weight replica: its cores, MG assignments and m-range."""

    replica: int
    cores: Tuple[int, ...]
    assigns: List[MgAssign]
    m_lo: int
    m_hi: int          # owns output positions [m_lo, m_hi)


@dataclass
class OpSchedule:
    """Everything codegen needs for one group."""

    gid: int
    name: str
    alloc: GroupAlloc
    replicas: List[ReplicaPlan]
    k_total: int               # im2col'd reduction length (elements)
    n_total: int               # output channels
    m_total: int               # output positions per sample
    m_chunk: int               # positions per CIM_MVM burst
    im2col: Optional[Im2colSpec]
    vector_ops: Tuple[str, ...]    # fused post-ops in execution order
    pool: Optional[PoolSpec] = None
    gap: bool = False          # fused global average pool
    weight_bits: int = 8
    n_rounds: int = 1          # weight-streaming rounds
    # weight-source metadata (see repro.core.graph.WEIGHT_SOURCES):
    weight_source: str = WEIGHT_STATIC
    weight_pred: Optional[int] = None   # producer group (None = graph in)
    w_rows: int = 0                     # producer output rows
    w_row_bytes: int = 0                # producer output row bytes
    w_transpose: bool = False           # W = producer outputᵀ (Q·Kᵀ)
    # append-only weight growth (KV-cached decode): samples s > 0 may
    # re-stage only the appended producer row (see incremental_ops)
    w_incremental: bool = False
    # graph-input op id of the weight operand when weight_pred is None
    # (multi-input graphs: codegen offsets the per-sample gmem region)
    w_input: Optional[int] = None

    @property
    def n_chunks(self) -> int:
        return math.ceil(self.m_total / self.m_chunk) if self.m_total else 0

    @property
    def psum_bytes_per_chunk(self) -> int:
        return self.m_chunk * self.n_total * 4

    @property
    def stage_in_bytes_per_chunk(self) -> int:
        return self.m_chunk * self.k_total


class OpLevelError(ValueError):
    pass


# ---------------------------------------------------------------------------
# Geometry helpers
# ---------------------------------------------------------------------------


def _conv_spec(cg: CondensedGraph, g: Group) -> Optional[Im2colSpec]:
    """Recover conv geometry from the source graph, if available."""
    if cg.source is None or g.anchor is None:
        return None
    op = cg.source.ops[g.anchor]
    if op.kind not in ("conv", "dwconv"):
        return None
    src = cg.source.ops[op.inputs[0]]
    h, w, cin = src.out_shape
    ho, wo, _ = op.out_shape
    return Im2colSpec(h=h, w=w, cin=cin, kh=op.attrs["k"], kw=op.attrs["k"],
                      stride=op.attrs["stride"], pad=op.attrs["padding"],
                      ho=ho, wo=wo, depthwise=(op.kind == "dwconv"))


def _fused_vector_ops(cg: CondensedGraph, g: Group) \
        -> Tuple[Tuple[str, ...], Optional[PoolSpec], bool]:
    """(post-anchor fused ops, pooling spec, gap?) — bn folds into requant."""
    if cg.source is None:
        return (), None, False
    out = []
    pool: Optional[PoolSpec] = None
    gap = False
    for i in g.op_ids:
        op = cg.source.ops[i]
        if op.is_mvm or op.kind in ("bn", "flatten", "identity"):
            continue
        if op.kind in ("maxpool", "avgpool"):
            ho, wo, _ = op.out_shape
            pool = PoolSpec(kind=op.kind, k=op.attrs["k"],
                            stride=op.attrs["stride"],
                            pad=op.attrs.get("padding", 0), ho=ho, wo=wo)
        if op.kind == "globalpool":
            gap = True
        out.append(op.kind)
    return tuple(out), pool, gap


def _split(total: int, tile: int) -> List[Tuple[int, int]]:
    """[(offset, length)] covering ``total`` in ``tile``-sized pieces."""
    out = []
    off = 0
    while off < total:
        out.append((off, min(tile, total - off)))
        off += tile
    return out or [(0, 0)]


# ---------------------------------------------------------------------------
# Physical mapping
# ---------------------------------------------------------------------------


def _n_tile_columns(g: Group, chip: ChipConfig) \
        -> List[List[Tuple[int, int, int, int, int, int]]]:
    """Tiles grouped into *columns*: each column is the list of k-tiles of
    one n-tile, [(k_off, k_len, n_off, n_len, ch_off, ch_cnt)].  A column's
    partial sums accumulate locally, so all its tiles land on one core.
    """
    cim = chip.core.cim
    rows, n_out = cim.macro.rows, cim.group_n_out
    if g.groups == 1:
        return [[(k_off, k_len, n_off, n_len, 0, 1)
                 for k_off, k_len in _split(g.gemm_k, rows)]
                for n_off, n_len in _split(g.gemm_n, n_out)]
    ch = max(1, min(rows // max(g.gemm_k, 1), n_out // max(g.gemm_n, 1)))
    if g.gemm_k > rows or g.gemm_n > n_out:
        # giant grouped op (per-group K or N exceeds one MG): tile each
        # conv group independently
        return [[(ci * g.gemm_k + k_off, k_len,
                  ci * g.gemm_n + n_off, n_len, ci, 1)
                 for k_off, k_len in _split(g.gemm_k, rows)]
                for ci in range(g.groups)
                for n_off, n_len in _split(g.gemm_n, n_out)]
    # block-diagonal packing: one tile per packed channel bundle
    return [[(ch_off * g.gemm_k, min(ch, g.groups - ch_off) * g.gemm_k,
              ch_off * g.gemm_n, min(ch, g.groups - ch_off) * g.gemm_n,
              ch_off, min(ch, g.groups - ch_off))]
            for ch_off in range(0, g.groups, ch)]


def plan_group(cg: CondensedGraph, g: Group, alloc: GroupAlloc,
               chip: ChipConfig, core_base: int,
               slot_base: Optional[dict] = None,
               op_owner: Optional[dict] = None) -> OpSchedule:
    """Physical mapping of one group onto its allocated cores.

    ``core_base`` is the first physical core of this group's allocation;
    replicas occupy consecutive ``alloc.cores``-sized windows.
    ``slot_base`` maps physical core -> first free MG slot (time-shared
    stages pack several groups' weights onto one core's macro groups).
    When a core's tiles exceed its *free* slots, the surplus executes in
    weight-streaming rounds that cycle the group's own slot range above
    its co-residents (INT32 partial sums accumulate across rounds, so a
    column's k-tiles may split between rounds).
    """
    cim = chip.core.cim
    spec = _conv_spec(cg, g)
    vops, pool, gap = _fused_vector_ops(cg, g)
    k_total = g.gemm_k * g.groups if g.groups > 1 else g.gemm_k
    n_total = g.gemm_n * g.groups if g.groups > 1 else g.gemm_n
    m_total = g.gemm_m
    slot_base = slot_base if slot_base is not None else {}
    dynamic = g.dynamic_weights

    columns = _n_tile_columns(g, chip)
    slots = cim.n_macro_groups

    # Bucket columns' tiles per logical core (round-robin), then assign
    # slots per PHYSICAL core above whatever co-resident groups already
    # occupy there (additive accounting — matches mapping.place_stage).
    per_core_tiles: List[List[Tuple[int, int, int, int, int, int]]] = \
        [[] for _ in range(alloc.cores)]
    for ci, col in enumerate(columns):
        per_core_tiles[ci % alloc.cores].extend(col)
    n_rounds = 1
    streamed_cores: set = set()
    placed_by_rep: List[List[MgAssign]] = []
    for r in range(alloc.dup):
        assigns: List[MgAssign] = []
        for c, tiles_c in enumerate(per_core_tiles):
            pc = core_base + r * alloc.cores + c
            start = slot_base.get(pc, 0)
            avail = slots - start
            if len(tiles_c) > avail:
                if avail <= 0:
                    raise OpLevelError(
                        f"{g.name}: no free MG slots on core {pc} "
                        f"(co-residents occupy all {slots})")
                # weight-streaming rounds cycle this group's own slot
                # range [start, slots) above any co-resident groups
                streamed_cores.add(pc)
                src = WEIGHT_DYNAMIC if dynamic else WEIGHT_STREAMED
                for s, t in enumerate(tiles_c):
                    rnd, slot = divmod(s, avail)
                    n_rounds = max(n_rounds, rnd + 1)
                    assigns.append(MgAssign(
                        core=pc, slot=start + slot, round=rnd, k_off=t[0],
                        k_len=t[1], n_off=t[2], n_len=t[3], ch_off=t[4],
                        ch_cnt=t[5], source=src))
            else:
                src = WEIGHT_DYNAMIC if dynamic else WEIGHT_STATIC
                for s, t in enumerate(tiles_c):
                    assigns.append(MgAssign(
                        core=pc, slot=start + s, round=0, k_off=t[0],
                        k_len=t[1], n_off=t[2], n_len=t[3], ch_off=t[4],
                        ch_cnt=t[5], source=src))
        placed_by_rep.append(assigns)
    # record additive occupancy: streamed cores are consumed to the top
    # (their rounds cycle everything above the co-residents)
    for r in range(alloc.dup):
        for c, tiles_c in enumerate(per_core_tiles):
            pc = core_base + r * alloc.cores + c
            slot_base[pc] = slots if pc in streamed_cores \
                else slot_base.get(pc, 0) + len(tiles_c)

    # Replica ownership is row-aligned for convs (and pool-stride aligned
    # when pooling is fused) so spatial slices map to whole rows.
    align = 1
    if spec is not None:
        align = spec.wo * (pool.stride if pool is not None else 1)
    m_per = math.ceil(max(m_total, 1) / alloc.dup)
    m_per = math.ceil(m_per / align) * align

    replicas: List[ReplicaPlan] = []
    for r in range(alloc.dup):
        cores = tuple(core_base + r * alloc.cores + c
                      for c in range(alloc.cores))
        replicas.append(ReplicaPlan(
            replica=r, cores=cores, assigns=placed_by_rep[r],
            m_lo=min(r * m_per, m_total), m_hi=min((r + 1) * m_per, m_total)))

    # m-chunking: one conv output row, bounded by rep field and lmem segment
    seg = chip.core.local_mem.segment_bytes
    if spec is not None:
        m_chunk = spec.wo
    else:
        m_chunk = min(max(m_total, 1), MAX_REP)
    m_chunk = min(m_chunk, MAX_REP)
    # staging (int8 K) + psum (int32 N) per chunk must fit one segment each
    while m_chunk > 1 and (m_chunk * k_total > seg
                           or m_chunk * n_total * 4 > seg):
        m_chunk = max(1, m_chunk // 2)

    # weight-source metadata: a dynamic group's weights are its anchor's
    # second input — a predecessor group's (or the graph input's)
    # activations, gathered from local memory every sample
    w_pred: Optional[int] = None
    w_rows = w_row_bytes = 0
    if dynamic:
        if cg.source is None or g.anchor is None:
            raise OpLevelError(f"{g.name}: dynamic weights need the "
                               f"source graph")
        anchor = cg.source.ops[g.anchor]
        if len(anchor.inputs) < 2:
            raise OpLevelError(f"{g.name}: dynamic-weight anchor has no "
                               f"weight operand")
        wop = cg.source.ops[anchor.inputs[1]]
        if op_owner is None:
            op_owner = {i: grp.idx for grp in cg for i in grp.op_ids}
        w_pred = op_owner.get(wop.idx)          # None => graph input
        w_input = wop.idx if w_pred is None else None
        w_row_bytes = int(wop.out_shape[-1]) * wop.act_bits // 8
        w_rows = max(1, wop.out_elems // max(int(wop.out_shape[-1]), 1))
    else:
        w_input = None
    source = (WEIGHT_DYNAMIC if dynamic
              else WEIGHT_STREAMED if n_rounds > 1 else WEIGHT_STATIC)

    return OpSchedule(
        gid=g.idx, name=g.name, alloc=alloc, replicas=replicas,
        k_total=k_total, n_total=n_total, m_total=m_total, m_chunk=m_chunk,
        im2col=spec, vector_ops=vops, pool=pool, gap=gap,
        weight_bits=g.weight_bits, n_rounds=n_rounds,
        weight_source=source, weight_pred=w_pred, w_rows=w_rows,
        w_row_bytes=w_row_bytes, w_transpose=g.transpose_weights,
        w_incremental=bool(dynamic and g.weight_incremental),
        w_input=w_input)


def incremental_ops(g: Group, sched: OpSchedule, a: MgAssign
                    ) -> Optional[Tuple[List[int], List[int]]]:
    """Append-row re-stage shape for one MG assign, or ``None``.

    For a ``kv_append`` dynamic group, samples ``s > 0`` differ from
    sample ``s-1`` in exactly one producer row — the appended cache
    entry ``w_rows - 1``.  This helper is the single definition of
    *which* tiles that row touches and *what* it costs, shared by
    codegen (instruction emission) and trace (unit pricing) so the two
    cannot drift:

    * non-transpose (``P·V``): the appended V row is one new *weight
      row*; gather one ``n_len``-wide row per packed head and CIM-write
      it with a single-row ``CIM_LOAD`` (array writes are
      row-granular, so an appended row costs exactly one row write).
    * transpose (``Q·Kᵀ``): the appended K row is one new *weight
      column*; gather one ``k_len``-deep column per packed head, but
      the row-granular array write must re-write the whole touched
      tile (``k_len`` rows) — still O(1) in the cache length, since
      ``k_len`` is the head dimension.

    Returns ``(gather_elems, load_rows)``: per-V_MOV element counts and
    per-CIM_LOAD row counts, or ``None`` when the assign's tile does
    not cover the appended row.  Only meaningful for single-round
    schedules (multi-round slot cycling leaves nothing resident).
    """
    if not (sched.w_incremental and sched.weight_source == WEIGHT_DYNAMIC):
        return None
    row = sched.w_rows - 1
    gk, gn = g.gemm_k, g.gemm_n
    if a.ch_cnt > 1:
        # block-diagonal packed tile: every packed head's block spans
        # the full per-head K and N, so the appended row always lands
        if sched.w_transpose:
            return [gk] * a.ch_cnt, [a.k_len]
        return [gn] * a.ch_cnt, [1] * a.ch_cnt
    ch = a.ch_off
    if sched.w_transpose:
        n0 = a.n_off - ch * gn          # tile-local cache-row window
        if not n0 <= row < n0 + a.n_len:
            return None
        return [a.k_len], [a.k_len]
    k0 = a.k_off - ch * gk
    if not k0 <= row < k0 + a.k_len:
        return None
    return [a.n_len], [1]


def plan_stage(cg: CondensedGraph, stage: StagePlan,
               chip: ChipConfig) -> List[OpSchedule]:
    """Assign physical cores to every group of the stage and plan each.

    Groups are placed left-to-right on the core grid in topological order —
    producers end up adjacent to consumers, which is what the NoC cost model
    assumes.  When the stage time-shares cores (``shared_cores``), groups
    overlap on the same windows (their programs serialize).
    """
    schedules: List[OpSchedule] = []
    slot_base: dict = {}
    op_owner = {i: grp.idx for grp in cg for i in grp.op_ids}
    if stage.bases is not None:
        # plan single-round (additive) groups first so a streaming
        # group's rounds cycle above ALL its co-residents' slots —
        # place_stage validated occupancy in size order, and additive
        # accounting is order-independent, so only the streamers (which
        # consume "the rest" of a core) must come last.  Results are
        # reported in stage order regardless.
        order = sorted(range(len(stage.allocs)),
                       key=lambda i: (stage.allocs[i].rounds > 1, i))
        out: List[Optional[OpSchedule]] = [None] * len(stage.allocs)
        for i in order:
            alloc, base = stage.allocs[i], stage.bases[i]
            out[i] = plan_group(cg, cg[alloc.gid], alloc, chip,
                                core_base=base, slot_base=slot_base,
                                op_owner=op_owner)
        return [s for s in out if s is not None]
    # fallback: sequential left-to-right walk (hand-built StagePlans)
    base = 0
    for alloc in stage.allocs:
        g = cg[alloc.gid]
        need = alloc.total_cores
        if base + need > chip.n_cores:
            base = 0                      # wrap: time-share from the left
        schedules.append(plan_group(cg, g, alloc, chip, core_base=base,
                                    slot_base=slot_base,
                                    op_owner=op_owner))
        base += need
        if base >= chip.n_cores:
            base = 0
    return schedules

"""DNN workload graph builders (paper §IV-A benchmark suite).

The paper evaluates on ResNet18, VGG19 (compute-intensive) and MobileNetV2,
EfficientNetB0 (compact, depth-wise separable).  All INT8 weights/activations
(§IV-A).  Builders return :class:`repro.core.graph.Graph` objects at standard
ImageNet geometry (224x224x3) unless ``res`` is overridden — tests use small
``res`` to keep the simulator fast.

A bonus ``transformer_lm`` builder exercises the compiler on transformer
blocks (attention score/context matmuls are dynamic-weight MVMs, marked
``attrs['dynamic_weights']``).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .graph import Graph, Op

__all__ = [
    "resnet18", "vgg19", "mobilenetv2", "efficientnetb0",
    "transformer_lm", "transformer_decode", "tiny_cnn", "WORKLOADS",
    "build",
]


# ---------------------------------------------------------------------------
# ResNet18
# ---------------------------------------------------------------------------


def resnet18(res: int = 224, n_classes: int = 1000) -> Graph:
    g = Graph("resnet18")
    x = g.input("image", (res, res, 3))
    x = g.conv("conv1", x, cout=64, k=7, stride=2, padding=3, act="relu")
    x = g.pool("maxpool", x, k=3, stride=2, padding=1)

    def block(x: int, name: str, cout: int, stride: int) -> int:
        cin = g.ops[x].out_shape[-1]
        y = g.conv(f"{name}.conv1", x, cout=cout, k=3, stride=stride,
                   act="relu")
        y = g.conv(f"{name}.conv2", y, cout=cout, k=3)
        if stride != 1 or cin != cout:
            x = g.conv(f"{name}.down", x, cout=cout, k=1, stride=stride)
        y = g.eltwise(f"{name}.add", "add", y, x)
        return g.unary(f"{name}.relu", "relu", y)

    for li, (cout, stride) in enumerate(
            [(64, 1), (128, 2), (256, 2), (512, 2)], start=1):
        x = block(x, f"layer{li}.0", cout, stride)
        x = block(x, f"layer{li}.1", cout, 1)

    x = g.globalpool("avgpool", x)
    g.linear("fc", x, cout=n_classes)
    return g


# ---------------------------------------------------------------------------
# VGG19
# ---------------------------------------------------------------------------


def vgg19(res: int = 224, n_classes: int = 1000) -> Graph:
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
           512, 512, 512, 512, "M", 512, 512, 512, 512, "M"]
    g = Graph("vgg19")
    x = g.input("image", (res, res, 3))
    ci = 0
    for v in cfg:
        if v == "M":
            x = g.pool(f"pool{ci}", x, k=2, stride=2)
        else:
            ci += 1
            x = g.conv(f"conv{ci}", x, cout=int(v), k=3, act="relu")
    x = g.unary("flatten", "flatten", x)
    # classifier operates on the flattened 7x7x512; keep gemm_m = 1
    h, w, c = g.ops[g.ops[x].inputs[0]].out_shape
    g.ops[x].out_shape = (h * w * c,)
    x = g.linear("fc1", x, cout=4096, act="relu")
    x = g.linear("fc2", x, cout=4096, act="relu")
    g.linear("fc3", x, cout=n_classes)
    return g


# ---------------------------------------------------------------------------
# MobileNetV2
# ---------------------------------------------------------------------------

_MBV2_CFG = [  # (expansion t, cout, repeats, stride)
    (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
    (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1),
]


def mobilenetv2(res: int = 224, n_classes: int = 1000) -> Graph:
    g = Graph("mobilenetv2")
    x = g.input("image", (res, res, 3))
    x = g.conv("stem", x, cout=32, k=3, stride=2, act="relu6")
    bi = 0
    for t, c, n, s in _MBV2_CFG:
        for i in range(n):
            stride = s if i == 0 else 1
            cin = g.ops[x].out_shape[-1]
            name = f"block{bi}"
            y = x
            hidden = cin * t
            if t != 1:
                y = g.conv(f"{name}.expand", y, cout=hidden, k=1, act="relu6")
            y = g.conv(f"{name}.dw", y, cout=hidden, k=3, stride=stride,
                       groups=hidden, act="relu6")
            y = g.conv(f"{name}.project", y, cout=c, k=1)
            if stride == 1 and cin == c:
                y = g.eltwise(f"{name}.add", "add", y, x)
            x = y
            bi += 1
    x = g.conv("head", x, cout=1280, k=1, act="relu6")
    x = g.globalpool("avgpool", x)
    g.linear("fc", x, cout=n_classes)
    return g


# ---------------------------------------------------------------------------
# EfficientNetB0
# ---------------------------------------------------------------------------

_EFB0_CFG = [  # (expansion, cout, repeats, stride, kernel)
    (1, 16, 1, 1, 3), (6, 24, 2, 2, 3), (6, 40, 2, 2, 5), (6, 80, 3, 2, 3),
    (6, 112, 3, 1, 5), (6, 192, 4, 2, 5), (6, 320, 1, 1, 3),
]


def efficientnetb0(res: int = 224, n_classes: int = 1000,
                   se_ratio: float = 0.25) -> Graph:
    g = Graph("efficientnetb0")
    x = g.input("image", (res, res, 3))
    x = g.conv("stem", x, cout=32, k=3, stride=2, act="silu")
    bi = 0
    for t, c, n, s, k in _EFB0_CFG:
        for i in range(n):
            stride = s if i == 0 else 1
            cin = g.ops[x].out_shape[-1]
            name = f"mbconv{bi}"
            y = x
            hidden = cin * t
            if t != 1:
                y = g.conv(f"{name}.expand", y, cout=hidden, k=1, act="silu")
            y = g.conv(f"{name}.dw", y, cout=hidden, k=k, stride=stride,
                       groups=hidden, act="silu")
            # squeeze-and-excite on the depthwise output
            se_c = max(1, int(cin * se_ratio))
            sq = g.globalpool(f"{name}.se.pool", y)
            sq = g.linear(f"{name}.se.reduce", sq, cout=se_c, act="silu")
            sq = g.linear(f"{name}.se.expand", sq, cout=hidden, act="sigmoid")
            y = g.eltwise(f"{name}.se.scale", "mul", y, sq)
            y = g.conv(f"{name}.project", y, cout=c, k=1)
            if stride == 1 and cin == c:
                y = g.eltwise(f"{name}.add", "add", y, x)
            x = y
            bi += 1
    x = g.conv("head", x, cout=1280, k=1, act="silu")
    x = g.globalpool("avgpool", x)
    g.linear("fc", x, cout=n_classes)
    return g


# ---------------------------------------------------------------------------
# Transformer LM (post-LN blocks; attention matmuls are dynamic-weight
# MVMs — their "weights" are the K / V activations, written into macro
# groups at runtime; see the weight-source abstraction in repro.core.graph)
# ---------------------------------------------------------------------------


def transformer_lm(n_layers: int = 4, d_model: int = 512, n_heads: int = 8,
                   d_ff: Optional[int] = None, seq: int = 128,
                   vocab: int = 32000) -> Graph:
    """Post-LN transformer blocks over an embedding projection.

    ``scores = q @ kᵀ`` carries ``attrs['transpose_weights']`` (the
    weight matrix is the transposed K activations); ``ctx = p @ v``
    uses V rows directly.  Both are grouped per-head GEMMs whose
    block-diagonal packing consumes whole activation rows, so the
    compiled input layout is exactly the producer's HW row layout.
    Post-LN placement keeps every residual tap a *group output*, which
    is the layout contract codegen's side-operand routing assumes.
    """
    d_ff = d_ff or 4 * d_model
    g = Graph(f"transformer_{n_layers}L_{d_model}d")
    x = g.input("tokens", (seq, d_model))   # token embeddings
    # embedding projection: gives layer 0's residual tap a group output
    x = g.linear("embed", x, cout=d_model, bias=False)
    dh = d_model // n_heads

    def mha(name: str, src: int) -> int:
        q = g.linear(f"{name}.q", src, cout=d_model, bias=False)
        k = g.linear(f"{name}.k", src, cout=d_model, bias=False)
        v = g.linear(f"{name}.v", src, cout=d_model, bias=False)
        # scores = q @ k^T : per-head (seq x dh) @ (dh x seq)
        sc = g.add(Op(name=f"{name}.scores", kind="matmul", inputs=(q, k),
                      out_shape=(n_heads, seq, seq), gemm_m=seq, gemm_k=dh,
                      gemm_n=seq, groups=n_heads,
                      attrs={"dynamic_weights": True,
                             "transpose_weights": True}))
        sm = g.unary(f"{name}.softmax", "softmax", sc)
        ctx = g.add(Op(name=f"{name}.ctx", kind="matmul", inputs=(sm, v),
                       out_shape=(seq, d_model), gemm_m=seq, gemm_k=seq,
                       gemm_n=dh, groups=n_heads,
                       attrs={"dynamic_weights": True}))
        o = g.linear(f"{name}.o", ctx, cout=d_model, bias=False)
        r = g.eltwise(f"{name}.res", "add", o, src)
        return g.unary(f"{name}.ln", "layernorm", r)

    for li in range(n_layers):
        x = mha(f"l{li}.attn", x)
        y = g.linear(f"l{li}.up", x, cout=d_ff, bias=False, act="gelu")
        y = g.linear(f"l{li}.down", y, cout=d_model, bias=False)
        y = g.eltwise(f"l{li}.res2", "add", y, x)
        x = g.unary(f"l{li}.ln2", "layernorm", y)
    g.linear("lm_head", x, cout=vocab, bias=False)
    return g


def transformer_decode(n_layers: int = 2, d_model: int = 128,
                       n_heads: int = 4, d_ff: Optional[int] = None,
                       kv_len: int = 64, vocab: int = 256,
                       incremental: bool = True) -> Graph:
    """One KV-cached decode step (seq=1) against a ``kv_len``-entry cache.

    The per-layer K/V caches are *graph inputs* ``(kv_len, d_model)``
    serving as the attention matmuls' dynamic-weight operands — the
    gmem-resident cache the chip streams into its macro groups.  The
    new token's K/V projections are emitted as boundary outputs (the
    cache-append write-back); they do not feed this step's attention,
    which reads the already-appended ``kv_len``-entry cache.

    ``incremental=True`` marks both attention matmuls ``kv_append``:
    across consecutive samples the cache differs only in its last row,
    so mapping/trace/codegen price an append-row re-stage (O(1) per
    step in ``kv_len``) instead of re-gathering the whole buffer.  With
    ``incremental=False`` the full per-sample re-stage of the dynamic
    path is priced — the O(kv_len) baseline the serving regression
    test compares against.
    """
    d_ff = d_ff or 4 * d_model
    dh = d_model // n_heads
    g = Graph(f"decode_{n_layers}L_{d_model}d_kv{kv_len}")
    x = g.input("token", (1, d_model))      # current-token embedding
    caches = [(g.input(f"l{li}.k_cache", (kv_len, d_model)),
               g.input(f"l{li}.v_cache", (kv_len, d_model)))
              for li in range(n_layers)]
    x = g.linear("embed", x, cout=d_model, bias=False)
    attn_attrs = {"dynamic_weights": True}
    if incremental:
        attn_attrs["kv_append"] = True

    def mha(name: str, src: int, kc: int, vc: int) -> int:
        q = g.linear(f"{name}.q", src, cout=d_model, bias=False)
        # cache-append write-back of the new token's K/V row (boundary
        # outputs: no in-graph consumer, spilled to gmem)
        g.linear(f"{name}.k", src, cout=d_model, bias=False)
        g.linear(f"{name}.v", src, cout=d_model, bias=False)
        # scores = q @ K_cacheᵀ : per-head (1 x dh) @ (dh x kv_len)
        sc = g.add(Op(name=f"{name}.scores", kind="matmul",
                      inputs=(q, kc), out_shape=(n_heads, 1, kv_len),
                      gemm_m=1, gemm_k=dh, gemm_n=kv_len, groups=n_heads,
                      attrs=dict(attn_attrs, transpose_weights=True)))
        sm = g.unary(f"{name}.softmax", "softmax", sc)
        ctx = g.add(Op(name=f"{name}.ctx", kind="matmul",
                       inputs=(sm, vc), out_shape=(1, d_model),
                       gemm_m=1, gemm_k=kv_len, gemm_n=dh, groups=n_heads,
                       attrs=dict(attn_attrs)))
        o = g.linear(f"{name}.o", ctx, cout=d_model, bias=False)
        r = g.eltwise(f"{name}.res", "add", o, src)
        return g.unary(f"{name}.ln", "layernorm", r)

    for li in range(n_layers):
        kc, vc = caches[li]
        x = mha(f"l{li}.attn", x, kc, vc)
        y = g.linear(f"l{li}.up", x, cout=d_ff, bias=False, act="gelu")
        y = g.linear(f"l{li}.down", y, cout=d_model, bias=False)
        y = g.eltwise(f"l{li}.res2", "add", y, x)
        x = g.unary(f"l{li}.ln2", "layernorm", y)
    g.linear("lm_head", x, cout=vocab, bias=False)
    return g


# ---------------------------------------------------------------------------
# Tiny CNN — used by the compile-and-run (ISS vs JAX oracle) tests
# ---------------------------------------------------------------------------


def tiny_cnn(res: int = 8, c: int = 8, n_classes: int = 10) -> Graph:
    g = Graph("tiny_cnn")
    x = g.input("image", (res, res, 3))
    x = g.conv("conv1", x, cout=c, k=3, act="relu", use_bn=False)
    x = g.pool("pool1", x, k=2, stride=2)
    x = g.conv("conv2", x, cout=2 * c, k=3, act="relu", use_bn=False)
    x = g.globalpool("gap", x)
    g.linear("fc", x, cout=n_classes)
    return g


def deepseek_proxy(n_layers: int = 8, d_model: int = 768, n_heads: int = 12,
                   d_ff: int = 2048, seq: int = 32,
                   vocab: int = 1024) -> Graph:
    """Scale-out proxy LM: a decoder stack whose resident int8 weights
    (~45 MB at the defaults) exceed one chip's weight-resident gmem
    capacity (~16.8 MB), so it compiles only through the
    :mod:`repro.system` multi-chip partitioner — the in-tree witness
    that the mesh genuinely extends reach rather than just latency."""
    g = transformer_lm(n_layers=n_layers, d_model=d_model, n_heads=n_heads,
                       d_ff=d_ff, seq=seq, vocab=vocab)
    g.name = f"deepseek_proxy_{n_layers}L_{d_model}d"
    return g


WORKLOADS = {
    "resnet18": resnet18,
    "vgg19": vgg19,
    "mobilenetv2": mobilenetv2,
    "efficientnetb0": efficientnetb0,
    "transformer": transformer_lm,
    "transformer_decode": transformer_decode,
    "tiny_cnn": tiny_cnn,
    "deepseek_proxy": deepseek_proxy,
}


def build(name: str, **kw) -> Graph:
    try:
        return WORKLOADS[name](**kw)
    except KeyError:
        raise KeyError(f"unknown workload {name!r}; "
                       f"have {sorted(WORKLOADS)}") from None

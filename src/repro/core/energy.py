"""Energy model (paper §III-D / §IV-A).

The paper takes per-component energy from post-layout analysis of its
reference macro [11], memory compilers, and synthesized RTL.  We have none of
those, so this table is calibrated to *published* figures instead:

* CIM macro: [11] reports 27.38 TOPS/W signed-INT8.  One full bit-serial
  macro pass performs ``rows x n_out = 512 x 8 = 4096`` MACs = 8192 ops →
  ``8192 / 27.38e12 ≈ 0.30 nJ`` per pass.
* On-chip SRAM: ~1 pJ/B (local 512 KB) to ~8 pJ/B (16 MB global) — memory-
  compiler-typical values at 28 nm.
* NoC: ~1 pJ per byte-hop (router + link at 28 nm, Noxim-calibrated order).
* Static: per-core leakage + clock tree ≈ 50 mW at 1 GHz → 0.05 nJ/cycle.
  Static energy is why latency wins translate into energy wins (idle cores
  still burn power while a slow schedule drags on).

Absolute joules are therefore *estimates*; the reproduction targets the
paper's **relative** results (speedup ratios, energy-reduction percentages,
breakdown shapes), as recorded in DESIGN.md §2.

Event ledger keys (produced by both the analytic cost model and the
cycle-accurate simulator):

    cim_macro_passes, cim_weight_load_bytes, vector_elems,
    noc_byte_hops, gmem_bytes, lmem_bytes, static_core_cycles
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Mapping

__all__ = ["EnergyTable", "DEFAULT_TABLE", "energy_breakdown", "total_energy"]


@dataclass(frozen=True)
class EnergyTable:
    """nJ per event."""

    cim_macro_pass: float = 0.30        # one bit-serial pass of one macro
    cim_weight_load_byte: float = 0.0012  # SRAM array write
    vector_elem: float = 0.002          # 32-bit vector lane op
    noc_byte_hop: float = 0.0010        # router+link traversal
    gmem_byte: float = 0.008            # 16 MB global SRAM access
    lmem_byte: float = 0.0015           # 512 KB local SRAM access
    static_core_cycle: float = 0.05     # leakage + clock per core-cycle

    def scaled(self, **kw: float) -> "EnergyTable":
        return replace(self, **kw)


DEFAULT_TABLE = EnergyTable()

_EVENT_TO_FIELD = {
    "cim_macro_passes": ("compute", "cim_macro_pass"),
    "cim_weight_load_bytes": ("weight_load", "cim_weight_load_byte"),
    "vector_elems": ("compute", "vector_elem"),
    "noc_byte_hops": ("noc", "noc_byte_hop"),
    "gmem_bytes": ("gmem", "gmem_byte"),
    "lmem_bytes": ("lmem", "lmem_byte"),
    "static_core_cycles": ("static", "static_core_cycle"),
}


def energy_breakdown(events: Mapping[str, float],
                     table: EnergyTable = DEFAULT_TABLE) -> Dict[str, float]:
    """Ledger -> {category: nJ} breakdown (+ 'total')."""
    out: Dict[str, float] = {"compute": 0.0, "weight_load": 0.0, "noc": 0.0,
                             "gmem": 0.0, "lmem": 0.0, "static": 0.0}
    for ev, count in events.items():
        if ev not in _EVENT_TO_FIELD:
            raise KeyError(f"unknown energy event {ev!r}")
        cat, fld = _EVENT_TO_FIELD[ev]
        out[cat] += count * getattr(table, fld)
    out["total"] = sum(out.values())
    return out


def total_energy(events: Mapping[str, float],
                 table: EnergyTable = DEFAULT_TABLE) -> float:
    return energy_breakdown(events, table)["total"]

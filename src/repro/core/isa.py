"""CIMFlow instruction set architecture.

Implements the paper's unified 32-bit instruction format (§III-B):

* 6-bit operation specifier (opcode), multiple 5-bit operand fields;
* supplementary fields: 6-bit functionality specifier, execution flags,
  and 10/16/26-bit immediates;
* up to four operands per instruction;
* three instruction categories — compute (CIM / vector / scalar),
  communication, and control flow;
* extensibility through a *customized instruction description template*
  (:class:`InstrDescriptor`): new operations integrate by registering a
  descriptor with its performance parameters (latency/energy classes), no
  framework changes required.

Encoding formats (bit widths sum to 32, packed MSB-first):

    R : opcode(6) rd(5) rs1(5) rs2(5) funct(6) flags(5)
    I : opcode(6) rd(5) rs1(5) imm16(16)
    C : opcode(6) rd(5) rs1(5) funct(6) imm10(10)
    J : opcode(6) imm26(26)

The compiler manipulates symbolic :class:`Instr` objects; `encode` /
`decode` provide the binary round-trip used by the ISA conformance tests.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FORMATS",
    "InstrDescriptor",
    "Instr",
    "Isa",
    "PackedProgram",
    "Program",
    "default_isa",
    "VFUNCT",
    "SALU_FUNCT",
    "SREG",
    "FLAGS",
]


class IsaError(ValueError):
    pass


# field name -> width, per format (MSB first)
FORMATS: Dict[str, List[Tuple[str, int]]] = {
    "R": [("opcode", 6), ("rd", 5), ("rs1", 5), ("rs2", 5),
          ("funct", 6), ("flags", 5)],
    "I": [("opcode", 6), ("rd", 5), ("rs1", 5), ("imm16", 16)],
    "C": [("opcode", 6), ("rd", 5), ("rs1", 5), ("funct", 6), ("imm10", 10)],
    "J": [("opcode", 6), ("imm26", 26)],
}

_SIGNED_FIELDS = {"imm16", "imm10", "imm26"}


def _check_format(fmt: str) -> List[Tuple[str, int]]:
    if fmt not in FORMATS:
        raise IsaError(f"unknown format {fmt!r}")
    return FORMATS[fmt]


@dataclass(frozen=True)
class InstrDescriptor:
    """Instruction description template (paper §III-B, extensibility).

    ``operands`` maps *semantic* operand names (what the compiler uses, e.g.
    ``dst``/``src``/``size``) to *encoding* fields of ``fmt`` (e.g. ``rd``).
    ``unit`` names the execution unit for the simulator's pipeline model;
    ``latency_class``/``energy_class`` key into its performance tables, so a
    new instruction is fully specified by one descriptor.
    """

    name: str
    opcode: int
    fmt: str
    unit: str                      # cim | vector | scalar | noc | control
    operands: Dict[str, str] = field(default_factory=dict)
    latency_class: str = "alu"
    energy_class: str = "scalar_alu"
    funct: Optional[int] = None    # fixed funct value, if the op owns one
    description: str = ""

    def __post_init__(self) -> None:
        fields = dict(_check_format(self.fmt))
        if not 0 <= self.opcode < 64:
            raise IsaError(f"{self.name}: opcode {self.opcode} out of range")
        for sem, enc in self.operands.items():
            if enc not in fields:
                raise IsaError(
                    f"{self.name}: operand {sem!r} maps to unknown field "
                    f"{enc!r} of format {self.fmt}")
            if enc == "opcode":
                raise IsaError(f"{self.name}: cannot bind operand to opcode")
        if self.funct is not None and "funct" not in fields:
            raise IsaError(f"{self.name}: format {self.fmt} has no funct")


@dataclass
class Instr:
    """A symbolic instruction: descriptor name + semantic operand values."""

    op: str
    args: Dict[str, int] = field(default_factory=dict)
    # Optional metadata used by the compiler/simulator, not encoded.
    meta: Dict[str, Any] = field(default_factory=dict)

    def __repr__(self) -> str:  # compact, stable for goldens
        a = ", ".join(f"{k}={v}" for k, v in self.args.items())
        return f"{self.op}({a})"


class Isa:
    """A registry of instruction descriptors with encode/decode."""

    def __init__(self, name: str = "cimflow-v1") -> None:
        self.name = name
        self._by_name: Dict[str, InstrDescriptor] = {}
        # (opcode, funct-or-None) -> descriptor; ops sharing an opcode must
        # use distinct fixed functs.
        self._by_code: Dict[Tuple[int, Optional[int]], InstrDescriptor] = {}
        self._opcode_fmt: Dict[int, str] = {}
        # dense op numbering (registration order): the decode tables the
        # pre-decoded simulator indexes with — unlike the sparse
        # (opcode, funct) encoding space, ids are contiguous ints
        self._index: Dict[str, int] = {}

    # -- registration --------------------------------------------------------

    def register(self, d: InstrDescriptor) -> InstrDescriptor:
        if d.name in self._by_name:
            raise IsaError(f"duplicate instruction name {d.name}")
        if d.opcode in self._opcode_fmt:
            if self._opcode_fmt[d.opcode] != d.fmt:
                raise IsaError(
                    f"{d.name}: opcode {d.opcode} already bound to format "
                    f"{self._opcode_fmt[d.opcode]}")
            if d.funct is None:
                raise IsaError(
                    f"{d.name}: opcode {d.opcode} shared but no fixed funct")
        key = (d.opcode, d.funct)
        if key in self._by_code:
            raise IsaError(f"{d.name}: opcode/funct collision with "
                           f"{self._by_code[key].name}")
        self._by_name[d.name] = d
        self._by_code[key] = d
        self._opcode_fmt[d.opcode] = d.fmt
        self._index[d.name] = len(self._index)
        return d

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    # -- dense numbering / decode tables -------------------------------------

    @property
    def n_ops(self) -> int:
        return len(self._index)

    def op_id(self, name: str) -> int:
        """Dense instruction id (registration order, 0..n_ops-1)."""
        try:
            return self._index[name]
        except KeyError:
            raise IsaError(f"unknown instruction {name!r}") from None

    @property
    def op_index(self) -> Dict[str, int]:
        """name -> dense id map (a copy; ids are registration order)."""
        return dict(self._index)

    def op_names(self) -> List[str]:
        """Dense-id -> name table (index i holds the name of op id i)."""
        return list(self._index)

    def __getitem__(self, name: str) -> InstrDescriptor:
        try:
            return self._by_name[name]
        except KeyError:
            raise IsaError(f"unknown instruction {name!r}") from None

    @property
    def descriptors(self) -> List[InstrDescriptor]:
        return list(self._by_name.values())

    def pack_streams(self, streams: Sequence[Sequence[Instr]]
                     ) -> Tuple[np.ndarray, Dict[str, np.ndarray],
                                np.ndarray]:
        """Pack several instruction streams into one SoA table.

        Returns ``(op, args, offs)`` where ``op``/``args`` cover the
        concatenation of all streams and ``offs[k]`` is stream *k*'s
        start (``offs[-1]`` = total length).  Extraction is grouped per
        (op, operand) from each op's descriptor: one gather per pair
        instead of a per-instruction dict walk.
        """
        from itertools import chain
        from operator import attrgetter, itemgetter
        sizes = [len(s) for s in streams]
        n = int(sum(sizes))
        offs = np.zeros(len(sizes) + 1, dtype=np.int64)
        np.cumsum(sizes, out=offs[1:])
        index = self._index
        flat = list(chain.from_iterable(streams))
        op = np.fromiter(map(index.__getitem__, map(attrgetter("op"),
                                                    flat)),
                         dtype=np.int32, count=n)  # KeyError -> unknown
        argdicts = list(map(attrgetter("args"), flat))
        names = list(self._index)
        cols: Dict[str, np.ndarray] = {}
        present = np.flatnonzero(np.bincount(op, minlength=len(names)))
        for oid in present.tolist():
            nm = names[oid]
            cols_of = tuple(self._by_name[nm].operands)
            if not cols_of:
                continue
            pos = np.flatnonzero(op == oid)
            rows = list(map(argdicts.__getitem__, pos.tolist()))
            try:
                if len(cols_of) == 1:
                    vals = (list(map(itemgetter(cols_of[0]), rows)),)
                else:
                    vals = list(zip(*map(itemgetter(*cols_of), rows)))
            except KeyError:              # operand omitted somewhere
                vals = [[r.get(k, 0) for r in rows] for k in cols_of]
            for k, v in zip(cols_of, vals):
                c = cols.get(k)
                if c is None:
                    c = cols[k] = np.zeros(n, dtype=np.int64)
                c[pos] = v
        return op, cols, offs

    def instr(self, op: str, **args: int) -> Instr:
        """Build + validate a symbolic instruction."""
        d = self[op]
        unknown = set(args) - set(d.operands)
        if unknown:
            raise IsaError(f"{op}: unknown operands {sorted(unknown)}")
        return Instr(op, dict(args))

    # -- binary encoding ------------------------------------------------------

    def encode(self, ins: Instr) -> int:
        d = self[ins.op]
        fields = _check_format(d.fmt)
        values = {name: 0 for name, _ in fields}
        values["opcode"] = d.opcode
        if d.funct is not None:
            values["funct"] = d.funct
        for sem, enc in d.operands.items():
            values[enc] = ins.args.get(sem, 0)
        word = 0
        for fname, width in fields:
            v = int(values[fname])
            lo, hi = 0, (1 << width) - 1
            if fname in _SIGNED_FIELDS:
                lo = -(1 << (width - 1))
                hi = (1 << (width - 1)) - 1
                if not lo <= v <= hi:
                    raise IsaError(
                        f"{ins.op}: field {fname}={v} out of signed range")
                v &= (1 << width) - 1
            elif not lo <= v <= hi:
                raise IsaError(f"{ins.op}: field {fname}={v} exceeds "
                               f"{width} bits")
            word = (word << width) | v
        return word

    def decode(self, word: int) -> Instr:
        if not 0 <= word < (1 << 32):
            raise IsaError("instruction word out of 32-bit range")
        opcode = (word >> 26) & 0x3F
        fmt = self._opcode_fmt.get(opcode)
        if fmt is None:
            raise IsaError(f"unknown opcode {opcode}")
        fields = _check_format(fmt)
        values: Dict[str, int] = {}
        shift = 32
        for fname, width in fields:
            shift -= width
            v = (word >> shift) & ((1 << width) - 1)
            if fname in _SIGNED_FIELDS and v >= (1 << (width - 1)):
                v -= 1 << width
            values[fname] = v
        funct = values.get("funct")
        d = self._by_code.get((opcode, funct)) or self._by_code.get(
            (opcode, None))
        if d is None:
            raise IsaError(f"unknown opcode/funct ({opcode}, {funct})")
        args = {}
        for sem, enc in d.operands.items():
            args[sem] = values[enc]
        return Instr(d.name, args)


@dataclass
class PackedProgram:
    """Structure-of-arrays view of a :class:`Program`.

    ``op`` holds dense instruction ids (:meth:`Isa.op_id`); ``args`` maps
    each semantic operand name appearing anywhere in the stream to an
    int64 column (0 where an instruction lacks the operand).  This is the
    decode-once table the vectorized perf simulator replays — numpy
    gather/compare over columns instead of per-``Instr`` dict traffic.
    """

    op: np.ndarray                       # (n,) int32 dense op ids
    args: Dict[str, np.ndarray]          # operand name -> (n,) int64
    core_id: int = 0

    def __len__(self) -> int:
        return int(self.op.size)

    def col(self, name: str) -> np.ndarray:
        """Operand column (a shared zeros column if never present)."""
        got = self.args.get(name)
        if got is None:
            got = np.zeros(self.op.size, dtype=np.int64)
            self.args[name] = got
        return got


@dataclass
class Program:
    """An instruction stream for one core."""

    instrs: List[Instr] = field(default_factory=list)
    core_id: int = 0
    labels: Dict[str, int] = field(default_factory=dict)

    def append(self, ins: Instr) -> int:
        self.instrs.append(ins)
        return len(self.instrs) - 1

    def extend(self, more: Iterable[Instr]) -> None:
        self.instrs.extend(more)

    def __len__(self) -> int:
        return len(self.instrs)

    def __iter__(self):
        return iter(self.instrs)

    def encode(self, isa: "Isa") -> np.ndarray:
        return np.array([isa.encode(i) for i in self.instrs], dtype=np.uint32)

    def invalidate_pack(self) -> None:
        """Drop the memoized :meth:`pack` table.

        ``append``/``extend`` are covered by the cache's length check;
        call this after replacing an instruction *in place*
        (``prog.instrs[i] = ...``) so the vectorized simulator cannot
        replay a stale table.
        """
        self.__dict__.pop("_packed", None)

    def pack(self, isa: "Isa") -> PackedProgram:
        """Decode the stream into :class:`PackedProgram` column arrays.

        The result is memoized per ``Isa`` (invalidated by length
        changes; see :meth:`invalidate_pack` for in-place edits) —
        codegen ships every emitted program with its table, and the
        simulator, the equivalence tests and any analysis pass share
        that one decode.
        """
        cached = getattr(self, "_packed", None)
        if cached is not None and cached[0] is isa \
                and cached[2] == len(self.instrs):
            return cached[1]
        op, cols, _ = isa.pack_streams([self.instrs])
        packed = PackedProgram(op=op, args=cols, core_id=self.core_id)
        self._packed = (isa, packed, len(self.instrs))
        return packed

    def disassemble(self, isa: "Isa") -> str:
        lines = []
        rev_labels = {v: k for k, v in self.labels.items()}
        for pc, ins in enumerate(self.instrs):
            if pc in rev_labels:
                lines.append(f"{rev_labels[pc]}:")
            lines.append(f"  {pc:5d}: {ins!r}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Default instruction set
# ---------------------------------------------------------------------------

# Vector functionality specifier codes (shared V_OP opcode).
VFUNCT: Dict[str, int] = {
    "add": 0, "sub": 1, "mul": 2, "mac": 3, "max": 4, "min": 5,
    "relu": 6, "shl": 7, "shr": 8, "quant": 9, "dequant": 10,
    "mov": 11, "reduce_sum": 12, "reduce_max": 13,
    "sigmoid": 14, "silu": 15, "gelu": 16, "tanh": 17, "exp": 18,
    "maxpool": 19, "avgpool": 20, "addi": 21, "muli": 22, "recip": 23,
    "rsqrt": 24, "abs": 25, "clip": 26,
    "zero": 27,     # write VLEN zeros (with V_REP/VSEG_D segments)
    "sum8": 28,     # int32 dst[i] += int8 a[i] (GAP accumulation)
    # row-segment transformer ops: VLEN = segment length, V_REP = rows
    # (int8 in/out; integer semantics in repro.core.vecsem)
    "softmax": 29,
    "layernorm": 30,
}

# Scalar ALU functs (shared S_ALU opcode).
SALU_FUNCT: Dict[str, int] = {
    "add": 0, "sub": 1, "mul": 2, "and": 3, "or": 4, "xor": 5,
    "slt": 6, "sll": 7, "srl": 8,
}

# Special-purpose register map (S_Reg file). Operation-specific state:
SREG: Dict[str, int] = {
    "VLEN": 0,          # vector length for V_OP
    "MG_MASK_LO": 1,    # active macro-group bitmap (low 16)
    "MG_MASK_HI": 2,
    "ACT_BITS": 3,      # bit-serial activation precision
    "Q_SCALE": 4,       # requant multiplier (fixed-point)
    "Q_SHIFT": 5,       # requant shift
    "Q_ZERO": 6,        # requant zero point
    "ACC_DIV": 7,       # requant pre-divisor (GAP mean folding); 0/1 = off
    "CLUSTER": 8,       # multicast cluster id for BCAST
    "VSTRIDE_D": 9,     # vector dst stride (elements)
    "VSTRIDE_A": 10,    # vector src-a stride
    "VSTRIDE_B": 11,    # vector src-b stride
    "POOL_W": 12,       # pooling window
    "POOL_S": 13,       # pooling stride
    # per-repetition segment advances (bytes) for V_REP'd vector ops
    "VSEG_D": 14,
    "VSEG_A": 15,
    "VSEG_B": 16,
    "V_REP": 17,        # vector-op repetition count (0/1 = single)
    # CIM macro-group addressing, latched by CIM_LOAD
    "MG_SEL": 18,       # target macro group for the next CIM_LOAD
    "MG_KOFF": 19,      # input-vector offset (elements) of the MG's k-slice
    "MG_NOFF": 20,      # output-channel offset of the MG's n-slice
    # CIM_MVM per-repetition address advances (bytes)
    "MVM_SEG_IN": 21,
    "MVM_SEG_OUT": 22,
    "MG_NLEN": 23,      # output channels of the MG being CIM_LOADed
    # virtual-channel id for SEND/RECV rendezvous: multiple logical
    # streams between one core pair stay order-independent (NoC message
    # tags / virtual channels)
    "CHANNEL": 24,
}

# Execution flag bits (R-format `flags` field).
FLAGS: Dict[str, int] = {
    "acc": 1 << 0,      # CIM_MVM: accumulate into dst instead of overwrite
    "relu": 1 << 1,     # fused relu on vector op result
    "i8": 1 << 2,       # operate on int8 data (default int32)
}


def default_isa() -> Isa:
    """Build the CIMFlow v1 instruction set."""
    isa = Isa()
    R = lambda **kw: isa.register(InstrDescriptor(**kw))  # noqa: E731

    # ---- CIM compute ------------------------------------------------------
    R(name="CIM_MVM", opcode=0, fmt="C", unit="cim",
      operands={"dst": "rd", "src": "rs1", "rep": "imm10", "acc": "funct"},
      latency_class="cim_mvm", energy_class="cim_mvm",
      description="Bit-serial MVM on the MGs selected by S_Reg[MG_MASK]; "
                  "reads activations at G[src], writes (acc&1: accumulates) "
                  "INT32 partial sums to G[dst]; rep = consecutive input "
                  "vectors, advancing by S_Reg[MVM_SEG_IN/OUT] bytes.")
    R(name="CIM_LOAD", opcode=1, fmt="C", unit="cim",
      operands={"mg": "rd", "src": "rs1", "rows": "imm10"},
      latency_class="cim_load", energy_class="cim_load",
      description="Load weight rows from local memory into macro group mg.")
    R(name="CIM_CFG", opcode=2, fmt="I", unit="cim",
      operands={"sreg": "rd", "imm": "imm16"},
      latency_class="alu", energy_class="scalar_alu",
      description="Write immediate to special register (CIM/vector config).")
    R(name="CIM_CFGR", opcode=3, fmt="R", unit="cim",
      operands={"sreg": "rd", "src": "rs1"},
      latency_class="alu", energy_class="scalar_alu",
      description="Write G_Reg value to special register.")

    # ---- Vector compute ---------------------------------------------------
    for vname, f in VFUNCT.items():
        R(name=f"V_{vname.upper()}", opcode=8, fmt="R", unit="vector",
          operands={"dst": "rd", "a": "rs1", "b": "rs2"},
          funct=f,
          latency_class=("vec_special" if vname in
                         ("sigmoid", "silu", "gelu", "tanh", "exp",
                          "recip", "rsqrt", "softmax", "layernorm")
                         else "vec_mul" if vname in ("mul", "mac", "muli",
                                                     "dequant", "quant")
                         else "vec_alu"),
          energy_class="vector_mul" if vname in ("mul", "mac", "muli")
                       else "vector_alu",
          description=f"Vector {vname} over S_Reg[VLEN] elements.")
    R(name="V_SETVL", opcode=9, fmt="I", unit="vector",
      operands={"len": "imm16"},
      latency_class="alu", energy_class="scalar_alu",
      description="Set vector length (elements).")

    # ---- Scalar compute ---------------------------------------------------
    for sname, f in SALU_FUNCT.items():
        R(name=f"S_{sname.upper()}", opcode=16, fmt="R", unit="scalar",
          operands={"dst": "rd", "a": "rs1", "b": "rs2"}, funct=f,
          latency_class="mul" if sname == "mul" else "alu",
          energy_class="scalar_alu",
          description=f"Scalar {sname}.")
    R(name="S_ADDI", opcode=17, fmt="I", unit="scalar",
      operands={"dst": "rd", "a": "rs1", "imm": "imm16"},
      latency_class="alu", energy_class="scalar_alu",
      description="dst = a + sign-extended imm16.")
    R(name="S_LUI", opcode=18, fmt="I", unit="scalar",
      operands={"dst": "rd", "imm": "imm16"},
      latency_class="alu", energy_class="scalar_alu",
      description="dst = imm16 << 16.")
    R(name="S_LD", opcode=19, fmt="I", unit="scalar",
      operands={"dst": "rd", "base": "rs1", "off": "imm16"},
      latency_class="mem", energy_class="lmem_read",
      description="Scalar load word from local memory.")
    R(name="S_ST", opcode=20, fmt="I", unit="scalar",
      operands={"src": "rd", "base": "rs1", "off": "imm16"},
      latency_class="mem", energy_class="lmem_write",
      description="Scalar store word to local memory.")

    # ---- Control flow -----------------------------------------------------
    R(name="BEQ", opcode=24, fmt="I", unit="control",
      operands={"a": "rd", "b": "rs1", "off": "imm16"},
      latency_class="branch", energy_class="scalar_alu",
      description="Branch to pc+off if G[a] == G[b].")
    R(name="BNE", opcode=25, fmt="I", unit="control",
      operands={"a": "rd", "b": "rs1", "off": "imm16"},
      latency_class="branch", energy_class="scalar_alu",
      description="Branch if not equal.")
    R(name="BLT", opcode=26, fmt="I", unit="control",
      operands={"a": "rd", "b": "rs1", "off": "imm16"},
      latency_class="branch", energy_class="scalar_alu",
      description="Branch if less-than (signed).")
    R(name="JAL", opcode=27, fmt="J", unit="control",
      operands={"off": "imm26"},
      latency_class="branch", energy_class="scalar_alu",
      description="Jump relative; link register is G[31].")
    R(name="HALT", opcode=28, fmt="J", unit="control",
      operands={},
      latency_class="alu", energy_class="scalar_alu",
      description="Stop the core.")
    R(name="NOP", opcode=29, fmt="J", unit="control", operands={},
      latency_class="alu", energy_class="scalar_alu",
      description="No operation.")

    # ---- Communication ----------------------------------------------------
    R(name="SEND", opcode=32, fmt="R", unit="noc",
      operands={"core": "rd", "src": "rs1", "size": "rs2"},
      latency_class="noc", energy_class="noc_flit",
      description="Send size bytes from local[G[src]] to core G[core]; "
                  "blocks until accepted by the NoC.")
    R(name="RECV", opcode=33, fmt="R", unit="noc",
      operands={"dst": "rd", "core": "rs1", "size": "rs2"},
      latency_class="noc", energy_class="noc_flit",
      description="Receive size bytes from core G[core] into local[G[dst]].")
    R(name="BCAST", opcode=34, fmt="R", unit="noc",
      operands={"src": "rs1", "size": "rs2"},
      latency_class="noc", energy_class="noc_flit",
      description="Multicast to the cluster in S_Reg[CLUSTER].")
    R(name="SYNC", opcode=35, fmt="I", unit="noc",
      operands={"barrier": "imm16"},
      latency_class="sync", energy_class="scalar_alu",
      description="Block until all cores of the barrier group arrive.")
    R(name="GLD", opcode=36, fmt="R", unit="noc",
      operands={"dst": "rd", "gaddr": "rs1", "size": "rs2"},
      latency_class="gmem", energy_class="gmem_read",
      description="Load size bytes from global memory to local[G[dst]].")
    R(name="GST", opcode=37, fmt="R", unit="noc",
      operands={"src": "rd", "gaddr": "rs1", "size": "rs2"},
      latency_class="gmem", energy_class="gmem_write",
      description="Store size bytes from local[G[src]] to global memory.")

    return isa

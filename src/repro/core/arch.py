"""Hierarchical hardware abstraction for digital CIM architectures.

Implements the three-level abstraction of the CIMFlow ISA (paper §III-B):

* **Chip level** — multiple cores on a 2-D mesh NoC with synchronous
  inter-core communication and a global memory.
* **Core level** — instruction memory, a CIM compute unit (macro groups),
  a vector unit, a scalar unit, register files (G_Reg / S_Reg) and a
  segmented local memory in a unified address space.
* **Unit level** — CIM macro geometry (rows x bit-columns, element tiles)
  and per-unit pipeline parameters.

Default parameters follow Tab. I of the paper:

    Chip:  64 cores, NoC flit 8 B, global mem 16 MB
    Core:  CIM unit = 16 macro groups, MG = 8 macros, local mem 512 KB
    Unit:  macro = 512 x 64 (bit columns), element = 32 x 8

Semantics adopted for the macro (documented because the paper leaves the
micro-architecture to its reference design [11]):

* ``rows`` is the input (reduction, K) dimension of the in-memory MVM.
* ``cols`` counts *bit* columns; an INT-``weight_bits`` weight occupies
  ``weight_bits`` adjacent columns, so a macro stores
  ``cols // weight_bits`` output channels of ``rows`` weights each.
* macros inside a macro group (MG) extend the output-channel dimension
  (weights organized along output channels; the input vector is broadcast
  across macros of the group — paper §III-B "unit level").
* distinct MGs may be mapped to different (k-tile, n-tile) coordinates of a
  layer; partial sums across k-tiles are combined on the vector unit.
* activations are processed bit-serially: an ``act_bits``-bit activation
  takes ``act_bits`` compute beats, plus an adder-tree latency of
  ``log2(rows / element_rows)`` beats (element = 32x8 adder-tree segment).
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "MacroConfig",
    "ProtectionConfig",
    "CimUnitConfig",
    "VectorUnitConfig",
    "ScalarUnitConfig",
    "LocalMemConfig",
    "RegFileConfig",
    "CoreConfig",
    "NocConfig",
    "ChipConfig",
    "default_chip",
    "chip_from_dict",
    "chip_from_json",
]


class ArchError(ValueError):
    """Raised when an architecture description is inconsistent."""


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ArchError(msg)


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


# ---------------------------------------------------------------------------
# Unit level
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MacroConfig:
    """Geometry and timing of one digital CIM macro."""

    rows: int = 512            # input (K) dimension
    cols: int = 64             # bit columns
    element_rows: int = 32     # adder-tree segment rows
    element_cols: int = 8      # adder-tree segment bit-columns
    weight_bits: int = 8       # bits per stored weight
    act_bits: int = 8          # bits per input activation (bit-serial)

    def __post_init__(self) -> None:
        _require(self.rows > 0 and self.cols > 0, "macro dims must be positive")
        _require(self.cols % self.weight_bits == 0,
                 f"cols ({self.cols}) must be a multiple of weight_bits "
                 f"({self.weight_bits})")
        _require(self.rows % self.element_rows == 0,
                 "rows must be a multiple of element_rows")
        _require(self.cols % self.element_cols == 0,
                 "cols must be a multiple of element_cols")
        _require(_is_pow2(self.rows // self.element_rows),
                 "rows/element_rows must be a power of two (adder tree)")

    @property
    def n_out(self) -> int:
        """Output channels held by one macro."""
        return self.cols // self.weight_bits

    @property
    def weight_bytes(self) -> int:
        """Weight storage of one macro in bytes."""
        return self.rows * self.cols // 8

    @property
    def adder_tree_depth(self) -> int:
        return int(math.log2(self.rows // self.element_rows))

    def mvm_beats(self) -> int:
        """Compute beats for one full-array bit-serial MVM pass.

        Bit-serial activations: one beat per activation bit; the adder tree
        and shift-accumulate are pipelined, so the tree depth appears once
        as fill latency.
        """
        return self.act_bits + self.adder_tree_depth


@dataclass(frozen=True)
class ProtectionConfig:
    """CIM-array fault-mitigation hardware.

    Three orthogonal mechanisms, each a classic CIM reliability knob:

    * ``ecc`` — SECDED across the weight storage (8 check bits per 64
      data bits): +12.5% stored weights and one extra decode stage in
      the MVM output path.
    * ``spare_rows`` — redundant macro rows with remap logic: storage
      and load time grow by ``spare_rows / macro.rows``.
    * ``tmr`` — triple modular redundancy on arrays + datapath: 3x
      storage, load time, compute energy and area, plus one voter
      stage of MVM latency.

    The cycle/energy/area overheads are priced centrally by
    :class:`repro.core.machine.MachineModel`; the *effectiveness*
    (residual fault rate) is modeled by
    :func:`repro.faults.residual_rate`.  All defaults off — a default
    chip is bit-identical to one predating this config.
    """

    ecc: bool = False
    spare_rows: int = 0
    tmr: bool = False

    def __post_init__(self) -> None:
        _require(self.spare_rows >= 0, "spare_rows must be >= 0")

    @property
    def enabled(self) -> bool:
        return self.ecc or self.spare_rows > 0 or self.tmr


@dataclass(frozen=True)
class CimUnitConfig:
    """Core-level CIM compute unit: a set of macro groups."""

    n_macro_groups: int = 16
    macros_per_group: int = 8
    macro: MacroConfig = field(default_factory=MacroConfig)
    # Cycles to load one macro row of weights from local memory
    # (row-parallel write ports are expensive; one row per cycle is typical).
    weight_load_rows_per_cycle: int = 1
    # Fault-mitigation hardware (defaults: all off = zero overhead).
    protection: ProtectionConfig = field(default_factory=ProtectionConfig)

    def __post_init__(self) -> None:
        _require(self.n_macro_groups > 0, "need at least one macro group")
        _require(self.macros_per_group > 0, "need at least one macro per MG")
        _require(self.protection.spare_rows < self.macro.rows,
                 "spare_rows must be smaller than macro rows")

    @property
    def group_n_out(self) -> int:
        """Output channels produced by one MG in one pass."""
        return self.macros_per_group * self.macro.n_out

    @property
    def group_k(self) -> int:
        """Input (reduction) capacity of one MG."""
        return self.macro.rows

    @property
    def group_weight_bytes(self) -> int:
        return self.macros_per_group * self.macro.weight_bytes

    @property
    def weight_capacity_bytes(self) -> int:
        """Total in-array weight storage of the unit."""
        return self.n_macro_groups * self.group_weight_bytes

    def macs_per_pass(self) -> int:
        """MACs performed by one MG in one bit-serial pass."""
        return self.group_k * self.group_n_out


@dataclass(frozen=True)
class VectorUnitConfig:
    """SIMD vector unit for activation/pooling/quantization ops."""

    lanes: int = 32            # elements per cycle
    width_bits: int = 32       # accumulator width
    # Latency classes in cycles (pipelined; these are issue latencies).
    alu_latency: int = 1
    mul_latency: int = 2
    special_latency: int = 4   # LUT-based activations (sigmoid/silu/gelu/exp)

    def __post_init__(self) -> None:
        _require(self.lanes > 0, "vector lanes must be positive")


@dataclass(frozen=True)
class ScalarUnitConfig:
    alu_latency: int = 1
    mul_latency: int = 3
    branch_penalty: int = 2
    ldst_latency: int = 2      # local-memory scalar load/store


@dataclass(frozen=True)
class LocalMemConfig:
    """Segmented core-local memory (activations in/out + spill)."""

    size_bytes: int = 512 * 1024
    n_segments: int = 4
    read_bytes_per_cycle: int = 64
    write_bytes_per_cycle: int = 64
    banks: int = 8

    def __post_init__(self) -> None:
        _require(self.size_bytes > 0, "local mem must be positive")
        _require(self.size_bytes % self.n_segments == 0,
                 "local mem must divide into equal segments")

    @property
    def segment_bytes(self) -> int:
        return self.size_bytes // self.n_segments


@dataclass(frozen=True)
class RegFileConfig:
    n_gregs: int = 32          # general-purpose (5-bit operand fields)
    n_sregs: int = 32          # special-purpose (CIM config, quant params...)

    def __post_init__(self) -> None:
        _require(self.n_gregs <= 32, "G_Reg addressable by 5-bit fields only")
        _require(self.n_sregs <= 64, "S_Reg space limited to 64")


# ---------------------------------------------------------------------------
# Core level
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CoreConfig:
    cim: CimUnitConfig = field(default_factory=CimUnitConfig)
    vector: VectorUnitConfig = field(default_factory=VectorUnitConfig)
    scalar: ScalarUnitConfig = field(default_factory=ScalarUnitConfig)
    local_mem: LocalMemConfig = field(default_factory=LocalMemConfig)
    regs: RegFileConfig = field(default_factory=RegFileConfig)
    imem_slots: int = 64 * 1024     # instruction memory (instructions)

    @property
    def weight_capacity_bytes(self) -> int:
        return self.cim.weight_capacity_bytes


# ---------------------------------------------------------------------------
# Chip level
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NocConfig:
    """2-D mesh NoC, XY routing, credit-based flow control."""

    flit_bytes: int = 8
    flits_per_cycle: int = 1      # link bandwidth in flits/cycle
    router_latency: int = 2       # cycles per hop
    inject_latency: int = 1

    def __post_init__(self) -> None:
        _require(self.flit_bytes > 0, "flit size must be positive")
        _require(self.flits_per_cycle > 0, "link bandwidth must be positive")

    @property
    def link_bytes_per_cycle(self) -> int:
        return self.flit_bytes * self.flits_per_cycle


@dataclass(frozen=True)
class ChipConfig:
    n_cores: int = 64
    mesh_cols: int = 8                     # NoC mesh X dimension
    core: CoreConfig = field(default_factory=CoreConfig)
    noc: NocConfig = field(default_factory=NocConfig)
    global_mem_bytes: int = 16 * 1024 * 1024
    global_mem_ports: int = 4              # concurrent core<->gmem streams
    global_mem_bytes_per_cycle: int = 64   # per port
    clock_ghz: float = 1.0
    name: str = "cimflow-default"

    def __post_init__(self) -> None:
        _require(self.n_cores > 0, "need at least one core")
        _require(self.mesh_cols > 0 and self.n_cores % self.mesh_cols == 0,
                 "cores must form a full 2-D mesh")

    # -- mesh geometry ------------------------------------------------------

    @property
    def mesh_rows(self) -> int:
        return self.n_cores // self.mesh_cols

    def core_xy(self, core_id: int) -> Tuple[int, int]:
        _require(0 <= core_id < self.n_cores, f"bad core id {core_id}")
        return core_id % self.mesh_cols, core_id // self.mesh_cols

    def hops(self, src: int, dst: int) -> int:
        """Manhattan distance under XY routing."""
        sx, sy = self.core_xy(src)
        dx, dy = self.core_xy(dst)
        return abs(sx - dx) + abs(sy - dy)

    def route(self, src: int, dst: int) -> List[Tuple[int, int]]:
        """XY route as a list of directed links ((x,y) -> next)."""
        sx, sy = self.core_xy(src)
        dx, dy = self.core_xy(dst)
        links: List[Tuple[int, int]] = []
        x, y = sx, sy
        while x != dx:
            nx = x + (1 if dx > x else -1)
            links.append((y * self.mesh_cols + x, y * self.mesh_cols + nx))
            x = nx
        while y != dy:
            ny = y + (1 if dy > y else -1)
            links.append((y * self.mesh_cols + x, ny * self.mesh_cols + x))
            y = ny
        return links

    # -- capacity -----------------------------------------------------------

    @property
    def total_weight_capacity_bytes(self) -> int:
        return self.n_cores * self.core.weight_capacity_bytes

    # -- peak rates (roofline-style anchors for the cost model) -------------

    def peak_macs_per_cycle_per_core(self) -> float:
        """All MGs firing, amortized over a bit-serial pass."""
        cim = self.core.cim
        per_pass = cim.n_macro_groups * cim.macs_per_pass()
        return per_pass / cim.macro.mvm_beats()

    def peak_tops(self) -> float:
        """Chip peak INT8 TOPS (2 ops per MAC)."""
        return (2 * self.peak_macs_per_cycle_per_core() * self.n_cores
                * self.clock_ghz * 1e9 / 1e12)

    # -- timing/energy rules -------------------------------------------------

    def machine(self, calibration: Any = None):
        """The chip's :class:`repro.core.machine.MachineModel` — the one
        object every fidelity reads timing/bandwidth/energy rules from."""
        from .machine import machine_for      # circular-import guard
        return machine_for(self, calibration)

    # -- (de)serialization ---------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), indent=2, **kw)

    def describe(self) -> str:
        cim = self.core.cim
        lines = [
            f"chip '{self.name}': {self.n_cores} cores "
            f"({self.mesh_rows}x{self.mesh_cols} mesh), "
            f"global mem {self.global_mem_bytes // (1024 * 1024)} MB, "
            f"flit {self.noc.flit_bytes} B",
            f"  core: {cim.n_macro_groups} MGs x {cim.macros_per_group} "
            f"macros ({cim.macro.rows}x{cim.macro.cols}), "
            f"local mem {self.core.local_mem.size_bytes // 1024} KB, "
            f"weight cap {self.core.weight_capacity_bytes // 1024} KB",
            f"  peak {self.peak_tops():.1f} INT8 TOPS @ {self.clock_ghz} GHz",
        ]
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Construction helpers
# ---------------------------------------------------------------------------


def default_chip(**overrides: Any) -> ChipConfig:
    """Tab. I default architecture, with keyword overrides.

    Convenience overrides understood beyond plain ChipConfig fields:
    ``macros_per_group``, ``n_macro_groups``, ``flit_bytes``,
    ``local_mem_kb``, ``protection``.
    """
    macro = MacroConfig()
    mg = overrides.pop("macros_per_group", 8)
    n_mg = overrides.pop("n_macro_groups", 16)
    flit = overrides.pop("flit_bytes", 8)
    lmem_kb = overrides.pop("local_mem_kb", 512)
    prot = overrides.pop("protection", ProtectionConfig())
    core = CoreConfig(
        cim=CimUnitConfig(n_macro_groups=n_mg, macros_per_group=mg,
                          macro=macro, protection=prot),
        local_mem=LocalMemConfig(size_bytes=lmem_kb * 1024),
    )
    noc = NocConfig(flit_bytes=flit)
    return ChipConfig(core=core, noc=noc, **overrides)


def _build(cls, data: Dict[str, Any]):
    """Recursively build nested frozen dataclasses from a dict."""
    kwargs: Dict[str, Any] = {}
    for f in dataclasses.fields(cls):
        if f.name not in data:
            continue
        v = data[f.name]
        if dataclasses.is_dataclass(f.type) and isinstance(v, dict):
            kwargs[f.name] = _build(f.type, v)
        elif isinstance(v, dict) and f.name in _NESTED:
            kwargs[f.name] = _build(_NESTED[f.name], v)
        else:
            kwargs[f.name] = v
    return cls(**kwargs)


_NESTED = {
    "macro": MacroConfig,
    "protection": ProtectionConfig,
    "cim": CimUnitConfig,
    "vector": VectorUnitConfig,
    "scalar": ScalarUnitConfig,
    "local_mem": LocalMemConfig,
    "regs": RegFileConfig,
    "core": CoreConfig,
    "noc": NocConfig,
}


def chip_from_dict(data: Dict[str, Any]) -> ChipConfig:
    return _build(ChipConfig, data)


def chip_from_json(text: str) -> ChipConfig:
    return chip_from_dict(json.loads(text))

"""Alg. 1 at pod scale: capacity-constrained model partitioning.

CIMFlow's core problem — partition a DNN across a grid of
capacity-limited compute-in-memory cores connected by a NoC, duplicating
weights into vacant cores when the cost model says it pays — is
isomorphic to placing an LLM on a TPU pod:

====================  =====================================
digital CIM chip      TPU pod
====================  =====================================
core SRAM capacity    chip HBM budget for params/opt state
NoC links             ICI links
execution stage       pipeline stage (weights resident)
weight duplication    data-parallel replication of a stage
inter-op pipeline     tensor parallelism within a stage
====================  =====================================

The planner reuses the paper's DP over dependency closures (a decoder
stack condenses to a chain, so closures are prefixes) with a TPU cost
model: per-stage interval = max(compute, HBM, ICI) per microbatch;
duplication multiplies throughput and divides the data-parallel batch.
Its output (`ParallelismPlan`) documents the recommended
(PP x DP x TP) decomposition per architecture and drives the elastic
re-mesh policy in :mod:`repro.runtime.elastic`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..configs.base import ArchConfig, ShapeConfig

__all__ = ["PodSpec", "PlanStage", "ParallelismPlan", "plan_parallelism"]


@dataclass(frozen=True)
class PodSpec:
    n_chips: int = 256
    peak_flops: float = 197e12        # bf16/chip
    hbm_bytes: float = 16e9
    hbm_bw: float = 819e9
    ici_bw: float = 50e9              # per link
    ici_links: int = 4
    mfu_target: float = 0.5           # achievable fraction of peak
    param_bytes: float = 2.0          # bf16 weights
    opt_bytes: float = 4.0            # moments (bf16 m+v) per param
    hbm_budget_frac: float = 0.85     # params+opt share of HBM
    max_tp: int = 16                  # one ICI dimension


@dataclass
class PlanStage:
    blocks: Tuple[int, int]           # [lo, hi) block range
    tp: int                           # chips per model replica (within stage)
    dup: int                          # stage replicas (data parallel)
    bytes_per_chip: float
    interval_s: float                 # per-microbatch steady state

    @property
    def chips(self) -> int:
        return self.tp * self.dup


@dataclass
class ParallelismPlan:
    arch: str
    shape: str
    pod: PodSpec
    stages: List[PlanStage]
    est_step_s: float
    tokens_per_s: float

    @property
    def pp(self) -> int:
        return len(self.stages)

    def describe(self) -> str:
        rows = [f"plan[{self.arch} x {self.shape}]: PP={self.pp}, "
                f"step≈{self.est_step_s * 1e3:.1f} ms, "
                f"{self.tokens_per_s / 1e6:.2f} Mtok/s"]
        for i, s in enumerate(self.stages):
            rows.append(
                f"  stage{i}: blocks[{s.blocks[0]}:{s.blocks[1]}) "
                f"tp={s.tp} dup={s.dup} "
                f"{s.bytes_per_chip / 2**30:.1f} GiB/chip "
                f"interval={s.interval_s * 1e3:.2f} ms")
        return "\n".join(rows)


def _block_stats(cfg: ArchConfig) -> Tuple[float, float, float]:
    """(bytes, flops/token, act_bytes/token) for one scan block."""
    total = cfg.param_count()
    embed = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    block_params = (total - embed) / cfg.n_blocks
    # training flops/token ≈ 6 x active params
    if cfg.moe is not None:
        m = cfg.moe
        per_expert = (3 if cfg.act == "swiglu" else 2) \
            * cfg.d_model * m.d_ff
        n_moe = sum(1 for i in range(len(cfg.block_pattern))
                    if i % max(m.moe_stride, 1) == 0)
        inactive = n_moe * (m.n_experts - m.experts_per_tok) * per_expert
        active = block_params - inactive
    else:
        active = block_params
    return (block_params, 6.0 * active,
            2.0 * cfg.d_model * len(cfg.block_pattern))


def _stage_plan(cfg: ArchConfig, shape: ShapeConfig, pod: PodSpec,
                n_stage_blocks: int, chips: int,
                tokens_per_micro: float) -> Optional[PlanStage]:
    """OptimalMapping analogue: choose (tp, dup) for one stage."""
    block_bytes, flops_tok, act_tok = _block_stats(cfg)
    per_param = pod.param_bytes + (pod.opt_bytes
                                   if shape.kind == "train" else 0.0)
    stage_bytes = n_stage_blocks * block_bytes / pod.param_bytes \
        * per_param
    budget = pod.hbm_bytes * pod.hbm_budget_frac
    tp_min = max(1, math.ceil(stage_bytes / budget))
    if tp_min > chips:
        return None
    best: Optional[PlanStage] = None
    tp = 1 << max(0, (tp_min - 1).bit_length())      # pow2 TP degrees
    while tp <= min(pod.max_tp, chips):
        dup = chips // tp
        if dup < 1:
            break
        compute = (tokens_per_micro / dup) * n_stage_blocks \
            * flops_tok / (tp * pod.peak_flops * pod.mfu_target)
        # TP all-reduce per block: ~4 x act bytes x 2 (fwd+bwd)
        coll = 0.0
        if tp > 1:
            coll = (tokens_per_micro / dup) * n_stage_blocks \
                * act_tok * 8.0 / (pod.ici_links * pod.ici_bw)
        interval = max(compute, coll)
        cand = PlanStage(blocks=(0, n_stage_blocks), tp=tp, dup=dup,
                         bytes_per_chip=stage_bytes / tp,
                         interval_s=interval)
        if best is None or cand.interval_s < best.interval_s:
            best = cand
        tp *= 2
    return best


def plan_parallelism(cfg: ArchConfig, shape: ShapeConfig,
                     pod: PodSpec = PodSpec(),
                     n_micro: int = 8) -> ParallelismPlan:
    """DP over chain prefixes (Alg. 1 on the block chain)."""
    nb = cfg.n_blocks
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    tokens_per_micro = tokens / n_micro
    INF = float("inf")
    dp: List[float] = [INF] * (nb + 1)
    prev: List[int] = [-1] * (nb + 1)
    plans: List[Optional[PlanStage]] = [None] * (nb + 1)
    dp[0] = 0.0
    # cache stage costs by length (chain is homogeneous per block)
    memo: Dict[int, Optional[PlanStage]] = {}

    for i in range(1, nb + 1):
        for j in range(i):
            length = i - j
            if length not in memo:
                # chips split evenly across the prospective stage count;
                # evaluated per candidate partition below via interval sum
                memo[length] = None
            # candidate cost computed lazily with chips = n/areas; handle
            # by assuming equal chip share per stage in this partition:
            pass
        # two-pass DP: enumerate stage length directly
        for j in range(i):
            length = i - j
            # chips proportional to the stage's share of total blocks —
            # balanced pipelines get equal intervals
            chips = max(1, int(pod.n_chips * length / nb))
            sp = _stage_plan(cfg, shape, pod, length, chips,
                             tokens_per_micro)
            if sp is None:
                continue
            # pipeline cost model: sum of intervals approximates the
            # bottleneck x stages for balanced partitions; fill added once
            cost = dp[j] + sp.interval_s * n_micro / max(1, 1)
            if cost < dp[i]:
                dp[i], prev[i] = cost, j
                plans[i] = PlanStage(blocks=(j, i), tp=sp.tp, dup=sp.dup,
                                     bytes_per_chip=sp.bytes_per_chip,
                                     interval_s=sp.interval_s)
    if dp[nb] == INF:
        raise ValueError(f"{cfg.name}: no feasible plan on "
                         f"{pod.n_chips} chips")
    stages: List[PlanStage] = []
    i = nb
    while i > 0:
        stages.append(plans[i])          # type: ignore[arg-type]
        i = prev[i]
    stages.reverse()
    # pipeline step estimate: bottleneck interval x microbatches + fill
    bott = max(s.interval_s for s in stages)
    fill = sum(s.interval_s for s in stages)
    step = bott * n_micro + fill
    return ParallelismPlan(arch=cfg.name, shape=shape.name, pod=pod,
                           stages=stages, est_step_s=step,
                           tokens_per_s=tokens / step)

"""CIMFlow core: the paper's contribution as a composable library.

Pipeline:  workloads -> graph (condense) -> partition (Alg. 1 / baselines)
           -> oplevel (virtual/physical mapping) -> codegen (ISA streams)
           -> simulator (cycle-accurate perf / functional ISS) -> energy.

These modules are the *pass implementations*; the user-facing compile
API is :mod:`repro.flow` (``flow.compile(workload, chip, options)``
with pluggable passes and evaluation backends).  The free functions
``partition()`` and ``compile_model()`` remain as deprecated shims.
"""

from . import (arch, codegen, energy, graph, isa, mapping, oplevel,
               partition, ref, simulator, workloads)
from .arch import ChipConfig, default_chip
from .codegen import CompiledModel, QuantParams, compile_model
from .graph import CondensedGraph, Graph
from .isa import Isa, Program, default_isa
from .mapping import CostParams
from .partition import (PartitionResult, STRATEGIES,
                        partition as partition_model)
from .simulator import SimReport, Simulator

__all__ = [
    "arch", "codegen", "energy", "graph", "isa", "mapping", "oplevel",
    "partition", "ref", "simulator", "workloads",
    "ChipConfig", "default_chip", "CompiledModel", "QuantParams",
    "compile_model", "CondensedGraph", "Graph", "Isa", "Program",
    "default_isa", "CostParams", "PartitionResult", "STRATEGIES",
    "partition_model", "SimReport", "Simulator",
]

"""One machine-timing/energy model shared by every fidelity.

Historically the analytic cost model (:mod:`repro.core.mapping`) and the
cycle-accurate simulator (:mod:`repro.core.simulator`) each read raw
``ChipConfig`` fields and re-derived latencies — bit-serial MVM beats,
NoC link occupancy, global-memory stream rates, scalar/vector issue
latencies — independently.  Any constant that drifted between the two
silently invalidated the workflow's central premise: that decisions
made against the cheap model hold on the expensive one.

:class:`MachineModel` is now the *only* place a timing, bandwidth or
energy rule is written down.  It is derived from a ``ChipConfig`` (the
structural description stays in :mod:`repro.core.arch`) and consumed by

* the analytic cost model (``core.mapping`` — stage intervals, load
  cycles, energy-event pricing),
* the cycle-accurate simulator (``core.simulator`` — per-instruction
  unit latencies, wormhole link occupancy, gmem port streams),
* the ``trace`` fidelity (``core.trace`` — StagePlan replay at
  unit/transfer granularity),
* benchmarks and reports (roofline anchors).

A :class:`Calibration` attached to the model carries per-unit
multiplicative correction factors fitted from a handful of simulator
runs (:func:`repro.flow.calibrate`): the raw model stays analytic and
chip-derived, while calibrated evaluations tighten the analytic and
trace fidelities toward simulator truth — which is what makes
cheap-fidelity *rankings* trustworthy in design-space exploration.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from functools import lru_cache
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

from .arch import ChipConfig
from .energy import DEFAULT_TABLE, EnergyTable, energy_breakdown

__all__ = [
    "Calibration", "IDENTITY_CALIBRATION", "MachineModel", "machine_for",
    "VECTOR_SPECIAL_FNS", "VECTOR_MUL_FNS",
    "InterChipLink", "LINK_TIERS", "link_tier",
]


# Vector-unit latency classes, shared by the simulator's dispatch, the
# trace replay and the analytic vector estimate.  ``special`` ops run
# through the LUT pipeline (one issue per lanes-wide beat); ``mul`` ops
# pay the multiplier latency; everything else is ALU-class.
VECTOR_SPECIAL_FNS = frozenset(
    {"sigmoid", "silu", "gelu", "tanh", "exp", "recip", "rsqrt",
     "softmax", "layernorm"})
VECTOR_MUL_FNS = frozenset({"mul", "mac", "muli", "quant", "dequant"})


@dataclass(frozen=True)
class Calibration:
    """Per-unit multiplicative correction factors (1.0 = uncalibrated).

    ``cim`` / ``vector`` / ``noc`` / ``gmem`` / ``load`` scale the
    matching cycle components of the analytic and trace fidelities;
    ``makespan`` is the residual serialization factor applied to a
    stage's total latency after the per-unit terms — it absorbs
    whole-sample handoff chains and in-order-issue stalls that no
    per-unit busy model can see.
    """

    cim: float = 1.0
    vector: float = 1.0
    noc: float = 1.0
    gmem: float = 1.0
    load: float = 1.0
    makespan: float = 1.0

    def __post_init__(self) -> None:
        for f in ("cim", "vector", "noc", "gmem", "load", "makespan"):
            v = getattr(self, f)
            if not (v > 0 and math.isfinite(v)):
                raise ValueError(f"calibration factor {f} must be a "
                                 f"positive finite number, got {v!r}")

    @property
    def is_identity(self) -> bool:
        return self == IDENTITY_CALIBRATION

    def scaled(self, **kw: float) -> "Calibration":
        return replace(self, **kw)

    def to_dict(self) -> Dict[str, float]:
        return {"cim": self.cim, "vector": self.vector, "noc": self.noc,
                "gmem": self.gmem, "load": self.load,
                "makespan": self.makespan}

    @classmethod
    def from_dict(cls, d: Mapping[str, float]) -> "Calibration":
        return cls(**{k: float(v) for k, v in d.items()})

    @classmethod
    def combine(cls, calibs: "list[Calibration]") -> "Calibration":
        """Geometric mean of several fits (e.g. one per candidate chip
        of a sweep) — factors are ratios, so the geomean is the
        bias-free aggregate."""
        if not calibs:
            return cls()
        out = {}
        for f in ("cim", "vector", "noc", "gmem", "load", "makespan"):
            vals = [getattr(c, f) for c in calibs]
            out[f] = math.exp(sum(math.log(v) for v in vals)
                              / len(vals))
        return cls(**out)

    def describe(self) -> str:
        return ("calibration(" +
                ", ".join(f"{k}={v:.3g}"
                          for k, v in self.to_dict().items()) + ")")


IDENTITY_CALIBRATION = Calibration()


# ---------------------------------------------------------------------------
# Inter-chip interconnect (mesh-of-chips tier above the NoC)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InterChipLink:
    """One inter-chip link technology tier.

    Chips of a :class:`repro.system.SystemConfig` mesh talk over these
    links; a transfer drains through the sending chip's reserved global
    memory ports, so the effective bandwidth is the min of the serdes
    payload rate and the boundary-port stream rate — exactly the
    "gmem-port-contended" pricing the system partitioner assumes.
    """

    name: str = "pcb"
    bytes_per_cycle: float = 16.0     # serdes payload per core clock
    hop_cycles: int = 500             # per-chip-hop latency (serdes+fifo)
    sync_cycles: int = 200            # fixed handshake per transfer
    energy_pj_per_byte: float = 10.0  # link traversal energy

    def __post_init__(self) -> None:
        if not (self.bytes_per_cycle > 0
                and math.isfinite(self.bytes_per_cycle)):
            raise ValueError(f"link bytes_per_cycle must be positive, "
                             f"got {self.bytes_per_cycle!r}")
        if self.hop_cycles < 0 or self.sync_cycles < 0:
            raise ValueError("link latencies must be non-negative")

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name,
                "bytes_per_cycle": self.bytes_per_cycle,
                "hop_cycles": self.hop_cycles,
                "sync_cycles": self.sync_cycles,
                "energy_pj_per_byte": self.energy_pj_per_byte}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "InterChipLink":
        return cls(name=str(d["name"]),
                   bytes_per_cycle=float(d["bytes_per_cycle"]),
                   hop_cycles=int(d["hop_cycles"]),
                   sync_cycles=int(d["sync_cycles"]),
                   energy_pj_per_byte=float(d["energy_pj_per_byte"]))


# Named technology tiers, best to worst: silicon interposer (chiplets on
# one substrate), PCB traces (chips on one board), cabled boards (a pod).
# These are THE inter-chip timing/energy constants — nothing outside
# this module may invent its own.
LINK_TIERS: Dict[str, InterChipLink] = {
    "interposer": InterChipLink("interposer", bytes_per_cycle=64.0,
                                hop_cycles=100, sync_cycles=50,
                                energy_pj_per_byte=1.0),
    "pcb": InterChipLink("pcb", bytes_per_cycle=16.0,
                         hop_cycles=500, sync_cycles=200,
                         energy_pj_per_byte=10.0),
    "cable": InterChipLink("cable", bytes_per_cycle=4.0,
                           hop_cycles=2000, sync_cycles=500,
                           energy_pj_per_byte=30.0),
}


def link_tier(name: str) -> InterChipLink:
    """Resolve a named inter-chip link tier."""
    try:
        return LINK_TIERS[name]
    except KeyError:
        raise KeyError(f"unknown inter-chip link tier {name!r} "
                       f"(have: {', '.join(sorted(LINK_TIERS))})") from None


@dataclass(frozen=True)
class MachineModel:
    """Every timing/bandwidth/energy rule of one chip, in one object.

    Frozen and hashable — safe to share across threads and cheap enough
    to construct per candidate chip in an arch sweep (all accessors are
    O(1) arithmetic over ``ChipConfig`` fields).  Use
    :func:`machine_for` to get the memoized instance.
    """

    chip: ChipConfig
    calib: Calibration = IDENTITY_CALIBRATION
    energy_table: EnergyTable = DEFAULT_TABLE

    # ------------------------------------------------------------------
    # CIM unit
    # ------------------------------------------------------------------

    @property
    def mvm_interval_beats(self) -> int:
        """Pipelined pass interval: one beat per activation bit."""
        return self.chip.core.cim.macro.act_bits

    @property
    def mvm_fill_beats(self) -> int:
        """Adder-tree fill latency paid once per MVM burst.

        Protection hardware adds pipeline stages to the output path:
        one ECC decode stage and one TMR voter stage (zero when off).
        """
        p = self.protection
        return (self.chip.core.cim.macro.adder_tree_depth
                + int(p.ecc) + int(p.tmr))

    @property
    def mvm_pass_beats(self) -> int:
        """One full bit-serial pass: interval + tree fill."""
        return self.mvm_interval_beats + self.mvm_fill_beats

    def mvm_cycles(self, rep: int) -> float:
        """A CIM_MVM burst of ``rep`` input vectors."""
        return rep * self.mvm_interval_beats + self.mvm_fill_beats

    def weight_load_cycles(self, rows: int) -> float:
        """CIM_LOAD of ``rows`` macro rows from local memory."""
        return rows / self.effective_weight_load_rows_per_cycle

    def group_load_cycles(self) -> float:
        """(Re)load of one full macro group."""
        return self.weight_load_cycles(self.chip.core.cim.macro.rows)

    @property
    def macros_per_group(self) -> int:
        return self.chip.core.cim.macros_per_group

    # ------------------------------------------------------------------
    # Fault-mitigation hardware (ECC / row sparing / TMR) overheads
    # ------------------------------------------------------------------

    @property
    def protection(self):
        """The chip's :class:`~repro.core.arch.ProtectionConfig`."""
        return self.chip.core.cim.protection

    @property
    def weight_storage_overhead(self) -> float:
        """Stored-bit inflation of the weight arrays: SECDED check
        bits (+12.5%) and spare rows (+``spare/rows``).  1.0 when
        protection is off."""
        p = self.protection
        macro = self.chip.core.cim.macro
        f = 1.0
        if p.ecc:
            f *= 1.125
        if p.spare_rows:
            f *= 1.0 + p.spare_rows / macro.rows
        return f

    @property
    def cim_compute_redundancy(self) -> float:
        """Physical MVM passes per logical pass (3.0 under TMR)."""
        return 3.0 if self.protection.tmr else 1.0

    @property
    def weight_load_factor(self) -> float:
        """CIM_LOAD time/bytes inflation: every stored copy and check
        bit must be written (storage overhead x TMR redundancy)."""
        return self.weight_storage_overhead * self.cim_compute_redundancy

    @property
    def protection_area_factor(self) -> float:
        """First-order CIM-unit area inflation from protection
        hardware — the area axis of a protection DSE sweep."""
        return self.weight_storage_overhead * self.cim_compute_redundancy

    @property
    def effective_weight_load_rows_per_cycle(self) -> float:
        """Row-write throughput after protection overhead.  Written as
        one shared divisor so the scalar, array-batched and JAX-fleet
        paths stay bit-identical."""
        return (self.chip.core.cim.weight_load_rows_per_cycle
                / self.weight_load_factor)

    # ------------------------------------------------------------------
    # Vector unit
    # ------------------------------------------------------------------

    @property
    def vector_lanes(self) -> int:
        return self.chip.core.vector.lanes

    def vector_cycles(self, fn: str, n: int) -> float:
        """One vector instruction over ``n`` elements (fn = op name
        without the ``V_`` prefix, lower-case)."""
        v = self.chip.core.vector
        beats = math.ceil(max(n, 1) / v.lanes)
        if fn in VECTOR_SPECIAL_FNS:
            return beats * v.special_latency
        if fn in VECTOR_MUL_FNS:
            return beats + v.mul_latency
        return beats + v.alu_latency

    def vector_class(self, fn: str) -> int:
        """Latency class id for :meth:`vector_cycles_array`:
        0 = ALU, 1 = multiplier, 2 = LUT/special."""
        if fn in VECTOR_SPECIAL_FNS:
            return 2
        if fn in VECTOR_MUL_FNS:
            return 1
        return 0

    def vector_cycles_array(self, vclass: "Any", n: "Any") -> "Any":
        """Batched :meth:`vector_cycles`: ``vclass`` int array (see
        :meth:`vector_class`) and ``n`` element-count array -> float64
        latencies.  One numpy pass for the pre-decoded simulator; the
        arithmetic is kept element-identical to the scalar accessor."""
        v = self.chip.core.vector
        n = np.maximum(np.asarray(n, dtype=np.int64), 1)
        beats = -(-n // v.lanes)          # ceil-div, exact in int64
        lat = beats + np.where(vclass == 1, v.mul_latency, v.alu_latency)
        return np.where(vclass == 2, beats * v.special_latency,
                        lat).astype(np.float64)

    def mvm_cycles_array(self, rep: "Any") -> "Any":
        """Batched :meth:`mvm_cycles` over a ``rep`` array."""
        rep = np.asarray(rep, dtype=np.int64)
        return (rep * self.mvm_interval_beats
                + self.mvm_fill_beats).astype(np.float64)

    def weight_load_cycles_array(self, rows: "Any") -> "Any":
        """Batched :meth:`weight_load_cycles` over a ``rows`` array."""
        rows = np.asarray(rows, dtype=np.float64)
        return rows / self.effective_weight_load_rows_per_cycle

    def send_issue_cycles_array(self, nbytes: "Any") -> "Any":
        """Batched :meth:`send_issue_cycles` over a byte-count array."""
        nbytes = np.asarray(nbytes, dtype=np.float64)
        return np.maximum(1.0, nbytes / self.link_bytes_per_cycle)

    # ------------------------------------------------------------------
    # Scalar unit
    # ------------------------------------------------------------------

    @property
    def scalar_alu_cycles(self) -> int:
        return self.chip.core.scalar.alu_latency

    @property
    def scalar_mul_cycles(self) -> int:
        return self.chip.core.scalar.mul_latency

    @property
    def scalar_ldst_cycles(self) -> int:
        return self.chip.core.scalar.ldst_latency

    def branch_cycles(self, taken: bool) -> int:
        s = self.chip.core.scalar
        return 1 + (s.branch_penalty if taken else 0)

    # ------------------------------------------------------------------
    # NoC
    # ------------------------------------------------------------------

    @property
    def link_bytes_per_cycle(self) -> int:
        return self.chip.noc.link_bytes_per_cycle

    @property
    def router_hop_cycles(self) -> int:
        return self.chip.noc.router_latency

    @property
    def inject_cycles(self) -> int:
        return self.chip.noc.inject_latency

    def link_occupancy_cycles(self, nbytes: int) -> float:
        """Cycles a wormhole flit stream occupies one directed link."""
        noc = self.chip.noc
        flits = max(1, math.ceil(nbytes / noc.flit_bytes))
        return flits / noc.flits_per_cycle

    def send_issue_cycles(self, nbytes: int) -> float:
        """Sender-side NoC-unit occupancy to inject a message."""
        return max(1.0, nbytes / self.link_bytes_per_cycle)

    @property
    def avg_hops(self) -> float:
        """Expected Manhattan distance between two uniform-random mesh
        cores: (rows + cols) / 3."""
        return (self.chip.mesh_rows + self.chip.mesh_cols) / 3.0

    def hops(self, src: int, dst: int) -> int:
        return self.chip.hops(src, dst)

    def route(self, src: int, dst: int) -> List[Tuple[int, int]]:
        return self.chip.route(src, dst)

    def noc_transfer_cycles(self, nbytes: int,
                            hops: Optional[float] = None) -> float:
        """Uncontended end-to-end transfer estimate."""
        h = self.avg_hops if hops is None else hops
        return (self.inject_cycles + h * self.router_hop_cycles
                + self.link_occupancy_cycles(nbytes))

    # ------------------------------------------------------------------
    # Global memory
    # ------------------------------------------------------------------

    @property
    def gmem_ports(self) -> int:
        return self.chip.global_mem_ports

    @property
    def gmem_port_bytes_per_cycle(self) -> int:
        return self.chip.global_mem_bytes_per_cycle

    @property
    def gmem_total_bytes_per_cycle(self) -> int:
        return self.gmem_ports * self.gmem_port_bytes_per_cycle

    def gmem_stream_cycles(self, nbytes: float,
                           ports: Optional[int] = None) -> float:
        """Stream ``nbytes`` over ``ports`` concurrent gmem ports."""
        n = self.gmem_ports if ports is None else max(1, min(
            ports, self.gmem_ports))
        return nbytes / (n * self.gmem_port_bytes_per_cycle)

    # ------------------------------------------------------------------
    # Inter-chip links (system tier above the NoC)
    # ------------------------------------------------------------------

    def interchip_bandwidth(self, link: InterChipLink,
                            ports: int = 1) -> float:
        """Effective B/cyc of one link transfer: the serdes payload
        rate, throttled by the sending chip's reserved boundary gmem
        ports (activations drain gmem -> serdes)."""
        n = max(1, min(int(ports), self.gmem_ports))
        return min(link.bytes_per_cycle,
                   float(n * self.gmem_port_bytes_per_cycle))

    def interchip_transfer_cycles(self, nbytes: float,
                                  link: InterChipLink,
                                  hops: int = 1,
                                  ports: int = 1) -> float:
        """End-to-end inter-chip transfer: handshake + per-chip-hop
        latency + port-contended streaming.  Scaled by the ``noc``
        calibration factor (the communication hierarchy shares one
        correction)."""
        if nbytes <= 0:
            return 0.0
        cyc = (link.sync_cycles + max(1, int(hops)) * link.hop_cycles
               + nbytes / self.interchip_bandwidth(link, ports))
        return cyc * self.calib.noc

    def interchip_collective_cycles(self, nbytes: float,
                                    link: InterChipLink,
                                    n_chips: int,
                                    kind: str = "allgather",
                                    ports: int = 1) -> float:
        """Ring collective over ``n_chips`` on ``nbytes`` of payload
        (the full un-sharded tensor).  ``allgather``/``reduce`` both
        move ``(C-1)/C`` of the payload through each chip's link in
        ``C-1`` latency-bearing steps; ``allreduce`` is reduce-scatter
        + all-gather (twice the traffic)."""
        c = int(n_chips)
        if c <= 1 or nbytes <= 0:
            return 0.0
        if kind not in ("allgather", "reduce", "allreduce"):
            raise ValueError(f"unknown collective kind {kind!r}")
        steps = (c - 1) * (2 if kind == "allreduce" else 1)
        bw = self.interchip_bandwidth(link, ports)
        cyc = (steps * (link.sync_cycles + link.hop_cycles)
               + steps * (nbytes / c) / bw)
        return cyc * self.calib.noc

    def interchip_energy_nj(self, nbytes: float,
                            link: InterChipLink) -> float:
        """Link-traversal energy of ``nbytes`` on one tier, in nJ."""
        return nbytes * link.energy_pj_per_byte * 1e-3

    # ------------------------------------------------------------------
    # Batched-decode constants (JAX engine / fleet evaluation)
    # ------------------------------------------------------------------

    def timing_constants(self) -> Dict[str, float]:
        """The scalar timing constants of the batchable decode subset.

        These are the *only* machine numbers the static stage-decode
        latency pass reads (:mod:`repro.core.jaxsim`); stacking them
        across machines yields the vmappable table pytree one XLA
        program evaluates for a whole fleet of chip variants ("same
        program, different chip constants").  Integer-valued entries
        stay exact ints so the batched arithmetic is bit-identical to
        the per-machine accessors above.
        """
        v = self.chip.core.vector
        return {
            "vector_lanes": int(v.lanes),
            "vector_alu_latency": int(v.alu_latency),
            "vector_mul_latency": int(v.mul_latency),
            "vector_special_latency": int(v.special_latency),
            "mvm_interval_beats": int(self.mvm_interval_beats),
            "mvm_fill_beats": int(self.mvm_fill_beats),
            "scalar_alu_cycles": float(self.scalar_alu_cycles),
            "scalar_ldst_cycles": float(self.scalar_ldst_cycles),
            "weight_load_rows_per_cycle": float(
                self.effective_weight_load_rows_per_cycle),
            "link_bytes_per_cycle": float(self.link_bytes_per_cycle),
        }

    # ------------------------------------------------------------------
    # Energy event pricing
    # ------------------------------------------------------------------

    def price_events(self, events: Mapping[str, float]) -> Dict[str, float]:
        """Event ledger -> {category: nJ} breakdown (+ ``total``).

        Protection hardware prices in here: TMR triples the physical
        macro passes behind each logical one, and every stored copy /
        check bit inflates the weight-load traffic.  With protection
        off the ledger passes through untouched.
        """
        if self.protection.enabled:
            events = dict(events)
            if "cim_macro_passes" in events:
                events["cim_macro_passes"] *= self.cim_compute_redundancy
            if "cim_weight_load_bytes" in events:
                events["cim_weight_load_bytes"] *= self.weight_load_factor
        return energy_breakdown(events, self.energy_table)

    # ------------------------------------------------------------------
    # Derived peaks (roofline anchors)
    # ------------------------------------------------------------------

    def peak_macs_per_cycle_per_core(self) -> float:
        return self.chip.peak_macs_per_cycle_per_core()

    # ------------------------------------------------------------------
    # Calibration plumbing
    # ------------------------------------------------------------------

    def with_calibration(self, calib: Optional[Calibration]
                         ) -> "MachineModel":
        return machine_for(self.chip, calib)

    def describe(self) -> str:
        lines = [
            f"machine '{self.chip.name}': mvm {self.mvm_interval_beats}"
            f"+{self.mvm_fill_beats} beats, MG load "
            f"{self.group_load_cycles():.0f} cyc, vector "
            f"{self.vector_lanes} lanes, link "
            f"{self.link_bytes_per_cycle} B/cyc "
            f"({self.router_hop_cycles} cyc/hop), gmem "
            f"{self.gmem_ports}x{self.gmem_port_bytes_per_cycle} B/cyc",
        ]
        if not self.calib.is_identity:
            lines.append(f"  {self.calib.describe()}")
        return "\n".join(lines)


@lru_cache(maxsize=512)
def _machine_for(chip: ChipConfig, calib: Calibration) -> MachineModel:
    return MachineModel(chip=chip, calib=calib)


def machine_for(chip: ChipConfig,
                calib: Optional[Calibration] = None) -> MachineModel:
    """The memoized machine model of a chip (+ optional calibration).

    ``ChipConfig`` and ``Calibration`` are frozen, so identical
    descriptions share one instance — arch sweeps construct thousands
    of models for free.
    """
    return _machine_for(chip, calib or IDENTITY_CALIBRATION)

"""Code generation: mapped stages -> CIMFlow ISA instruction streams.

Final compilation phase (paper §III-C): consumes the CG-level partition
(:class:`PartitionResult`), the OP-level schedules (:mod:`.oplevel`) and
emits one :class:`~repro.core.isa.Program` per core per stage, plus the
global-memory image layout (weight blobs, activation buffers).

Execution contract (shared with the simulator):

* Stages execute sequentially; each stage's programs start with a weight
  prologue (GLD weight blobs -> CIM_LOAD into macro groups) and then an
  **unrolled** sample loop: acquire inputs (GLD for stage-boundary groups,
  RECV for intra-stage), im2col-gather, CIM_MVM chunks, fused vector ops,
  deliver outputs (SEND to consumers / GST to global memory).
* Data layout is HWC int8 for activations, ``(ky, kx, c)`` patch ordering
  (``(g, ky, kx)`` for depth-wise); INT32 partial sums; per-group
  fixed-point requantization (``Q_SCALE``/``Q_SHIFT``/``ACC_DIV``).
* Multi-core replicas: every core computes its own n-tile columns; cores
  send quantized slices to the replica's core 0 (*assembly core*), which
  interleaves them into the HWC output buffer and handles fused pooling /
  GAP / residual adds and outbound routing.
* Weight duplication: replicas own row-aligned slices of the output map;
  consumers receive exactly the rows they need (halo included); fused
  pooling recomputes its window halo locally.
* "Rows" generalize: a conv group's row is one feature-map line
  (``W*C`` bytes); a linear group's row is one gemm position (``K`` bytes
  in, ``N`` out).  Producer/consumer row units always agree.

Functional fidelity holds at any size, but local-memory segment bounds are
only *enforced* under ``strict_lmem`` (functional-simulation mode) — large
perf-mode models may logically exceed a segment, which leaves timing
unaffected (the simulator prices transfer sizes and repetition counts; a
production backend would ring-buffer rows with identical traffic).

Weight sources (see :mod:`repro.core.graph`): ``static`` tiles GLD a
gmem blob and CIM_LOAD it in the stage prologue; ``streamed`` tiles
repeat that inside the sample loop, cycling the group's own slot range
(above any co-residents on a time-shared core); ``dynamic`` tiles have
no gmem blob at all — the weight operand is a predecessor group's
activations, RECV'd (or GLD'd across a stage boundary) into a ``wsrc``
buffer and gather-transposed into the CIM write layout
(:func:`repro.core.vecsem.dynamic_weight_matrix`) by strided ``V_MOV``
before every per-sample ``CIM_LOAD``.  Fused ``softmax`` / ``layernorm``
/ ``gelu`` tails lower to the row-segment vector ops whose integer
semantics live in :mod:`repro.core.vecsem`.

Limitations (documented): ``avgpool`` as a fused op is not generated
(none of the paper's benchmarks use it outside GAP); *static*
multi-round weight streaming requires single-chunk groups (true for the
oversized FC layers that trigger it; the dynamic path re-loads per
chunk instead); other non-affine activations (silu/sigmoid/...)
execute on the vector unit's LUT path — timing is modeled, functional
simulation rejects them.
"""

from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .arch import ChipConfig
from .graph import CondensedGraph, Group
from .isa import FLAGS, Instr, Isa, Program, SREG, VFUNCT, default_isa
from .mapping import StagePlan
from .oplevel import (Im2colSpec, MgAssign, OpSchedule, PoolSpec,
                      ReplicaPlan, incremental_ops, plan_stage)
from .partition import PartitionResult

__all__ = ["QuantParams", "GmemLayout", "StageProgram", "CompiledModel",
           "compile_model", "CodegenError"]

GMEM_BASE = 0x1000_0000


class CodegenError(ValueError):
    pass


@dataclass(frozen=True)
class QuantParams:
    """Fixed-point requant: out = clip(rnd(acc*scale / (div*2^shift)), i8)."""

    scale: int = 1
    shift: int = 8

    def __post_init__(self):
        if not 0 < self.scale < (1 << 15):
            raise CodegenError(f"q-scale {self.scale} out of imm16 range")
        if not 0 <= self.shift < 31:
            raise CodegenError(f"q-shift {self.shift} out of range")


@dataclass
class GmemLayout:
    """Global-memory address map (addresses carry GMEM_BASE)."""

    weights: Dict[Tuple[int, int, int, int], Tuple[int, int]] = \
        field(default_factory=dict)      # (gid,k_off,n_off,ch_off)->(addr,nb)
    biases: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    acts: Dict[Tuple[int, int], Tuple[int, int]] = \
        field(default_factory=dict)      # (gid, sample) -> (addr, nbytes)
    inputs: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    # graph-input op id -> byte offset within each per-sample input
    # region (multi-input graphs, e.g. decode's token + KV caches)
    input_offsets: Dict[int, int] = field(default_factory=dict)
    size: int = 0                        # bytes used (above GMEM_BASE)

    def alloc(self, nbytes: int) -> int:
        addr = GMEM_BASE + self.size
        self.size += (nbytes + 63) & ~63      # 64B aligned
        return addr


@dataclass
class StageProgram:
    stage: StagePlan
    schedules: List[OpSchedule]
    programs: Dict[int, Program]

    @property
    def total_instrs(self) -> int:
        return sum(len(p) for p in self.programs.values())


@dataclass
class CompiledModel:
    cg: CondensedGraph
    chip: ChipConfig
    result: PartitionResult
    stages: List[StageProgram]
    layout: GmemLayout
    batch: int
    isa: Isa
    quant: Dict[int, QuantParams]

    @property
    def total_instrs(self) -> int:
        return sum(s.total_instrs for s in self.stages)

    # -- functional-mode gmem image -------------------------------------------

    def build_gmem_image(self, weights: Dict[int, np.ndarray],
                         biases: Optional[Dict[int, np.ndarray]],
                         inputs: np.ndarray) -> np.ndarray:
        """Materialize weight/bias/input blobs into a gmem byte image.

        ``weights[gid]``: int8 ``(K_total, N_total)`` matrix in the group's
        im2col layout (for depth-wise groups this is the block-diagonal
        expansion; tile blobs are dense slices of it).
        ``inputs``: int8 ``(batch, H, W, C)`` (or ``(batch, K)``).
        """
        biases = biases or {}
        img = np.zeros(self.layout.size, dtype=np.int8)

        def put(addr: int, flat: np.ndarray) -> None:
            off = addr - GMEM_BASE
            img[off:off + flat.size] = flat

        for (gid, k_off, n_off, ch_off), (addr, nb) in \
                self.layout.weights.items():
            w = weights[gid]
            a = self._assign(gid, k_off, n_off, ch_off)
            blob = np.ascontiguousarray(
                w[k_off:k_off + a.k_len, n_off:n_off + a.n_len],
                dtype=np.int8).reshape(-1)
            assert blob.size == nb, (gid, blob.size, nb)
            put(addr, blob)
        for gid, (addr, nb) in self.layout.biases.items():
            b = np.ascontiguousarray(biases[gid], dtype=np.int32)
            assert b.nbytes == nb
            put(addr, b.view(np.int8).reshape(-1))
        for s, (addr, nb) in self.layout.inputs.items():
            put(addr, np.ascontiguousarray(
                inputs[s], dtype=np.int8).reshape(-1))
        return img

    def _assign(self, gid, k_off, n_off, ch_off) -> MgAssign:
        for st in self.stages:
            for sc in st.schedules:
                if sc.gid != gid:
                    continue
                for a in sc.replicas[0].assigns:
                    if (a.k_off, a.n_off, a.ch_off) == (k_off, n_off,
                                                        ch_off):
                        return a
        raise KeyError((gid, k_off, n_off, ch_off))

    def output_addr(self, gid: int, sample: int) -> Tuple[int, int]:
        """gmem (addr, nbytes) of a boundary group's output."""
        return self.layout.acts[(gid, sample)]


# ---------------------------------------------------------------------------
# Emission helper
# ---------------------------------------------------------------------------


class _Emitter:
    """Per-core instruction emitter with S_Reg/G_Reg write coalescing
    (constant propagation + dead-write elimination at emission time)."""

    def __init__(self, isa: Isa, core: int) -> None:
        self.isa = isa
        self.prog = Program(core_id=core)
        self._sregs: Dict[int, int] = {}
        self._gregs: Dict[int, int] = {}
        self.channel_log: List[Tuple[str, int, int, int, str]] = []

    def raw(self, op: str, **args) -> None:
        self.prog.append(self.isa.instr(op, **args))

    def greg(self, reg: int, value: int) -> None:
        if self._gregs.get(reg) == value:
            return
        lo, hi = value & 0xFFFF, (value >> 16) & 0xFFFF
        if lo >= 0x8000:                 # ADDI sign-extends; compensate
            hi = (hi + 1) & 0xFFFF
        s16 = lambda v: v - 0x10000 if v >= 0x8000 else v  # noqa: E731
        if hi:
            self.raw("S_LUI", dst=reg, imm=s16(hi))
            if lo:
                self.raw("S_ADDI", dst=reg, a=reg, imm=s16(lo))
        else:
            self.raw("S_ADDI", dst=reg, a=0, imm=s16(lo))
        self._gregs[reg] = value

    def sreg(self, name: str, value: int) -> None:
        idx = SREG[name]
        if self._sregs.get(idx) == value:
            return
        if -(1 << 15) <= value < (1 << 15):
            self.raw("CIM_CFG", sreg=idx, imm=value)
        else:
            self.greg(9, value)
            self.raw("CIM_CFGR", sreg=idx, src=9)
        self._sregs[idx] = value

    # -- idioms ---------------------------------------------------------------

    def gld(self, dst_lmem: int, gaddr: int, size: int) -> None:
        if size <= 0:
            return
        self.greg(1, dst_lmem)
        self.greg(2, gaddr)
        self.greg(3, size)
        self.raw("GLD", dst=1, gaddr=2, size=3)

    def gst(self, src_lmem: int, gaddr: int, size: int) -> None:
        if size <= 0:
            return
        self.greg(1, src_lmem)
        self.greg(2, gaddr)
        self.greg(3, size)
        self.raw("GST", src=1, gaddr=2, size=3)

    # channel log for the compile-time validation pass: (kind, peer,
    # stream, size, tag) in program order.  ``stream`` is the virtual
    # channel id (S_Reg[CHANNEL]) so multiple logical flows between one
    # core pair rendezvous independently.
    def send(self, dst_core: int, src_lmem: int, size: int,
             tag: str = "", stream: int = 0) -> None:
        if size <= 0:
            return
        self.sreg("CHANNEL", stream)
        self.greg(1, dst_core)
        self.greg(2, src_lmem)
        self.greg(3, size)
        self.raw("SEND", core=1, src=2, size=3)
        self.channel_log.append(("send", dst_core, stream, size, tag))

    def recv(self, dst_lmem: int, src_core: int, size: int,
             tag: str = "", stream: int = 0) -> None:
        if size <= 0:
            return
        self.sreg("CHANNEL", stream)
        self.greg(1, dst_lmem)
        self.greg(2, src_core)
        self.greg(3, size)
        self.raw("RECV", dst=1, core=2, size=3)
        self.channel_log.append(("recv", src_core, stream, size, tag))

    def vec(self, funct_name: str, dst: int, a: int, b: int = 0, *,
            vlen: int, rep: int = 1, seg_d: int = 0, seg_a: int = 0,
            seg_b: int = 0, stride_d: int = 1, stride_a: int = 1,
            stride_b: int = 1, flags: int = 0) -> None:
        if vlen <= 0 or rep <= 0:
            return
        self.sreg("VLEN", vlen)
        self.sreg("V_REP", rep)
        self.sreg("VSEG_D", seg_d)
        self.sreg("VSEG_A", seg_a)
        self.sreg("VSEG_B", seg_b)
        self.sreg("VSTRIDE_D", stride_d)
        self.sreg("VSTRIDE_A", stride_a)
        self.sreg("VSTRIDE_B", stride_b)
        self.greg(4, dst)
        self.greg(5, a)
        self.greg(6, b)
        self.raw(f"V_{funct_name.upper()}", dst=4, a=5, b=6, flags=flags)

    def mvm(self, dst: int, src: int, rep: int, acc: bool, mask: int,
            seg_in: int, seg_out: int) -> None:
        if rep <= 0:
            return
        self.sreg("MG_MASK_LO", mask & 0xFFFF)
        self.sreg("MG_MASK_HI", (mask >> 16) & 0xFFFF)
        self.sreg("MVM_SEG_IN", seg_in)
        self.sreg("MVM_SEG_OUT", seg_out)
        self.greg(7, dst)
        self.greg(8, src)
        self.raw("CIM_MVM", dst=7, src=8, rep=rep, acc=1 if acc else 0)

    def halt(self) -> None:
        self.raw("HALT")


def _ensure_vec_flag_operand(isa: Isa) -> None:
    """R-format V_* instructions carry FLAGS in their 5-bit field."""
    for d in isa.descriptors:
        if d.name.startswith("V_") and d.fmt == "R" and \
                "flags" not in d.operands:
            d.operands["flags"] = "flags"


# ---------------------------------------------------------------------------
# Local memory planning
# ---------------------------------------------------------------------------


class _Lmem:
    def __init__(self, chip: ChipConfig, strict: bool) -> None:
        self.seg = chip.core.local_mem.segment_bytes
        self.n_seg = chip.core.local_mem.n_segments
        self.strict = strict
        self.cursor = [0] * self.n_seg
        # perf-mode out-of-bounds segments, surfaced as one warning per
        # stage by _compile_stage (the silent-overflow footgun fix)
        self.overflows: List[Tuple[int, str]] = []

    def alloc(self, seg: int, nbytes: int, what: str) -> int:
        addr = seg * self.seg + self.cursor[seg]
        self.cursor[seg] += (max(nbytes, 0) + 63) & ~63
        if self.cursor[seg] > self.seg:
            if self.strict:
                raise CodegenError(
                    f"lmem segment {seg} overflow allocating {what} "
                    f"({self.cursor[seg]} > {self.seg})")
            self.overflows.append((seg, what))
        return addr


# ---------------------------------------------------------------------------
# Routing geometry
# ---------------------------------------------------------------------------


def _out_geometry(cg: CondensedGraph, sched: OpSchedule) \
        -> Tuple[int, int, int]:
    """(rows, row_bytes, total_bytes) of the group's *final* output."""
    if sched.gap:
        g = cg[sched.gid]
        return 1, g.out_bytes, g.out_bytes
    if sched.pool is not None:
        p = sched.pool
        return p.ho, p.wo * sched.n_total, p.ho * p.wo * sched.n_total
    if sched.im2col is not None:
        s = sched.im2col
        return s.ho, s.wo * sched.n_total, s.ho * s.wo * sched.n_total
    return (max(sched.m_total, 1), sched.n_total,
            max(sched.m_total, 1) * sched.n_total)


def _pooled_rows(cg: CondensedGraph, sched: OpSchedule,
                 rep: ReplicaPlan) -> Tuple[int, int]:
    """Pooled-output row range a replica owns (pre-GAP)."""
    wo = sched.im2col.wo
    if rep.m_hi <= rep.m_lo:
        return 0, 0
    y0, y1 = rep.m_lo // wo, math.ceil(rep.m_hi / wo)
    p = sched.pool
    # pooled row p owned iff its window start s*p - pad falls in [y0, y1)
    def owner_lo(y): return max(0, math.ceil((y + p.pad) / p.stride))
    p0 = 0 if y0 == 0 else owner_lo(y0)
    p1 = owner_lo(y1) if y1 < sched.im2col.ho else p.ho
    return min(p0, p.ho), min(p1, p.ho)


def _owned_out_rows(cg: CondensedGraph, sched: OpSchedule,
                    rep: ReplicaPlan) -> Tuple[int, int]:
    """Final-output row range produced (and delivered) by a replica."""
    if sched.gap:
        return (0, 1) if rep.replica == 0 else (0, 0)
    if sched.im2col is None:
        return rep.m_lo, rep.m_hi
    if rep.m_hi <= rep.m_lo:
        return 0, 0
    if sched.pool is None:
        wo = sched.im2col.wo
        return rep.m_lo // wo, math.ceil(rep.m_hi / wo)
    return _pooled_rows(cg, sched, rep)


def _conv_rows_to_compute(cg: CondensedGraph, sched: OpSchedule,
                          rep: ReplicaPlan) -> Tuple[int, int]:
    """Anchor output rows a replica computes (incl. pool halo recompute)."""
    if sched.im2col is None:
        return rep.m_lo, rep.m_hi
    if rep.m_hi <= rep.m_lo:
        return 0, 0
    s = sched.im2col
    y0, y1 = rep.m_lo // s.wo, math.ceil(rep.m_hi / s.wo)
    if sched.pool is not None:
        p = sched.pool
        p0, p1 = _pooled_rows(cg, sched, rep)
        if p1 > p0:
            y0 = min(y0, max(0, p0 * p.stride - p.pad))
            y1 = max(y1, min(s.ho, (p1 - 1) * p.stride - p.pad + p.k))
    return y0, y1


def _needed_in_rows(cg: CondensedGraph, sched: OpSchedule,
                    rep: ReplicaPlan, in_rows: int) -> Tuple[int, int]:
    """Input row range a replica needs (conv: feature rows; else m rows)."""
    if sched.im2col is None:
        return rep.m_lo, rep.m_hi
    s = sched.im2col
    y0, y1 = _conv_rows_to_compute(cg, sched, rep)
    if y1 <= y0:
        return 0, 0
    r0 = max(0, y0 * s.stride - s.pad)
    r1 = min(in_rows, (y1 - 1) * s.stride - s.pad + s.kh)
    return r0, max(r0, r1)


def _in_row_bytes(sched: OpSchedule) -> int:
    if sched.im2col is not None:
        return sched.im2col.w * sched.im2col.cin
    return sched.k_total


def _side_pre_reduce(sched: OpSchedule) -> bool:
    """True when the fused residual add/scale precedes pool/GAP in graph
    order (e.g. ResNet head: conv -> add -> relu -> GAP)."""
    vo = list(sched.vector_ops)
    si = min((vo.index(o) for o in ("add", "mul") if o in vo),
             default=None)
    ri = min((vo.index(o) for o in ("maxpool", "avgpool", "globalpool")
              if o in vo), default=None)
    return si is not None and ri is not None and si < ri


# fused ops applied as int8 row-segment tails after the residual side
# op (integer semantics shared with the oracle via repro.core.vecsem)
SPECIAL_TAIL_OPS = ("softmax", "layernorm", "gelu")


def _validate_special_tail(sched: OpSchedule) -> None:
    """Codegen applies softmax/layernorm/gelu last, on the assembled
    int8 output rows — reject fusion orders that contract can't honor."""
    vo = sched.vector_ops
    sp = [i for i, v in enumerate(vo) if v in SPECIAL_TAIL_OPS]
    if not sp:
        return
    if sched.pool is not None or sched.gap:
        raise CodegenError(
            f"{sched.name}: fused {vo[sp[0]]} cannot combine with "
            f"pooling/GAP")
    side = [i for i, v in enumerate(vo) if v in ("add", "mul")]
    if side and min(sp) < max(side):
        raise CodegenError(
            f"{sched.name}: fused {vo[min(sp)]} precedes a residual "
            f"add/mul — unsupported fusion order")
    # everything after the first special must itself be a special tail
    # (codegen emits nothing else back there — e.g. a trailing relu
    # would be silently dropped and diverge from the oracle)
    trailing = [v for v in vo[min(sp):] if v not in SPECIAL_TAIL_OPS]
    if trailing:
        raise CodegenError(
            f"{sched.name}: fused {trailing[0]!r} follows "
            f"{vo[min(sp)]} — unsupported fusion order")


def _relu_after_side(sched: OpSchedule) -> bool:
    vo = list(sched.vector_ops)
    if "relu" not in vo:
        return False
    si = min((vo.index(o) for o in ("add", "mul") if o in vo),
             default=None)
    return si is not None and vo.index("relu") > si


def _side_rows(cg: CondensedGraph, sched: OpSchedule,
               rep: ReplicaPlan) -> Tuple[int, int, int]:
    """(row_lo, row_hi, row_bytes) at which the side operand is applied."""
    if _side_pre_reduce(sched):
        y0, y1 = _conv_rows_to_compute(cg, sched, rep)
        row_nb = (sched.im2col.wo * sched.n_total
                  if sched.im2col is not None else sched.n_total)
        return y0, y1, row_nb
    o0, o1 = _owned_out_rows(cg, sched, rep)
    _, row_nb, _ = _out_geometry(cg, sched)
    return o0, o1, row_nb


def _weight_pred(cg: CondensedGraph, g: Group,
                 op_owner: Dict[int, int]) -> Optional[int]:
    """Weight-producer group of a dynamic-weight anchor (None for static
    groups and for dynamic weights sourced from the graph input)."""
    if not g.dynamic_weights or g.anchor is None or cg.source is None:
        return None
    anchor = cg.source.ops[g.anchor]
    if len(anchor.inputs) < 2:
        return None
    return op_owner.get(anchor.inputs[1])


def _main_and_skip_preds(cg: CondensedGraph, g: Group,
                         op_owner: Dict[int, int]) -> Tuple[Optional[int],
                                                            List[int]]:
    """Main (im2col source) pred group vs side (residual) pred groups.

    A dynamic-weight anchor's second input is its *weight* operand, not
    a residual — it is excluded here and routed by the weight path."""
    main: Optional[int] = None
    if g.anchor is not None and cg.source is not None:
        src_op = cg.source.ops[g.anchor].inputs[0]
        main = op_owner.get(src_op)      # None => graph input
    elif g.preds:
        main = g.preds[0]
    wp = _weight_pred(cg, g, op_owner)
    side = [p for p in g.preds if p != main
            and not (g.dynamic_weights and p == wp)]
    return main, side


def _side_input_ops(cg: CondensedGraph, g: Group) -> List[int]:
    """Graph-input op ids feeding this group's side (residual/scale)
    operands.  Impossible in a freshly condensed model graph (inputs
    are always main operands there), but a system-level pipeline cut
    can turn a residual producer on another chip into a slice input —
    the skip path then loads from the gmem input region instead of a
    producer group's activations."""
    if cg.source is None:
        return []
    main_in = _main_input_op(cg, g)
    wop: Optional[int] = None
    if g.dynamic_weights and g.anchor is not None:
        ins = cg.source.ops[g.anchor].inputs
        wop = ins[1] if len(ins) > 1 else None
    member = set(g.op_ids)
    out: List[int] = []
    for i in g.op_ids:
        for s in cg.source.ops[i].inputs:
            if s in member or cg.source.ops[s].kind != "input":
                continue
            if s == wop or s in out:
                continue
            if s == main_in and (g.anchor is None or i == g.anchor):
                continue
            out.append(s)
    return out


def _main_input_op(cg: CondensedGraph, g: Group) -> Optional[int]:
    """Graph-input op id the group's main operand reads (or None)."""
    if cg.source is None:
        return None
    if g.anchor is not None:
        ins = cg.source.ops[g.anchor].inputs
        return ins[0] if ins else None
    return next((s for i in g.op_ids for s in cg.source.ops[i].inputs
                 if cg.source.ops[s].kind == "input"), None)


# ---------------------------------------------------------------------------
# Model compiler
# ---------------------------------------------------------------------------


def compile_model(result: PartitionResult, batch: Optional[int] = None,
                  quant: Optional[Dict[int, QuantParams]] = None,
                  isa: Optional[Isa] = None,
                  strict_lmem: bool = False) -> CompiledModel:
    """Deprecated free-function entry point.

    Use ``repro.flow.compile(...)`` and ``Artifact.model`` — the
    pass-based pipeline instruments codegen and caches its output.
    This shim stays for existing callers and the golden tests.
    """
    warnings.warn(
        "repro.core.codegen.compile_model() is deprecated; use "
        "repro.flow.compile(workload, chip, options) and the returned "
        "Artifact (its .model / .evaluate(backend=...))",
        DeprecationWarning, stacklevel=2)
    return _compile_model(result, batch, quant, isa, strict_lmem)


def _compile_model(result: PartitionResult, batch: Optional[int] = None,
                   quant: Optional[Dict[int, QuantParams]] = None,
                   isa: Optional[Isa] = None,
                   strict_lmem: bool = False,
                   force_boundary: Optional[Set[int]] = None
                   ) -> CompiledModel:
    """Internal codegen body (the :mod:`repro.flow` codegen pass).

    ``force_boundary`` names group ids whose outputs must be written to
    their gmem activation buffer even when every consumer shares the
    stage — the multi-chip system path reads cut-crossing activations
    out of gmem to feed the next chip.
    """
    cg = result.cg
    chip = result.chip
    isa = isa or default_isa()
    _ensure_vec_flag_operand(isa)
    batch = batch if batch is not None else result.params.batch
    quant = quant or {}
    qp = {g.idx: quant.get(g.idx, QuantParams()) for g in cg}

    layout = GmemLayout()
    in_bytes = _graph_input_bytes(cg)
    if cg.source is not None:
        off = 0
        for op in cg.source.ops:
            if op.kind == "input":
                layout.input_offsets[op.idx] = off
                off += int(np.prod(op.out_shape))
    for s in range(batch):
        layout.inputs[s] = (layout.alloc(in_bytes), in_bytes)

    op_owner: Dict[int, int] = {}
    for g in cg:
        for i in g.op_ids:
            op_owner[i] = g.idx

    stages: List[StageProgram] = []
    for sp in result.stages:
        schedules = plan_stage(cg, sp, chip)
        stages.append(_compile_stage(cg, sp, schedules, chip, isa, layout,
                                     qp, batch, op_owner, strict_lmem,
                                     force_boundary or set()))
    return CompiledModel(cg=cg, chip=chip, result=result, stages=stages,
                         layout=layout, batch=batch, isa=isa, quant=qp)


def _graph_input_bytes(cg: CondensedGraph) -> int:
    if cg.source is None:
        return max((g.in_bytes for g in cg if not g.preds), default=0)
    return sum(int(np.prod(op.out_shape)) for op in cg.source.ops
               if op.kind == "input")


# ---------------------------------------------------------------------------
# Stage compilation
# ---------------------------------------------------------------------------


def _compile_stage(cg: CondensedGraph, sp: StagePlan,
                   schedules: List[OpSchedule], chip: ChipConfig, isa: Isa,
                   layout: GmemLayout, qp: Dict[int, QuantParams],
                   batch: int, op_owner: Dict[int, int],
                   strict_lmem: bool,
                   force_boundary: Optional[Set[int]] = None
                   ) -> StageProgram:
    force_boundary = force_boundary or set()
    by_gid = {s.gid: s for s in schedules}
    member = set(sp.gids)

    # gmem allocation: weight blobs (static sources only — dynamic
    # weights are activations, they never materialize in gmem) +
    # boundary activation buffers
    for sched in schedules:
        if sched.weight_source != "dynamic":
            for a in sched.replicas[0].assigns:
                key = (sched.gid, a.k_off, a.n_off, a.ch_off)
                if key not in layout.weights:
                    nb = a.k_len * a.n_len
                    layout.weights[key] = (layout.alloc(nb), nb)
        if "bias" in sched.vector_ops and sched.gid not in layout.biases:
            nb = sched.n_total * 4
            layout.biases[sched.gid] = (layout.alloc(nb), nb)
        if sched.n_rounds > 1 and sched.n_chunks > 1 \
                and sched.weight_source != "dynamic":
            # the dynamic path re-loads weights per (chunk, round) from
            # local memory instead; gmem-streamed groups would re-fetch
            # the whole blob per chunk, which we refuse to emit
            raise CodegenError(
                f"{sched.name}: multi-round weight streaming requires a "
                f"single m-chunk (got {sched.n_chunks})")
        _validate_special_tail(sched)
    for sched in schedules:
        g = cg[sched.gid]
        consumers = [h for h in cg if g.idx in h.preds]
        boundary_out = (not consumers) or any(h.idx not in member
                                              for h in consumers) \
            or g.idx in force_boundary
        if boundary_out:
            _, _, total = _out_geometry(cg, sched)
            for s in range(batch):
                if (g.idx, s) not in layout.acts:
                    layout.acts[(g.idx, s)] = (layout.alloc(total), total)

    emitters: Dict[int, _Emitter] = {}
    lmems: Dict[int, _Lmem] = {}

    def em(core: int) -> _Emitter:
        if core not in emitters:
            emitters[core] = _Emitter(isa, core)
            lmems[core] = _Lmem(chip, strict_lmem)
        return emitters[core]

    bufs: Dict[Tuple[int, int], Dict] = {}
    for sched in schedules:
        for rep in sched.replicas:
            bufs[(sched.gid, rep.replica)] = _plan_buffers(
                cg, sched, rep, chip, lmems, em, op_owner)

    ctx = _Ctx(cg=cg, sp=sp, chip=chip, layout=layout, bufs=bufs, qp=qp,
               member=member, by_gid=by_gid, op_owner=op_owner, em=em,
               batch=batch, force_boundary=force_boundary)

    # 1. weight prologue (round 0; later rounds stream inside the loop).
    # Dynamic groups have no prologue — their weights are per-sample
    # activations — but any static bias blob still loads here.
    for sched in schedules:
        for rep in sched.replicas:
            if sched.weight_source == "dynamic":
                if "bias" in sched.vector_ops \
                        and sched.gid in layout.biases:
                    addr, nb = layout.biases[sched.gid]
                    bb = bufs[(sched.gid, rep.replica)]
                    for c in rep.cores:
                        em(c).gld(bb["bias"][c], addr, nb)
                continue
            _emit_weight_load(ctx, sched, rep, rnd=0)

    # 2. unrolled sample loop, groups in topological order
    for s in range(batch):
        for sched in schedules:
            for rep in sched.replicas:
                _emit_sample(ctx, sched, rep, s)

    for e in emitters.values():
        e.halt()
        # ship the program with its pre-decoded SoA table: the
        # vectorized simulator replays these columns directly, so the
        # decode pass rides codegen (which is already lazy — analytic /
        # trace evaluations never build programs at all)
        e.prog.pack(isa)
    _validate_channels(emitters)
    over = [(c, seg, what) for c, lm in sorted(lmems.items())
            for seg, what in lm.overflows]
    if over:
        c0, seg0, what0 = over[0]
        more = f" (+{len(over) - 1} more)" if len(over) > 1 else ""
        warnings.warn(
            f"perf-mode lmem overflow: segment {seg0} allocating {what0} "
            f"on core {c0}{more}; timing is unaffected, but functional "
            f"runs require strict_lmem=True", RuntimeWarning,
            stacklevel=3)
    return StageProgram(stage=sp, schedules=schedules,
                        programs={c: e.prog for c, e in emitters.items()})


def _validate_channels(emitters: Dict[int, _Emitter]) -> None:
    """Compiler-side validation (paper §III-A): every SEND must pair with
    a RECV of identical size, in FIFO order per (src, dst, stream)
    virtual channel."""
    sends: Dict[Tuple[int, int, int], List[Tuple[int, str]]] = {}
    recvs: Dict[Tuple[int, int, int], List[Tuple[int, str]]] = {}
    for core, e in emitters.items():
        for kind, peer, stream, size, tag in e.channel_log:
            if kind == "send":
                sends.setdefault((core, peer, stream), []).append(
                    (size, tag))
            else:
                recvs.setdefault((peer, core, stream), []).append(
                    (size, tag))
    for chan in sorted(set(sends) | set(recvs)):
        s = sends.get(chan, [])
        r = recvs.get(chan, [])
        if [x[0] for x in s] != [x[0] for x in r]:
            for i, (a, b) in enumerate(zip(s + [(None, "?")] * len(r),
                                           r + [(None, "?")] * len(s))):
                if a[0] != b[0]:
                    raise CodegenError(
                        f"channel {chan[0]}->{chan[1]}#{chan[2]} "
                        f"message {i}: send {a[0]} ({a[1]}) vs "
                        f"recv {b[0]} ({b[1]})")


def _stream_id(producer_gid: int, consumer_gid: int, kind: int) -> int:
    """Virtual-channel id: (producer, consumer, kind) -> unique tag."""
    return (producer_gid * 128 + consumer_gid) * 8 + kind


@dataclass
class _Ctx:
    cg: CondensedGraph
    sp: StagePlan
    chip: ChipConfig
    layout: GmemLayout
    bufs: Dict
    qp: Dict[int, QuantParams]
    member: Set[int]
    by_gid: Dict[int, OpSchedule]
    op_owner: Dict[int, int]
    em: object
    batch: int
    force_boundary: Set[int] = field(default_factory=set)


def _plan_buffers(cg: CondensedGraph, sched: OpSchedule, rep: ReplicaPlan,
                  chip: ChipConfig, lmems, em, op_owner) -> Dict:
    """Per-(group, replica) lmem buffers; per-core address maps."""
    g = cg[sched.gid]
    tag = f"group {g.idx} ({g.name})"
    for c in rep.cores:
        em(c)                                      # materialize lmem
    out: Dict = {"in": {}, "stage": {}, "wstage": {}, "psum": {},
                 "qtmp": {}, "bias": {}, "wsrc": {}}
    spec = sched.im2col
    r0, r1 = _needed_in_rows(cg, sched, rep,
                             spec.h if spec is not None else 0)
    in_nb = max(r1 - r0, 0) * _in_row_bytes(sched)
    out["in_row0"] = r0
    w_nb = sched.w_rows * sched.w_row_bytes \
        if sched.weight_source == "dynamic" else 0
    for c in rep.cores:
        out["in"][c] = lmems[c].alloc(0, in_nb, f"{tag} input")
        if w_nb:
            out["wsrc"][c] = lmems[c].alloc(0, w_nb, f"{tag} wsrc")
        out["stage"][c] = lmems[c].alloc(
            1, sched.m_chunk * sched.k_total if spec is not None else 0,
            f"{tag} im2col")
        # weight staging: sized to the largest tile actually loaded on
        # this core (a full MG upper-bounds it, but time-shared stages
        # pack many groups per core and the bound wastes segments)
        wstage_nb = max((a.k_len * a.n_len for a in rep.assigns
                         if a.core == c), default=0)
        out["wstage"][c] = lmems[c].alloc(1, wstage_nb, f"{tag} wstage")
        out["psum"][c] = lmems[c].alloc(
            2, sched.m_chunk * sched.n_total * 4, f"{tag} psum")
        out["qtmp"][c] = lmems[c].alloc(
            2, sched.m_chunk * sched.n_total, f"{tag} qtmp")
        if "bias" in sched.vector_ops:
            out["bias"][c] = lmems[c].alloc(2, sched.n_total * 4,
                                            f"{tag} bias")
    asm = rep.cores[0]
    y0, y1 = _conv_rows_to_compute(cg, sched, rep)
    if spec is not None:
        conv_nb = max(y1 - y0, 0) * spec.wo * sched.n_total
    else:
        conv_nb = max(rep.m_hi - rep.m_lo, 0) * sched.n_total
    out["conv"] = lmems[asm].alloc(3, conv_nb, f"{tag} conv-out")
    out["conv_row0"] = y0
    _, row_nb, _ = _out_geometry(cg, sched)
    o0, o1 = _owned_out_rows(cg, sched, rep)
    if sched.pool is not None or sched.gap:
        out["final"] = lmems[asm].alloc(3, max(o1 - o0, 1) * row_nb,
                                        f"{tag} final")
        out["final_row0"] = o0
    else:
        out["final"] = out["conv"]
        out["final_row0"] = y0 if spec is not None else rep.m_lo
    if sched.gap:
        out["gapacc"] = lmems[asm].alloc(2, sched.n_total * 4,
                                         f"{tag} gapacc")
        out["gaptmp"] = lmems[asm].alloc(2, sched.n_total * 4,
                                         f"{tag} gaptmp")
        if sched.pool is not None:
            p0, p1 = _pooled_rows(cg, sched, rep)
            out["pooled"] = lmems[asm].alloc(
                3, max(p1 - p0, 1) * sched.pool.wo * sched.n_total,
                f"{tag} pooled")
    _, side = _main_and_skip_preds(cg, g, op_owner)
    if side or _side_input_ops(cg, g):
        k0, k1, krow_nb = _side_rows(cg, sched, rep)
        out["skip"] = lmems[asm].alloc(
            0, max(max(k1 - k0, 1) * krow_nb, (o1 - o0) * row_nb),
            f"{tag} skip")
    return out


def _round_mask(rep: ReplicaPlan, core: int, rnd: int) -> int:
    mask = 0
    for a in rep.assigns:
        if a.core == core and a.round == rnd:
            mask |= 1 << a.slot
    return mask


def _emit_weight_gather(ctx: _Ctx, sched: OpSchedule, b, e: _Emitter,
                        a: MgAssign) -> None:
    """Stage one dynamic tile: strided V_MOV gather of the weight
    producer's activations (resident in ``wsrc``) into the dense
    ``(k_len, n_len)`` CIM write layout of ``wstage`` — the in-memory
    mirror of :func:`repro.core.vecsem.dynamic_weight_matrix`."""
    g = ctx.cg[sched.gid]
    wsrc = b["wsrc"][a.core]
    wstage = b["wstage"][a.core]
    C = sched.w_row_bytes
    gk, gn = g.gemm_k, g.gemm_n
    if a.ch_cnt > 1:
        # block-diagonal tile: off-diagonal bytes must read as zero
        e.vec("zero", wstage, 0, 0, vlen=a.k_len * a.n_len,
              flags=FLAGS["i8"])
        blocks = [(a.ch_off + ci, 0, gk, 0, gn, ci * gk, ci * gn)
                  for ci in range(a.ch_cnt)]
    else:
        ch = a.ch_off
        blocks = [(ch, a.k_off - ch * gk, a.k_len,
                   a.n_off - ch * gn, a.n_len, 0, 0)]
    for ch, k0, klen, n0, nlen, dr, dc in blocks:
        dst = wstage + dr * a.n_len + dc
        if sched.w_transpose:
            # W[k, n] = wsrc[(n0 + n)·C + ch·gk + k0 + k]  (Q·Kᵀ)
            e.vec("mov", dst, wsrc + n0 * C + ch * gk + k0, 0,
                  vlen=nlen, rep=klen, seg_d=a.n_len, seg_a=1,
                  stride_a=C, flags=FLAGS["i8"])
        else:
            # W[k, n] = wsrc[(k0 + k)·C + ch·gn + n0 + n]  (P·V)
            e.vec("mov", dst, wsrc + k0 * C + ch * gn + n0, 0,
                  vlen=nlen, rep=klen, seg_d=a.n_len, seg_a=C,
                  flags=FLAGS["i8"])


def _emit_weight_load(ctx: _Ctx, sched: OpSchedule, rep: ReplicaPlan,
                      rnd: int) -> None:
    b = ctx.bufs[(sched.gid, rep.replica)]
    dynamic = sched.weight_source == "dynamic"
    for a in rep.assigns:
        if a.round != rnd:
            continue
        e = ctx.em(a.core)
        if dynamic:
            _emit_weight_gather(ctx, sched, b, e, a)
        else:
            addr, nb = ctx.layout.weights[(sched.gid, a.k_off, a.n_off,
                                           a.ch_off)]
            e.gld(b["wstage"][a.core], addr, nb)
        e.sreg("MG_SEL", a.slot)
        e.sreg("MG_KOFF", a.k_off)
        e.sreg("MG_NOFF", a.n_off)
        e.greg(1, b["wstage"][a.core])
        e.sreg("MG_NLEN", a.n_len)
        e.raw("CIM_LOAD", mg=a.slot, src=1, rows=a.k_len)
    # static bias rides round 0 of the (re)load; dynamic groups load it
    # once in the stage prologue instead (their weights re-load every
    # sample, the bias blob does not change)
    if rnd == 0 and not dynamic and "bias" in sched.vector_ops \
            and sched.gid in ctx.layout.biases:
        addr, nb = ctx.layout.biases[sched.gid]
        for c in rep.cores:
            ctx.em(c).gld(b["bias"][c], addr, nb)


def _emit_weight_load_incr(ctx: _Ctx, sched: OpSchedule,
                           rep: ReplicaPlan) -> None:
    """Append-row weight re-stage (``kv_append`` groups, samples > 0).

    The appended producer row is resident at the tail of ``wsrc`` (the
    incremental GLD in the sample loop); only the tiles it touches are
    re-staged — the shapes come from :func:`~repro.core.oplevel.
    incremental_ops`, the single shared definition trace prices:

    * non-transpose (``P·V``): the new V row is one new weight *row*
      per head — one ``n_len``-wide gather V_MOV and a single-row
      ``CIM_LOAD`` at the row's array offset;
    * transpose (``Q·Kᵀ``): the new K row is one new weight *column*
      per head — a strided column gather, then a row-granular re-write
      of the touched tile (``k_len`` = head-dim rows).

    Timing-faithful emission (what trace and the perf simulator price);
    functionally-exact decode would need per-assign ``wstage``
    persistence, which the shared staging buffer does not provide —
    decode runs on the perf/trace rungs of the ladder.
    """
    g = ctx.cg[sched.gid]
    b = ctx.bufs[(sched.gid, rep.replica)]
    row = sched.w_rows - 1
    C = sched.w_row_bytes
    gk, gn = g.gemm_k, g.gemm_n

    def load(e: _Emitter, a: MgAssign, src: int, k_off: int,
             rows: int) -> None:
        e.sreg("MG_SEL", a.slot)
        e.sreg("MG_KOFF", k_off)
        e.sreg("MG_NOFF", a.n_off)
        e.greg(1, src)
        e.sreg("MG_NLEN", a.n_len)
        e.raw("CIM_LOAD", mg=a.slot, src=1, rows=rows)

    for a in rep.assigns:
        if incremental_ops(g, sched, a) is None:
            continue
        e = ctx.em(a.core)
        wsrc = b["wsrc"][a.core]
        wstage = b["wstage"][a.core]
        if a.ch_cnt > 1:
            if sched.w_transpose:
                for ci in range(a.ch_cnt):
                    ch = a.ch_off + ci
                    # new column `row` of head ch's diagonal block
                    e.vec("mov",
                          wstage + ci * gk * a.n_len + ci * gn + row,
                          wsrc + row * C + ch * gk, 0, vlen=1, rep=gk,
                          seg_d=a.n_len, seg_a=1, flags=FLAGS["i8"])
                load(e, a, wstage, a.k_off, a.k_len)
            else:
                for ci in range(a.ch_cnt):
                    ch = a.ch_off + ci
                    lrow = ci * gk + row    # block-local weight row
                    e.vec("mov", wstage + lrow * a.n_len + ci * gn,
                          wsrc + row * C + ch * gn, 0, vlen=gn,
                          flags=FLAGS["i8"])
                    load(e, a, wstage + lrow * a.n_len,
                         a.k_off + lrow, 1)
            continue
        ch = a.ch_off
        if sched.w_transpose:
            col = row - (a.n_off - ch * gn)
            e.vec("mov", wstage + col,
                  wsrc + row * C + ch * gk + (a.k_off - ch * gk), 0,
                  vlen=1, rep=a.k_len, seg_d=a.n_len, seg_a=1,
                  flags=FLAGS["i8"])
            load(e, a, wstage, a.k_off, a.k_len)
        else:
            lrow = row - (a.k_off - ch * gk)
            e.vec("mov", wstage + lrow * a.n_len,
                  wsrc + row * C + ch * gn + (a.n_off - ch * gn), 0,
                  vlen=a.n_len, flags=FLAGS["i8"])
            load(e, a, wstage + lrow * a.n_len, a.k_off + lrow, 1)


# ---------------------------------------------------------------------------
# Per-sample emission
# ---------------------------------------------------------------------------


def _emit_sample(ctx: _Ctx, sched: OpSchedule, rep: ReplicaPlan,
                 s: int) -> None:
    cg = ctx.cg
    g = cg[sched.gid]
    b = ctx.bufs[(sched.gid, rep.replica)]
    spec = sched.im2col
    q = ctx.qp[g.idx]
    main, side = _main_and_skip_preds(cg, g, ctx.op_owner)

    # ---- 1. acquire main input ----------------------------------------------
    # routing works in BYTE ranges of the producer's output buffer so that
    # differing row units (feature rows vs flattened gemm rows) compose
    in_rows_total = spec.h if spec is not None else 0
    r0, r1 = _needed_in_rows(cg, sched, rep, in_rows_total)
    row_nb = _in_row_bytes(sched)
    need_lo, need_hi = r0 * row_nb, r1 * row_nb
    if main is None or main not in ctx.member:
        base, _ = (ctx.layout.inputs[s] if main is None
                   else ctx.layout.acts[(main, s)])
        if main is None:
            # multi-input graphs: offset to this group's input operand
            # within the per-sample region (0 for single-input graphs)
            base += ctx.layout.input_offsets.get(
                _main_input_op(cg, g) or -1, 0)
        for c in rep.cores:
            ctx.em(c).gld(b["in"][c], base + need_lo, need_hi - need_lo)
    else:
        prod = ctx.by_gid[main]
        _, prnb, _ = _out_geometry(cg, prod)
        for prep in prod.replicas:
            p0, p1 = _owned_out_rows(cg, prod, prep)
            lo, hi = max(need_lo, p0 * prnb), min(need_hi, p1 * prnb)
            if hi <= lo:
                continue
            for c in rep.cores:
                ctx.em(c).recv(b["in"][c] + lo - need_lo,
                               prep.cores[0], hi - lo,
                               tag=f"in:{g.name}@s{s}",
                               stream=_stream_id(main, g.idx, 0))

    # ---- 1b. acquire skip/scale operands --------------------------------------
    o0, o1 = _owned_out_rows(cg, sched, rep)
    _, out_row_nb, _ = _out_geometry(cg, sched)
    k0, k1, krow_nb = _side_rows(cg, sched, rep)
    bcast_side = False
    for sgid in side:
        if k1 <= k0:
            break
        prod_sched = ctx.by_gid.get(sgid)
        prod_rows, prod_row_nb = None, None
        if prod_sched is not None:
            prod_rows, prod_row_nb, _ = _out_geometry(cg, prod_sched)
        bcast = prod_rows == 1 and ((k1 - k0) * krow_nb > krow_nb
                                    or krow_nb != prod_row_nb)
        if sgid in ctx.member:
            prod = ctx.by_gid[sgid]
            for prep in prod.replicas:
                p0, p1 = _owned_out_rows(cg, prod, prep)
                if bcast:
                    lo, hi = (0, 1) if (p0, p1) == (0, 1) else (0, 0)
                else:
                    lo, hi = max(k0, p0), min(k1, p1)
                if hi <= lo:
                    continue
                nb = prod_row_nb if bcast else krow_nb
                off = 0 if bcast else (lo - k0) * krow_nb
                ctx.em(rep.cores[0]).recv(
                    b["skip"] + off, prep.cores[0], (hi - lo) * nb,
                    tag=f"skip:{g.name}@s{s}",
                    stream=_stream_id(sgid, g.idx, 2 if bcast else 1))
        else:
            base, nbt = ctx.layout.acts[(sgid, s)]
            if bcast:
                ctx.em(rep.cores[0]).gld(b["skip"], base, nbt)
            else:
                ctx.em(rep.cores[0]).gld(b["skip"], base + k0 * krow_nb,
                                         (k1 - k0) * krow_nb)
        bcast_side = bcast_side or bcast
    side_inputs = _side_input_ops(cg, g)
    if k1 > k0:
        # residual operand arriving as a graph input (a system-level
        # pipeline cut upstream): load it from the gmem input region
        for sop in side_inputs:
            base, _ = ctx.layout.inputs[s]
            base += ctx.layout.input_offsets.get(sop, 0)
            ctx.em(rep.cores[0]).gld(b["skip"], base + k0 * krow_nb,
                                     (k1 - k0) * krow_nb)

    # ---- 1c. acquire dynamic weights (a predecessor's activations) ----------
    dynamic = sched.weight_source == "dynamic"
    incr = False
    if dynamic:
        if spec is not None:
            raise CodegenError(f"{g.name}: dynamic weights on a conv "
                               f"anchor are not supported")
        wgid = sched.weight_pred
        w_nb = sched.w_rows * sched.w_row_bytes
        # append-only cache (kv_append): samples > 0 fetch only the
        # appended row into the resident wsrc and re-stage just the
        # tiles it touches.  Needs a gmem-resident source (an in-stage
        # producer re-SENDs its whole output every sample) and a
        # single-round schedule (slot cycling leaves nothing resident).
        incr = (sched.w_incremental and sched.n_rounds == 1 and s > 0
                and (wgid is None or wgid not in ctx.member))
        if wgid is None or wgid not in ctx.member:
            base, _ = (ctx.layout.inputs[s] if wgid is None
                       else ctx.layout.acts[(wgid, s)])
            if wgid is None and sched.w_input is not None:
                base += ctx.layout.input_offsets.get(sched.w_input, 0)
            if incr:
                off = (sched.w_rows - 1) * sched.w_row_bytes
                for c in rep.cores:
                    ctx.em(c).gld(b["wsrc"][c] + off, base + off,
                                  sched.w_row_bytes)
            else:
                for c in rep.cores:
                    ctx.em(c).gld(b["wsrc"][c], base, w_nb)
        else:
            prod = ctx.by_gid[wgid]
            _, prnb, ptot = _out_geometry(cg, prod)
            if prnb != sched.w_row_bytes or ptot != w_nb:
                raise CodegenError(
                    f"{g.name}: weight producer '{prod.name}' output "
                    f"layout ({ptot}B rows of {prnb}) does not match "
                    f"the weight operand ({w_nb}B rows of "
                    f"{sched.w_row_bytes})")
            for prep in prod.replicas:
                p0, p1 = _owned_out_rows(cg, prod, prep)
                if p1 <= p0:
                    continue
                for c in rep.cores:
                    ctx.em(c).recv(b["wsrc"][c] + p0 * prnb,
                                   prep.cores[0], (p1 - p0) * prnb,
                                   tag=f"wgt:{g.name}@s{s}",
                                   stream=_stream_id(wgid, g.idx, 5))

    # ---- 2. compute ------------------------------------------------------------
    y0, y1 = _conv_rows_to_compute(cg, sched, rep)
    if dynamic and sched.n_rounds > 1:
        # weights change per sample AND exceed the free slots: re-gather
        # and re-load per (chunk, round) from the resident wsrc — pure
        # local-memory traffic, so the single-m-chunk restriction of the
        # gmem-streamed path does not apply
        _emit_linear_chunks_dynamic(ctx, sched, rep, b, q)
    else:
        for rnd in range(sched.n_rounds):
            # multi-round groups stream weights every sample (slots were
            # left holding the previous sample's last round); dynamic
            # groups re-write their arrays every sample — append-only
            # caches re-stage just the appended row's tiles
            if incr:
                if rnd == 0:
                    _emit_weight_load_incr(ctx, sched, rep)
            elif rnd > 0 or (sched.n_rounds > 1 and s > 0) or dynamic:
                _emit_weight_load(ctx, sched, rep, rnd)
            if spec is not None:
                for y in range(y0, y1):
                    for x0 in range(0, spec.wo, sched.m_chunk):
                        x1 = min(spec.wo, x0 + sched.m_chunk)
                        _emit_conv_chunk(ctx, sched, rep, b, spec, y, x0,
                                         x1, rnd, q, y0)
            else:
                _emit_linear_chunks(ctx, sched, rep, b, rnd, q)

    # ---- 3. assembly (multi-core replicas) ------------------------------------
    if len(rep.cores) > 1:
        _emit_assembly(ctx, sched, rep, b, spec, y0, y1)

    e = ctx.em(rep.cores[0])

    # ---- 4. fused tail (graph order) ------------------------------------------
    has_side_op = "add" in sched.vector_ops or "mul" in sched.vector_ops
    self_skip = has_side_op and not side and not side_inputs
    side_pre = _side_pre_reduce(sched)

    def apply_side(buf_addr: int, lo: int, hi: int, row_nb: int) -> None:
        """Saturating residual add / SE scale (+ trailing relu) on int8."""
        if hi <= lo:
            return
        if self_skip:
            # the residual operand IS the main input: rows already local
            if spec is None or spec.stride != 1 or \
                    _in_row_bytes(sched) != row_nb:
                raise CodegenError(f"{sched.name}: self-residual needs a "
                                   f"stride-1 shape-preserving anchor")
            src = b["in"][rep.cores[0]] + (lo - b["in_row0"]) * row_nb
        else:
            src = b["skip"]
        if "mul" in sched.vector_ops and bcast_side:
            e.vec("mul", buf_addr, buf_addr, src, vlen=sched.n_total,
                  rep=(hi - lo) * row_nb // sched.n_total,
                  seg_d=sched.n_total, seg_a=sched.n_total, seg_b=0,
                  flags=FLAGS["i8"])
        else:
            e.vec("add", buf_addr, buf_addr, src,
                  vlen=(hi - lo) * row_nb, flags=FLAGS["i8"])
        if _relu_after_side(sched):
            e.vec("relu", buf_addr, buf_addr, 0,
                  vlen=(hi - lo) * row_nb, flags=FLAGS["i8"])

    if has_side_op and side_pre:
        apply_side(b["conv"], k0, k1, krow_nb)
    if sched.gap:
        if sched.pool is not None:
            p0, p1 = _pooled_rows(cg, sched, rep)
            _emit_pool(sched, rep, b, e, spec, y0, y1, p0, p1,
                       dst_buf=b["pooled"])
        _emit_gap(ctx, sched, rep, b, spec, y0, y1, q)
        if rep.replica != 0:
            return
        o0, o1 = 0, 1
    elif sched.pool is not None:
        _emit_pool(sched, rep, b, e, spec, y0, y1, o0, o1,
                   dst_buf=b["final"])
    if has_side_op and not side_pre:
        apply_side(b["final"], o0, o1, out_row_nb)

    # ---- 4b. fused special tails (softmax / layernorm / gelu) -----------------
    # applied on the assembled int8 rows, in graph order after the
    # residual (validated by _validate_special_tail); row-segment
    # integer semantics shared with the oracle via repro.core.vecsem
    for vop in sched.vector_ops:
        if vop not in SPECIAL_TAIL_OPS or o1 <= o0:
            continue
        total = (o1 - o0) * out_row_nb
        if vop == "gelu":
            e.vec("gelu", b["final"], b["final"], 0, vlen=total,
                  flags=FLAGS["i8"])
            continue
        # softmax normalizes per head-row segment; layernorm per row
        seg = g.gemm_n if vop == "softmax" else sched.n_total
        e.vec(vop, b["final"], b["final"], 0, vlen=seg,
              rep=total // seg, seg_d=seg, seg_a=seg, flags=FLAGS["i8"])

    # ---- 5. deliver -------------------------------------------------------------
    consumers = [h for h in cg if g.idx in h.preds]
    boundary_out = (not consumers) or any(h.idx not in ctx.member
                                          for h in consumers) \
        or g.idx in ctx.force_boundary
    my_rows, my_row_nb, _ = _out_geometry(cg, sched)
    for h in consumers:
        if h.idx not in ctx.member:
            continue
        cons = ctx.by_gid[h.idx]
        hmain, _ = _main_and_skip_preds(cg, h, ctx.op_owner)
        if cons.weight_source == "dynamic" and cons.weight_pred == g.idx:
            # this output IS the consumer's weight operand: every core
            # of every consumer replica gathers tiles from it
            if o1 > o0:
                for crep in cons.replicas:
                    for tc in crep.cores:
                        e.send(tc, b["final"], (o1 - o0) * my_row_nb,
                               tag=f"wgt:{g.name}->{h.name}@s{s}",
                               stream=_stream_id(g.idx, h.idx, 5))
            if hmain != g.idx:
                continue
        for crep in cons.replicas:
            if hmain == g.idx:
                # byte-range intersection (mirrors consumer acquisition)
                c0, c1 = _needed_in_rows(cg, cons, crep,
                                         cons.im2col.h
                                         if cons.im2col is not None else 0)
                crnb = _in_row_bytes(cons)
                lo_b = max(o0 * my_row_nb, c0 * crnb)
                hi_b = min(o1 * my_row_nb, c1 * crnb)
                if hi_b <= lo_b:
                    continue
                for tc in crep.cores:
                    e.send(tc, b["final"] + lo_b - o0 * my_row_nb,
                           hi_b - lo_b,
                           tag=f"out:{g.name}->{h.name}@s{s}",
                           stream=_stream_id(g.idx, h.idx, 0))
                continue
            c0, c1, crow_nb = _side_rows(cg, cons, crep)
            if my_rows == 1 and (c1 - c0 != 1 or crow_nb != my_row_nb):
                # broadcast (SE-style) operand: one row to replica 0
                if c1 > c0 and o0 == 0 and o1 >= 1:
                    e.send(crep.cores[0], b["final"], my_row_nb,
                           tag=f"bcast:{g.name}->{h.name}@s{s}",
                           stream=_stream_id(g.idx, h.idx, 2))
                continue
            lo, hi = max(o0, c0), min(o1, c1)
            if hi <= lo:
                continue
            e.send(crep.cores[0], b["final"] + (lo - o0) * out_row_nb,
                   (hi - lo) * out_row_nb,
                   tag=f"side:{g.name}->{h.name}@s{s}",
                   stream=_stream_id(g.idx, h.idx, 1))
    if boundary_out and o1 > o0:
        base, _ = ctx.layout.acts[(g.idx, s)]
        e.gst(b["final"], base + o0 * out_row_nb, (o1 - o0) * out_row_nb)


# -- chunk emission -----------------------------------------------------------


def _emit_conv_chunk(ctx: _Ctx, sched: OpSchedule, rep: ReplicaPlan, b,
                     spec: Im2colSpec, y: int, x0: int, x1: int, rnd: int,
                     q: QuantParams, conv_y0: int) -> None:
    npos = x1 - x0
    K = sched.k_total
    r0 = b["in_row0"]
    s = spec.stride
    for c in rep.cores:
        e = ctx.em(c)
        stage = b["stage"][c]
        inb = b["in"][c]
        # interior position range whose full kw window is in [0, W)
        xlo = max(x0, math.ceil(spec.pad / s)) if spec.pad else x0
        xhi = min(x1, (spec.w - spec.kw + spec.pad) // s + 1)
        top_bot = (y * s - spec.pad < 0
                   or y * s - spec.pad + spec.kh > spec.h)
        if spec.pad > 0 and (top_bot or xlo > x0 or xhi < x1):
            e.vec("zero", stage, 0, 0, vlen=K, rep=npos, seg_d=K,
                  flags=FLAGS["i8"])
        for ky in range(spec.kh):
            iy = y * s - spec.pad + ky
            if iy < 0 or iy >= spec.h:
                continue
            irow = inb + (iy - r0) * spec.w * spec.cin
            if not spec.depthwise:
                # bulk: positions [xlo, xhi) copy their full (kw*cin) row
                if xhi > xlo:
                    e.vec("mov",
                          stage + (xlo - x0) * K + ky * spec.kw * spec.cin,
                          irow + (xlo * s - spec.pad) * spec.cin, 0,
                          vlen=spec.kw * spec.cin, rep=xhi - xlo,
                          seg_d=K, seg_a=s * spec.cin, flags=FLAGS["i8"])
                # clipped edges, one position at a time
                for x in list(range(x0, min(xlo, x1))) + \
                        list(range(max(xhi, x0), x1)):
                    sx = x * s - spec.pad
                    c0 = max(0, -sx)                  # first valid tap
                    c1 = min(spec.kw, spec.w - sx)    # end of valid taps
                    if c1 <= c0:
                        continue
                    e.vec("mov",
                          stage + (x - x0) * K
                          + (ky * spec.kw + c0) * spec.cin,
                          irow + (sx + c0) * spec.cin, 0,
                          vlen=(c1 - c0) * spec.cin, rep=1,
                          flags=FLAGS["i8"])
            else:
                # depth-wise: per (ky,kx) channel-contiguous taps into the
                # (g, ky, kx) patch layout
                for kx in range(spec.kw):
                    sx0 = -spec.pad + kx
                    lo = max(x0, math.ceil(-sx0 / s))
                    hi = min(x1 - 1, (spec.w - 1 - sx0) // s)
                    if hi < lo:
                        continue
                    e.vec("mov",
                          stage + (lo - x0) * K + ky * spec.kw + kx,
                          irow + (lo * s + sx0) * spec.cin, 0,
                          vlen=spec.cin, rep=hi - lo + 1,
                          seg_d=K, seg_a=s * spec.cin,
                          stride_d=spec.kh * spec.kw, stride_a=1,
                          flags=FLAGS["i8"])
        mask = _round_mask(rep, c, rnd)
        e.mvm(b["psum"][c], stage, rep=npos, acc=(rnd > 0), mask=mask,
              seg_in=K, seg_out=sched.n_total * 4)
    _emit_postops_chunk(ctx, sched, rep, b, q, npos=npos,
                        out_off=((y - conv_y0) * spec.wo + x0)
                        * sched.n_total,
                        last_round=(rnd == sched.n_rounds - 1))


def _emit_linear_mvm(ctx: _Ctx, sched: OpSchedule, rep: ReplicaPlan, b,
                     c0: int, npos: int, rnd: int) -> None:
    """One m-chunk's MVM burst on every core of the replica (shared by
    the round-outer static path and the chunk-outer dynamic path)."""
    K = sched.k_total
    for c in rep.cores:
        e = ctx.em(c)
        e.mvm(b["psum"][c], b["in"][c] + (c0 - rep.m_lo) * K, rep=npos,
              acc=(rnd > 0), mask=_round_mask(rep, c, rnd), seg_in=K,
              seg_out=sched.n_total * 4)


def _emit_linear_chunks(ctx: _Ctx, sched: OpSchedule, rep: ReplicaPlan,
                        b, rnd: int, q: QuantParams) -> None:
    m0, m1 = rep.m_lo, rep.m_hi
    for c0 in range(m0, m1, sched.m_chunk):
        c1 = min(m1, c0 + sched.m_chunk)
        npos = c1 - c0
        _emit_linear_mvm(ctx, sched, rep, b, c0, npos, rnd)
        _emit_postops_chunk(ctx, sched, rep, b, q, npos=npos,
                            out_off=(c0 - m0) * sched.n_total,
                            last_round=(rnd == sched.n_rounds - 1))


def _emit_linear_chunks_dynamic(ctx: _Ctx, sched: OpSchedule,
                                rep: ReplicaPlan, b,
                                q: QuantParams) -> None:
    """Dynamic multi-round emission: chunk-outer / round-inner.

    Each m-chunk's INT32 partial sums accumulate across rounds before
    post-ops run once, with the round's weights re-gathered from the
    resident ``wsrc`` buffer — this is what lifts the static path's
    "multi-round requires a single m-chunk" restriction for dynamic
    weights (re-loading costs local-memory traffic only)."""
    m0, m1 = rep.m_lo, rep.m_hi
    for c0 in range(m0, m1, sched.m_chunk):
        c1 = min(m1, c0 + sched.m_chunk)
        npos = c1 - c0
        for rnd in range(sched.n_rounds):
            _emit_weight_load(ctx, sched, rep, rnd)
            _emit_linear_mvm(ctx, sched, rep, b, c0, npos, rnd)
        _emit_postops_chunk(ctx, sched, rep, b, q, npos=npos,
                            out_off=(c0 - m0) * sched.n_total,
                            last_round=True)


def _core_columns(rep: ReplicaPlan, core: int) -> List[MgAssign]:
    seen: Dict[int, MgAssign] = {}
    for a in rep.assigns:
        if a.core == core and a.n_off not in seen:
            seen[a.n_off] = a
    return list(seen.values())


def _emit_postops_chunk(ctx: _Ctx, sched: OpSchedule, rep: ReplicaPlan, b,
                        q: QuantParams, npos: int, out_off: int,
                        last_round: bool) -> None:
    """bias -> relu -> requant -> place int8 rows."""
    if not last_round:
        return
    N = sched.n_total
    multi = len(rep.cores) > 1
    # relu applies on INT32 pre-quant iff it is the first fused op after
    # bias (graph order); a relu that follows add/mul runs post-add on int8
    first = next((v for v in sched.vector_ops if v != "bias"), None)
    relu_here = first == "relu"
    for c in rep.cores:
        e = ctx.em(c)
        cols = _core_columns(rep, c)
        if "bias" in sched.vector_ops:
            for a in cols:
                e.vec("add", b["psum"][c] + a.n_off * 4,
                      b["psum"][c] + a.n_off * 4,
                      b["bias"][c] + a.n_off * 4,
                      vlen=a.n_len, rep=npos, seg_d=N * 4, seg_a=N * 4,
                      seg_b=0)
        e.sreg("Q_SCALE", q.scale)
        e.sreg("Q_SHIFT", q.shift)
        e.sreg("ACC_DIV", 1)
        if not multi:
            if relu_here:
                e.vec("relu", b["psum"][c], b["psum"][c], 0,
                      vlen=npos * N)
            e.vec("quant", b["conv"] + out_off, b["psum"][c], 0,
                  vlen=npos * N)
        else:
            for a in cols:
                if relu_here:
                    e.vec("relu", b["psum"][c] + a.n_off * 4,
                          b["psum"][c] + a.n_off * 4, 0,
                          vlen=a.n_len, rep=npos, seg_d=N * 4,
                          seg_a=N * 4)
                e.vec("quant", b["qtmp"][c], b["psum"][c] + a.n_off * 4,
                      0, vlen=a.n_len, rep=npos, seg_d=a.n_len,
                      seg_a=N * 4)
                if c != rep.cores[0]:
                    e.send(rep.cores[0], b["qtmp"][c], npos * a.n_len,
                           tag=f"nslice:{sched.name}",
                           stream=_stream_id(sched.gid, sched.gid, 3))
                else:
                    e.vec("mov", b["conv"] + out_off + a.n_off,
                          b["qtmp"][c], 0, vlen=a.n_len, rep=npos,
                          seg_d=N, seg_a=a.n_len, flags=FLAGS["i8"])


def _emit_assembly(ctx: _Ctx, sched: OpSchedule, rep: ReplicaPlan, b,
                   spec, y0: int, y1: int) -> None:
    """Assembly core interleaves sibling cores' quantized n-slices."""
    e = ctx.em(rep.cores[0])
    N = sched.n_total
    if spec is not None:
        chunks = [((y - y0) * spec.wo + x0, min(spec.wo - x0, sched.m_chunk))
                  for y in range(y0, y1)
                  for x0 in range(0, spec.wo, sched.m_chunk)]
    else:
        span = rep.m_hi - rep.m_lo
        chunks = [(c0, min(span - c0, sched.m_chunk))
                  for c0 in range(0, span, sched.m_chunk)]
    for (off, npos) in chunks:
        for c in rep.cores[1:]:
            for a in _core_columns(rep, c):
                e.recv(b["qtmp"][rep.cores[0]], c, npos * a.n_len,
                       tag=f"asm:{sched.name}",
                       stream=_stream_id(sched.gid, sched.gid, 3))
                e.vec("mov", b["conv"] + off * N + a.n_off,
                      b["qtmp"][rep.cores[0]], 0, vlen=a.n_len, rep=npos,
                      seg_d=N, seg_a=a.n_len, flags=FLAGS["i8"])


def _emit_pool(sched: OpSchedule, rep: ReplicaPlan, b, e, spec,
               y0: int, y1: int, o0: int, o1: int,
               dst_buf: int = 0) -> None:
    """Fused max pooling over this replica's conv rows (HWC, post-relu:
    zero-init equals -inf since inputs are non-negative)."""
    p = sched.pool
    if p.kind != "maxpool":
        raise CodegenError(f"{sched.name}: fused {p.kind} not supported")
    N = sched.n_total
    W = spec.wo
    for po in range(o0, o1):
        dst = dst_buf + (po - o0) * p.wo * N
        e.vec("zero", dst, 0, 0, vlen=p.wo * N, flags=FLAGS["i8"])
        for jy in range(p.k):
            iy = po * p.stride - p.pad + jy
            if iy < y0 or iy >= y1:
                continue
            for jx in range(p.k):
                sx0 = -p.pad + jx
                lo = max(0, math.ceil(-sx0 / p.stride))
                hi = min(p.wo - 1, (W - 1 - sx0) // p.stride)
                if hi < lo:
                    continue
                e.vec("max", dst + lo * N, dst + lo * N,
                      b["conv"] + (iy - y0) * W * N
                      + (lo * p.stride + sx0) * N,
                      vlen=N, rep=hi - lo + 1, seg_d=N, seg_a=N,
                      seg_b=p.stride * N, flags=FLAGS["i8"])


def _emit_gap(ctx: _Ctx, sched: OpSchedule, rep: ReplicaPlan, b, spec,
              y0: int, y1: int, q: QuantParams) -> None:
    """Global average pool: per-replica partials, reduce on replica 0."""
    e = ctx.em(rep.cores[0])
    N = sched.n_total
    if sched.pool is not None:
        p0, p1 = _pooled_rows(ctx.cg, sched, rep)
        src, npos = b["pooled"], (p1 - p0) * sched.pool.wo
    elif spec is not None:
        src, npos = b["conv"], (y1 - y0) * spec.wo
    else:
        src, npos = b["conv"], rep.m_hi - rep.m_lo
    acc = b["gapacc"]
    e.vec("zero", acc, 0, 0, vlen=N)
    if npos > 0:
        e.vec("sum8", acc, src, 0, vlen=N, rep=npos, seg_d=0,
              seg_a=N)
    rep0 = sched.replicas[0]
    if rep.replica != 0:
        e.send(rep0.cores[0], acc, N * 4, tag=f"gap:{sched.name}",
               stream=_stream_id(sched.gid, sched.gid, 4))
        return
    e0 = ctx.em(rep0.cores[0])
    for other in sched.replicas[1:]:
        e0.recv(b["gaptmp"], other.cores[0], N * 4,
                tag=f"gap:{sched.name}",
                stream=_stream_id(sched.gid, sched.gid, 4))
        e0.vec("add", acc, acc, b["gaptmp"], vlen=N)
    if sched.pool is not None:
        m = sched.pool.ho * sched.pool.wo
    else:
        m = max(sched.m_total, 1)
    e0.sreg("Q_SCALE", q.scale)
    e0.sreg("Q_SHIFT", q.shift)
    e0.sreg("ACC_DIV", m)              # mean folded into the requant
    e0.vec("quant", b["final"], acc, 0, vlen=N)

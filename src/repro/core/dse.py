"""Design-space exploration driver (paper §IV-C).

Sweeps architectural parameters (MG size, NoC flit width, local-memory
size, core count) x compilation strategies, evaluating each point with
the analytic cost model (fast) or the cycle-accurate simulator (ground
truth).  Powers the Fig. 6 / Fig. 7 benchmarks and the ``dse_sweep``
example.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional

from .arch import ChipConfig, default_chip
from .codegen import compile_model
from .energy import DEFAULT_TABLE, energy_breakdown
from .graph import CondensedGraph
from .mapping import CostParams
from .partition import partition
from .simulator import Simulator

__all__ = ["DsePoint", "evaluate", "sweep_mg_flit", "SWEEP_MG",
           "SWEEP_FLIT"]

SWEEP_MG = (4, 8, 16)          # macros per MG (Fig. 6 x-axis)
SWEEP_FLIT = (8, 16)           # NoC flit bytes (light/dark shading)


@dataclass
class DsePoint:
    model: str
    strategy: str
    macros_per_group: int
    flit_bytes: int
    cycles: float
    throughput_sps: float       # samples/s at 1 GHz
    energy: Dict[str, float]    # nJ breakdown
    simulated: bool

    def row(self) -> Dict:
        return {
            "model": self.model, "strategy": self.strategy,
            "mg": self.macros_per_group, "flit": self.flit_bytes,
            "cycles": self.cycles, "throughput_sps": self.throughput_sps,
            "energy_total_mJ": self.energy["total"] / 1e6,
            **{f"energy_{k}_frac":
               (self.energy[k] / self.energy["total"]
                if self.energy["total"] else 0.0)
               for k in ("compute", "weight_load", "noc", "gmem",
                         "lmem", "static")},
            "simulated": self.simulated,
        }


def evaluate(cg: CondensedGraph, chip: ChipConfig, strategy: str,
             params: Optional[CostParams] = None,
             simulate: bool = False) -> DsePoint:
    params = params or CostParams(batch=4)
    res = partition(cg, chip, strategy, params)
    if simulate:
        model = compile_model(res, batch=params.batch)
        rep = Simulator(chip, model.isa, mode="perf").run_model(model)
        cycles = rep.cycles
        energy = rep.energy()
    else:
        cycles = res.latency_cycles()
        energy = energy_breakdown(res.energy_events())
    sps = params.batch / (cycles / (chip.clock_ghz * 1e9))
    return DsePoint(model=cg.name, strategy=strategy,
                    macros_per_group=chip.core.cim.macros_per_group,
                    flit_bytes=chip.noc.flit_bytes, cycles=cycles,
                    throughput_sps=sps, energy=energy,
                    simulated=simulate)


def sweep_mg_flit(cg: CondensedGraph, strategy: str = "generic",
                  mgs: Iterable[int] = SWEEP_MG,
                  flits: Iterable[int] = SWEEP_FLIT,
                  simulate: bool = False,
                  params: Optional[CostParams] = None) -> List[DsePoint]:
    out = []
    for mg in mgs:
        for flit in flits:
            chip = default_chip(macros_per_group=mg, flit_bytes=flit)
            out.append(evaluate(cg, chip, strategy, params, simulate))
    return out

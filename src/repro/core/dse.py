"""Deprecated shim over :mod:`repro.explore` (the DSE subsystem).

The serial fixed-grid driver that used to live here was replaced by the
``repro.explore`` package — declarative design spaces, a pool-parallel
cached evaluation engine, search strategies and Pareto analysis.  This
module keeps the original public surface (``DsePoint``, ``evaluate``,
``sweep_mg_flit``, ``SWEEP_MG``, ``SWEEP_FLIT``) alive for existing
callers; new code should import from :mod:`repro.explore`.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional

from ..explore.engine import evaluate_chip
from ..explore.space import SWEEP_FLIT, SWEEP_MG
from .arch import ChipConfig, default_chip
from .graph import CondensedGraph
from .mapping import CostParams

__all__ = ["DsePoint", "evaluate", "sweep_mg_flit", "SWEEP_MG",
           "SWEEP_FLIT"]

warnings.warn(
    "repro.core.dse is deprecated; use the repro.explore subsystem "
    "(ExplorationEngine, DesignSpace, search, pareto) instead",
    DeprecationWarning, stacklevel=2)


@dataclass
class DsePoint:
    model: str
    strategy: str
    macros_per_group: int
    flit_bytes: int
    cycles: float
    throughput_sps: float       # samples/s at 1 GHz
    energy: Dict[str, float]    # nJ breakdown
    simulated: bool

    def row(self) -> Dict:
        return {
            "model": self.model, "strategy": self.strategy,
            "mg": self.macros_per_group, "flit": self.flit_bytes,
            "cycles": self.cycles, "throughput_sps": self.throughput_sps,
            "energy_total_mJ": self.energy["total"] / 1e6,
            **{f"energy_{k}_frac":
               (self.energy[k] / self.energy["total"]
                if self.energy["total"] else 0.0)
               for k in ("compute", "weight_load", "noc", "gmem",
                         "lmem", "static")},
            "simulated": self.simulated,
        }


def evaluate(cg: CondensedGraph, chip: ChipConfig, strategy: str,
             params: Optional[CostParams] = None,
             simulate: bool = False) -> DsePoint:
    out = evaluate_chip(cg, chip, strategy, params,
                        fidelity="simulate" if simulate else "analytic")
    return DsePoint(model=cg.name, strategy=strategy,
                    macros_per_group=chip.core.cim.macros_per_group,
                    flit_bytes=chip.noc.flit_bytes, cycles=out["cycles"],
                    throughput_sps=out["throughput_sps"],
                    energy=out["energy"], simulated=simulate)


def sweep_mg_flit(cg: CondensedGraph, strategy: str = "generic",
                  mgs: Iterable[int] = SWEEP_MG,
                  flits: Iterable[int] = SWEEP_FLIT,
                  simulate: bool = False,
                  params: Optional[CostParams] = None) -> List[DsePoint]:
    out = []
    for mg in mgs:
        for flit in flits:
            chip = default_chip(macros_per_group=mg, flit_bytes=flit)
            out.append(evaluate(cg, chip, strategy, params, simulate))
    return out

"""Vectorized pre-decoded replay engine for the perf-mode simulator.

The scalar interpreter in :mod:`repro.core.simulator` pays a Python
dispatch per instruction — ~10 µs each on the golden workloads — which
makes cycle-accurate ground-truthing the bottleneck of every calibrated
DSE run.  This module removes that cost for ``mode="perf"`` without
changing a single reported number:

* **Decode once** — every core's :class:`~repro.core.isa.Program` is
  packed into structure-of-arrays columns (:meth:`Program.pack`) and the
  whole stage is *statically executed* in one batch of numpy passes over
  the concatenated instruction stream: in perf mode no instruction reads
  simulated data (``S_LD`` does not write back), so every G_Reg/S_Reg
  value, macro-group occupancy mask, per-instruction unit and
  :class:`~repro.core.machine.MachineModel` latency is known at decode
  time from the immediate stream alone (segmented cumulative sums for
  register dataflow, ``searchsorted`` timelines for register reads,
  cumulative OR for MG occupancy, batched latency lookups).
* **Basic blocks** — each stream splits at the instructions that touch
  *shared* state (SEND / RECV / GLD / GST / SYNC / HALT).  Everything
  between two such points is core-local, so its event-ledger and
  unit-busy contributions are summed at decode time, and its timing
  collapses to a short list of *unit runs*: consecutive instructions on
  one execution unit advance the in-order issue clock by
  ``max(1, latency)`` each, so a run replays as one addition of a
  precomputed cumulative sum.
* **Replay** — the runtime loop schedules *blocks and boundary ops*
  instead of instructions, with exactly the scalar interpreter's
  pick-order (earliest core time, program-dict order on ties).  Shared
  NoC-link / gmem-port / channel / barrier state is only ever mutated
  by boundary handlers that are line-for-line ports of the scalar ones,
  so link reservations and port picks happen in the identical global
  order and the replay is cycle- and event-identical.

Exactness note: block replay re-associates float additions only through
pre-summed run/ledger constants.  Every latency the default and swept
chips produce is a dyadic rational (integer latencies, power-of-two
bandwidth divisors), for which float addition is exact in any order; a
chip configured with non-power-of-two divisors could in principle
differ from the scalar path in the last ulp.

Branches and scalar-ALU register chains are *statically resolved*: in
perf mode no instruction writes a register from simulated data (S_LD
does not write back), so the register file — and with it every branch
condition and loop bound — is a pure function of the immediate stream.
Such programs are unrolled at decode time by a scalar pre-execution
(:meth:`StageDecoder.unroll_decode`) into the same block/boundary
replay items the vectorized path produces.  Only custom instructions
and unrolls beyond the cap fall back to the scalar interpreter per
stage; ``mode="func"`` always uses it (bit-exact data semantics are
inherently per-instruction).

The replay loop schedules cores through an event heap keyed on
``(core time, program order)`` — identical pick order to the scalar
interpreter's linear min-scan, O(log n_cores) per item.
"""

from __future__ import annotations

import heapq
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .isa import Isa, Program, SREG
from .machine import MachineModel

__all__ = ["StageDecoder", "DecodeUnsupported", "run_stage"]


class DecodeUnsupported(Exception):
    """Program uses features outside the static perf-decode subset."""


# execution-unit numbering shared by decode tables and replay state
UNITS = ("scalar", "vector", "cim", "noc")
_SCALAR, _VECTOR, _CIM, _NOC = range(4)

# instruction kinds; everything >= _K_SEND is a shared-state boundary
_K_CONST, _K_VEC, _K_MVM, _K_WLOAD, _K_BCAST = range(5)
_K_SEND, _K_RECV, _K_GLD, _K_GST, _K_SYNC, _K_HALT = range(5, 11)
_K_UNSUP = 11

# runtime item tags (boundary tags reuse the kind ids)
_BLOCK, _END = 100, 101

# event-ledger keys whose totals are decode-time constants
_EV_KEYS = ("lmem_bytes", "cim_weight_load_bytes", "cim_macro_passes",
            "vector_elems")

_S_VLEN = SREG["VLEN"]
_S_VREP = SREG["V_REP"]
_S_CHANNEL = SREG["CHANNEL"]
_S_MASK_LO = SREG["MG_MASK_LO"]
_S_MASK_HI = SREG["MG_MASK_HI"]
_S_SEG_IN = SREG["MVM_SEG_IN"]
_S_SEG_OUT = SREG["MVM_SEG_OUT"]
_S_NLEN = SREG["MG_NLEN"]
_I8_FLAG = 1 << 2                      # FLAGS["i8"]


class _DecodedStage:
    """One stage's pre-decoded replay plan + static ledger totals."""

    __slots__ = ("items", "n_prog", "busy", "unit_used", "events",
                 "ev_present", "n_static")

    def __init__(self) -> None:
        self.items: Dict[int, List[tuple]] = {}   # core -> replay items
        self.n_prog: Dict[int, int] = {}          # core -> program length
        self.busy = [0.0] * 4                     # block-op busy cycles
        self.unit_used = [False] * 4
        self.events = [0.0] * 4                   # _EV_KEYS totals
        self.ev_present = [False] * 4
        self.n_static = 0                         # block-op instructions


class _Prep:
    """Machine-independent front half of a stage decode.

    Holds the concatenated, dead-code-filtered instruction columns of
    every *batchable* program of a stage, plus the lists of empty and
    unroll-needed programs.  Produced by :meth:`StageDecoder._prep` and
    consumed by both the numpy passes (:meth:`StageDecoder.decode_stage`)
    and the JAX engine (:mod:`repro.core.jaxsim`), which share it so a
    fleet evaluation preps each stage exactly once.
    """

    __slots__ = ("cids", "packs", "sizes", "offs", "op", "kind", "pid",
                 "starts", "n", "n_prog", "empty", "unroll",
                 "_colcache", "_live", "_all_live", "_zeros")

    def __init__(self) -> None:
        self.cids: List[int] = []
        self.packs: List[Any] = []
        self.n_prog: Dict[int, int] = {}
        self.empty: List[int] = []
        self.unroll: List[Tuple[int, Program]] = []
        self.n = 0
        self._colcache: Dict[str, np.ndarray] = {}
        self._zeros: Optional[np.ndarray] = None

    def col(self, name: str) -> np.ndarray:
        """Concatenated operand column (zeros where ops lack it)."""
        c = self._colcache.get(name)
        if c is None:
            if self._zeros is None:
                self._zeros = np.zeros(self.n, dtype=np.int64)
            parts = [p.args.get(name) for p in self.packs]
            if not any(x is not None for x in parts):
                c = self._zeros
            else:
                c = (parts[0] if len(self.packs) == 1
                     else np.concatenate(
                         [x if x is not None
                          else np.zeros(s, dtype=np.int64)
                          for x, s in zip(parts, self.sizes.tolist())]))
                if not self._all_live:
                    c = c[self._live]
            self._colcache[name] = c
        return c


def _finish_decode(out: _DecodedStage, pr: _Prep, unit: np.ndarray,
                   lat: np.ndarray, bitems: Dict[int, tuple],
                   ev_tot: List[float], ev_cnt: List[int]) -> None:
    """Back half of a stage decode, shared by the numpy and JAX paths.

    From the per-instruction latencies and resolved boundary items,
    collapse each program into unit runs + boundary replay items and
    accumulate the static busy / event / instruction totals into
    ``out``.  Everything here is plain numpy over ``pr``'s columns, so
    both engines produce byte-identical replay plans given identical
    ``lat`` / ``bitems``.
    """
    kind, pid, offs = pr.kind, pr.pid, pr.offs
    cids, n = pr.cids, pr.n
    bmask = kind >= _K_SEND
    bound_pos = np.flatnonzero(bmask)
    for p in np.flatnonzero(kind == _K_HALT).tolist():
        bitems[p] = (_K_HALT,)

    # ---- unit runs --------------------------------------------------
    nb = ~bmask
    run_start = nb.copy()
    run_start[1:] &= (unit[1:] != unit[:-1]) | bmask[:-1]
    run_start[offs[:-1]] = nb[offs[:-1]]         # break at core boundary
    rs = np.flatnonzero(run_start)
    mstep = np.maximum(1.0, lat)
    mstep[bmask] = 0.0
    if len(rs):
        marks = np.flatnonzero(run_start | bmask)
        mext = np.append(marks, n)
        ends = mext[np.searchsorted(marks, rs, side="right")] - 1
        run_A = np.add.reduceat(mstep, rs) - mstep[ends]
        runs = list(zip(unit[rs].tolist(), run_A.tolist(),
                        lat[ends].tolist()))
    else:
        runs = []

    # ---- static stage totals ----------------------------------------
    lat_nb = np.where(bmask, 0.0, lat)
    busy = np.bincount(unit, weights=lat_nb, minlength=4)
    cnt = np.bincount(unit[nb], minlength=4)
    for u in range(4):
        out.busy[u] += float(busy[u])
        out.unit_used[u] = out.unit_used[u] or bool(cnt[u])
    for k in range(4):
        out.events[k] += ev_tot[k]
        out.ev_present[k] = out.ev_present[k] or ev_cnt[k] > 0
    out.n_static += int(nb.sum())

    # ---- assemble per-core replay items -----------------------------
    # all run-index lookups batched: for each boundary, the block
    # before it spans runs [kp, kb); per-program tails span [kt, kh)
    nb_b = len(bound_pos)
    prange = np.arange(len(pr.packs))
    b_by_pid = pid[bound_pos]
    b_first = np.searchsorted(b_by_pid, prange, side="left")
    b_last = np.searchsorted(b_by_pid, prange, side="right")
    prev_pos = np.empty(nb_b, dtype=np.int64)
    if nb_b:
        prev_pos[0] = offs[b_by_pid[0]]
        same = b_by_pid[1:] == b_by_pid[:-1]
        prev_pos[1:] = np.where(same, bound_pos[:-1] + 1,
                                offs[b_by_pid[1:]])
    kb = np.searchsorted(rs, bound_pos).tolist()
    kp = np.searchsorted(rs, prev_pos).tolist()
    tail_pos = np.where(b_last > b_first,
                        bound_pos[np.maximum(b_last - 1, 0)] + 1
                        if nb_b else offs[:-1],
                        offs[:-1][prange])
    kt = np.searchsorted(rs, tail_pos).tolist()
    kh = np.searchsorted(rs, offs[1:]).tolist()
    bp_list = bound_pos.tolist()
    for p, cid in enumerate(cids):
        items: List[tuple] = []
        hi = int(offs[p + 1])
        b0, b1 = int(b_first[p]), int(b_last[p])
        for i in range(b0, b1):
            if kb[i] > kp[i]:
                items.append((_BLOCK, runs[kp[i]:kb[i]]))
            items.append(bitems[bp_list[i]])
        if kh[p] > kt[p]:
            items.append((_BLOCK, runs[kt[p]:kh[p]]))
        if not (b1 > b0 and bitems[bp_list[b1 - 1]][0] == _K_HALT
                and bp_list[b1 - 1] == hi - 1):
            items.append((_END,))
        out.items[cid] = items


class StageDecoder:
    """Decode tables for one (Isa, MachineModel) pair.

    Built once per :class:`~repro.core.simulator.Simulator`; holds dense
    per-op-id kind / unit / constant-latency / vector-class tables so
    :meth:`decode_stage` is a fixed number of numpy passes over the
    stage's concatenated program columns, independent of core count.
    """

    def __init__(self, isa: Isa, m: MachineModel) -> None:
        self.isa = isa
        self.m = m
        n = isa.n_ops
        self.kind = np.full(n, _K_UNSUP, dtype=np.int8)
        self.unit = np.zeros(n, dtype=np.int8)
        self.clat = np.zeros(n, dtype=np.float64)
        self.vcls = np.zeros(n, dtype=np.int8)
        oid = isa.op_index

        const = {
            "NOP": 1.0, "CIM_CFG": 1.0, "CIM_CFGR": 1.0, "V_SETVL": 1.0,
            "S_ADDI": float(m.scalar_alu_cycles),
            "S_LUI": float(m.scalar_alu_cycles),
            "S_LD": float(m.scalar_ldst_cycles),
            "S_ST": float(m.scalar_ldst_cycles),
        }
        bound = {"SEND": _K_SEND, "RECV": _K_RECV, "GLD": _K_GLD,
                 "GST": _K_GST, "SYNC": _K_SYNC, "HALT": _K_HALT}
        for d in isa.descriptors:
            i = oid[d.name]
            if d.name in const:
                self.kind[i] = _K_CONST
                self.unit[i] = _SCALAR
                self.clat[i] = const[d.name]
            elif d.name in bound:
                self.kind[i] = bound[d.name]
            elif d.name == "CIM_MVM":
                self.kind[i], self.unit[i] = _K_MVM, _CIM
            elif d.name == "CIM_LOAD":
                self.kind[i], self.unit[i] = _K_WLOAD, _CIM
            elif d.name == "BCAST":
                self.kind[i], self.unit[i] = _K_BCAST, _NOC
            elif d.unit == "vector":
                self.kind[i], self.unit[i] = _K_VEC, _VECTOR
                self.vcls[i] = m.vector_class(d.name[2:].lower())
            # anything else (scalar ALU chains, branches, custom ops)
            # stays _K_UNSUP -> scalar-interpreter fallback
        g = lambda nm: oid.get(nm, -1)            # noqa: E731
        self.id_addi, self.id_lui = g("S_ADDI"), g("S_LUI")
        self.id_cfg, self.id_cfgr = g("CIM_CFG"), g("CIM_CFGR")
        self.id_setvl = g("V_SETVL")
        self.id_sld, self.id_sst = g("S_LD"), g("S_ST")
        self._vector_ops = {d.name for d in isa.descriptors
                            if d.unit == "vector" and d.name != "V_SETVL"}

    # -- decode-time unrolling (branches / scalar-ALU chains) ---------------

    _SALU = {
        "S_ADD": lambda x, y: x + y, "S_SUB": lambda x, y: x - y,
        "S_MUL": lambda x, y: x * y, "S_AND": lambda x, y: x & y,
        "S_OR": lambda x, y: x | y, "S_XOR": lambda x, y: x ^ y,
        "S_SLT": lambda x, y: int(x < y),
        "S_SLL": lambda x, y: x << (y & 31),
        "S_SRL": lambda x, y: (x & 0xFFFFFFFF) >> (y & 31),
    }
    UNROLL_CAP = 1_000_000

    def _needs_unroll(self, pk) -> bool:
        """True when the live range holds control flow / S_ALU chains —
        interpretable at decode time but outside the vectorized batch."""
        op = pk.op
        kind = self.kind[op]
        h = np.flatnonzero(kind == _K_HALT)
        end = int(h[0]) + 1 if len(h) else len(op)
        if bool((kind[:end] == _K_UNSUP).any()):
            return True
        dst = pk.args.get("dst")
        acol = pk.args.get("a")
        if dst is None or acol is None:
            return False
        addi = op[:end] == self.id_addi
        return bool((addi & (dst[:end] != 0) & (acol[:end] != 0)
                     & (acol[:end] != dst[:end])).any())

    def unroll_decode(self, program: Program, cid: int,
                      out: "_DecodedStage") -> None:
        """Scalar pre-execution of one program at decode time.

        Perf mode's register file is fully static (no instruction
        writes a register from simulated data), so branches and scalar
        ALU chains resolve here: the walked pc trace collapses into the
        same block/boundary replay items — and identical busy / event /
        instruction totals — the vectorized path produces.  Raises
        :class:`DecodeUnsupported` for instructions even this path
        cannot execute (custom ops) or when the unrolled trace exceeds
        :data:`UNROLL_CAP`.
        """
        m = self.m
        instrs = program.instrs
        n = len(instrs)
        G = [0] * 32
        S = [0] * 64
        occ = 0                               # MG occupancy mask
        items: List[tuple] = []
        runs: List[tuple] = []
        cur: List[float] = []
        cur_u = -1
        busy = [0.0] * 4
        used = [False] * 4
        ev = [0.0] * 4
        evp = [False] * 4
        n_static = 0
        halted = False

        def close_run() -> None:
            nonlocal cur
            if cur:
                A = 0.0
                for lat in cur[:-1]:
                    A += lat if lat > 1.0 else 1.0
                runs.append((cur_u, A, cur[-1]))
                cur = []

        def emit(u: int, lat: float) -> None:
            nonlocal cur_u, n_static
            if u != cur_u:
                close_run()
                cur_u = u
            cur.append(float(lat))
            busy[u] += lat
            used[u] = True
            n_static += 1

        def boundary(item: tuple) -> None:
            nonlocal cur_u
            close_run()
            cur_u = -1
            if runs:
                items.append((_BLOCK, list(runs)))
                runs.clear()
            items.append(item)

        pc = 0
        steps = 0
        while pc < n:
            steps += 1
            if steps > self.UNROLL_CAP:
                raise DecodeUnsupported(
                    f"core {cid}: unrolled trace exceeds "
                    f"{self.UNROLL_CAP} instructions")
            ins = instrs[pc]
            name = ins.op
            a = ins.args
            if name == "HALT":
                boundary((_K_HALT,))
                halted = True
                break
            if name == "NOP":
                emit(_SCALAR, 1.0)
            elif name == "S_ADDI":
                emit(_SCALAR, m.scalar_alu_cycles)
                if a["dst"]:
                    G[a["dst"]] = G[a["a"]] + a["imm"]
            elif name == "S_LUI":
                emit(_SCALAR, m.scalar_alu_cycles)
                if a["dst"]:
                    G[a["dst"]] = (a["imm"] & 0xFFFF) << 16
            elif name in self._SALU:
                emit(_SCALAR, m.scalar_mul_cycles if name == "S_MUL"
                     else m.scalar_alu_cycles)
                if a.get("dst"):
                    G[a["dst"]] = self._SALU[name](G[a["a"]], G[a["b"]])
            elif name in ("S_LD", "S_ST"):
                emit(_SCALAR, m.scalar_ldst_cycles)
                ev[0] += 4.0
                evp[0] = True
            elif name in ("BEQ", "BNE", "BLT"):
                x, y = G[a["a"]], G[a["b"]]
                taken = {"BEQ": x == y, "BNE": x != y,
                         "BLT": x < y}[name]
                emit(_SCALAR, m.branch_cycles(taken))
                if taken:
                    pc += a["off"]
                    continue
            elif name == "JAL":
                emit(_SCALAR, m.branch_cycles(True))
                G[31] = pc + 1
                pc += a["off"]
                continue
            elif name == "CIM_CFG":
                emit(_SCALAR, 1.0)
                S[a["sreg"]] = a["imm"]
            elif name == "CIM_CFGR":
                emit(_SCALAR, 1.0)
                S[a["sreg"]] = G[a["src"]]
            elif name == "CIM_LOAD":
                rows = a["rows"]
                emit(_CIM, m.weight_load_cycles(rows))
                wl = rows * max(S[_S_NLEN], 1)
                ev[0] += wl
                ev[1] += wl
                evp[0] = evp[1] = True
                occ |= 1 << a["mg"]
            elif name == "CIM_MVM":
                rep = a["rep"]
                emit(_CIM, m.mvm_cycles(rep))
                mask = (S[_S_MASK_LO] & 0xFFFF) | (S[_S_MASK_HI] << 16)
                active = bin(occ & mask).count("1")
                ev[2] += rep * active * m.macros_per_group
                ev[0] += rep * (S[_S_SEG_IN] + S[_S_SEG_OUT])
                evp[0] = evp[2] = True
            elif name == "V_SETVL":
                emit(_SCALAR, 1.0)
                S[_S_VLEN] = a["len"]
            elif name == "BCAST":
                emit(_NOC, m.send_issue_cycles(int(G[a["size"]])))
            elif name in self._vector_ops:
                fn = name[2:].lower()
                nel = max(1, S[_S_VLEN]) * max(1, S[_S_VREP])
                emit(_VECTOR, m.vector_cycles(fn, nel))
                esz = 1 if (a.get("flags", 0) & _I8_FLAG) else 4
                ev[3] += nel
                ev[0] += nel * esz * 2
                evp[0] = evp[3] = True
            elif name == "SEND":
                boundary((_K_SEND, int(G[a["core"]]), int(G[a["size"]]),
                          S[_S_CHANNEL]))
            elif name == "RECV":
                boundary((_K_RECV, int(G[a["core"]]), int(G[a["size"]]),
                          S[_S_CHANNEL]))
            elif name == "GLD":
                boundary((_K_GLD, int(G[a["size"]])))
            elif name == "GST":
                boundary((_K_GST, int(G[a["size"]])))
            elif name == "SYNC":
                boundary((_K_SYNC, a["barrier"]))
            else:
                raise DecodeUnsupported(
                    f"core {cid}: instruction {name!r}")
            pc += 1
        if not halted:
            close_run()
            if runs:
                items.append((_BLOCK, list(runs)))
                runs.clear()
            items.append((_END,))
        out.items[cid] = items
        for u in range(4):
            out.busy[u] += busy[u]
            out.unit_used[u] = out.unit_used[u] or used[u]
            out.events[u] += ev[u]
            out.ev_present[u] = out.ev_present[u] or evp[u]
        out.n_static += n_static

    # -- dataflow helpers ---------------------------------------------------

    @staticmethod
    def _group(key: np.ndarray, pos: np.ndarray, *vals: np.ndarray
               ) -> Dict[int, Tuple[np.ndarray, ...]]:
        """Split (pos, *vals) into per-key slices (pos stays sorted)."""
        out: Dict[int, Tuple[np.ndarray, ...]] = {}
        if not len(pos):
            return out
        order = np.lexsort((pos, key))       # by key, position-sorted
        key_s = key[order]
        first = np.ones(len(key_s), dtype=bool)
        first[1:] = key_s[1:] != key_s[:-1]
        starts = np.flatnonzero(first)
        ends = np.append(starts[1:], len(key_s))
        cols = (pos[order],) + tuple(v[order] for v in vals)
        for s, e in zip(starts.tolist(), ends.tolist()):
            out[int(key_s[s])] = tuple(c[s:e] for c in cols)
        return out

    def _timeline(self, wmap, key: int, pos: np.ndarray,
                  start: np.ndarray) -> np.ndarray:
        """Value of per-core timeline ``key`` just before positions
        ``pos`` (``start`` = each position's program start, so a read
        never observes another core's writes)."""
        out = np.zeros(pos.shape, dtype=np.int64)
        got = wmap.get(int(key))
        if got is None or not len(pos):
            return out
        wp, wv = got
        j = np.searchsorted(wp, pos, side="left")
        has = j > 0
        jj = j[has] - 1
        ok = wp[jj] >= start[has]
        sel = np.flatnonzero(has)[ok]
        out[sel] = wv[jj[ok]]
        return out

    def _resolve_gregs(self, gmap, regs: np.ndarray, pos: np.ndarray,
                       start: np.ndarray) -> np.ndarray:
        """G_Reg values ``G[regs[i]]`` just before positions ``pos``."""
        out = np.zeros(len(pos), dtype=np.int64)
        for r, (p, s) in self._group(regs, pos, start).items():
            if r == 0:
                continue
            idx = np.searchsorted(pos, p)        # positions are unique
            out[idx] = self._timeline(gmap, r, p, s)
        return out

    # -- decode -------------------------------------------------------------

    def _prep(self, programs: Dict[int, Program]) -> "_Prep":
        """Shared front half of decode: pack, split off empty/unrolled
        programs, drop dead code, and concatenate the batchable columns.

        Machine-independent — the numpy passes below and the JAX engine
        (:mod:`repro.core.jaxsim`) both start from the same `_Prep`.
        Raises :class:`DecodeUnsupported` for live instructions outside
        the batchable subset.
        """
        pr = _Prep()
        cids = pr.cids
        packs = pr.packs
        for cid, prog in programs.items():
            pr.n_prog[cid] = len(prog)
            if len(prog) == 0:
                pr.empty.append(cid)
                continue
            try:
                # cache hit when codegen shipped the table with the
                # program; handwritten programs pack here once
                pk = prog.pack(self.isa)
            except KeyError as e:            # op not in the ISA at all
                raise DecodeUnsupported(
                    f"unknown instruction {e}") from e
            if self._needs_unroll(pk):
                # control flow / scalar-ALU chains: statically resolved
                # by decode-time scalar pre-execution (perf mode's
                # register file never depends on simulated data)
                pr.unroll.append((cid, prog))
            else:
                cids.append(cid)
                packs.append(pk)
        if not cids:
            return pr

        sizes = np.array([p.op.size for p in packs], dtype=np.int64)
        offs = np.zeros(len(packs) + 1, dtype=np.int64)
        np.cumsum(sizes, out=offs[1:])
        op = (packs[0].op if len(packs) == 1
              else np.concatenate([p.op for p in packs]))
        kind = self.kind[op]

        # ---- drop dead code (straight-line: nothing runs past HALT) ----
        pid = np.repeat(np.arange(len(packs)), sizes)
        hpos = np.flatnonzero(kind == _K_HALT)
        n_eff = sizes.copy()
        if len(hpos):
            hpid = pid[hpos]
            p_first, i_first = np.unique(hpid, return_index=True)
            n_eff[p_first] = hpos[i_first] - offs[p_first] + 1
        live_end = offs[:-1] + n_eff
        live = np.arange(offs[-1]) < live_end[pid]
        all_live = bool(live.all())
        if not all_live:
            op, kind, pid = op[live], kind[live], pid[live]
            offs = np.zeros(len(packs) + 1, dtype=np.int64)
            np.cumsum(n_eff, out=offs[1:])
        n = int(offs[-1])

        if (kind == _K_UNSUP).any():
            bad = int(np.flatnonzero(kind == _K_UNSUP)[0])
            p = int(pid[bad])
            raise DecodeUnsupported(
                f"core {cids[p]}: instruction "
                f"{programs[cids[p]].instrs[bad - int(offs[p])].op!r}")

        pr.sizes, pr.offs = sizes, offs
        pr.op, pr.kind, pr.pid = op, kind, pid
        pr.starts = offs[:-1][pid]               # program start of each pc
        pr.n = n
        pr._live, pr._all_live = live, all_live

        is_addi = op == self.id_addi
        dst, a_col = pr.col("dst"), pr.col("a")
        bad = is_addi & (dst != 0) & (a_col != 0) & (a_col != dst)
        if bad.any():
            raise DecodeUnsupported("S_ADDI with cross-register source")
        return pr

    def decode_stage(self, programs: Dict[int, Program]) -> _DecodedStage:
        """Statically execute all of a stage's programs in one batch.

        Raises :class:`DecodeUnsupported` when any live instruction is
        outside the subset (the caller falls back to the interpreter).
        """
        out = _DecodedStage()
        pr = self._prep(programs)
        out.n_prog = pr.n_prog
        for cid in pr.empty:
            out.items[cid] = [(_END,)]
        for cid, prog in pr.unroll:
            self.unroll_decode(prog, cid, out)
        if not pr.cids:
            return out

        op, kind, pid = pr.op, pr.kind, pr.pid
        starts, col = pr.starts, pr.col

        m = self.m
        unit = self.unit[op]
        lat = self.clat[op].copy()
        ev_tot = [0.0] * 4
        ev_cnt = [0] * 4

        # ---- G_Reg dataflow (emitter idiom: LUI / ADDI-from-0/self) ----
        dst, a_col, imm = col("dst"), col("a"), col("imm")
        is_lui = op == self.id_lui
        is_addi = op == self.id_addi
        wpos = np.flatnonzero((is_lui | is_addi) & (dst != 0))
        gmap: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        if len(wpos):
            reg = dst[wpos]
            lui = is_lui[wpos]
            w_imm = imm[wpos]
            base_val = np.where(lui, (w_imm & 0xFFFF) << 16, w_imm)
            incr = ~lui & (a_col[wpos] == reg)    # ADDI dst, dst, imm
            order = np.lexsort((wpos, reg))
            reg_s, pos_s = reg[order], wpos[order]
            first = np.ones(len(reg_s), dtype=bool)
            # a chain resets at a load-immediate, at the register's first
            # write, and at a program (core) boundary
            first[1:] = ((reg_s[1:] != reg_s[:-1])
                         | (pid[pos_s[1:]] != pid[pos_s[:-1]]))
            reset = ~incr[order] | first
            contrib = np.where(reset, base_val[order], w_imm[order])
            cs = np.cumsum(contrib)
            rpos = np.flatnonzero(reset)
            seg = np.cumsum(reset) - 1
            val_s = cs - (cs[rpos] - contrib[rpos])[seg]
            gstarts = np.flatnonzero(
                np.concatenate([[True], reg_s[1:] != reg_s[:-1]]))
            gends = np.append(gstarts[1:], len(reg_s))
            for s, e in zip(gstarts.tolist(), gends.tolist()):
                gmap[int(reg_s[s])] = (pos_s[s:e], val_s[s:e])

        # ---- S_Reg dataflow (CIM_CFG / CIM_CFGR / V_SETVL) -------------
        cfg = np.flatnonzero(op == self.id_cfg)
        cfgr = np.flatnonzero(op == self.id_cfgr)
        setvl = np.flatnonzero(op == self.id_setvl)
        sreg_col = col("sreg")
        spos = np.concatenate([cfg, cfgr, setvl])
        sidx = np.concatenate([sreg_col[cfg], sreg_col[cfgr],
                               np.full(len(setvl), _S_VLEN,
                                       dtype=np.int64)])
        sval = np.concatenate([
            imm[cfg],
            self._resolve_gregs(gmap, col("src")[cfgr], cfgr,
                                starts[cfgr]),
            col("len")[setvl]])
        smap = {k: (p, v) for k, (p, v)
                in self._group(sidx, spos, sval).items()}

        # ---- S_LD / S_ST ledger traffic (4 B words) --------------------
        mem = np.flatnonzero((op == self.id_sld) | (op == self.id_sst))
        ev_tot[0] += 4.0 * len(mem)
        ev_cnt[0] += len(mem)

        # ---- vector ops: n = max(1, VLEN) * max(1, V_REP) --------------
        vpos = np.flatnonzero(kind == _K_VEC)
        if len(vpos):
            vstart = starts[vpos]
            vlen = self._timeline(smap, _S_VLEN, vpos, vstart)
            vrep = self._timeline(smap, _S_VREP, vpos, vstart)
            n_el = np.maximum(vlen, 1) * np.maximum(vrep, 1)
            lat[vpos] = m.vector_cycles_array(self.vcls[op[vpos]], n_el)
            esz = np.where(col("flags")[vpos] & _I8_FLAG, 1, 4)
            ev_tot[0] += float((n_el * esz * 2).sum())
            ev_tot[3] += float(n_el.sum())
            ev_cnt[0] += len(vpos)
            ev_cnt[3] += len(vpos)

        # ---- CIM_LOAD: rows latency, rows * MG_NLEN ledger -------------
        lpos = np.flatnonzero(kind == _K_WLOAD)
        if len(lpos):
            rows = col("rows")[lpos]
            lat[lpos] = m.weight_load_cycles_array(rows)
            nlen = np.maximum(
                self._timeline(smap, _S_NLEN, lpos, starts[lpos]), 1)
            wl = float((rows * nlen).sum())
            ev_tot[0] += wl
            ev_tot[1] += wl
            ev_cnt[0] += len(lpos)
            ev_cnt[1] += len(lpos)

        # ---- CIM_MVM: rep latency, MG-occupancy macro passes -----------
        mpos = np.flatnonzero(kind == _K_MVM)
        if len(mpos):
            mstart = starts[mpos]
            rep = col("rep")[mpos]
            lat[mpos] = m.mvm_cycles_array(rep)
            mask = ((self._timeline(smap, _S_MASK_LO, mpos, mstart)
                     & 0xFFFF)
                    | (self._timeline(smap, _S_MASK_HI, mpos,
                                      mstart) << 16))
            loaded = np.zeros(len(mpos), dtype=np.int64)
            if len(lpos):
                bits = 1 << col("mg")[lpos]
                occ = np.empty(len(lpos), dtype=np.int64)
                lpid = pid[lpos]
                lstarts = np.flatnonzero(
                    np.concatenate([[True], lpid[1:] != lpid[:-1]]))
                lends = np.append(lstarts[1:], len(lpos))
                for s, e in zip(lstarts.tolist(), lends.tolist()):
                    occ[s:e] = np.bitwise_or.accumulate(bits[s:e])
                j = np.searchsorted(lpos, mpos, side="left")
                has = j > 0
                jj = j[has] - 1
                ok = lpos[jj] >= mstart[has]
                sel = np.flatnonzero(has)[ok]
                loaded[sel] = occ[jj[ok]]
            act = loaded & mask
            active = np.zeros(len(mpos), dtype=np.int64)
            for b in range(32):
                active += (act >> b) & 1
            ev_tot[2] += float((rep * active).sum() * m.macros_per_group)
            seg = (self._timeline(smap, _S_SEG_IN, mpos, mstart)
                   + self._timeline(smap, _S_SEG_OUT, mpos, mstart))
            ev_tot[0] += float((rep * seg).sum())
            ev_cnt[0] += len(mpos)
            ev_cnt[2] += len(mpos)

        # ---- BCAST: sender-side injection occupancy (core-local) -------
        bcast = np.flatnonzero(kind == _K_BCAST)
        if len(bcast):
            size = self._resolve_gregs(gmap, col("size")[bcast], bcast,
                                       starts[bcast])
            lat[bcast] = m.send_issue_cycles_array(size)

        # ---- boundary items --------------------------------------------
        bitems: Dict[int, tuple] = {}
        for tag in (_K_SEND, _K_RECV):
            kpos = np.flatnonzero(kind == tag)
            if not len(kpos):
                continue
            kstart = starts[kpos]
            peer = self._resolve_gregs(gmap, col("core")[kpos], kpos,
                                       kstart)
            size = self._resolve_gregs(gmap, col("size")[kpos], kpos,
                                       kstart)
            stream = self._timeline(smap, _S_CHANNEL, kpos, kstart)
            for p, c, s, st in zip(kpos.tolist(), peer.tolist(),
                                   size.tolist(), stream.tolist()):
                bitems[p] = (tag, c, s, st)
        for tag in (_K_GLD, _K_GST):
            kpos = np.flatnonzero(kind == tag)
            if len(kpos):
                size = self._resolve_gregs(gmap, col("size")[kpos], kpos,
                                           starts[kpos])
                for p, s in zip(kpos.tolist(), size.tolist()):
                    bitems[p] = (tag, s)
        sync = np.flatnonzero(kind == _K_SYNC)
        if len(sync):
            barrier = col("barrier")[sync]
            for p, b in zip(sync.tolist(), barrier.tolist()):
                bitems[p] = (_K_SYNC, b)

        _finish_decode(out, pr, unit, lat, bitems, ev_tot, ev_cnt)
        return out


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------


class _VCore:
    __slots__ = ("id", "items", "ip", "time", "F", "blocked", "halted",
                 "n_prog")

    def __init__(self, core_id: int, items: List[tuple],
                 n_prog: int) -> None:
        self.id = core_id
        self.items = items
        self.ip = 0
        self.time = 0.0
        self.F = [0.0, 0.0, 0.0, 0.0]       # per-unit free times
        self.blocked = False
        self.halted = False
        self.n_prog = n_prog


def run_stage(sim: Any, sp: Any) -> Optional[Tuple[float, Dict[str, float],
                                                   Dict[str, float], int]]:
    """Vectorized replay of one stage.

    Returns ``(makespan, events, busy, instrs)`` exactly as the scalar
    ``Simulator._run_stage`` would, or ``None`` when any program of the
    stage is outside the decodable subset.
    """
    dec = getattr(sim, "_vdecoder", None)
    if dec is None or dec.isa is not sim.isa:
        dec = sim._vdecoder = StageDecoder(sim.isa, sim.m)
    try:
        ds = dec.decode_stage(sp.programs)
    except DecodeUnsupported:
        return None
    return replay_stage(sim, sp, ds)


def replay_stage(sim: Any, sp: Any,
                 ds: _DecodedStage) -> Tuple[float, Dict[str, float],
                                             Dict[str, float], int]:
    """Replay one pre-decoded stage (shared by the numpy/JAX engines).

    ``sim`` only needs ``.m`` and ``.max_cycles`` — the fleet evaluator
    passes a lightweight shim instead of a full ``Simulator``.
    """
    from .simulator import Deadlock, SimError     # late: avoid cycle
    m = sim.m
    max_cycles = sim.max_cycles
    cores = {cid: _VCore(cid, ds.items[cid], ds.n_prog[cid])
             for cid in sp.programs}
    pending = [c for c in cores.values() if c.n_prog > 0]

    # decode-time constants: block-op busy/ledger/instruction totals
    # (every block replays exactly once on any run that returns)
    events: Dict[str, float] = {}
    busy4 = list(ds.busy)
    used4 = list(ds.unit_used)
    instrs = ds.n_static
    for k in range(4):
        if ds.ev_present[k]:
            events[_EV_KEYS[k]] = ds.events[k]

    links: Dict[Tuple[int, int], float] = {}
    ports = [0.0] * m.gmem_ports
    chan: Dict[Tuple[int, int, int], deque] = {}
    barriers: Dict[int, List[_VCore]] = {}
    n_need = len(cores)

    def ev(key: str, amount: float) -> None:
        events[key] = events.get(key, 0.0) + amount

    # The three helpers below are line-for-line ports of
    # Simulator._use / _route_delay / _gmem_xfer: any change to NoC
    # arbitration, port policy or issue timing MUST be mirrored there
    # (the equivalence suite and the bench cycle gate pin the goldens,
    # but only shapes they cover).
    def use_noc(core: _VCore, latency: float) -> float:
        t_issue = core.time + 1.0
        if core.F[_NOC] > t_issue:
            t_issue = core.F[_NOC]
        core.F[_NOC] = t_issue + latency
        busy4[_NOC] += latency
        used4[_NOC] = True
        core.time = t_issue
        return t_issue + latency

    def route_delay(src: int, dst: int, nbytes: int,
                    t_start: float) -> float:
        occupy = m.link_occupancy_cycles(nbytes)
        t = t_start + m.inject_cycles
        if src == dst:
            return t + occupy
        for link in m.route(src, dst):
            t = max(t, links.get(link, 0.0)) + m.router_hop_cycles
            links[link] = t + occupy
        ev("noc_byte_hops", nbytes * m.hops(src, dst))
        return t + occupy

    # event heap keyed on (time, program order): pops exactly the core
    # the interpreter's linear min-scan would pick (earliest time, then
    # program-dict order on ties), at O(log n) per item — the linear
    # scan dominated replay wall time on 64+-core stages.  Invariant:
    # every runnable (non-halted, non-blocked) core sits in the heap
    # exactly once; blocked cores re-enter when a SEND/SYNC frees them.
    seq = {c.id: i for i, c in enumerate(pending)}
    heap: List[Tuple[float, int, _VCore]] = [
        (c.time, seq[c.id], c) for c in pending]
    heapq.heapify(heap)

    def wake(other: "_VCore") -> None:
        other.blocked = False
        heapq.heappush(heap, (other.time, seq[other.id], other))

    while heap:
        et, sq, core = heapq.heappop(heap)
        if core.halted:
            continue
        if et != core.time:
            # stale key (a SYNC release advanced this core's clock
            # while it sat in the heap): lazily re-key so pick order
            # stays exactly (current time, program order) — the scalar
            # interpreter's min-scan
            heapq.heappush(heap, (core.time, sq, core))
            continue
        item = core.items[core.ip]
        tag = item[0]

        if tag == _BLOCK:
            t = core.time
            F = core.F
            for u, A, L in item[1]:
                x = t + 1.0
                f = F[u]
                t = (f if f > x else x) + A
                F[u] = t + L
            core.time = t
            core.ip += 1
        elif tag == _K_SEND:
            instrs += 1
            _, dst, size, stream = item
            done = use_noc(core, m.send_issue_cycles(size))
            arrival = route_delay(core.id, dst, size, done)
            chan.setdefault((core.id, dst, stream),
                            deque()).append((arrival, size, None))
            ev("lmem_bytes", size)
            other = cores.get(dst)
            if other is not None and other.blocked:
                wake(other)
            core.ip += 1
        elif tag == _K_RECV:
            instrs += 1
            _, src, size, stream = item
            q = chan.get((src, core.id, stream))
            if not q:
                core.blocked = True          # retry when a SEND arrives
            else:
                arrival, msize, _data = q.popleft()
                if msize != size:
                    raise SimError(
                        f"recv size mismatch {src}->{core.id}"
                        f"#{stream}: expected {size}, got {msize}")
                if arrival > core.time:
                    core.time = arrival
                use_noc(core, m.send_issue_cycles(size))
                ev("lmem_bytes", size)
                core.ip += 1
        elif tag in (_K_GLD, _K_GST):
            instrs += 1
            size = item[1]
            t_start = core.time + 1
            i = min(range(len(ports)), key=ports.__getitem__)
            t0 = ports[i] if ports[i] > t_start else t_start
            done = t0 + m.gmem_stream_cycles(size, ports=1)
            ports[i] = done
            ev("gmem_bytes", size)
            use_noc(core, max(1.0, done - core.time - 1))
            ev("lmem_bytes", size)
            core.ip += 1
        elif tag == _K_SYNC:
            instrs += 1
            group = barriers.setdefault(item[1], [])
            if core not in group:
                group.append(core)
            if len(group) < n_need:
                core.blocked = True
            else:
                t = max(c.time for c in group) + 1
                for c in group:
                    c.time = t
                    c.ip += 1
                    if c is core:
                        c.blocked = False
                    elif c.blocked:
                        # a member spuriously woken by an earlier SEND
                        # already holds a heap ticket — don't double-push
                        wake(c)
                barriers[item[1]] = []
        elif tag == _K_HALT:
            instrs += 1
            core.time += 1
            core.halted = True
        else:                                  # _END
            core.halted = True
        if core.time > max_cycles:
            raise SimError("max_cycles exceeded")
        if not core.halted and not core.blocked:
            heapq.heappush(heap, (core.time, seq[core.id], core))

    if pending and not all(c.halted for c in pending):
        blocked = [c.id for c in pending if c.blocked]
        raise Deadlock(f"cores {blocked} blocked "
                       f"(recv/sync with no sender)")
    makespan = max((c.time for c in cores.values()), default=0.0)
    busy = {UNITS[u]: busy4[u] for u in range(4) if used4[u]}
    return makespan, events, busy, instrs

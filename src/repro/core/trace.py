"""The ``trace`` fidelity: StagePlan replay at unit/transfer granularity.

Sits between the closed-form analytic cost model (§III-C) and the
cycle-accurate simulator (§III-D).  Instead of stepping per-core
instruction streams, it replays each stage as a timeline of
``(group, replica, sample)`` events whose unit costs are derived from
the *same* op-level schedules codegen lowers (``core.oplevel``) and the
*same* :class:`~repro.core.machine.MachineModel` the simulator charges
— so it sees the three effects the analytic model idealizes away:

* **im2col gather work** — the vector-unit cost of staging conv patches
  (dominant on spatial layers; the analytic ``vector_elems`` estimate
  misses it entirely);
* **whole-sample handoffs** — codegen emits an unrolled sample loop
  with blocking SEND/RECV per (producer, consumer, sample), so stages
  pipeline at sample granularity, not the row-chunk granularity the
  analytic fill model assumes;
* **per-sample weight re-streaming / dynamic staging** — weight costs
  derive from the schedules' weight-source metadata: ``streamed``
  groups (columns exceed their cores' free MG slots) re-fetch from
  gmem every round of every sample; ``dynamic`` groups (attention)
  wait on their weight producer's activations, then pay the gather
  V_MOVs and CIM array writes every sample.

Cost: one ``plan_stage`` call per stage plus ``O(groups x replicas x
batch)`` timeline events — typically two to three orders of magnitude
faster than perf-mode simulation, and within its cycle count by design
(the fidelity-agreement suite pins the band).

Replay consults a handful of private geometry helpers from
:mod:`repro.core.codegen` (`_needed_in_rows`, `_out_geometry`, ...) on
purpose: the trace fidelity must mirror what codegen actually emits,
and sharing the helpers keeps the two from drifting.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .arch import ChipConfig
from .codegen import (_conv_rows_to_compute, _core_columns, _in_row_bytes,
                      _main_and_skip_preds, _needed_in_rows, _out_geometry,
                      _owned_out_rows, _pooled_rows, _side_pre_reduce,
                      _side_rows)
from .energy import DEFAULT_TABLE, EnergyTable, energy_breakdown
from .graph import CondensedGraph
from .machine import Calibration, MachineModel, machine_for
from .mapping import StagePlan
from .oplevel import (OpSchedule, ReplicaPlan, incremental_ops,
                      plan_stage)
from .partition import PartitionResult

__all__ = ["TraceReport", "TraceEngine", "trace_model"]


@dataclass
class TraceReport:
    """Trace-fidelity evaluation result (shape mirrors ``SimReport``)."""

    cycles: float
    stage_cycles: List[float]
    events: Dict[str, float]
    unit_busy: Dict[str, float]
    n_events: int                     # replayed timeline events
    table: EnergyTable = DEFAULT_TABLE

    def energy(self, table: Optional[EnergyTable] = None
               ) -> Dict[str, float]:
        return energy_breakdown(self.events, table or self.table)

    def summary(self) -> str:
        e = self.energy()
        return (f"{self.cycles:.0f} cycles (trace, {self.n_events} "
                f"events), {e['total'] / 1e6:.3f} mJ")

    @classmethod
    def stitch(cls, reports: "List[TraceReport]",
               link_cycles: float = 0.0) -> "TraceReport":
        """Concatenate per-chip trace replays into one system-level
        report: chips of a pipeline-parallel plan run their stage lists
        back to back, joined by inter-chip link transfers whose total
        occupancy is ``link_cycles`` (priced by the caller against
        :class:`~repro.core.machine.InterChipLink` — link energy is
        accounted there too, not in this event ledger)."""
        if not reports:
            raise ValueError("stitch needs at least one TraceReport")
        events: Dict[str, float] = {}
        busy: Dict[str, float] = {}
        stage_cycles: List[float] = []
        for r in reports:
            stage_cycles.extend(r.stage_cycles)
            for k, v in r.events.items():
                events[k] = events.get(k, 0.0) + v
            for k, v in r.unit_busy.items():
                busy[k] = busy.get(k, 0.0) + v
        if link_cycles > 0:
            busy["interchip"] = busy.get("interchip", 0.0) + link_cycles
        return cls(cycles=sum(r.cycles for r in reports) + link_cycles,
                   stage_cycles=stage_cycles, events=events,
                   unit_busy=busy,
                   n_events=sum(r.n_events for r in reports),
                   table=reports[0].table)


# ---------------------------------------------------------------------------
# Per-(group, replica) replay profile
# ---------------------------------------------------------------------------


@dataclass
class _Profile:
    """Sample-invariant unit costs of one replica (raw, uncalibrated)."""

    cores: Tuple[int, ...]
    asm_core: int
    main: Optional[int]               # main-input producer gid (None=gmem)
    main_in_member: bool
    in_nb: int                        # needed input bytes per core
    side_inputs: List[Tuple[int, int, bool]] = field(default_factory=list)
    # (sgid, nbytes, producer_in_stage)
    cim: float = 0.0                  # per-sample CIM-unit busy
    vec: float = 0.0                  # per-sample vector busy (asm core)
    noc: float = 0.0                  # per-sample intra-replica NoC busy
    send_issue: float = 0.0           # delivery serialization on asm core
    gst_bytes: int = 0                # boundary-out bytes per sample
    # weight-stream costs, derived from the schedule's weight-source
    # metadata (static: prologue only; streamed: prologue + per-sample
    # gmem re-stream; dynamic: per-sample gather + CIM write, no gmem)
    prologue_gld_bytes: int = 0       # round-0 weight stream (static)
    prologue_cim: float = 0.0         # round-0 CIM_LOAD cycles (per core)
    reload_gld_bytes_tail: int = 0    # rounds >= 1 re-stream (sample 0)
    reload_gld_bytes_full: int = 0    # all rounds re-stream (samples > 0)
    reload_cim_tail: float = 0.0
    reload_cim_full: float = 0.0
    # dynamic weights: producer handoff + per-sample staging costs
    dyn_w: Optional[Tuple[int, int, bool]] = None   # (gid|-1, nb, in_stage)
    dyn_gather_vec: float = 0.0       # gather V_MOVs (per core, max)
    dyn_load_cim: float = 0.0         # CIM_LOAD cycles, all rounds
    # append-only (kv_append) staging: samples > 0 fetch one producer
    # row and re-stage only the tiles it touches (incremental_ops)
    dyn_w_incr: bool = False
    dyn_w_row_nb: int = 0             # appended-row bytes
    dyn_gather_vec_incr: float = 0.0  # per-core max, incremental gather
    dyn_load_cim_incr: float = 0.0    # per-core max, incremental load


def _chunk_shapes(sched: OpSchedule, rep: ReplicaPlan,
                  cg: CondensedGraph) -> Tuple[int, List[int]]:
    """(row_repeats, chunk widths): conv rows share one chunk template."""
    spec = sched.im2col
    if spec is not None:
        y0, y1 = _conv_rows_to_compute(cg, sched, rep)
        widths = [min(spec.wo - x0, sched.m_chunk)
                  for x0 in range(0, spec.wo, sched.m_chunk)]
        return max(0, y1 - y0), widths
    span = max(0, rep.m_hi - rep.m_lo)
    widths = [min(span - c0, sched.m_chunk)
              for c0 in range(0, span, sched.m_chunk)]
    return (1, widths) if widths else (0, [])


def _profile(cg: CondensedGraph, sched: OpSchedule, rep: ReplicaPlan,
             by_gid: Dict[int, OpSchedule], member: set,
             op_owner: Dict[int, int], m: MachineModel) -> _Profile:
    g = cg[sched.gid]
    spec = sched.im2col
    K, N = sched.k_total, sched.n_total
    multi = len(rep.cores) > 1
    vo = sched.vector_ops
    first = next((v for v in vo if v != "bias"), None)
    relu_here = first == "relu"

    main, side = _main_and_skip_preds(cg, g, op_owner)
    in_rows_total = spec.h if spec is not None else 0
    r0, r1 = _needed_in_rows(cg, sched, rep, in_rows_total)
    in_nb = max(0, r1 - r0) * _in_row_bytes(sched)

    p = _Profile(cores=rep.cores, asm_core=rep.cores[0], main=main,
                 main_in_member=(main is not None and main in member),
                 in_nb=in_nb)

    # -- weight load / re-stream / dynamic staging -------------------------
    # all three costs derive from the same MgAssign weight-source
    # metadata codegen lowers (one definition, no drift)
    dyn = sched.weight_source == "dynamic"
    per_core_rows: Dict[Tuple[int, int], float] = {}
    per_core_gather: Dict[int, float] = {}
    for a in rep.assigns:
        nb = a.k_len * a.n_len
        if not dyn:
            if a.round == 0:
                p.prologue_gld_bytes += nb
            else:
                p.reload_gld_bytes_tail += nb
            p.reload_gld_bytes_full += nb
        else:
            per_core_gather[a.core] = per_core_gather.get(a.core, 0.0) \
                + m.vector_cycles("mov", nb)
        key = (a.core, a.round)
        per_core_rows[key] = per_core_rows.get(key, 0.0) \
            + m.weight_load_cycles(a.k_len)
    by_round: Dict[int, float] = {}
    for (c, rnd), cyc in per_core_rows.items():
        by_round[rnd] = max(by_round.get(rnd, 0.0), cyc)
    if dyn:
        # every round's arrays are (re)written every sample, from the
        # RECV'd/GLD'd producer activations resident in local memory;
        # the multi-round path re-loads per m-chunk (codegen's
        # chunk-outer/round-inner emission), single-round loads once
        chunk_f = sched.n_chunks if sched.n_rounds > 1 else 1
        p.dyn_load_cim = sum(by_round.values()) * chunk_f
        p.dyn_gather_vec = max(per_core_gather.values(),
                               default=0.0) * chunk_f
        p.dyn_w = (sched.weight_pred if sched.weight_pred is not None
                   else -1,
                   sched.w_rows * sched.w_row_bytes,
                   sched.weight_pred is not None
                   and sched.weight_pred in member)
        if sched.w_incremental and sched.n_rounds == 1:
            # append-only staging (codegen's incremental emission):
            # per-core cost of re-staging just the appended row's tiles
            gv: Dict[int, float] = {}
            lc: Dict[int, float] = {}
            for a in rep.assigns:
                ops = incremental_ops(g, sched, a)
                if ops is None:
                    continue
                movs, loads = ops
                gv[a.core] = gv.get(a.core, 0.0) + sum(
                    m.vector_cycles("mov", e) for e in movs)
                lc[a.core] = lc.get(a.core, 0.0) + sum(
                    m.weight_load_cycles(r) for r in loads)
            p.dyn_w_incr = True
            p.dyn_w_row_nb = sched.w_row_bytes
            p.dyn_gather_vec_incr = max(gv.values(), default=0.0)
            p.dyn_load_cim_incr = max(lc.values(), default=0.0)
    else:
        p.prologue_cim = by_round.get(0, 0.0)
        p.reload_cim_tail = sum(v for r, v in by_round.items() if r > 0)
        p.reload_cim_full = sum(by_round.values())
        if sched.n_rounds <= 1:
            p.reload_gld_bytes_tail = p.reload_gld_bytes_full = 0
            p.reload_cim_tail = p.reload_cim_full = 0.0

    # -- side (residual / SE-scale) operands -------------------------------
    k0, k1, krow_nb = _side_rows(cg, sched, rep)
    for sgid in side:
        if k1 <= k0:
            break
        nbytes = (k1 - k0) * krow_nb
        prod_sched = by_gid.get(sgid)
        if prod_sched is not None:
            prod_rows, prod_row_nb, _ = _out_geometry(cg, prod_sched)
            if prod_rows == 1 and ((k1 - k0) * krow_nb > krow_nb
                                   or krow_nb != prod_row_nb):
                nbytes = prod_row_nb          # broadcast operand
        p.side_inputs.append((sgid, nbytes, sgid in member))

    # -- compute: chunk template x rows ------------------------------------
    nrows, widths = _chunk_shapes(sched, rep, cg)
    cols_by_core = {c: _core_columns(rep, c) for c in rep.cores}
    for npos in widths:
        # CIM: one MVM burst per round per core (cores fire in parallel)
        p.cim += m.mvm_cycles(npos) * sched.n_rounds * nrows
        # vector gather (per round — re-staged for every round)
        gather = 0.0
        if spec is not None:
            if spec.pad > 0:
                gather += m.vector_cycles("zero", K * npos)
            if spec.depthwise:
                gather += spec.kh * spec.kw \
                    * m.vector_cycles("mov", spec.cin * npos)
            else:
                gather += spec.kh \
                    * m.vector_cycles("mov", spec.kw * spec.cin * npos)
        p.vec += gather * sched.n_rounds * nrows
        # post-ops (last round only); the asm core is the serialization
        # point — its own columns plus assembly of the siblings'
        asm_cols = cols_by_core[p.asm_core]
        post = 0.0
        if "bias" in vo:
            post += sum(m.vector_cycles("add", a.n_len * npos)
                        for a in asm_cols)
        if not multi:
            if relu_here:
                post += m.vector_cycles("relu", npos * N)
            post += m.vector_cycles("quant", npos * N)
        else:
            for a in asm_cols:
                if relu_here:
                    post += m.vector_cycles("relu", a.n_len * npos)
                post += m.vector_cycles("quant", a.n_len * npos)
                post += m.vector_cycles("mov", a.n_len * npos)
            for c in rep.cores[1:]:
                for a in cols_by_core[c]:
                    # sibling SEND + asm RECV + interleave mov
                    p.noc += 2 * m.send_issue_cycles(a.n_len * npos) \
                        * nrows
                    post += m.vector_cycles("mov", a.n_len * npos)
        p.vec += post * nrows

    # -- fused tail (once per sample, on the asm core) ---------------------
    has_side_op = "add" in vo or "mul" in vo
    side_pre = _side_pre_reduce(sched)
    o0, o1 = _owned_out_rows(cg, sched, rep)
    _, out_row_nb, _ = _out_geometry(cg, sched)
    if has_side_op:
        lo, hi, row_nb = (k0, k1, krow_nb) if side_pre \
            else (o0, o1, out_row_nb)
        if hi > lo:
            fn = "mul" if "mul" in vo else "add"
            p.vec += m.vector_cycles(fn, (hi - lo) * row_nb)
            if "relu" in vo and not relu_here:
                p.vec += m.vector_cycles("relu", (hi - lo) * row_nb)
    for vop in vo:
        # fused special tails (softmax/layernorm/gelu) on the asm core
        if vop in ("softmax", "layernorm", "gelu") and o1 > o0:
            p.vec += m.vector_cycles(vop, (o1 - o0) * out_row_nb)
    if sched.pool is not None:
        pl = sched.pool
        if sched.gap:
            plo, phi = _pooled_rows(cg, sched, rep)
        else:
            plo, phi = o0, o1
        per_row = (m.vector_cycles("zero", pl.wo * N)
                   + pl.k * pl.k * m.vector_cycles("max", pl.wo * N))
        p.vec += max(0, phi - plo) * per_row
    if sched.gap:
        if sched.pool is not None:
            plo, phi = _pooled_rows(cg, sched, rep)
            src_pos = max(0, phi - plo) * sched.pool.wo
        elif spec is not None:
            y0, y1 = _conv_rows_to_compute(cg, sched, rep)
            src_pos = max(0, y1 - y0) * spec.wo
        else:
            src_pos = max(0, rep.m_hi - rep.m_lo)
        p.vec += m.vector_cycles("zero", N)
        if src_pos:
            p.vec += m.vector_cycles("sum8", N * src_pos)
        if rep.replica == 0:
            others = len(sched.replicas) - 1
            p.noc += others * m.send_issue_cycles(N * 4)
            p.vec += others * m.vector_cycles("add", N)
            p.vec += m.vector_cycles("quant", N)
        else:
            p.send_issue += m.send_issue_cycles(N * 4)

    # -- delivery ----------------------------------------------------------
    consumers = [h for h in cg if g.idx in h.preds]
    boundary_out = (not consumers) or any(h.idx not in member
                                          for h in consumers)
    my_rows, my_row_nb, _ = _out_geometry(cg, sched)
    if not (sched.gap and rep.replica != 0):
        for h in consumers:
            if h.idx not in member:
                continue
            cons = by_gid[h.idx]
            hmain, _ = _main_and_skip_preds(cg, h, op_owner)
            for crep in cons.replicas:
                if hmain == g.idx:
                    c0, c1 = _needed_in_rows(
                        cg, cons, crep,
                        cons.im2col.h if cons.im2col is not None else 0)
                    crnb = _in_row_bytes(cons)
                    lo_b = max(o0 * my_row_nb, c0 * crnb)
                    hi_b = min(o1 * my_row_nb, c1 * crnb)
                    if hi_b <= lo_b:
                        continue
                    p.send_issue += len(crep.cores) \
                        * m.send_issue_cycles(hi_b - lo_b)
                    continue
                c0, c1, crow_nb = _side_rows(cg, cons, crep)
                if my_rows == 1 and (c1 - c0 != 1 or crow_nb != my_row_nb):
                    if c1 > c0 and o0 == 0 and o1 >= 1:
                        p.send_issue += m.send_issue_cycles(my_row_nb)
                    continue
                lo, hi = max(o0, c0), min(o1, c1)
                if hi > lo:
                    p.send_issue += m.send_issue_cycles(
                        (hi - lo) * out_row_nb)
        if boundary_out and o1 > o0:
            p.gst_bytes = (o1 - o0) * out_row_nb
    return p


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class TraceEngine:
    """Replays a :class:`PartitionResult` on the shared machine model."""

    def __init__(self, chip: ChipConfig,
                 calibration: Optional[Calibration] = None) -> None:
        self.chip = chip
        self.m = machine_for(chip, calibration)

    # -- gmem port booking -------------------------------------------------

    def _gmem(self, ports: List[float], nbytes: float, t0: float,
              streams: int = 1) -> float:
        """Book ``nbytes`` split over ``streams`` port streams."""
        if nbytes <= 0:
            return t0
        k = max(1, min(streams, len(ports)))
        per = self.m.gmem_stream_cycles(nbytes / k, ports=1) \
            * self.m.calib.gmem
        done = t0
        for j in sorted(range(len(ports)), key=ports.__getitem__)[:k]:
            start = max(t0, ports[j])
            ports[j] = start + per
            done = max(done, ports[j])
        return done

    # -- stage replay ------------------------------------------------------

    def _run_stage(self, cg: CondensedGraph, sp: StagePlan, batch: int,
                   op_owner: Dict[int, int], busy: Dict[str, float]
                   ) -> Tuple[float, int]:
        m, cal = self.m, self.m.calib
        schedules = plan_stage(cg, sp, self.chip)
        by_gid = {s.gid: s for s in schedules}
        member = set(sp.gids)
        profiles: Dict[Tuple[int, int], _Profile] = {}
        for sched in schedules:
            for ri, rep in enumerate(sched.replicas):
                profiles[(sched.gid, ri)] = _profile(
                    cg, sched, rep, by_gid, member, op_owner, m)

        ports = [0.0] * m.gmem_ports
        core_free: Dict[int, float] = {}

        # 1. weight prologue (round 0), replicas stream concurrently
        for sched in schedules:
            for ri, rep in enumerate(sched.replicas):
                p = profiles[(sched.gid, ri)]
                t0 = max((core_free.get(c, 0.0) for c in rep.cores),
                         default=0.0)
                t = self._gmem(ports, p.prologue_gld_bytes, t0,
                               streams=len(rep.cores))
                t += p.prologue_cim * cal.load
                for c in rep.cores:
                    core_free[c] = t

        # 2. unrolled sample loop, groups in stage (= topological) order
        fin: Dict[Tuple[int, int, int], float] = {}
        n_events = 0
        for s in range(batch):
            for sched in schedules:
                for ri, rep in enumerate(sched.replicas):
                    p = profiles[(sched.gid, ri)]
                    n_events += 1
                    t = max(core_free.get(c, 0.0) for c in rep.cores)
                    # input acquisition
                    if p.main_in_member:
                        prod = by_gid[p.main]
                        for pr in range(len(prod.replicas)):
                            src = profiles[(p.main, pr)].asm_core
                            hop = m.hops(src, p.asm_core)
                            arr = fin[(p.main, pr, s)] + cal.noc * (
                                hop * m.router_hop_cycles
                                + m.link_occupancy_cycles(p.in_nb))
                            t = max(t, arr)
                    elif p.in_nb:
                        t = self._gmem(ports, p.in_nb * len(rep.cores), t,
                                       streams=len(rep.cores))
                    for sgid, nbytes, in_stage in p.side_inputs:
                        if in_stage:
                            for pr in range(len(by_gid[sgid].replicas)):
                                arr = fin[(sgid, pr, s)] + cal.noc * (
                                    m.avg_hops * m.router_hop_cycles
                                    + m.link_occupancy_cycles(nbytes))
                                t = max(t, arr)
                        else:
                            t = self._gmem(ports, nbytes, t, streams=1)
                    # dynamic weights: producer handoff + per-sample
                    # gather/CIM-write staging (local memory, no gmem)
                    if p.dyn_w is not None:
                        wgid, w_nb, in_stage = p.dyn_w
                        # append-only cache: steady-state samples fetch
                        # one row and re-stage only the touched tiles
                        # (in-stage producers re-send the full buffer
                        # every sample, so incremental needs gmem src)
                        incr = p.dyn_w_incr and s > 0 and not in_stage
                        if in_stage:
                            for pr in range(len(by_gid[wgid].replicas)):
                                arr = fin[(wgid, pr, s)] + cal.noc * (
                                    m.avg_hops * m.router_hop_cycles
                                    + m.link_occupancy_cycles(w_nb))
                                t = max(t, arr)
                        elif w_nb:
                            nb = p.dyn_w_row_nb if incr else w_nb
                            t = self._gmem(ports, nb * len(rep.cores),
                                           t, streams=len(rep.cores))
                        gv = p.dyn_gather_vec_incr if incr \
                            else p.dyn_gather_vec
                        lc = p.dyn_load_cim_incr if incr \
                            else p.dyn_load_cim
                        t += gv * cal.vector + lc * cal.load
                        nc = len(rep.cores)
                        busy["vector"] = busy.get("vector", 0.0) \
                            + gv * nc
                        busy["cim"] = busy.get("cim", 0.0) \
                            + lc * nc
                    # per-sample weight re-streaming (streamed source)
                    rl_bytes = p.reload_gld_bytes_full if s \
                        else p.reload_gld_bytes_tail
                    rl_cim = p.reload_cim_full if s else p.reload_cim_tail
                    if rl_bytes:
                        t = self._gmem(ports, rl_bytes, t,
                                       streams=len(rep.cores))
                        t += rl_cim * cal.load
                    # decoupled unit pipelines: service = slowest unit
                    dt = max(p.cim * cal.cim, p.vec * cal.vector,
                             p.noc * cal.noc)
                    t_end = t + dt + p.send_issue * cal.noc
                    if p.gst_bytes:
                        t_end = self._gmem(ports, p.gst_bytes, t_end,
                                           streams=1)
                    fin[(sched.gid, ri, s)] = t_end
                    for c in rep.cores:
                        core_free[c] = t_end
                    nc = len(rep.cores)
                    busy["cim"] = busy.get("cim", 0.0) + p.cim * nc
                    busy["vector"] = busy.get("vector", 0.0) + p.vec * nc
                    busy["noc"] = busy.get("noc", 0.0) \
                        + p.noc + p.send_issue
        makespan = max(core_free.values(), default=0.0) * cal.makespan
        return makespan, n_events

    # -- public API --------------------------------------------------------

    def run(self, result: PartitionResult,
            batch: Optional[int] = None) -> TraceReport:
        batch = batch if batch is not None else result.params.batch
        cg = result.cg
        op_owner: Dict[int, int] = {}
        for g in cg:
            for i in g.op_ids:
                op_owner[i] = g.idx
        busy: Dict[str, float] = {}
        stage_cycles: List[float] = []
        n_events = 0
        for sp in result.stages:
            c, n = self._run_stage(cg, sp, batch, op_owner, busy)
            stage_cycles.append(c)
            n_events += n
        total = float(sum(stage_cycles))
        # event ledger: the analytic model's traffic/compute counts are
        # exact for the replayed schedule; only the static term follows
        # the traced makespan
        events = result.energy_events(batch)
        events["static_core_cycles"] = total * self.chip.n_cores
        return TraceReport(cycles=total, stage_cycles=stage_cycles,
                           events=events, unit_busy=busy,
                           n_events=n_events, table=self.m.energy_table)


def trace_model(result: PartitionResult, batch: Optional[int] = None,
                calibration: Optional[Calibration] = None) -> TraceReport:
    """One-shot trace evaluation of a partitioned model."""
    return TraceEngine(result.chip, calibration).run(result, batch)

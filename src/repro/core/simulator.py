"""Cycle-accurate simulator + functional ISS (paper §III-D).

Models the chip at instruction granularity:

* **Core pipeline** — in-order single-issue IF/DE (1 cycle/instr) feeding
  per-unit execution pipelines (CIM / vector / scalar / NoC); units are
  decoupled (double-buffered staging), so a core's steady-state interval is
  the *max* of its unit loads, matching the compiler's cost model.  RECV /
  SYNC / blocking sends are hard synchronization points.
* **NoC** — 2-D mesh, XY routing, wormhole-style link reservation: every
  directed link a flit stream crosses is occupied for ``flits`` cycles;
  contention emerges from link ``free_at`` times.  Per-hop router latency.
* **Global memory** — ``ports`` concurrent streams at
  ``global_mem_bytes_per_cycle`` each; transfers pick the earliest-free
  port (bandwidth contention across cores).
* **Energy** — every instruction deposits events into the same ledger the
  analytic model uses (:mod:`repro.core.energy` prices them).
* **Functional mode** (``mode="func"``) — additionally executes full data
  semantics: int8 local memories, macro-group weight arrays, INT32 MVM
  accumulation, requantization, strided vector ops, real SEND/RECV payloads
  and the global-memory image.  This is the ISS used to validate compiled
  programs bit-exactly against the JAX INT8 oracle.

The simulator executes each *stage*'s programs to completion (all cores
HALT) and sums stage makespans — the sequential-stage execution model the
partitioner optimizes for.

Perf mode runs on the pre-decoded vectorized engine by default
(:mod:`repro.core.vectorsim`): programs decode once into numpy tables,
basic blocks replay as unit-run sums, and only the shared-state
instructions (SEND / RECV / GLD / GST / SYNC / HALT) execute through the
scheduler — cycle-, event- and busy-identical to this interpreter at a
fraction of the wall time (see ``benchmarks/bench_sim.py``).  The
``engine`` parameter pins a path explicitly; functional mode always
interprets.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import vecsem, vectorsim
from .arch import ChipConfig
from .codegen import GMEM_BASE, CompiledModel, StageProgram
from .energy import DEFAULT_TABLE, EnergyTable, energy_breakdown
from .isa import FLAGS, Instr, Isa, Program, SREG, VFUNCT
from .machine import MachineModel, machine_for

__all__ = ["Simulator", "SimReport", "SimError", "ENGINES"]

# Perf-mode execution engines: "vector" replays pre-decoded basic blocks
# (see :mod:`repro.core.vectorsim`), "scalar" interprets one instruction
# at a time, "auto" vectorizes when the program is statically decodable
# and falls back to the interpreter otherwise, and "jax" runs the
# decode's dataflow/latency passes as one jitted XLA program per
# decode-table shape (see :mod:`repro.core.jaxsim`) — bit-identical to
# "vector"/"scalar", with the same scalar fallback as "auto" for
# programs outside the decodable subset.  ``mode="func"`` always
# interprets (data semantics are inherently per-instruction).
ENGINES = ("auto", "vector", "scalar", "jax")


class SimError(RuntimeError):
    pass


class Deadlock(SimError):
    pass


@dataclass
class SimReport:
    cycles: float
    stage_cycles: List[float]
    events: Dict[str, float]
    unit_busy: Dict[str, float]           # unit -> total busy cycles
    instrs: int
    gmem: Optional[np.ndarray] = None     # functional mode: final image
    # pricing table the machine model attached (shared across fidelities)
    table: EnergyTable = DEFAULT_TABLE

    def energy(self, table: Optional[EnergyTable] = None
               ) -> Dict[str, float]:
        return energy_breakdown(self.events, table or self.table)

    def utilization(self, chip: ChipConfig) -> Dict[str, float]:
        denom = self.cycles * chip.n_cores
        return {u: b / denom for u, b in sorted(self.unit_busy.items())}

    def summary(self) -> str:
        e = self.energy()
        return (f"{self.cycles:.0f} cycles, {self.instrs} instrs, "
                f"{e['total'] / 1e6:.3f} mJ "
                f"(compute {100 * e['compute'] / e['total']:.0f}%, "
                f"noc {100 * e['noc'] / e['total']:.0f}%, "
                f"gmem {100 * e['gmem'] / e['total']:.0f}%, "
                f"static {100 * e['static'] / e['total']:.0f}%)")


# ---------------------------------------------------------------------------
# Per-core state
# ---------------------------------------------------------------------------


@dataclass
class _MgState:
    w: Optional[np.ndarray]     # (rows, n_len) int8, functional mode only
    rows: int
    n_len: int
    k_off: int
    n_off: int


class _Core:
    def __init__(self, core_id: int, prog: Program, chip: ChipConfig,
                 func: bool) -> None:
        self.id = core_id
        self.prog = prog
        self.pc = 0
        self.time = 0.0
        self.halted = False
        self.blocked = False
        self.gregs = np.zeros(32, dtype=np.int64)
        self.sregs = np.zeros(64, dtype=np.int64)
        self.sregs[SREG["ACC_DIV"]] = 1
        self.unit_free: Dict[str, float] = {}
        self.mgs: Dict[int, _MgState] = {}
        # functional-mode local memory is allocated lazily on first
        # access: a core whose program never loads/stores (or a wide
        # chip's mostly-idle cores) pays nothing
        self._func = func
        self._lmem_bytes = chip.core.local_mem.size_bytes
        self._lmem: Optional[np.ndarray] = None

    @property
    def lmem(self) -> Optional[np.ndarray]:
        if self._lmem is None and self._func:
            self._lmem = np.zeros(self._lmem_bytes, dtype=np.int8)
        return self._lmem

    def sreg(self, name: str) -> int:
        return int(self.sregs[SREG[name]])


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------


class Simulator:
    def __init__(self, chip: ChipConfig, isa: Isa, mode: str = "perf",
                 max_cycles: float = 5e9, engine: str = "auto",
                 faults: Optional[object] = None) -> None:
        if mode not in ("perf", "func"):
            raise ValueError(mode)
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, "
                             f"got {engine!r}")
        if engine in ("vector", "jax") and mode == "func":
            raise ValueError("functional mode requires the scalar "
                             "engine (engine='auto' or 'scalar')")
        self.chip = chip
        # the one source of timing/bandwidth/energy rules — shared with
        # the analytic cost model and the trace fidelity
        self.m: MachineModel = machine_for(chip)
        self.isa = isa
        self.func = mode == "func"
        self.engine = engine
        self.max_cycles = max_cycles
        # physical CIM-array fault injection (functional mode): any
        # object with corrupt_loaded(core_id, mg, w) -> w', typically a
        # repro.faults.PhysicalCimFaults.  None = fault-free (exact
        # no-op; perf-mode timing never depends on it).
        self.faults = faults
        self._vfunct_names = {v: k for k, v in VFUNCT.items()}

    # -- public API ------------------------------------------------------------

    def run_model(self, model: CompiledModel,
                  gmem_image: Optional[np.ndarray] = None) -> SimReport:
        if self.func and gmem_image is None:
            raise SimError("functional mode requires a gmem image")
        gmem = None
        if gmem_image is not None:
            gmem = np.zeros(model.layout.size, dtype=np.int8)
            gmem[:gmem_image.size] = gmem_image
        events: Dict[str, float] = {}
        busy: Dict[str, float] = {}
        stage_cycles: List[float] = []
        instrs = 0
        vectorize = not self.func and self.engine != "scalar"
        if self.engine == "jax":
            from . import jaxsim           # lazy: jax is heavyweight
            stage_fn = jaxsim.run_stage
        else:
            stage_fn = vectorsim.run_stage
        for sp in model.stages:
            out = stage_fn(self, sp) if vectorize else None
            if out is None:
                if self.engine == "vector":
                    raise SimError(
                        "engine='vector': stage program is not "
                        "statically decodable (branches / scalar-ALU "
                        "register chains / custom ops)")
                out = self._run_stage(sp, gmem)
            c, ev, bz, n = out
            stage_cycles.append(c)
            instrs += n
            for k, v in ev.items():
                events[k] = events.get(k, 0.0) + v
            for k, v in bz.items():
                busy[k] = busy.get(k, 0.0) + v
        total = float(sum(stage_cycles))
        events["static_core_cycles"] = total * self.chip.n_cores
        return SimReport(cycles=total, stage_cycles=stage_cycles,
                         events=events, unit_busy=busy, instrs=instrs,
                         gmem=gmem, table=self.m.energy_table)

    # -- stage loop --------------------------------------------------------------

    def _run_stage(self, sp: StageProgram, gmem: Optional[np.ndarray]):
        chip = self.chip
        cores = {cid: _Core(cid, prog, chip, self.func)
                 for cid, prog in sp.programs.items()}
        self._gmem = gmem
        self._events: Dict[str, float] = {}
        self._busy: Dict[str, float] = {}
        self._instrs = 0
        # NoC / gmem shared state
        self._links: Dict[Tuple[int, int], float] = {}
        self._ports = [0.0] * self.m.gmem_ports
        self._chan: Dict[Tuple[int, int], deque] = {}
        self._barriers: Dict[int, List[_Core]] = {}

        pending = [c for c in cores.values() if len(c.prog) > 0]
        while True:
            ready = [c for c in pending if not c.halted and not c.blocked]
            if not ready:
                if all(c.halted for c in pending):
                    break
                blocked = [c.id for c in pending if c.blocked]
                raise Deadlock(f"cores {blocked} blocked "
                               f"(recv/sync with no sender)")
            core = min(ready, key=lambda c: c.time)
            self._step(core, cores)
            if core.time > self.max_cycles:
                raise SimError("max_cycles exceeded")
        makespan = max((c.time for c in cores.values()), default=0.0)
        return makespan, self._events, self._busy, self._instrs

    # -- helpers -----------------------------------------------------------------

    def _ev(self, key: str, amount: float) -> None:
        self._events[key] = self._events.get(key, 0.0) + amount

    # NOTE: _use/_route_delay/_gmem_xfer have line-for-line ports in
    # repro.core.vectorsim (boundary handlers) — keep them in sync or
    # the engines diverge on shapes outside the pinned goldens.
    def _use(self, core: _Core, unit: str, latency: float) -> float:
        """Issue on a unit: in-order issue, decoupled unit pipelines."""
        t_issue = max(core.time + 1.0, core.unit_free.get(unit, 0.0))
        core.unit_free[unit] = t_issue + latency
        self._busy[unit] = self._busy.get(unit, 0.0) + latency
        core.time = t_issue
        return t_issue + latency

    def _sync(self, core: _Core, t: float) -> None:
        core.time = max(core.time, t)

    def _route_delay(self, src: int, dst: int, nbytes: int,
                     t_start: float) -> float:
        """Wormhole transfer: reserve each link on the XY route."""
        m = self.m
        occupy = m.link_occupancy_cycles(nbytes)
        t = t_start + m.inject_cycles
        if src == dst:
            return t + occupy
        for link in m.route(src, dst):
            t = max(t, self._links.get(link, 0.0)) + m.router_hop_cycles
            self._links[link] = t + occupy
        self._ev("noc_byte_hops", nbytes * m.hops(src, dst))
        return t + occupy

    def _gmem_xfer(self, nbytes: int, t_start: float) -> float:
        """Pick earliest-free gmem port."""
        i = min(range(len(self._ports)), key=lambda j: self._ports[j])
        t0 = max(t_start, self._ports[i])
        t1 = t0 + self.m.gmem_stream_cycles(nbytes, ports=1)
        self._ports[i] = t1
        self._ev("gmem_bytes", nbytes)
        return t1

    # -- instruction dispatch ------------------------------------------------------

    def _step(self, core: _Core, cores: Dict[int, "_Core"]) -> None:
        if core.pc >= len(core.prog):
            core.halted = True
            return
        ins = core.prog.instrs[core.pc]
        self._instrs += 1
        d = self.isa[ins.op]
        name, unit = ins.op, d.unit
        a = ins.args
        G, S = core.gregs, core.sregs

        if name == "HALT":
            core.pc += 1
            core.time += 1
            core.halted = True
            return
        if name == "NOP":
            core.pc += 1
            self._use(core, "scalar", 1)
            return

        # ---- scalar / control -------------------------------------------------
        if name == "S_ADDI":
            self._use(core, "scalar", self.m.scalar_alu_cycles)
            if a["dst"]:
                G[a["dst"]] = G[a["a"]] + a["imm"]
        elif name == "S_LUI":
            self._use(core, "scalar", self.m.scalar_alu_cycles)
            if a["dst"]:
                G[a["dst"]] = (a["imm"] & 0xFFFF) << 16
        elif name.startswith("S_") and name not in ("S_LD", "S_ST"):
            self._use(core, "scalar",
                      self.m.scalar_mul_cycles if name == "S_MUL"
                      else self.m.scalar_alu_cycles)
            if a.get("dst"):
                x, y = int(G[a["a"]]), int(G[a["b"]])
                G[a["dst"]] = {
                    "S_ADD": x + y, "S_SUB": x - y, "S_MUL": x * y,
                    "S_AND": x & y, "S_OR": x | y, "S_XOR": x ^ y,
                    "S_SLT": int(x < y), "S_SLL": x << (y & 31),
                    "S_SRL": (x & 0xFFFFFFFF) >> (y & 31),
                }[name]
        elif name in ("S_LD", "S_ST"):
            self._use(core, "scalar", self.m.scalar_ldst_cycles)
            if self.func:
                addr = int(G[a["base"]]) + a["off"]
                lm32 = core.lmem.view(np.int32)
                if name == "S_LD":
                    G[a["dst"]] = int(lm32[addr // 4])
                else:
                    lm32[addr // 4] = np.int32(G[a["src"]])
            self._ev("lmem_bytes", 4)
        elif name in ("BEQ", "BNE", "BLT"):
            x, y = int(G[a["a"]]), int(G[a["b"]])
            taken = {"BEQ": x == y, "BNE": x != y, "BLT": x < y}[name]
            self._use(core, "scalar", self.m.branch_cycles(taken))
            if taken:
                core.pc += a["off"]
                return
        elif name == "JAL":
            self._use(core, "scalar", self.m.branch_cycles(True))
            G[31] = core.pc + 1
            core.pc += a["off"]
            return

        # ---- CIM config -----------------------------------------------------------
        elif name == "CIM_CFG":
            self._use(core, "scalar", 1)
            S[a["sreg"]] = a["imm"]
        elif name == "CIM_CFGR":
            self._use(core, "scalar", 1)
            S[a["sreg"]] = G[a["src"]]

        # ---- CIM compute ------------------------------------------------------------
        elif name == "CIM_LOAD":
            rows = a["rows"]
            n_len = core.sreg("MG_NLEN")
            self._use(core, "cim", self.m.weight_load_cycles(rows))
            self._ev("cim_weight_load_bytes", rows * max(n_len, 1))
            self._ev("lmem_bytes", rows * max(n_len, 1))
            w = None
            if self.func:
                src = int(G[a["src"]])
                w = core.lmem[src:src + rows * n_len] \
                    .reshape(rows, n_len).copy()
                if self.faults is not None:
                    # the array's stuck bits corrupt whatever the
                    # compiler latches into it
                    w = self.faults.corrupt_loaded(core.id, a["mg"], w)
            core.mgs[a["mg"]] = _MgState(
                w=w, rows=rows, n_len=n_len,
                k_off=core.sreg("MG_KOFF"), n_off=core.sreg("MG_NOFF"))
        elif name == "CIM_MVM":
            rep = a["rep"]
            mask = (core.sreg("MG_MASK_LO") & 0xFFFF) \
                | (core.sreg("MG_MASK_HI") << 16)
            active = [core.mgs[i] for i in core.mgs if mask & (1 << i)]
            self._use(core, "cim", self.m.mvm_cycles(rep))
            seg_in = core.sreg("MVM_SEG_IN")
            seg_out = core.sreg("MVM_SEG_OUT")
            self._ev("cim_macro_passes",
                     rep * len(active) * self.m.macros_per_group)
            self._ev("lmem_bytes", rep * (seg_in + seg_out))
            if self.func and active:
                src, dst = int(G[a["src"]]), int(G[a["dst"]])
                lm = core.lmem
                lm32 = lm.view(np.int32)
                for t in range(rep):
                    obase = dst + t * seg_out
                    oview = lm32[obase // 4: obase // 4 + seg_out // 4]
                    if not (a.get("acc", 0) & 1):
                        oview[:] = 0
                    ibase = src + t * seg_in
                    for mg in active:
                        x = lm[ibase + mg.k_off: ibase + mg.k_off
                               + mg.rows].astype(np.int32)
                        y = x @ mg.w.astype(np.int32)
                        oview[mg.n_off: mg.n_off + mg.n_len] += y

        # ---- vector ---------------------------------------------------------------
        elif unit == "vector":
            self._exec_vector(core, ins)

        # ---- communication ----------------------------------------------------------
        elif name == "SEND":
            dst_core = int(G[a["core"]])
            src = int(G[a["src"]])
            size = int(G[a["size"]])
            stream = core.sreg("CHANNEL")
            done = self._use(core, "noc", self.m.send_issue_cycles(size))
            arrival = self._route_delay(core.id, dst_core, size, done)
            data = None
            if self.func:
                data = core.lmem[src:src + size].copy()
            self._chan.setdefault((core.id, dst_core, stream),
                                  deque()).append((arrival, size, data))
            self._ev("lmem_bytes", size)
            self._unblock(cores.get(dst_core))
        elif name == "RECV":
            src_core = int(G[a["core"]])
            dst = int(G[a["dst"]])
            size = int(G[a["size"]])
            stream = core.sreg("CHANNEL")
            q = self._chan.get((src_core, core.id, stream))
            if not q:
                core.blocked = True
                return                       # retry when a SEND arrives
            arrival, msize, data = q.popleft()
            if msize != size:
                raise SimError(
                    f"recv size mismatch {src_core}->{core.id}"
                    f"#{stream}: expected {size}, got {msize}")
            self._sync(core, arrival)
            self._use(core, "noc", self.m.send_issue_cycles(size))
            if self.func:
                core.lmem[dst:dst + size] = data
            self._ev("lmem_bytes", size)
        elif name == "BCAST":
            size = int(G[a["size"]])
            self._use(core, "noc", self.m.send_issue_cycles(size))
        elif name == "SYNC":
            bid = a["barrier"]
            group = self._barriers.setdefault(bid, [])
            if core not in group:
                group.append(core)
            n_need = len([c for c in cores.values()])
            if len(group) < n_need:
                core.blocked = True
                return
            t = max(c.time for c in group) + 1
            for c in group:
                c.time = t
                c.blocked = False
                if c is not core:
                    c.pc += 1
            self._barriers[bid] = []
        elif name == "GLD":
            gaddr = int(G[a["gaddr"]])
            dst = int(G[a["dst"]])
            size = int(G[a["size"]])
            done = self._gmem_xfer(size, core.time + 1)
            self._use(core, "noc", max(1.0, done - core.time - 1))
            self._ev("lmem_bytes", size)
            if self.func:
                off = gaddr - GMEM_BASE
                core.lmem[dst:dst + size] = self._gmem[off:off + size]
        elif name == "GST":
            gaddr = int(G[a["gaddr"]])
            src = int(G[a["src"]])
            size = int(G[a["size"]])
            done = self._gmem_xfer(size, core.time + 1)
            self._use(core, "noc", max(1.0, done - core.time - 1))
            self._ev("lmem_bytes", size)
            if self.func:
                off = gaddr - GMEM_BASE
                self._gmem[off:off + size] = core.lmem[src:src + size]
        else:
            raise SimError(f"unhandled instruction {name}")

        core.pc += 1

    def _unblock(self, core: Optional[_Core]) -> None:
        if core is not None and core.blocked:
            core.blocked = False

    # -- vector execution ----------------------------------------------------------

    def _exec_vector(self, core: _Core, ins: Instr) -> None:
        name = ins.op
        if name == "V_SETVL":
            self._use(core, "scalar", 1)
            core.sregs[SREG["VLEN"]] = ins.args["len"]
            return
        fn = name[2:].lower()
        vlen = max(1, core.sreg("VLEN"))
        rep = max(1, core.sreg("V_REP"))
        n = vlen * rep
        self._use(core, "vector", self.m.vector_cycles(fn, n))
        self._ev("vector_elems", n)
        flags = ins.args.get("flags", 0)
        i8 = bool(flags & FLAGS["i8"])
        esz = 1 if i8 else 4
        self._ev("lmem_bytes", n * esz * 2)
        if not self.func:
            return

        G, S = core.gregs, core.sregs
        lm = core.lmem
        dst, a_, b_ = int(G[ins.args["dst"]]), int(G[ins.args["a"]]), \
            int(G[ins.args["b"]])
        sd, sa, sb = core.sreg("VSEG_D"), core.sreg("VSEG_A"), \
            core.sreg("VSEG_B")
        td, ta, tb = max(1, core.sreg("VSTRIDE_D")), \
            max(1, core.sreg("VSTRIDE_A")), max(1, core.sreg("VSTRIDE_B"))

        lane = np.arange(vlen, dtype=np.int64)
        reps = np.arange(rep, dtype=np.int64)

        def idx(base: int, seg: int, stride: int, sz: int) -> np.ndarray:
            # element indices for (rep, vlen), in elements of ``sz`` bytes
            return ((base + reps[:, None] * seg) // sz
                    + lane[None, :] * stride)

        if fn == "zero":
            view = lm if i8 else lm.view(np.int32)
            view[idx(dst, sd, td, esz)] = 0
            return

        if fn == "quant":
            # int32 src -> int8 dst
            x = lm.view(np.int32)[idx(a_, sa, ta, 4)].astype(np.int64)
            scale = core.sreg("Q_SCALE")
            shift = core.sreg("Q_SHIFT")
            div = max(1, core.sreg("ACC_DIV"))
            zero = core.sreg("Q_ZERO")
            den = div << shift
            q = (x * scale + (den >> 1)) // den + zero
            lm[idx(dst, sd, td, 1)] = \
                np.clip(q, -128, 127).astype(np.int8)
            return
        if fn == "sum8":
            # int8 src accumulates into int32 dst
            acc = lm.view(np.int32)
            x = lm[idx(a_, sa, ta, 1)].astype(np.int32)
            di = idx(dst, sd, td, 4)
            if sd == 0 and td == 1:
                acc[di[0]] += x.sum(axis=0)
            else:
                for t in range(rep):
                    acc[di[t]] += x[t]
            return

        di = idx(dst, sd, td, esz)
        ai = idx(a_, sa, ta, esz)
        if fn == "mov":
            view = lm if i8 else lm.view(np.int32)
            view[di] = view[ai]
            return
        if fn == "relu":
            view = lm if i8 else lm.view(np.int32)
            view[di] = np.maximum(view[ai], 0)
            return
        if fn in ("softmax", "layernorm", "gelu"):
            # transformer tails: int8 row-segment semantics shared with
            # the oracle through repro.core.vecsem (bit-exact contract)
            if not i8:
                raise SimError(f"functional mode: {fn} requires int8 "
                               f"operands")
            x = lm[ai]                       # (rep, vlen) row segments
            lm[di] = {"softmax": vecsem.softmax_i8,
                      "layernorm": vecsem.layernorm_i8,
                      "gelu": vecsem.gelu_i8}[fn](x)
            return

        bi = idx(b_, sb, tb, esz)
        if i8:
            x = lm[ai].astype(np.int16)
            y = lm[bi].astype(np.int16)
        else:
            v32 = lm.view(np.int32)
            x = v32[ai].astype(np.int64)
            y = v32[bi].astype(np.int64)
        if fn == "add":
            z = x + y
        elif fn == "sub":
            z = x - y
        elif fn == "mul":
            z = x * y
        elif fn == "max":
            z = np.maximum(x, y)
        elif fn == "min":
            z = np.minimum(x, y)
        else:
            raise SimError(f"functional mode: vector op {fn!r} "
                           f"not implemented (perf-only LUT op)")
        if i8:
            lm[di] = np.clip(z, -128, 127).astype(np.int8)
        else:
            lm.view(np.int32)[di] = \
                np.clip(z, -2**31, 2**31 - 1).astype(np.int32)

"""Shared integer semantics for data-dependent vector ops.

The functional ISS (:mod:`repro.core.simulator`) and the numpy oracle
(:mod:`repro.core.ref`) must agree *bit-exactly* on every operation a
compiled program performs.  For relu / add / quant that contract is a
few lines of saturating int8 arithmetic; the transformer ops —
softmax, layernorm, gelu — need a fixed-point definition that both
sides share, so it lives here and is imported by both.

The definitions are LUT/shift arithmetic a digital CIM vector unit can
realize:

* ``softmax_i8``  — per row segment: ``e = EXP2_LUT[max(x) - x]``
  (Q14 table of ``2^(-d/16)``), output ``round(127·e / Σe)``;
* ``layernorm_i8`` — per row: n-scaled deviations ``d = n·x - Σx``,
  integer RMS via exact ``isqrt``, output ``round(G·d / rms)`` with
  gain ``G = 48`` (≈ 2.6σ of headroom in int8);
* ``gelu_i8``     — 256-entry LUT at 1/16-unit input scale.

Also provides :func:`dynamic_weight_matrix`, the one definition of how
a *dynamic* weight operand (a predecessor op's activations — see the
weight-source abstraction in :mod:`repro.core.graph`) maps onto the
block-diagonal ``(K_total, N_total)`` CIM layout.  Codegen's gather
V_MOVs, the functional ISS and the oracle all follow this layout.
"""

from __future__ import annotations

import numpy as np

__all__ = ["softmax_i8", "layernorm_i8", "gelu_i8",
           "dynamic_weight_matrix", "EXP2_LUT", "GELU_LUT", "LN_GAIN"]

# EXP2_LUT[d] = round(2^14 · 2^(-d/16)) for d = max(x) - x in [0, 255]:
# a 16-th-of-a-unit exponent step keeps 8 input units of dynamic range.
EXP2_LUT = np.round(
    2.0 ** 14 * 2.0 ** (-np.arange(256, dtype=np.float64) / 16.0)
).astype(np.int64)

# GELU on int8 at 1/16-unit input scale: y = round(v · Φ(v/16))
# (tanh approximation), clipped to int8.
_v = np.arange(-128, 128, dtype=np.float64)
_t = _v / 16.0
_phi = 0.5 * (1.0 + np.tanh(0.7978845608028654
                            * (_t + 0.044715 * _t ** 3)))
GELU_LUT = np.clip(np.round(_v * _phi), -128, 127).astype(np.int8)
del _v, _t, _phi

LN_GAIN = 48          # layernorm output scale (target std in int8 units)


def softmax_i8(x: np.ndarray) -> np.ndarray:
    """Row-wise integer softmax: int8 ``(..., n)`` → int8 in [0, 127]."""
    xi = x.astype(np.int64)
    d = np.clip(xi.max(axis=-1, keepdims=True) - xi, 0, 255)
    e = EXP2_LUT[d]
    s = e.sum(axis=-1, keepdims=True)
    y = (127 * e + (s >> 1)) // s
    return np.clip(y, 0, 127).astype(np.int8)


def _isqrt(v: np.ndarray) -> np.ndarray:
    """Exact elementwise floor-sqrt of non-negative int64."""
    r = np.sqrt(v.astype(np.float64)).astype(np.int64)
    r = np.where(r * r > v, r - 1, r)            # float64 sqrt is within
    r = np.where((r + 1) * (r + 1) <= v, r + 1, r)   # ±1 ulp of exact
    return np.maximum(r, 0)


def layernorm_i8(x: np.ndarray) -> np.ndarray:
    """Row-wise integer layernorm: int8 ``(..., n)`` → int8."""
    xi = x.astype(np.int64)
    n = x.shape[-1]
    s = xi.sum(axis=-1, keepdims=True)
    d = n * xi - s                               # n-scaled deviation
    ss = (d * d).sum(axis=-1, keepdims=True)
    r = _isqrt(ss // n) + 1                      # n-scaled RMS (+1: /0)
    y = (2 * LN_GAIN * d + r) // (2 * r)         # round-half-up
    return np.clip(y, -128, 127).astype(np.int8)


def gelu_i8(x: np.ndarray) -> np.ndarray:
    """Elementwise int8 GELU through the shared LUT."""
    return GELU_LUT[x.astype(np.int16) + 128]


def dynamic_weight_matrix(buf: np.ndarray, gemm_k: int, gemm_n: int,
                          groups: int, transpose: bool) -> np.ndarray:
    """Producer activations → block-diagonal ``(K_total, N_total)`` int8.

    ``buf`` is the weight producer's per-sample output in its natural
    row layout — ``(rows, groups·gemm_k)`` when ``transpose`` (Q·Kᵀ:
    rows are sequence positions, per-head channels become weight rows)
    or ``(gemm_k, groups·gemm_n)`` otherwise (P·V: rows are weight
    rows directly).
    """
    w = gemm_k if transpose else gemm_n
    b = np.asarray(buf).reshape(-1, groups * w)
    W = np.zeros((groups * gemm_k, groups * gemm_n), dtype=np.int8)
    for gi in range(groups):
        blk = b[:, gi * w:(gi + 1) * w]
        W[gi * gemm_k:(gi + 1) * gemm_k,
          gi * gemm_n:(gi + 1) * gemm_n] = blk.T if transpose else blk
    return W

"""Search strategies over a :class:`DesignSpace`.

All strategies consume an :class:`ExplorationEngine` (so caching and
pool parallelism apply transparently) and minimize an *objective* — any
``EvalRecord -> float``.  Stock objectives: :func:`by_cycles`,
:func:`by_energy`, :func:`by_edp`.

* :func:`grid_search` — exhaustive enumeration of the valid grid.
* :func:`random_search` — uniform sampling without replacement.
* :func:`hill_climb` — restarted stochastic hill-climbing: batches of
  mutated neighbors per step (batch evaluation keeps the pool busy),
  move to the best improving neighbor, restart from a fresh random
  point at local optima.
* :func:`successive_halving` — the two-fidelity mode: screen every
  candidate with the analytic cost model, then promote only the top-K
  survivors to the cycle-accurate simulator.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .engine import ExplorationEngine
from .records import EvalRecord
from .space import DesignPoint, DesignSpace

__all__ = [
    "by_cycles", "by_energy", "by_edp", "SearchResult",
    "grid_search", "random_search", "hill_climb", "successive_halving",
]

Objective = Callable[[EvalRecord], float]


def by_cycles(r: EvalRecord) -> float:
    return r.cycles


def by_energy(r: EvalRecord) -> float:
    return r.energy_total


def by_edp(r: EvalRecord) -> float:
    return r.edp


@dataclass
class SearchResult:
    """Best record found plus the full evaluation trace."""

    best: EvalRecord
    history: List[EvalRecord] = field(default_factory=list)
    n_evals: int = 0

    @property
    def best_point(self) -> DesignPoint:
        return self.best.point


def _pick_best(records: Sequence[EvalRecord],
               objective: Objective) -> EvalRecord:
    if not records:
        raise ValueError("no records to pick from")
    return min(records, key=objective)


def grid_search(engine: ExplorationEngine, space: DesignSpace,
                objective: Objective = by_edp,
                fidelity: Optional[str] = None) -> SearchResult:
    recs = engine.sweep(space, fidelity)
    return SearchResult(best=_pick_best(recs, objective), history=recs,
                        n_evals=len(recs))


def random_search(engine: ExplorationEngine, space: DesignSpace,
                  n: int, objective: Objective = by_edp, seed: int = 0,
                  fidelity: Optional[str] = None) -> SearchResult:
    pts = space.sample(n, seed=seed)
    recs = engine.evaluate(pts, fidelity)
    return SearchResult(best=_pick_best(recs, objective), history=recs,
                        n_evals=len(recs))


def hill_climb(engine: ExplorationEngine, space: DesignSpace,
               objective: Objective = by_edp, seed: int = 0,
               iters: int = 24, neighbors: int = 4, restarts: int = 2,
               fidelity: Optional[str] = None) -> SearchResult:
    """Restarted stochastic hill-climbing with batched neighbor evals.

    ``iters`` is the *total* step budget across all restarts; each step
    evaluates up to ``neighbors`` distinct mutations of the incumbent
    (one pool batch).  Previously-seen points are skipped — with the
    engine's cache they would be free anyway, but skipping keeps the
    step budget meaningful on small spaces.
    """
    rng = random.Random(seed)
    history: List[EvalRecord] = []
    seen: Dict[DesignPoint, EvalRecord] = {}

    def eval_points(pts: Sequence[DesignPoint]) -> List[EvalRecord]:
        fresh = [p for p in pts if p not in seen]
        for rec in engine.evaluate(fresh, fidelity):
            seen[rec.point] = rec
            history.append(rec)
        return [seen[p] for p in pts]

    best: Optional[EvalRecord] = None
    steps = 0
    for _ in range(max(1, restarts)):
        cur = eval_points([space.random_point(rng)])[0]
        if best is None or objective(cur) < objective(best):
            best = cur
        while steps < iters:
            steps += 1
            cand: List[DesignPoint] = []
            for _ in range(neighbors * 4):
                m = space.mutate(cur.point, rng)
                if m != cur.point and m not in cand:
                    cand.append(m)
                if len(cand) >= neighbors:
                    break
            if not cand:
                break
            recs = eval_points(cand)
            step_best = _pick_best(recs, objective)
            if objective(step_best) < objective(cur):
                cur = step_best
                if objective(cur) < objective(best):
                    best = cur
            else:
                break               # local optimum -> restart
        if steps >= iters:
            break
    assert best is not None
    return SearchResult(best=best, history=history,
                        n_evals=len(history))


def successive_halving(engine: ExplorationEngine,
                       points_or_space, top_k: int = 4,
                       objective: Objective = by_edp,
                       screen_fidelity: str = "analytic",
                       calibrate: int = 0,
                       ) -> Tuple[SearchResult, List[EvalRecord]]:
    """Two-fidelity screening: cheap everywhere, simulate the top-K.

    ``screen_fidelity`` picks the cheap rung (``"analytic"`` or
    ``"trace"``).  With ``calibrate=N > 0`` the screen runs twice: a
    raw pass picks N representative points, the engine fits per-unit
    correction factors from their simulator runs
    (:meth:`ExplorationEngine.calibrate`), and the *calibrated* screen
    decides the promotions — the fix for cheap-model mis-rankings on
    communication-heavy workloads (the resnet18@112 ~10x gap).

    Returns ``(result, screened)`` where ``result`` ranks only the
    simulator-validated survivors and ``screened`` holds the final
    cheap-fidelity pass (for Pareto plots of the whole space).
    """
    if isinstance(points_or_space, DesignSpace):
        points = points_or_space.points()
    else:
        points = list(points_or_space)
    screened = engine.evaluate(points, fidelity=screen_fidelity)
    n_evals = len(screened)
    if calibrate > 0:
        ranked = sorted(screened, key=objective)
        anchors = [r.point for r in ranked[:calibrate] if r.ok]
        if anchors:
            engine.calibrate(anchors, fidelity=screen_fidelity,
                             max_points=calibrate)
            n_evals += len(anchors)     # one simulator run per anchor
            screened = engine.evaluate(points,
                                       fidelity=screen_fidelity)
            n_evals += len(screened)
    ranked = sorted(screened, key=objective)
    survivors = [r.point for r in ranked[:max(1, top_k)]]
    promoted = engine.evaluate(survivors, fidelity="simulate")
    res = SearchResult(best=_pick_best(promoted, objective),
                       history=promoted,
                       n_evals=n_evals + len(promoted))
    return res, screened

"""Content-addressed on-disk cache for evaluation results.

The cache key is the SHA-256 of a canonical-JSON description of
everything that determines an evaluation's outcome: the workload
(model name + geometry), the full ``ChipConfig`` dict, the compile
strategy, the cost-model parameters, and the fidelity.  Identical
(model, chip, strategy, mode) re-runs — and overlapping sweeps from
*different* drivers — therefore share entries and are free.

Entries are JSON files sharded by key prefix (``<root>/ab/<key>.json``)
and written atomically (tmp + rename) so concurrent pool workers and
concurrent sweeps never observe torn files.

Eviction: entries are never aged out automatically, but a cache
constructed with ``max_age_days`` / ``max_entries`` (or given them at
call time) can be compacted with :meth:`ResultCache.prune` — drop
entries older than the age limit (file mtime), then the oldest entries
beyond the count limit.  ``python -m repro.explore cache prune`` wires
this to the command line; pruning is safe alongside running sweeps
(``put`` retries when its shard directory is concurrently removed,
readers tolerate vanished files).
"""

from __future__ import annotations

import bisect
import dataclasses
import hashlib
import json
import os
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

from ..core.arch import ChipConfig
from ..core.mapping import CostParams

__all__ = ["ResultCache", "default_cache_dir", "cache_key"]

_ENV_VAR = "REPRO_EXPLORE_CACHE"
_SCHEMA_VERSION = 1


def default_cache_dir() -> str:
    return os.environ.get(_ENV_VAR,
                          os.path.join("results", "explore_cache"))


def cache_key(model: str, chip: ChipConfig, strategy: str,
              fidelity: str, params: Optional[CostParams] = None,
              **extra: Any) -> str:
    """Deterministic content hash of one evaluation's full inputs."""
    desc: Dict[str, Any] = {
        "v": _SCHEMA_VERSION,
        "model": model,
        "chip": chip.to_dict(),
        "strategy": strategy,
        "fidelity": fidelity,
        "params": dataclasses.asdict(params) if params else None,
        **extra,
    }
    # chip names are cosmetic — two identically-dimensioned chips with
    # different labels must share cache entries
    desc["chip"].pop("name", None)
    blob = json.dumps(desc, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


class ResultCache:
    """Sharded JSON file cache with hit/miss accounting and an
    optional eviction policy (applied by :meth:`prune`, not on every
    ``put`` — pruning scans the whole tree)."""

    def __init__(self, root: Optional[str] = None,
                 max_age_days: Optional[float] = None,
                 max_entries: Optional[int] = None) -> None:
        self.root = root or default_cache_dir()
        self.max_age_days = max_age_days
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key[:2], key + ".json")

    def _entries(self, want_mtimes: bool = True
                 ) -> List[Tuple[float, str]]:
        """All entry files as sorted ``(mtime, path)``, oldest first.

        ``want_mtimes=False`` skips the per-file stat and the sort
        (``__len__``/``clear`` only need the paths).
        """
        out: List[Tuple[float, str]] = []
        if not os.path.isdir(self.root):
            return out
        for shard in os.listdir(self.root):
            sdir = os.path.join(self.root, shard)
            try:
                names = os.listdir(sdir)
            except (NotADirectoryError, FileNotFoundError):
                continue              # stray file / concurrent rmdir
            for f in names:
                if not f.endswith(".json"):
                    continue
                path = os.path.join(sdir, f)
                if not want_mtimes:
                    out.append((0.0, path))
                    continue
                try:
                    out.append((os.path.getmtime(path), path))
                except OSError:
                    continue          # concurrently pruned
        if want_mtimes:
            out.sort()
        return out

    def prune(self, max_age_days: Optional[float] = None,
              max_entries: Optional[int] = None,
              now: Optional[float] = None) -> int:
        """Evict entries by age and count; returns how many were removed.

        Age first (mtime older than ``max_age_days``), then the oldest
        entries beyond ``max_entries``.  Limits default to the ones the
        cache was constructed with; ``None`` disables that criterion.
        ``now`` is injectable for tests.
        """
        max_age_days = (self.max_age_days if max_age_days is None
                        else max_age_days)
        max_entries = (self.max_entries if max_entries is None
                       else max_entries)
        entries = self._entries()
        now = time.time() if now is None else now
        doomed: List[str] = []
        if max_age_days is not None:
            cutoff = now - max_age_days * 86400.0
            i = bisect.bisect_left(entries, (cutoff,))
            doomed.extend(p for _, p in entries[:i])
            entries = entries[i:]
        if max_entries is not None and len(entries) > max_entries:
            extra = len(entries) - max_entries
            doomed.extend(p for _, p in entries[:extra])
            del entries[:extra]
        removed = 0
        for path in doomed:
            try:
                os.unlink(path)
                removed += 1
            except OSError:
                pass                  # concurrently pruned
        self._remove_empty_shards()
        return removed

    def _remove_empty_shards(self) -> None:
        if not os.path.isdir(self.root):
            return
        for shard in os.listdir(self.root):
            sdir = os.path.join(self.root, shard)
            if os.path.isdir(sdir) and not os.listdir(sdir):
                try:
                    os.rmdir(sdir)
                except OSError:
                    pass

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        try:
            with open(self._path(key)) as f:
                out = json.load(f)
        except (OSError, json.JSONDecodeError):
            self.misses += 1
            return None
        self.hits += 1
        return out

    def put(self, key: str, value: Dict[str, Any]) -> None:
        path = self._path(key)
        sdir = os.path.dirname(path)
        for _ in range(8):
            os.makedirs(sdir, exist_ok=True)
            try:
                fd, tmp = tempfile.mkstemp(dir=sdir, suffix=".tmp")
                break
            except FileNotFoundError:
                continue    # concurrent prune rmdir'd the empty shard
        else:
            raise OSError(f"cache shard {sdir} keeps vanishing "
                          f"(concurrent prune?)")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(value, f, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __contains__(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def __len__(self) -> int:
        return len(self._entries(want_mtimes=False))

    def clear(self) -> int:
        """Delete all entries; returns how many were removed."""
        n = 0
        for _, path in self._entries(want_mtimes=False):
            try:
                os.unlink(path)
                n += 1
            except OSError:
                pass
        self._remove_empty_shards()
        return n

    @property
    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses}

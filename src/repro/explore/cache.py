"""Content-addressed on-disk cache for evaluation results.

The cache key is the SHA-256 of a canonical-JSON description of
everything that determines an evaluation's outcome: the workload
(model name + geometry), the full ``ChipConfig`` dict, the compile
strategy, the cost-model parameters, and the fidelity.  Identical
(model, chip, strategy, mode) re-runs — and overlapping sweeps from
*different* drivers — therefore share entries and are free.

Entries are JSON files sharded by key prefix (``<root>/ab/<key>.json``)
and written atomically (tmp + rename) so concurrent pool workers and
concurrent sweeps never observe torn files.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Any, Dict, Optional

from ..core.arch import ChipConfig
from ..core.mapping import CostParams

__all__ = ["ResultCache", "default_cache_dir", "cache_key"]

_ENV_VAR = "REPRO_EXPLORE_CACHE"
_SCHEMA_VERSION = 1


def default_cache_dir() -> str:
    return os.environ.get(_ENV_VAR,
                          os.path.join("results", "explore_cache"))


def cache_key(model: str, chip: ChipConfig, strategy: str,
              fidelity: str, params: Optional[CostParams] = None,
              **extra: Any) -> str:
    """Deterministic content hash of one evaluation's full inputs."""
    desc: Dict[str, Any] = {
        "v": _SCHEMA_VERSION,
        "model": model,
        "chip": chip.to_dict(),
        "strategy": strategy,
        "fidelity": fidelity,
        "params": dataclasses.asdict(params) if params else None,
        **extra,
    }
    # chip names are cosmetic — two identically-dimensioned chips with
    # different labels must share cache entries
    desc["chip"].pop("name", None)
    blob = json.dumps(desc, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


class ResultCache:
    """Sharded JSON file cache with hit/miss accounting."""

    def __init__(self, root: Optional[str] = None) -> None:
        self.root = root or default_cache_dir()
        self.hits = 0
        self.misses = 0

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key[:2], key + ".json")

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        try:
            with open(self._path(key)) as f:
                out = json.load(f)
        except (OSError, json.JSONDecodeError):
            self.misses += 1
            return None
        self.hits += 1
        return out

    def put(self, key: str, value: Dict[str, Any]) -> None:
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(value, f, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __contains__(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def __len__(self) -> int:
        if not os.path.isdir(self.root):
            return 0
        return sum(1 for shard in os.listdir(self.root)
                   if os.path.isdir(os.path.join(self.root, shard))
                   for f in os.listdir(os.path.join(self.root, shard))
                   if f.endswith(".json"))

    def clear(self) -> int:
        """Delete all entries; returns how many were removed."""
        n = 0
        if not os.path.isdir(self.root):
            return 0
        for shard in os.listdir(self.root):
            sdir = os.path.join(self.root, shard)
            if not os.path.isdir(sdir):
                continue
            for f in os.listdir(sdir):
                if f.endswith(".json"):
                    os.unlink(os.path.join(sdir, f))
                    n += 1
        return n

    @property
    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses}

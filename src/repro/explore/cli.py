"""``python -m repro.explore`` — sweeps without writing a script.

Subcommands:

* ``sweep MODEL`` — evaluate a design space over the cached pool
  engine and print the result table (optionally append to a JSONL
  store / promote the top-K to the simulator).
* ``pareto STORE.jsonl`` — Pareto frontier of previously recorded
  evaluations.
* ``cache prune|stats|clear`` — manage the on-disk result cache.

Examples::

    python -m repro.explore sweep tiny_cnn --res 8 --mg 4,8 --flit 8
    python -m repro.explore sweep resnet18 --res 112 --pool 8 \
        --store results/resnet18.jsonl --top-k 3
    python -m repro.explore pareto results/resnet18.jsonl \
        --axes cycles,energy
    python -m repro.explore cache prune --max-age-days 30 \
        --max-entries 10000
"""

from __future__ import annotations

import argparse
from typing import List, Optional, Sequence

from ..core.mapping import CostParams
from ..core.partition import STRATEGIES
from .cache import ResultCache, default_cache_dir
from .engine import ExplorationEngine
from .pareto import frontier_report
from .records import EvalRecord, RecordStore
from .search import by_edp, successive_halving
from .space import (DesignSpace, default_space, mg_flit_space,
                    protection_space, timing_space)

__all__ = ["main"]


def _ints(csv: str) -> List[int]:
    return [int(v) for v in csv.split(",") if v]


def _row_table(recs: Sequence[EvalRecord]) -> str:
    out = ["model            strategy  MG n_mg cores flit lmem  "
           "cycles        EDP         error"]
    for r in recs:
        p = r.point
        err = (r.error or "")[:40]
        out.append(
            f"{r.model:16s} {p.strategy:9s} {p.macros_per_group:2d} "
            f"{p.n_macro_groups:4d} {p.n_cores:5d} {p.flit_bytes:4d} "
            f"{p.local_mem_kb:4d}  {r.cycles:<12.5g}  "
            f"{r.edp:<10.4g}  {err}")
    return "\n".join(out)


def _build_space(args: argparse.Namespace) -> DesignSpace:
    strategies = tuple(args.strategies.split(","))
    for s in strategies:
        if s not in STRATEGIES:
            raise SystemExit(f"unknown strategy {s!r}; "
                             f"have {list(STRATEGIES)}")
    if args.space in ("default", "timing", "protection"):
        if args.mg is not None or args.flit is not None:
            raise SystemExit("--mg/--flit restrict the mg-flit grid "
                             "only; they cannot be combined with "
                             f"--space {args.space}")
        if args.space == "timing":
            return timing_space(strategies=strategies)
        if args.space == "protection":
            return protection_space(strategies=strategies)
        return default_space(strategies=strategies)
    return mg_flit_space(_ints(args.mg or "4,8,16"),
                         _ints(args.flit or "8,16"),
                         strategies=strategies)


def _cmd_sweep(args: argparse.Namespace) -> int:
    if args.top_k and args.fidelity == "simulate":
        raise SystemExit(
            "--top-k implies the two-fidelity successive-halving flow "
            "(cheap screen, simulator promotion); it cannot be "
            "combined with --fidelity simulate")
    space = _build_space(args)
    kw = {}
    if args.res is not None:
        kw["res"] = args.res
    eng = ExplorationEngine(
        args.model, params=CostParams(batch=args.batch),
        pool=args.pool,
        cache=None if args.no_cache else (args.cache_root
                                          or default_cache_dir()),
        store=args.store, flow_cache=args.flow_cache,
        calibration=getattr(args, "calibration", None),
        engine=args.engine, **kw)
    print(f"sweeping {args.model}: {space.describe()}")
    if args.top_k:
        result, screened = successive_halving(
            eng, space, top_k=args.top_k, objective=by_edp,
            screen_fidelity=args.fidelity, calibrate=args.calibrate)
        if eng.calibration is not None:
            print(eng.calibration.describe())
        print(_row_table(screened))
        print(f"\ntop-{args.top_k} promoted to the simulator:")
        print(_row_table(result.history))
    else:
        if args.resume and not args.store:
            raise SystemExit("--resume needs --store (the JSONL record "
                             "store is what the sweep resumes from)")
        recs = eng.sweep(space, fidelity=args.fidelity,
                         resume=args.resume)
        print(_row_table(recs))
    print(f"\ncache: {eng.cache_stats()}")
    if args.store:
        print(f"records appended to {args.store}")
    return 0


def _cmd_pareto(args: argparse.Namespace) -> int:
    recs = RecordStore(args.store).load()
    if args.model:
        recs = [r for r in recs if r.model == args.model]
    if not recs:
        raise SystemExit(f"no records in {args.store}"
                         + (f" for model {args.model!r}"
                            if args.model else ""))
    axes = tuple(args.axes.split(","))
    print(f"{len(recs)} records; Pareto frontier on {axes}:")
    print(frontier_report(recs, axes=axes))
    return 0


def _cmd_cache(args: argparse.Namespace) -> int:
    cache = ResultCache(args.cache_root or default_cache_dir())
    if args.cache_cmd == "stats":
        print(f"{cache.root}: {len(cache)} entries")
        return 0
    if args.cache_cmd == "clear":
        print(f"removed {cache.clear()} entries from {cache.root}")
        return 0
    # prune
    if args.max_age_days is None and args.max_entries is None:
        raise SystemExit("cache prune needs --max-age-days and/or "
                         "--max-entries")
    n = cache.prune(max_age_days=args.max_age_days,
                    max_entries=args.max_entries)
    print(f"pruned {n} entries from {cache.root} "
          f"({len(cache)} remain)")
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.explore",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    sw = sub.add_parser("sweep", help="evaluate a design space")
    sw.add_argument("model", help="workload name (e.g. resnet18)")
    sw.add_argument("--res", type=int, default=None,
                    help="input resolution for CNN workloads")
    sw.add_argument("--batch", type=int, default=4)
    sw.add_argument("--space", choices=("mg-flit", "default", "timing",
                                        "protection"),
                    default="mg-flit",
                    help="mg-flit: Fig.6 grid; default: full 5-dim "
                         "space; timing: 64-point unit-latency grid "
                         "sharing one compiled program (pairs with "
                         "--engine jax)")
    sw.add_argument("--mg", default=None,
                    help="[mg-flit only] comma-separated MG sizes "
                         "(default 4,8,16)")
    sw.add_argument("--flit", default=None,
                    help="[mg-flit only] comma-separated flit widths "
                         "(default 8,16)")
    sw.add_argument("--strategies", default=",".join(STRATEGIES))
    sw.add_argument("--fidelity",
                    choices=("analytic", "trace", "simulate"),
                    default="analytic",
                    help="sweep fidelity; with --top-k this is the "
                         "screening rung (simulate is then invalid)")
    sw.add_argument("--top-k", type=int, default=0,
                    help="successive halving: cheap screen, then "
                         "promote the top-K to the simulator "
                         "(exclusive with --fidelity simulate)")
    sw.add_argument("--calibrate", type=int, default=0,
                    help="[with --top-k] fit per-unit correction "
                         "factors from N simulator runs before the "
                         "deciding screen")
    sw.add_argument("--calibration", default=None,
                    help="named calibration preset to start from "
                         "(results/calibrations/<name>.json, written "
                         "by flow.calibrate(..., save=name))")
    sw.add_argument("--flow-cache", default=None,
                    help="directory for the persistent flow "
                         "pass-output cache (shared by pool workers)")
    sw.add_argument("--pool", type=int, default=0,
                    help="worker processes (0 = serial)")
    sw.add_argument("--engine",
                    choices=("auto", "scalar", "vector", "jax"),
                    default="auto",
                    help="perf-simulator engine for simulate-fidelity "
                         "points; jax batches same-structure chips "
                         "through one vmapped XLA program")
    sw.add_argument("--resume", action="store_true",
                    help="skip points already successfully recorded "
                         "in --store (restart a killed sweep where "
                         "it left off)")
    sw.add_argument("--store", default=None,
                    help="append records to this JSONL file")
    sw.add_argument("--cache-root", default=None)
    sw.add_argument("--no-cache", action="store_true")
    sw.set_defaults(fn=_cmd_sweep)

    pa = sub.add_parser("pareto", help="frontier of recorded results")
    pa.add_argument("store", help="JSONL record store path")
    pa.add_argument("--axes", default="cycles,energy",
                    help="comma-separated minimized axes")
    pa.add_argument("--model", default=None,
                    help="filter records to one workload")
    pa.set_defaults(fn=_cmd_pareto)

    ca = sub.add_parser("cache", help="manage the result cache")
    ca.add_argument("cache_cmd", choices=("prune", "stats", "clear"))
    ca.add_argument("--cache-root", default=None)
    ca.add_argument("--max-age-days", type=float, default=None)
    ca.add_argument("--max-entries", type=int, default=None)
    ca.set_defaults(fn=_cmd_cache)

    args = ap.parse_args(argv)
    return args.fn(args)

"""Declarative design space over ``ChipConfig`` x compile strategy.

The exploration subsystem (paper §IV-C) treats a candidate design as a
:class:`DesignPoint` — a small, hashable record of the architectural
knobs the paper sweeps (macro-group size, MG count, core grid, NoC flit
width, local-memory size) plus the compilation strategy.  A
:class:`DesignSpace` is an ordered set of :class:`Dimension` values with
validity constraints; it can enumerate the full grid, sample uniformly,
and mutate a point along one axis (the neighborhood structure used by
hill-climbing / evolutionary search).

Points are *descriptions*, not hardware: :meth:`DesignPoint.chip`
materializes the ``ChipConfig`` (raising nothing for valid points —
validity is checked at space level via :meth:`DesignSpace.is_valid`).
"""

from __future__ import annotations

import dataclasses
import itertools
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

from ..core.arch import (ArchError, ChipConfig, ProtectionConfig,
                         default_chip)
from ..core.partition import STRATEGIES

__all__ = [
    "DesignPoint", "Dimension", "DesignSpace", "default_space",
    "mg_flit_space", "mesh_space", "timing_space", "protection_space",
    "SWEEP_MG", "SWEEP_FLIT",
]

# The paper's Fig. 6 / Fig. 7 grid — the single source of truth shared
# by mg_flit_space() defaults, the fig6/fig7 benchmarks and the
# core.dse shim, so overlapping sweeps keep hitting the same cache keys.
SWEEP_MG = (4, 8, 16)          # macros per MG (Fig. 6 x-axis)
SWEEP_FLIT = (8, 16)           # NoC flit bytes (light/dark shading)


def _mesh_cols(n_cores: int) -> int:
    """Squarest 2-D mesh factorization: largest divisor <= sqrt(n)."""
    best = 1
    d = 1
    while d * d <= n_cores:
        if n_cores % d == 0:
            best = d
        d += 1
    return best


@dataclass(frozen=True, order=True)
class DesignPoint:
    """One candidate design: architecture knobs + compile strategy."""

    macros_per_group: int = 8
    n_macro_groups: int = 16
    n_cores: int = 64
    flit_bytes: int = 8
    local_mem_kb: int = 512
    strategy: str = "generic"
    # multi-chip scale-out axes (repro.system); chips=1 keeps the
    # classic single-chip path (and its historical cache keys)
    chips: int = 1
    link: str = "pcb"
    parallel: str = "pipeline"
    # timing-only axes: none of these steer partitioning or codegen, so
    # points differing only here share one canonical chip — the fleet
    # evaluator (explore.fleet) compiles once and vmaps the batch
    scalar_alu_latency: int = 1
    vector_alu_latency: int = 1
    weight_load_rows_per_cycle: int = 1
    router_latency: int = 2
    # fault-mitigation axes (repro.faults): cycle/energy/area overhead
    # vs residual fault rate.  All-off keeps the historical chip.
    ecc: bool = False
    spare_rows: int = 0
    tmr: bool = False

    def chip(self) -> ChipConfig:
        prot = ProtectionConfig(ecc=self.ecc,
                                spare_rows=self.spare_rows,
                                tmr=self.tmr)
        suffix = ""
        if prot.enabled:
            suffix = ("-p" + ("e" if self.ecc else "")
                      + (f"s{self.spare_rows}" if self.spare_rows else "")
                      + ("t" if self.tmr else ""))
        chip = default_chip(
            macros_per_group=self.macros_per_group,
            n_macro_groups=self.n_macro_groups,
            flit_bytes=self.flit_bytes,
            local_mem_kb=self.local_mem_kb,
            n_cores=self.n_cores,
            mesh_cols=_mesh_cols(self.n_cores),
            protection=prot,
            name=(f"c{self.n_cores}-mg{self.macros_per_group}"
                  f"x{self.n_macro_groups}-f{self.flit_bytes}"
                  f"-l{self.local_mem_kb}{suffix}"),
        )
        if (self.scalar_alu_latency, self.vector_alu_latency,
                self.weight_load_rows_per_cycle,
                self.router_latency) == (1, 1, 1, 2):
            return chip              # defaults: historical chip object
        core = chip.core
        return dataclasses.replace(
            chip,
            core=dataclasses.replace(
                core,
                scalar=dataclasses.replace(
                    core.scalar, alu_latency=self.scalar_alu_latency),
                vector=dataclasses.replace(
                    core.vector, alu_latency=self.vector_alu_latency),
                cim=dataclasses.replace(
                    core.cim, weight_load_rows_per_cycle=(
                        self.weight_load_rows_per_cycle))),
            noc=dataclasses.replace(chip.noc,
                                    router_latency=self.router_latency))

    def system(self) -> Optional[Any]:
        """``SystemConfig`` mesh for multi-chip points, else ``None``."""
        if self.chips <= 1:
            return None
        from ..system import SystemConfig
        return SystemConfig.mesh(self.chips, link=self.link,
                                 parallel=self.parallel)

    @property
    def total_macros(self) -> int:
        """Silicon-cost axis for Pareto — macro count across all chips."""
        return (self.n_cores * self.n_macro_groups * self.macros_per_group
                * max(1, self.chips))

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "DesignPoint":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def replace(self, **kw: Any) -> "DesignPoint":
        return dataclasses.replace(self, **kw)


_POINT_FIELDS = tuple(f.name for f in dataclasses.fields(DesignPoint))

Constraint = Callable[[DesignPoint], bool]


@dataclass(frozen=True)
class Dimension:
    """One axis of the design space (name must be a DesignPoint field)."""

    name: str
    values: Tuple[Any, ...]

    def __post_init__(self) -> None:
        if self.name not in _POINT_FIELDS:
            raise ValueError(f"unknown dimension {self.name!r}; "
                             f"DesignPoint has {_POINT_FIELDS}")
        if not self.values:
            raise ValueError(f"dimension {self.name!r} has no values")
        object.__setattr__(self, "values", tuple(self.values))


class DesignSpace:
    """Cartesian product of :class:`Dimension` values with constraints.

    Unlisted ``DesignPoint`` fields stay at their defaults.  Built-in
    validity = the point's ``ChipConfig`` constructs without
    :class:`ArchError`; extra predicates narrow it further.
    """

    def __init__(self, dims: Sequence[Dimension],
                 constraints: Sequence[Constraint] = ()) -> None:
        seen = set()
        for d in dims:
            if d.name in seen:
                raise ValueError(f"duplicate dimension {d.name!r}")
            seen.add(d.name)
        self.dims: Tuple[Dimension, ...] = tuple(dims)
        self.constraints: Tuple[Constraint, ...] = tuple(constraints)

    # -- validity -----------------------------------------------------------

    def is_valid(self, pt: DesignPoint) -> bool:
        try:
            pt.chip()
            pt.system()
        except (ArchError, ValueError):
            return False
        return all(c(pt) for c in self.constraints)

    # -- enumeration / sampling --------------------------------------------

    @property
    def grid_size(self) -> int:
        """Size of the raw grid (before constraint filtering)."""
        n = 1
        for d in self.dims:
            n *= len(d.values)
        return n

    def __iter__(self) -> Iterator[DesignPoint]:
        """All *valid* points, grid order (last dimension fastest)."""
        names = [d.name for d in self.dims]
        for combo in itertools.product(*(d.values for d in self.dims)):
            pt = DesignPoint(**dict(zip(names, combo)))
            if self.is_valid(pt):
                yield pt

    def points(self) -> List[DesignPoint]:
        out: List[DesignPoint] = []
        for pt in self.__iter__():
            out.append(pt)
        return out

    def __len__(self) -> int:
        return len(self.points())

    def __contains__(self, pt: DesignPoint) -> bool:
        for d in self.dims:
            if getattr(pt, d.name) not in d.values:
                return False
        return self.is_valid(pt)

    def random_point(self, rng: random.Random) -> DesignPoint:
        """One uniformly-sampled valid point (rejection sampling)."""
        for _ in range(10_000):
            pt = DesignPoint(**{d.name: rng.choice(d.values)
                                for d in self.dims})
            if self.is_valid(pt):
                return pt
        raise ArchError("design space appears empty (10k rejections)")

    def sample(self, n: int, seed: int = 0) -> List[DesignPoint]:
        """``n`` distinct valid points (or the whole space if smaller)."""
        rng = random.Random(seed)
        pts = self.points()
        if n >= len(pts):
            return pts
        return rng.sample(pts, n)

    # -- neighborhood (mutation) -------------------------------------------

    def mutate(self, pt: DesignPoint, rng: random.Random) -> DesignPoint:
        """Step one randomly-chosen dimension to an adjacent/other value."""
        dims = [d for d in self.dims if len(d.values) > 1]
        if not dims:
            return pt
        for _ in range(100):
            d = rng.choice(dims)
            cur = getattr(pt, d.name)
            if cur in d.values:
                i = d.values.index(cur)
                # prefer adjacent values (smooth walk) over teleports
                cand = [j for j in (i - 1, i + 1) if 0 <= j < len(d.values)]
                j = rng.choice(cand)
            else:
                j = rng.randrange(len(d.values))
            new = pt.replace(**{d.name: d.values[j]})
            if new != pt and self.is_valid(new):
                return new
        return pt

    def neighbors(self, pt: DesignPoint) -> List[DesignPoint]:
        """All valid single-dimension steps from ``pt``."""
        out: List[DesignPoint] = []
        for d in self.dims:
            cur = getattr(pt, d.name)
            idx = d.values.index(cur) if cur in d.values else None
            cand = (d.values if idx is None
                    else [d.values[j] for j in (idx - 1, idx + 1)
                          if 0 <= j < len(d.values)])
            for v in cand:
                new = pt.replace(**{d.name: v})
                if new != pt and self.is_valid(new):
                    out.append(new)
        return out

    def describe(self) -> str:
        dims = ", ".join(f"{d.name}={list(d.values)}" for d in self.dims)
        return f"DesignSpace({dims}; grid {self.grid_size})"


# ---------------------------------------------------------------------------
# Stock spaces
# ---------------------------------------------------------------------------


def mg_flit_space(mgs: Sequence[int] = SWEEP_MG,
                  flits: Sequence[int] = SWEEP_FLIT,
                  strategies: Sequence[str] = ("generic",)) -> DesignSpace:
    """The seed's Fig. 6 / Fig. 7 grid: MG size x flit width (x strategy)."""
    return DesignSpace([
        Dimension("macros_per_group", tuple(mgs)),
        Dimension("flit_bytes", tuple(flits)),
        Dimension("strategy", tuple(strategies)),
    ])


def mesh_space(chips: Sequence[int] = (1, 2, 4),
               links: Sequence[str] = ("interposer", "pcb"),
               parallel: Sequence[str] = ("pipeline",)) -> DesignSpace:
    """Scale-out grid: chip count x inter-chip link tier (x parallelism).

    Single-chip points ignore the ``link``/``parallel`` axes; the grid
    still enumerates every combination, so pair this with a constraint
    (or dedup on ``pt.system()``) when exact point counts matter.
    """
    return DesignSpace([
        Dimension("chips", tuple(chips)),
        Dimension("link", tuple(links)),
        Dimension("parallel", tuple(parallel)),
    ])


def timing_space(scalar_alu: Sequence[int] = (1, 2),
                 vector_alu: Sequence[int] = (1, 2, 3, 4),
                 wl_rate: Sequence[int] = (1, 2, 4, 8),
                 router: Sequence[int] = (1, 2),
                 strategies: Sequence[str] = ("dp",)) -> DesignSpace:
    """Timing-only sweep on a fixed structure (64 points by default).

    Every point shares one canonical chip, so the jax fleet evaluator
    (``ExplorationEngine(engine="jax")``) compiles the workload once and
    evaluates the whole grid in one vmapped decode per stage.
    """
    return DesignSpace([
        Dimension("scalar_alu_latency", tuple(scalar_alu)),
        Dimension("vector_alu_latency", tuple(vector_alu)),
        Dimension("weight_load_rows_per_cycle", tuple(wl_rate)),
        Dimension("router_latency", tuple(router)),
        Dimension("strategy", tuple(strategies)),
    ])


def protection_space(spares: Sequence[int] = (0, 2, 4),
                     strategies: Sequence[str] = ("dp",)) -> DesignSpace:
    """Fault-mitigation sweep on the default structure (12 points).

    ECC x TMR x spare-row grid over one chip: pairs with
    :func:`repro.faults.residual_rate` to trade protection overhead
    (cycles/energy via :class:`~repro.core.machine.MachineModel`
    accessors, area via ``protection_area_factor``) against residual
    fault rate at a given raw-defect rate.
    """
    return DesignSpace([
        Dimension("ecc", (False, True)),
        Dimension("tmr", (False, True)),
        Dimension("spare_rows", tuple(spares)),
        Dimension("strategy", tuple(strategies)),
    ])


def default_space(strategies: Sequence[str] = STRATEGIES) -> DesignSpace:
    """The full 5-dimension architecture space from the ISSUE/paper §IV-C."""
    return DesignSpace([
        Dimension("macros_per_group", (2, 4, 8, 16)),
        Dimension("n_macro_groups", (8, 16, 32)),
        Dimension("n_cores", (16, 36, 64)),
        Dimension("flit_bytes", (8, 16, 32)),
        Dimension("local_mem_kb", (256, 512, 1024)),
        Dimension("strategy", tuple(strategies)),
    ])

"""Pareto-frontier extraction over evaluation records.

Objectives are *minimized*.  Axes can be named strings ("cycles",
"energy", "edp", "macros", "latency_s") or arbitrary
``EvalRecord -> float`` callables; the default pair is the paper's
cycles-vs-energy trade-off, and adding "macros" gives the
3-objective performance/energy/silicon frontier.

:func:`annotate` attaches per-point dominance metadata
(:class:`ParetoPoint`: on-frontier flag, how many points dominate it,
frontier rank by non-dominated sorting); :func:`pareto_frontier`
returns just the non-dominated records.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence, Tuple, Union

from .records import EvalRecord

__all__ = ["AXES", "ParetoPoint", "dominates", "annotate",
           "pareto_frontier", "frontier_report"]

Axis = Union[str, Callable[[EvalRecord], float]]

AXES: Dict[str, Callable[[EvalRecord], float]] = {
    "cycles": lambda r: r.cycles,
    "energy": lambda r: r.energy_total,
    "edp": lambda r: r.edp,
    "macros": lambda r: float(r.point.total_macros),
    "latency_s": lambda r: r.cycles,   # monotone alias of cycles
}


def _resolve(axes: Sequence[Axis]) -> List[Callable[[EvalRecord], float]]:
    out = []
    for a in axes:
        if callable(a):
            out.append(a)
        elif a in AXES:
            out.append(AXES[a])
        else:
            raise KeyError(f"unknown Pareto axis {a!r}; "
                           f"have {sorted(AXES)} or pass a callable")
    return out


def _values(rec: EvalRecord,
            fns: Sequence[Callable[[EvalRecord], float]]
            ) -> Tuple[float, ...]:
    return tuple(f(rec) for f in fns)


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True iff ``a`` is no worse than ``b`` everywhere, better somewhere."""
    return all(x <= y for x, y in zip(a, b)) \
        and any(x < y for x, y in zip(a, b))


@dataclass
class ParetoPoint:
    """A record plus its dominance metadata within one analyzed set."""

    record: EvalRecord
    values: Tuple[float, ...]      # objective vector (minimized)
    on_frontier: bool
    dominated_by: int              # how many points dominate this one
    rank: int                      # non-dominated sorting front (0 = frontier)


def annotate(records: Sequence[EvalRecord],
             axes: Sequence[Axis] = ("cycles", "energy")
             ) -> List[ParetoPoint]:
    """Full dominance analysis: O(n^2) pairwise + front peeling."""
    fns = _resolve(axes)
    vals = [_values(r, fns) for r in records]
    n = len(records)
    dom_count = [0] * n
    for i in range(n):
        for j in range(n):
            if i != j and dominates(vals[j], vals[i]):
                dom_count[i] += 1

    # non-dominated sorting (front peeling) for ranks
    rank = [-1] * n
    remaining = set(range(n))
    level = 0
    while remaining:
        front = {i for i in remaining
                 if not any(dominates(vals[j], vals[i])
                            for j in remaining if j != i)}
        if not front:          # identical duplicate vectors: break ties
            front = set(remaining)
        for i in front:
            rank[i] = level
        remaining -= front
        level += 1

    return [ParetoPoint(record=records[i], values=vals[i],
                        on_frontier=dom_count[i] == 0,
                        dominated_by=dom_count[i], rank=rank[i])
            for i in range(n)]


def pareto_frontier(records: Sequence[EvalRecord],
                    axes: Sequence[Axis] = ("cycles", "energy")
                    ) -> List[EvalRecord]:
    """The non-dominated subset, sorted by the first axis.

    Failed evaluations (``record.ok == False``) are excluded up front —
    their infinite objective vectors would survive dominance checks in
    the all-errors corner case.
    """
    records = [r for r in records if r.ok]
    fns = _resolve(axes)
    pts = [p for p in annotate(records, axes) if p.on_frontier]
    pts.sort(key=lambda p: p.values)
    # collapse exact duplicates (same objective vector + same point)
    out: List[EvalRecord] = []
    seen = set()
    for p in pts:
        key = (p.values, p.record.point)
        if key not in seen:
            seen.add(key)
            out.append(p.record)
    return out


def frontier_report(records: Sequence[EvalRecord],
                    axes: Sequence[Axis] = ("cycles", "energy")
                    ) -> str:
    """Human-readable frontier table for benchmark reports."""
    front = pareto_frontier(records, axes)
    names = [a if isinstance(a, str) else getattr(a, "__name__", "obj")
             for a in axes]
    head = ("point (strategy mg n_mg cores flit lmem)  "
            + "  ".join(f"{n:>12s}" for n in names))
    lines = [head]
    fns = _resolve(axes)
    for r in front:
        p = r.point
        lines.append(
            f"{p.strategy:8s} {p.macros_per_group:3d} "
            f"{p.n_macro_groups:4d} {p.n_cores:5d} {p.flit_bytes:4d} "
            f"{p.local_mem_kb:5d}  "
            + "  ".join(f"{f(r):12.4g}" for f in fns))
    return "\n".join(lines)

"""Parallel evaluation engine for design-space exploration.

Evaluates :class:`~repro.explore.space.DesignPoint` batches against one
workload, at either fidelity:

* ``"analytic"`` — partition + the analytic cost model (fast; the
  screening fidelity for large sweeps and successive halving);
* ``"simulate"`` — compile to ISA streams and run the cycle-accurate
  simulator (ground truth; ~100x slower).

The engine checks the content-addressed :class:`ResultCache` first, fans
the misses out over a ``multiprocessing`` pool (the core pipeline is
numpy-only, so workers are cheap to spawn and fork-safe), writes results
back to the cache, and optionally appends every record to a JSONL
:class:`RecordStore`.  Results always come back in input order, and a
given key always produces an identical record — cached or not.
"""

from __future__ import annotations

import multiprocessing as mp
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from .. import flow
from ..core import workloads
from ..core.arch import ArchError, ChipConfig
from ..core.graph import CondensedGraph
from ..core.mapping import CostParams
from ..flow import CompileOptions
from .cache import ResultCache, cache_key
from .records import FIDELITIES, EvalRecord, RecordStore
from .space import DesignPoint, DesignSpace

__all__ = ["evaluate_chip", "ExplorationEngine"]


def evaluate_chip(cg: CondensedGraph, chip: ChipConfig, strategy: str,
                  params: Optional[CostParams] = None,
                  fidelity: str = "analytic") -> Dict[str, Any]:
    """Score one (graph, chip, strategy) at the given fidelity.

    Runs on the :mod:`repro.flow` pass pipeline, so a point promoted
    from the analytic screen to the simulator in the same process
    reuses its cached partition instead of re-partitioning.  Returns
    ``{"cycles", "energy", "throughput_sps"}`` — the payload the cache
    stores and :class:`EvalRecord` wraps.
    """
    if fidelity not in FIDELITIES:
        raise ValueError(f"fidelity must be one of {FIDELITIES}, "
                         f"got {fidelity!r}")
    params = params or CostParams(batch=4)
    art = flow.compile(cg, chip,
                       CompileOptions(strategy=strategy, params=params,
                                      fidelity=fidelity))
    rep = art.evaluate()
    return {"cycles": rep.cycles, "energy": dict(rep.energy),
            "throughput_sps": rep.throughput_sps}


# ---------------------------------------------------------------------------
# Pool workers (module-level for spawn-context picklability)
# ---------------------------------------------------------------------------

_WORKER: Dict[str, Any] = {}


def _init_worker(model: str, workload_kw: Dict[str, Any],
                 params: CostParams) -> None:
    _WORKER["cg"] = workloads.build(model, **workload_kw).condense()
    _WORKER["params"] = params


def _eval_worker(job: Tuple[DesignPoint, str]) -> Dict[str, Any]:
    """Evaluate one point; infeasible points become error payloads
    (cycles=inf) instead of killing the whole sweep."""
    point, fidelity = job
    t0 = time.perf_counter()
    try:
        out = evaluate_chip(_WORKER["cg"], point.chip(), point.strategy,
                            _WORKER["params"], fidelity)
    except Exception as e:        # noqa: BLE001 — point-local failure
        out = {"cycles": float("inf"), "energy": {"total": float("inf")},
               "throughput_sps": 0.0,
               "error": f"{type(e).__name__}: {e}"}
    out["wall_s"] = time.perf_counter() - t0
    return out


class ExplorationEngine:
    """Cached, pool-parallel evaluator for one workload.

    Parameters
    ----------
    model:
        Workload name from :data:`repro.core.workloads.WORKLOADS`.
    pool:
        Worker processes; ``0``/``1`` evaluates serially in-process.
    cache:
        ``ResultCache`` instance, a directory path, or ``None`` to
        disable caching entirely.
    store:
        Optional ``RecordStore`` (or path) appended to on every eval.
    """

    def __init__(self, model: str, params: Optional[CostParams] = None,
                 pool: int = 0,
                 cache: Union[ResultCache, str, None] = None,
                 store: Union[RecordStore, str, None] = None,
                 fidelity: str = "analytic",
                 **workload_kw: Any) -> None:
        # validate eagerly: an unknown model raising inside a pool
        # worker's initializer would respawn workers forever
        if model not in workloads.WORKLOADS:
            raise KeyError(f"unknown workload {model!r}; "
                           f"have {sorted(workloads.WORKLOADS)}")
        self.model = model
        self.workload_kw = dict(workload_kw)
        self.params = params or CostParams(batch=4)
        self.pool = int(pool)
        self.fidelity = fidelity
        if isinstance(cache, str):
            cache = ResultCache(cache)
        self.cache = cache
        if isinstance(store, str):
            store = RecordStore(store)
        self.store = store
        self._cg: Optional[CondensedGraph] = None

    @property
    def cg(self) -> CondensedGraph:
        if self._cg is None:
            self._cg = workloads.build(self.model,
                                       **self.workload_kw).condense()
        return self._cg

    # -- keys ---------------------------------------------------------------

    def _key(self, point: DesignPoint, fidelity: str) -> str:
        return cache_key(self.model, point.chip(), point.strategy,
                         fidelity, self.params,
                         workload_kw=self.workload_kw)

    # -- evaluation ---------------------------------------------------------

    def evaluate(self, points: Sequence[DesignPoint],
                 fidelity: Optional[str] = None) -> List[EvalRecord]:
        """Evaluate points (cache-first, pool for misses), input order."""
        fidelity = fidelity or self.fidelity
        if fidelity not in FIDELITIES:
            # caller bug, not an infeasible point — fail loudly instead
            # of letting the per-point error capture swallow it
            raise ValueError(f"fidelity must be one of {FIDELITIES}, "
                             f"got {fidelity!r}")
        points = list(points)
        results: List[Optional[Dict[str, Any]]] = [None] * len(points)
        hit: List[bool] = [False] * len(points)
        keys: List[Optional[str]] = [None] * len(points)

        # pre-screen chip construction in the parent: a point whose
        # ChipConfig cannot even be built must become an error record on
        # every path (cache keying calls point.chip() before workers
        # would get a chance to capture the failure)
        dispatchable: List[bool] = [True] * len(points)
        for i, pt in enumerate(points):
            try:
                pt.chip()
            except ArchError as e:
                results[i] = {"cycles": float("inf"),
                              "energy": {"total": float("inf")},
                              "throughput_sps": 0.0, "wall_s": 0.0,
                              "error": f"{type(e).__name__}: {e}"}
                dispatchable[i] = False

        if self.cache is not None:
            for i, pt in enumerate(points):
                if not dispatchable[i]:
                    continue
                keys[i] = self._key(pt, fidelity)
                got = self.cache.get(keys[i])
                if got is not None:
                    results[i] = got
                    hit[i] = True

        miss_idx = [i for i, r in enumerate(results) if r is None]
        jobs = [(points[i], fidelity) for i in miss_idx]
        if jobs:
            if self.pool > 1 and len(jobs) > 1:
                fresh = self._run_pool(jobs)
            else:
                _WORKER["cg"] = self.cg       # built once per engine
                _WORKER["params"] = self.params
                fresh = [_eval_worker(j) for j in jobs]
            for i, out in zip(miss_idx, fresh):
                results[i] = out
                # errors are deterministic for a given key but cheap to
                # recompute; keep the cache clean of failure payloads
                if self.cache is not None and keys[i] is not None \
                        and "error" not in out:
                    self.cache.put(keys[i], out)

        records = [
            EvalRecord(point=pt, model=self.model, fidelity=fidelity,
                       cycles=out["cycles"],
                       throughput_sps=out["throughput_sps"],
                       energy=out["energy"], batch=self.params.batch,
                       cache_hit=hit[i],
                       wall_s=out.get("wall_s", 0.0),
                       error=out.get("error"))
            for i, (pt, out) in enumerate(zip(points, results))
        ]
        if self.store is not None:
            self.store.extend(records)
        return records

    def evaluate_one(self, point: DesignPoint,
                     fidelity: Optional[str] = None) -> EvalRecord:
        return self.evaluate([point], fidelity)[0]

    def sweep(self, space: DesignSpace,
              fidelity: Optional[str] = None) -> List[EvalRecord]:
        """Exhaustive grid evaluation of a space."""
        return self.evaluate(space.points(), fidelity)

    def _run_pool(self, jobs: List[Tuple[DesignPoint, str]]
                  ) -> List[Dict[str, Any]]:
        try:
            # fork children inherit the parent's prepared graph — no
            # per-worker workloads.build() in the initializer
            ctx = mp.get_context("fork")
            _WORKER["cg"] = self.cg
            _WORKER["params"] = self.params
            init, initargs = None, ()
        except ValueError:
            ctx = mp.get_context("spawn")
            init = _init_worker
            initargs = (self.model, self.workload_kw, self.params)
        n = min(self.pool, len(jobs))
        chunk = max(1, len(jobs) // (n * 4))
        with ctx.Pool(processes=n, initializer=init,
                      initargs=initargs) as pool:
            return pool.map(_eval_worker, jobs, chunksize=chunk)

    def cache_stats(self) -> Dict[str, int]:
        return dict(self.cache.stats) if self.cache is not None \
            else {"hits": 0, "misses": 0}

"""Parallel evaluation engine for design-space exploration.

Evaluates :class:`~repro.explore.space.DesignPoint` batches against one
workload, at any rung of the fidelity ladder:

* ``"analytic"`` — partition + the analytic cost model (fast; the
  screening fidelity for large sweeps and successive halving);
* ``"trace"`` — StagePlan replay at unit/transfer granularity
  (~100x faster than the simulator, within its documented band);
* ``"simulate"`` — compile to ISA streams and run the cycle-accurate
  simulator (ground truth).

The engine checks the content-addressed :class:`ResultCache` first, fans
the misses out over a ``multiprocessing`` pool (the core pipeline is
numpy-only, so workers are cheap to spawn and fork-safe), writes results
back to the cache, and optionally appends every record to a JSONL
:class:`RecordStore`.  Results always come back in input order, and a
given key always produces an identical record — cached or not.

Cheap-fidelity misses (analytic / trace) are evaluated in *batches*:
one ``flow.compile_many`` invocation partitions N candidate chips
against the engine's single condensed graph, so an arch sweep pays the
condense pass once per process instead of once per point.  A
:class:`~repro.core.machine.Calibration` (see
:meth:`ExplorationEngine.calibrate`) rides into every cheap evaluation
— and into the cache key — so calibrated screening ranks match
simulator ranks.
"""

from __future__ import annotations

import multiprocessing as mp
import os
import time
import warnings
from collections import defaultdict
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from .. import flow
from ..core import workloads
from ..core.arch import ArchError, ChipConfig
from ..core.graph import CondensedGraph
from ..core.machine import Calibration
from ..core.mapping import CostParams
from ..flow import CompileOptions
from ..flow.diskcache import ENV_VAR as _FLOW_CACHE_ENV
from .cache import ResultCache, cache_key
from .records import FIDELITIES, EvalRecord, RecordStore
from .space import DesignPoint, DesignSpace

__all__ = ["evaluate_chip", "ExplorationEngine"]

# fidelities the batched compile_many path handles (no codegen needed)
_CHEAP = ("analytic", "trace")


def evaluate_chip(cg: CondensedGraph, chip: ChipConfig, strategy: str,
                  params: Optional[CostParams] = None,
                  fidelity: str = "analytic",
                  calibration: Optional[Calibration] = None,
                  system: Optional[Any] = None,
                  engine: str = "auto") -> Dict[str, Any]:
    """Score one (graph, chip, strategy) at the given fidelity.

    Runs on the :mod:`repro.flow` pass pipeline, so a point promoted
    from the analytic screen to the simulator in the same process
    reuses its cached partition instead of re-partitioning.  With a
    ``system`` (:class:`repro.system.SystemConfig`), the chip is
    replicated over the mesh and the score covers the whole multi-chip
    plan.  Returns ``{"cycles", "energy", "throughput_sps"}`` — the
    payload the cache stores and :class:`EvalRecord` wraps.
    """
    if fidelity not in FIDELITIES:
        raise ValueError(f"fidelity must be one of {FIDELITIES}, "
                         f"got {fidelity!r}")
    params = params or CostParams(batch=4)
    art = flow.compile(cg, chip,
                       CompileOptions(strategy=strategy, params=params,
                                      fidelity=fidelity,
                                      calibration=calibration,
                                      system=system))
    # only the simulator backend takes an engine; cheap fidelities have
    # no per-instruction execution path to select
    kw = ({"engine": engine}
          if engine != "auto" and fidelity == "simulate" and system is None
          else {})
    rep = art.evaluate(**kw)
    return {"cycles": rep.cycles, "energy": dict(rep.energy),
            "throughput_sps": rep.throughput_sps}


# ---------------------------------------------------------------------------
# Pool workers (module-level for spawn-context picklability)
# ---------------------------------------------------------------------------

_WORKER: Dict[str, Any] = {}


def _init_worker(model: str, workload_kw: Dict[str, Any],
                 params: CostParams,
                 calibration: Optional[Calibration] = None,
                 flow_cache: Optional[str] = None,
                 engine: str = "auto") -> None:
    if flow_cache:
        os.environ[_FLOW_CACHE_ENV] = flow_cache
    _WORKER["cg"] = workloads.build(model, **workload_kw).condense()
    _WORKER["params"] = params
    _WORKER["calibration"] = calibration
    _WORKER["engine"] = engine


def _err_payload(e: Exception, wall_s: float = 0.0) -> Dict[str, Any]:
    return {"cycles": float("inf"), "energy": {"total": float("inf")},
            "throughput_sps": 0.0, "wall_s": wall_s,
            "error": f"{type(e).__name__}: {e}"}


def _eval_worker(job: Tuple[DesignPoint, str]) -> Dict[str, Any]:
    """Evaluate one point; infeasible points become error payloads
    (cycles=inf) instead of killing the whole sweep."""
    point, fidelity = job
    t0 = time.perf_counter()
    try:
        out = evaluate_chip(_WORKER["cg"], point.chip(), point.strategy,
                            _WORKER["params"], fidelity,
                            _WORKER.get("calibration"),
                            system=point.system(),
                            engine=_WORKER.get("engine", "auto"))
    except Exception as e:        # noqa: BLE001 — point-local failure
        out = _err_payload(e)
    out["wall_s"] = time.perf_counter() - t0
    return out


def _eval_batch_worker(jobs: List[Tuple[DesignPoint, str]]
                       ) -> List[Dict[str, Any]]:
    """Batched cheap-fidelity evaluation: one ``flow.compile_many``
    per (strategy, fidelity) group — the condense pass runs once for
    the whole chunk.  Any group-level failure falls back to per-point
    evaluation so one infeasible chip cannot poison its batch."""
    cg = _WORKER["cg"]
    params = _WORKER["params"]
    calibration = _WORKER.get("calibration")
    results: List[Optional[Dict[str, Any]]] = [None] * len(jobs)
    groups: Dict[Tuple[str, str, Any], List[int]] = defaultdict(list)
    for i, (pt, fid) in enumerate(jobs):
        # SystemConfig is a frozen dataclass, so it groups/hashes fine;
        # single-chip points all land in the system=None group
        try:
            groups[(pt.strategy, fid, pt.system())].append(i)
        except Exception as e:           # noqa: BLE001 — bad mesh/link
            results[i] = _err_payload(e)
    for (strategy, fidelity, system), idxs in groups.items():
        chips: List[ChipConfig] = []
        ok: List[int] = []
        for i in idxs:
            try:
                chips.append(jobs[i][0].chip())
                ok.append(i)
            except Exception as e:       # noqa: BLE001
                results[i] = _err_payload(e)
        if not ok:
            continue
        t0 = time.perf_counter()
        try:
            arts = flow.compile_many(
                cg, chips,
                CompileOptions(strategy=strategy, params=params,
                               fidelity=fidelity,
                               calibration=calibration,
                               system=system))
        except Exception:                # noqa: BLE001
            # e.g. one chip infeasible mid-batch: isolate per point
            for i in ok:
                results[i] = _eval_worker(jobs[i])
            continue
        per_compile = (time.perf_counter() - t0) / len(arts)
        for i, art in zip(ok, arts):
            t1 = time.perf_counter()
            try:
                rep = art.evaluate()
                results[i] = {
                    "cycles": rep.cycles, "energy": dict(rep.energy),
                    "throughput_sps": rep.throughput_sps,
                    "wall_s": (time.perf_counter() - t1) + per_compile}
            except Exception as e:       # noqa: BLE001
                results[i] = _err_payload(
                    e, (time.perf_counter() - t1) + per_compile)
    return results


class ExplorationEngine:
    """Cached, pool-parallel evaluator for one workload.

    Parameters
    ----------
    model:
        Workload name from :data:`repro.core.workloads.WORKLOADS`.
    pool:
        Worker processes; ``0``/``1`` evaluates serially in-process.
    cache:
        ``ResultCache`` instance, a directory path, or ``None`` to
        disable caching entirely.
    store:
        Optional ``RecordStore`` (or path) appended to on every eval.
    calibration:
        Per-unit correction factors applied to cheap fidelities
        (analytic / trace) and mixed into every cache key.  Fit one
        with :meth:`calibrate` or :func:`repro.flow.calibrate`; a
        string names a saved preset (``results/calibrations/*.json``,
        written by ``flow.calibrate(..., save=name)``).
    flow_cache:
        Directory for the :mod:`repro.flow` *pass-output* disk cache
        (distinct from ``cache``, which stores finished evaluation
        payloads).  Pool workers inherit it, so no worker ever
        re-partitions a (workload, chip, strategy) any process has
        already partitioned.
    """

    def __init__(self, model: str, params: Optional[CostParams] = None,
                 pool: int = 0,
                 cache: Union[ResultCache, str, None] = None,
                 store: Union[RecordStore, str, None] = None,
                 fidelity: str = "analytic",
                 calibration: Union[Calibration, str, None] = None,
                 flow_cache: Optional[str] = None,
                 engine: str = "auto",
                 pool_retries: int = 2,
                 pool_backoff_s: float = 0.5,
                 **workload_kw: Any) -> None:
        # validate eagerly: an unknown model raising inside a pool
        # worker's initializer would respawn workers forever
        if model not in workloads.WORKLOADS:
            raise KeyError(f"unknown workload {model!r}; "
                           f"have {sorted(workloads.WORKLOADS)}")
        from ..core.simulator import ENGINES
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, "
                             f"got {engine!r}")
        self.engine = engine
        self.model = model
        if pool_retries < 0 or pool_backoff_s < 0:
            raise ValueError("pool_retries and pool_backoff_s must be "
                             "non-negative")
        self.pool_retries = pool_retries
        self.pool_backoff_s = pool_backoff_s
        self.workload_kw = dict(workload_kw)
        self.params = params or CostParams(batch=4)
        self.pool = int(pool)
        self.fidelity = fidelity
        if isinstance(calibration, str):
            calibration = flow.load_calibration(calibration)
        self.calibration = calibration
        self.flow_cache = flow_cache
        if flow_cache:
            # the parent's default pipeline (and fork children) attach
            # the disk tier; spawn children get it via the initializer.
            # Rebind an existing tier too — parent and workers must
            # agree on one directory or workers' partitions are lost.
            # NOTE: the flow pass cache is process-wide by design (all
            # compiles in this process funnel through the default
            # pipeline), so the last engine constructed wins; warn when
            # engines disagree instead of silently redirecting.
            os.environ[_FLOW_CACHE_ENV] = flow_cache
            pipe = flow.default_pipeline()
            if pipe.disk is not None and pipe.disk.root != flow_cache:
                warnings.warn(
                    f"flow pass cache is process-wide: rebinding it "
                    f"from {pipe.disk.root!r} to {flow_cache!r} for "
                    f"every engine/compile in this process",
                    RuntimeWarning, stacklevel=2)
            if pipe.disk is None or pipe.disk.root != flow_cache:
                pipe.disk = flow.PassDiskCache(flow_cache)
        if isinstance(cache, str):
            cache = ResultCache(cache)
        self.cache = cache
        if isinstance(store, str):
            store = RecordStore(store)
        self.store = store
        self._cg: Optional[CondensedGraph] = None

    @property
    def cg(self) -> CondensedGraph:
        if self._cg is None:
            self._cg = workloads.build(self.model,
                                       **self.workload_kw).condense()
        return self._cg

    # -- keys ---------------------------------------------------------------

    def _key(self, point: DesignPoint, fidelity: str) -> str:
        # calibration changes cheap-fidelity outcomes, so it must enter
        # the key; the simulator is calibration-free by construction.
        # Omit the kwarg entirely when uncalibrated so pre-calibration
        # cache entries (including expensive simulator runs) stay valid.
        extra: Dict[str, Any] = {"workload_kw": self.workload_kw}
        if self.calibration is not None and fidelity in _CHEAP:
            extra["calibration"] = self.calibration.to_dict()
        system = point.system()
        if system is not None:
            # only multi-chip points carry the kwarg, so every
            # pre-scale-out cache entry keeps its key
            extra["system"] = system.to_dict()
        if self.engine == "jax" and fidelity == "simulate":
            # fleet results use pinned-program semantics (compiled on
            # the point's canonical chip — see explore.fleet), which
            # can diverge from per-point compilation when a timing
            # field steers the partitioner; key them separately so the
            # two paths never share entries.  scalar/vector/auto are
            # bit-identical per-point runs and keep the historical key.
            extra["engine"] = "jax"
        return cache_key(self.model, point.chip(), point.strategy,
                         fidelity, self.params, **extra)

    # -- calibration --------------------------------------------------------

    def calibrate(self, points: Sequence[DesignPoint],
                  fidelity: Optional[str] = None,
                  max_points: int = 3) -> Calibration:
        """Fit (and adopt) per-unit correction factors for this
        workload from perf-simulator runs on a few design points.

        Each point costs one simulator run; factors are combined by
        geometric mean across points so no single chip's quirks
        dominate.  The fit is stored on the engine — subsequent cheap
        evaluations (and their cache keys) use it automatically.
        """
        fidelity = fidelity or (self.fidelity
                                if self.fidelity in _CHEAP
                                else "analytic")
        fits = []
        for pt in list(points)[:max(1, max_points)]:
            rep = flow.calibrate(
                [(self.model, self.workload_kw)], pt.chip(),
                strategy=pt.strategy, params=self.params,
                fidelity=fidelity)
            fits.append(rep.calibration)
            # the fit's ground-truth run IS this point's simulator
            # evaluation — seed the result cache so a later promotion
            # of the same point is a hit instead of a re-simulation
            row = rep.rows[0]
            if self.cache is not None and row.sim_energy is not None:
                self.cache.put(self._key(pt, "simulate"), {
                    "cycles": row.sim_cycles,
                    "energy": row.sim_energy,
                    "throughput_sps": row.sim_throughput_sps,
                    "wall_s": row.sim_wall_s})
        self.calibration = Calibration.combine(fits)
        return self.calibration

    # -- evaluation ---------------------------------------------------------

    def evaluate(self, points: Sequence[DesignPoint],
                 fidelity: Optional[str] = None) -> List[EvalRecord]:
        """Evaluate points (cache-first, pool for misses), input order."""
        fidelity = fidelity or self.fidelity
        if fidelity not in FIDELITIES:
            # caller bug, not an infeasible point — fail loudly instead
            # of letting the per-point error capture swallow it
            raise ValueError(f"fidelity must be one of {FIDELITIES}, "
                             f"got {fidelity!r}")
        points = list(points)
        results: List[Optional[Dict[str, Any]]] = [None] * len(points)
        hit: List[bool] = [False] * len(points)
        keys: List[Optional[str]] = [None] * len(points)

        # pre-screen chip construction in the parent: a point whose
        # ChipConfig cannot even be built must become an error record on
        # every path (cache keying calls point.chip() before workers
        # would get a chance to capture the failure)
        dispatchable: List[bool] = [True] * len(points)
        for i, pt in enumerate(points):
            try:
                pt.chip()
                pt.system()
            except (ArchError, ValueError) as e:
                results[i] = {"cycles": float("inf"),
                              "energy": {"total": float("inf")},
                              "throughput_sps": 0.0, "wall_s": 0.0,
                              "error": f"{type(e).__name__}: {e}"}
                dispatchable[i] = False

        if self.cache is not None:
            for i, pt in enumerate(points):
                if not dispatchable[i]:
                    continue
                keys[i] = self._key(pt, fidelity)
                got = self.cache.get(keys[i])
                if got is not None:
                    results[i] = got
                    hit[i] = True

        miss_idx = [i for i, r in enumerate(results) if r is None]
        jobs = [(points[i], fidelity) for i in miss_idx]
        if jobs:
            fresh: List[Optional[Dict[str, Any]]] = [None] * len(jobs)
            rest = list(range(len(jobs)))
            if fidelity == "simulate" and self.engine == "jax":
                # fleet path: single-chip misses batch into vmapped
                # device calls — one compile + one decode per canonical
                # chip group instead of a pipeline per point.  Mesh
                # (system) points keep the per-point path below.
                fleet_k = [k for k in rest
                           if jobs[k][0].system() is None]
                if fleet_k:
                    outs = self._fleet().evaluate(
                        [(jobs[k][0].chip(), jobs[k][0].strategy)
                         for k in fleet_k])
                    for k, out in zip(fleet_k, outs):
                        fresh[k] = out
                    taken = set(fleet_k)
                    rest = [k for k in rest if k not in taken]
            sub = [jobs[k] for k in rest]
            if sub:
                if self.pool > 1 and len(sub) > 1:
                    got = self._run_pool(sub, fidelity)
                else:
                    _WORKER["cg"] = self.cg   # built once per engine
                    _WORKER["params"] = self.params
                    _WORKER["calibration"] = self.calibration
                    _WORKER["engine"] = self.engine
                    if fidelity in _CHEAP:
                        got = _eval_batch_worker(sub)
                    else:
                        got = [_eval_worker(j) for j in sub]
                for k, out in zip(rest, got):
                    fresh[k] = out
            for i, out in zip(miss_idx, fresh):
                results[i] = out
                # errors are deterministic for a given key but cheap to
                # recompute; keep the cache clean of failure payloads
                if self.cache is not None and keys[i] is not None \
                        and "error" not in out:
                    self.cache.put(keys[i], out)

        rec_engine = self.engine if fidelity == "simulate" else "auto"
        records = [
            EvalRecord(point=pt, model=self.model, fidelity=fidelity,
                       cycles=out["cycles"],
                       throughput_sps=out["throughput_sps"],
                       energy=out["energy"], batch=self.params.batch,
                       cache_hit=hit[i],
                       wall_s=out.get("wall_s", 0.0),
                       error=out.get("error"),
                       engine=rec_engine)
            for i, (pt, out) in enumerate(zip(points, results))
        ]
        if self.store is not None:
            self.store.extend(records)
        return records

    def evaluate_one(self, point: DesignPoint,
                     fidelity: Optional[str] = None) -> EvalRecord:
        return self.evaluate([point], fidelity)[0]

    def sweep(self, space: DesignSpace,
              fidelity: Optional[str] = None,
              resume: bool = False) -> List[EvalRecord]:
        """Exhaustive grid evaluation of a space.

        With ``resume=True`` (requires a ``store``), points that this
        engine's :class:`RecordStore` already holds a *successful*
        record for — same model, same fidelity — are not re-evaluated:
        the stored record is returned in place.  A sweep killed
        mid-run (OOM, Ctrl-C, node preemption) picks up where the
        JSONL left off instead of starting over; failed records are
        always retried.
        """
        fidelity = fidelity or self.fidelity
        points = space.points()
        if not resume:
            return self.evaluate(points, fidelity)
        if self.store is None:
            raise ValueError("sweep(resume=True) needs a RecordStore "
                             "(construct the engine with store=...)")
        prior: Dict[DesignPoint, EvalRecord] = {}
        for rec in self.store:
            if rec.ok and rec.model == self.model \
                    and rec.fidelity == fidelity:
                prior[rec.point] = rec
        todo = [pt for pt in points if pt not in prior]
        skipped = len(points) - len(todo)
        if skipped:
            warnings.warn(
                f"sweep resume: skipping {skipped}/{len(points)} "
                f"points already recorded in {self.store.path}",
                RuntimeWarning, stacklevel=2)
        fresh: Dict[DesignPoint, EvalRecord] = {}
        if todo:
            # evaluate() appends the fresh records to the store itself
            fresh = {r.point: r for r in self.evaluate(todo, fidelity)}
        return [prior[pt] if pt in prior else fresh[pt]
                for pt in points]

    def _run_pool(self, jobs: List[Tuple[DesignPoint, str]],
                  fidelity: str) -> List[Dict[str, Any]]:
        """Pool evaluation with bounded retry.

        Worker *exceptions* are already captured per point
        (``_err_payload``); what reaches here is pool-infrastructure
        failure — a worker killed by the OOM reaper, a wedged fork,
        an unpicklable result.  Those are frequently transient, so the
        batch is retried with exponential backoff; when the pool keeps
        collapsing, the sweep degrades to serial in-process evaluation
        rather than dying.
        """
        last: Optional[BaseException] = None
        for attempt in range(self.pool_retries + 1):
            try:
                return self._run_pool_once(jobs, fidelity)
            except KeyboardInterrupt:
                raise
            except Exception as e:     # noqa: BLE001 — pool-level only
                last = e
                if attempt < self.pool_retries:
                    delay = self.pool_backoff_s * (2 ** attempt)
                    warnings.warn(
                        f"worker pool failed ({type(e).__name__}: {e});"
                        f" retrying batch in {delay:.1f}s "
                        f"(attempt {attempt + 1}/{self.pool_retries})",
                        RuntimeWarning, stacklevel=2)
                    time.sleep(delay)
        warnings.warn(
            f"worker pool failed {self.pool_retries + 1} times "
            f"({type(last).__name__}: {last}); falling back to serial "
            f"in-process evaluation for this batch",
            RuntimeWarning, stacklevel=2)
        _WORKER["cg"] = self.cg
        _WORKER["params"] = self.params
        _WORKER["calibration"] = self.calibration
        _WORKER["engine"] = self.engine
        if fidelity in _CHEAP:
            return _eval_batch_worker(jobs)
        return [_eval_worker(j) for j in jobs]

    def _run_pool_once(self, jobs: List[Tuple[DesignPoint, str]],
                       fidelity: str) -> List[Dict[str, Any]]:
        try:
            # fork children inherit the parent's prepared graph — no
            # per-worker workloads.build() in the initializer
            ctx = mp.get_context("fork")
            _WORKER["cg"] = self.cg
            _WORKER["params"] = self.params
            _WORKER["calibration"] = self.calibration
            _WORKER["engine"] = self.engine
            init, initargs = None, ()
        except ValueError:
            ctx = mp.get_context("spawn")
            init = _init_worker
            initargs = (self.model, self.workload_kw, self.params,
                        self.calibration, self.flow_cache, self.engine)
        n = min(self.pool, len(jobs))
        chunk = max(1, len(jobs) // (n * 4))
        with ctx.Pool(processes=n, initializer=init,
                      initargs=initargs) as pool:
            if fidelity in _CHEAP:
                # batched path: each worker chunk shares one condense
                # (and one compile_many per strategy in the chunk)
                chunks = [jobs[i:i + chunk]
                          for i in range(0, len(jobs), chunk)]
                out: List[Dict[str, Any]] = []
                for batch in pool.map(_eval_batch_worker, chunks):
                    out.extend(batch)
                return out
            return pool.map(_eval_worker, jobs, chunksize=chunk)

    def _fleet(self) -> Any:
        fe = getattr(self, "_fleet_eval", None)
        if fe is None:
            from .fleet import FleetEvaluator
            fe = self._fleet_eval = FleetEvaluator(self.cg,
                                                   params=self.params)
        return fe

    def cache_stats(self) -> Dict[str, int]:
        return dict(self.cache.stats) if self.cache is not None \
            else {"hits": 0, "misses": 0}

"""Fleet evaluation: one compiled program, a vmapped batch of chips.

A timing sweep — "how do cycles move as scalar/vector/CIM/NoC latencies
change?" — evaluates the *same* compiled program under different
:class:`~repro.core.machine.MachineModel` constants.  The pool-parallel
engine pays a full per-point pipeline for each such point; this module
pays it once:

1. **Canonicalize** — :func:`canonical_chip` resets every timing-only
   field (unit latencies, weight-load rate, NoC rates, clock) to its
   default, leaving the structural fields (cores, macro groups, memory,
   flit width) that actually shape partitioning and codegen.  Points
   sharing a canonical chip share one ``flow.compile``.
2. **Batch-decode** — each stage preps once
   (:meth:`~repro.core.vectorsim.StageDecoder._prep`) and one
   ``vmap``-ed XLA call over the stacked
   :class:`~repro.core.jaxsim.MachineTables` produces every machine's
   per-instruction latencies; the machine-independent dataflow half is
   computed once for the whole fleet
   (:class:`~repro.core.jaxsim.FleetStageDecoder`).
3. **Replay per chip** — the shared
   :func:`~repro.core.vectorsim.replay_stage` runs against a
   lightweight shim carrying each point's own ``MachineModel``, so NoC
   arbitration / gmem ports / barriers replay with that machine's
   replay-side constants.

Semantics ("pinned program"): every chip in a group executes the
binary compiled for the group's canonical chip.  For chips that differ
only in the canonicalized timing fields this matches per-point
compilation whenever those fields don't steer the partitioner; the
equivalence contract the tests pin is the sharper one that always
holds — a fleet evaluation equals a loop of
``Simulator(chip_i, engine="jax").run_model`` calls over the same
compiled model.  :class:`~repro.explore.engine.ExplorationEngine`
keys fleet results under an ``engine="jax"`` cache marker so they can
never collide with per-point-compiled entries.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from .. import flow
from ..core import workloads
from ..core.arch import (ChipConfig, NocConfig, ScalarUnitConfig,
                         VectorUnitConfig)
from ..core.graph import CondensedGraph
from ..core.machine import MachineModel, energy_breakdown, machine_for
from ..core.mapping import CostParams
from ..core.vectorsim import DecodeUnsupported, replay_stage
from ..flow import CompileOptions

__all__ = ["canonical_chip", "FleetEvaluator"]

# default-valued donors for the timing-only fields
_SCALAR_DEFAULT = ScalarUnitConfig()
_VECTOR_DEFAULT = VectorUnitConfig()
_NOC_DEFAULT = NocConfig()
_CIM_WL_DEFAULT = 1            # CimUnitConfig.weight_load_rows_per_cycle


def canonical_chip(chip: ChipConfig) -> ChipConfig:
    """``chip`` with every timing-only field reset to its default.

    Two chips with equal canonical forms describe the same *structure*
    (partitioning / codegen inputs) and may share one compiled program;
    they differ only in the :class:`MachineModel` constants the decode
    and replay passes consume.
    """
    core = chip.core
    vec = core.vector
    return dataclasses.replace(
        chip,
        core=dataclasses.replace(
            core,
            scalar=_SCALAR_DEFAULT,
            vector=dataclasses.replace(
                vec,
                alu_latency=_VECTOR_DEFAULT.alu_latency,
                mul_latency=_VECTOR_DEFAULT.mul_latency,
                special_latency=_VECTOR_DEFAULT.special_latency),
            cim=dataclasses.replace(
                core.cim, weight_load_rows_per_cycle=_CIM_WL_DEFAULT)),
        noc=dataclasses.replace(
            chip.noc,
            flits_per_cycle=_NOC_DEFAULT.flits_per_cycle,
            router_latency=_NOC_DEFAULT.router_latency,
            inject_latency=_NOC_DEFAULT.inject_latency),
        clock_ghz=1.0,
        # labels are cosmetic (the flow cache already ignores them) but
        # enter ChipConfig equality — normalize so same-structure chips
        # group into one compile
        name="canonical")


class _ShimSim:
    """The two attributes :func:`replay_stage` reads from a Simulator."""

    __slots__ = ("m", "max_cycles")

    def __init__(self, m: MachineModel, max_cycles: float) -> None:
        self.m = m
        self.max_cycles = max_cycles


def _err_payload(e: Exception) -> Dict[str, Any]:
    return {"cycles": float("inf"), "energy": {"total": float("inf")},
            "throughput_sps": 0.0, "wall_s": 0.0,
            "error": f"{type(e).__name__}: {e}"}


class FleetEvaluator:
    """Batched perf-simulator evaluation of many chips on one workload.

    Parameters mirror :class:`~repro.explore.engine.ExplorationEngine`
    where they overlap; ``model`` may be a workload name or an
    already-condensed graph (the engine hands over its own, so fleet
    promotion never re-condenses).
    """

    def __init__(self, model: Union[str, CondensedGraph],
                 params: Optional[CostParams] = None,
                 max_cycles: float = 5e9, **workload_kw: Any) -> None:
        self.params = params or CostParams(batch=4)
        self.max_cycles = max_cycles
        if isinstance(model, str):
            self._cg: Optional[CondensedGraph] = None
            self.model = model
            self.workload_kw = dict(workload_kw)
        else:
            self._cg = model
            self.model = getattr(model, "name", "<graph>")
            self.workload_kw = dict(workload_kw)

    @property
    def cg(self) -> CondensedGraph:
        if self._cg is None:
            self._cg = workloads.build(self.model,
                                       **self.workload_kw).condense()
        return self._cg

    # -- evaluation ---------------------------------------------------------

    def evaluate(self, jobs: Sequence[Tuple[ChipConfig, str]]
                 ) -> List[Dict[str, Any]]:
        """Evaluate ``(chip, strategy)`` jobs at simulate/perf fidelity.

        Returns payload dicts in input order (``cycles`` / ``energy`` /
        ``throughput_sps`` / ``wall_s``, or an ``error`` entry for
        point-local failures) — the same shape the exploration engine
        caches and wraps into :class:`EvalRecord`.
        """
        results: List[Optional[Dict[str, Any]]] = [None] * len(jobs)
        groups: Dict[Tuple[ChipConfig, str], List[int]] = {}
        for i, (chip, strategy) in enumerate(jobs):
            try:
                key = (canonical_chip(chip), strategy)
            except Exception as e:       # noqa: BLE001 — bad chip
                results[i] = _err_payload(e)
                continue
            groups.setdefault(key, []).append(i)
        for (canon, strategy), idxs in groups.items():
            chips = [jobs[i][0] for i in idxs]
            for i, payload in zip(idxs,
                                  self._eval_group(canon, strategy,
                                                   chips)):
                results[i] = payload
        return results           # type: ignore[return-value]

    def _eval_group(self, canon: ChipConfig, strategy: str,
                    chips: List[ChipConfig]) -> List[Dict[str, Any]]:
        from ..core.jaxsim import FleetStageDecoder
        from ..core.simulator import Simulator

        t0 = time.perf_counter()
        n = len(chips)
        try:
            art = flow.compile(self.cg, canon,
                               CompileOptions(strategy=strategy,
                                              params=self.params,
                                              fidelity="simulate"))
            cm = art.ensure_model()
        except Exception as e:           # noqa: BLE001 — group-level
            return [_err_payload(e) for _ in range(n)]
        machines = [machine_for(c) for c in chips]
        dec = FleetStageDecoder(cm.isa, machines)
        shims = [_ShimSim(m, self.max_cycles) for m in machines]
        scalar_sims: List[Optional[Simulator]] = [None] * n

        stage_cycles: List[List[float]] = [[] for _ in range(n)]
        events: List[Dict[str, float]] = [{} for _ in range(n)]
        busy: List[Dict[str, float]] = [{} for _ in range(n)]
        instrs = [0] * n
        err: List[Optional[str]] = [None] * n

        for sp in cm.stages:
            try:
                outs = dec.decode_stage(sp.programs)
            except DecodeUnsupported:
                outs = None              # scalar fallback, per chip
            for i in range(n):
                if err[i] is not None:
                    continue
                try:
                    if outs is None:
                        sim = scalar_sims[i]
                        if sim is None:
                            sim = scalar_sims[i] = Simulator(
                                chips[i], cm.isa, engine="scalar",
                                max_cycles=self.max_cycles)
                        out = sim._run_stage(sp, None)
                    else:
                        out = replay_stage(shims[i], sp, outs[i])
                except Exception as e:   # noqa: BLE001 — point-local
                    err[i] = f"{type(e).__name__}: {e}"
                    continue
                c, ev, bz, ni = out
                stage_cycles[i].append(c)
                instrs[i] += ni
                for k, v in ev.items():
                    events[i][k] = events[i].get(k, 0.0) + v
                for k, v in bz.items():
                    busy[i][k] = busy[i].get(k, 0.0) + v

        wall = (time.perf_counter() - t0) / n
        payloads: List[Dict[str, Any]] = []
        for i, chip in enumerate(chips):
            if err[i] is not None:
                payloads.append({"cycles": float("inf"),
                                 "energy": {"total": float("inf")},
                                 "throughput_sps": 0.0, "wall_s": wall,
                                 "error": err[i]})
                continue
            # identical aggregation to Simulator.run_model /
            # SimulatorBackend.evaluate — same events, same pricing
            total = float(sum(stage_cycles[i]))
            events[i]["static_core_cycles"] = total * chip.n_cores
            energy = dict(energy_breakdown(events[i],
                                           machines[i].energy_table))
            sps = (0.0 if total <= 0
                   else cm.batch / (total / (chip.clock_ghz * 1e9)))
            payloads.append({"cycles": total, "energy": energy,
                             "throughput_sps": sps, "wall_s": wall})
        return payloads

    def report(self, chip: ChipConfig, strategy: str) -> Dict[str, Any]:
        """Single-chip convenience wrapper around :meth:`evaluate`."""
        return self.evaluate([(chip, strategy)])[0]

"""Evaluation records and the JSONL result store.

Every evaluated design point becomes an :class:`EvalRecord` — the point,
the workload it was scored on, the fidelity used ("analytic" cost model
vs "simulate" cycle-accurate), and the measured cycles / throughput /
energy breakdown.  Records round-trip through plain dicts (the cache and
the JSONL store share one format) and flatten to the legacy
``core.dse.DsePoint.row()`` schema so existing benchmark reports keep
working unchanged.
"""

from __future__ import annotations

import json
import os
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

from .space import DesignPoint

__all__ = ["FIDELITIES", "EvalRecord", "RecordStore"]

# the fidelity ladder, cheap to expensive ("func" is a validation mode,
# not an exploration fidelity)
FIDELITIES = ("analytic", "trace", "simulate")

_ENERGY_KEYS = ("compute", "weight_load", "noc", "gmem", "lmem", "static")


@dataclass
class EvalRecord:
    """One (model x design point x fidelity) evaluation result."""

    point: DesignPoint
    model: str
    fidelity: str               # "analytic" | "simulate"
    cycles: float
    throughput_sps: float       # samples/s at the chip clock
    energy: Dict[str, float]    # nJ breakdown, incl. "total"
    batch: int = 4
    cache_hit: bool = False
    wall_s: float = 0.0
    error: Optional[str] = None   # evaluation failed (infeasible point)
    # perf-simulator execution path for simulate-fidelity rows
    # ("auto" | "scalar" | "vector" | "jax"); cheap fidelities run no
    # simulator and always record "auto"
    engine: str = "auto"

    @property
    def ok(self) -> bool:
        return self.error is None

    # -- derived objectives -------------------------------------------------

    @property
    def energy_total(self) -> float:
        return self.energy.get("total", 0.0)

    @property
    def edp(self) -> float:
        """Energy-delay product (nJ * cycles) — the example's objective."""
        return self.cycles * self.energy_total

    @property
    def simulated(self) -> bool:
        return self.fidelity == "simulate"

    # -- (de)serialization --------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {
            "point": self.point.to_dict(), "model": self.model,
            "fidelity": self.fidelity, "cycles": self.cycles,
            "throughput_sps": self.throughput_sps, "energy": self.energy,
            "batch": self.batch, "cache_hit": self.cache_hit,
            "wall_s": self.wall_s, "error": self.error,
            "engine": self.engine,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "EvalRecord":
        return cls(point=DesignPoint.from_dict(d["point"]),
                   model=d["model"], fidelity=d["fidelity"],
                   cycles=d["cycles"],
                   throughput_sps=d["throughput_sps"],
                   energy=dict(d["energy"]), batch=d.get("batch", 4),
                   cache_hit=d.get("cache_hit", False),
                   wall_s=d.get("wall_s", 0.0),
                   error=d.get("error"),
                   engine=d.get("engine", "auto"))

    def row(self) -> Dict[str, Any]:
        """Flat dict in the legacy ``DsePoint.row()`` schema (+ extras)."""
        tot = self.energy_total
        return {
            "model": self.model, "strategy": self.point.strategy,
            "mg": self.point.macros_per_group,
            "flit": self.point.flit_bytes,
            "cycles": self.cycles, "throughput_sps": self.throughput_sps,
            "energy_total_mJ": tot / 1e6,
            **{f"energy_{k}_frac":
               (self.energy.get(k, 0.0) / tot if tot else 0.0)
               for k in _ENERGY_KEYS},
            "simulated": self.simulated,
            # extras beyond the legacy schema
            "fidelity": self.fidelity,
            "n_mg": self.point.n_macro_groups,
            "cores": self.point.n_cores,
            "lmem_kb": self.point.local_mem_kb,
            "total_macros": self.point.total_macros,
            "cache_hit": self.cache_hit,
            "error": self.error,
            "engine": self.engine,
        }


class RecordStore:
    """Append-only JSONL store of :class:`EvalRecord` dicts."""

    def __init__(self, path: str) -> None:
        self.path = path

    def append(self, rec: EvalRecord) -> None:
        self.extend([rec])

    def extend(self, recs: List[EvalRecord]) -> None:
        if not recs:
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(self.path, "a") as f:
            for r in recs:
                f.write(json.dumps(r.to_dict(), sort_keys=True) + "\n")

    def __iter__(self) -> Iterator[EvalRecord]:
        if not os.path.exists(self.path):
            return
        with open(self.path) as f:
            for lineno, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    yield EvalRecord.from_dict(json.loads(line))
                except (json.JSONDecodeError, KeyError, TypeError) as e:
                    # a crash mid-append leaves a truncated last line;
                    # skip it (the sweep will simply redo that point)
                    # instead of making the whole store unreadable
                    warnings.warn(
                        f"record store {self.path}:{lineno}: skipping "
                        f"unreadable record ({type(e).__name__}: {e})",
                        RuntimeWarning, stacklevel=2)

    def load(self) -> List[EvalRecord]:
        out: List[EvalRecord] = []
        for rec in self.__iter__():
            out.append(rec)
        return out

    def __len__(self) -> int:
        return len(self.load())

"""``repro.explore`` — the design-space-exploration subsystem (§IV-C).

Replaces the serial fixed-grid driver in :mod:`repro.core.dse` with:

* :mod:`~repro.explore.space` — declarative ``ChipConfig`` x strategy
  design spaces with constraints, sampling and mutation;
* :mod:`~repro.explore.engine` — pool-parallel evaluation behind a
  content-addressed on-disk result cache;
* :mod:`~repro.explore.search` — grid / random / hill-climbing /
  two-fidelity successive-halving strategies;
* :mod:`~repro.explore.pareto` + :mod:`~repro.explore.records` —
  JSONL result store and Pareto-frontier dominance analysis.

Quickstart::

    from repro.explore import (ExplorationEngine, default_space,
                               pareto_frontier, successive_halving)
    eng = ExplorationEngine("resnet18", res=112, pool=8)
    result, screened = successive_halving(eng, default_space(), top_k=4)
    front = pareto_frontier(screened, axes=("cycles", "energy"))
"""

from ..core.machine import Calibration
from . import cache, cli, engine, fleet, pareto, records, search, space
from .cache import ResultCache, cache_key, default_cache_dir
from .engine import ExplorationEngine, evaluate_chip
from .fleet import FleetEvaluator, canonical_chip
from .pareto import (AXES, ParetoPoint, annotate, frontier_report,
                     pareto_frontier)
from .records import FIDELITIES, EvalRecord, RecordStore
from .search import (SearchResult, by_cycles, by_edp, by_energy,
                     grid_search, hill_climb, random_search,
                     successive_halving)
from .space import (SWEEP_FLIT, SWEEP_MG, DesignPoint, DesignSpace,
                    Dimension, default_space, mesh_space, mg_flit_space,
                    protection_space, timing_space)

__all__ = [
    "cache", "cli", "engine", "fleet", "pareto", "records", "search",
    "space",
    "ResultCache", "cache_key", "default_cache_dir",
    "ExplorationEngine", "evaluate_chip", "Calibration",
    "FleetEvaluator", "canonical_chip",
    "AXES", "ParetoPoint", "annotate", "frontier_report",
    "pareto_frontier",
    "FIDELITIES", "EvalRecord", "RecordStore",
    "SearchResult", "by_cycles", "by_edp", "by_energy", "grid_search",
    "hill_climb", "random_search", "successive_halving",
    "DesignPoint", "DesignSpace", "Dimension", "default_space",
    "mesh_space", "mg_flit_space", "protection_space", "timing_space",
    "SWEEP_MG", "SWEEP_FLIT",
]

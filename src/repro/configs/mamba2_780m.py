"""Mamba-2-780M [arXiv:2405.21060] — attention-free SSD stack."""
from .base import ArchConfig, SsmConfig

ARCH = ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=0, vocab=50280,
    norm="rmsnorm", act="swiglu", tie_embeddings=True,
    block_pattern="M",
    ssm=SsmConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=256),
    notes="SSD (state-space duality); constant-state decode -> "
          "long_500k runs",
)

"""Whisper-small [arXiv:2212.04356] — encoder-decoder; the conv/audio
frontend is a STUB (input_specs provides precomputed frame embeddings).
Decoder self-attention uses RoPE in place of learned positions
(documented adaptation, DESIGN.md §Arch-applicability)."""
from .base import ArchConfig

ARCH = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, head_dim=64,
    norm="layernorm", act="gelu",
    encoder_layers=12, encoder_seq=1500,
    notes="enc-dec; cross-attention decode; full attention -> "
          "long_500k skipped",
)

"""H2O-Danube-3-4B [arXiv:2401.16818] — llama/mistral mix with sliding-
window attention (window 4096) -> long_500k decodes in O(window)."""
from .base import ArchConfig

ARCH = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000, head_dim=120,
    norm="rmsnorm", act="swiglu", rope_theta=10_000.0,
    sliding_window=4096,
    notes="SWA ring KV cache; long_500k runs",
)

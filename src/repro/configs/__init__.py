"""Architecture registry: ``--arch <id>`` resolution + cell validity."""

from __future__ import annotations

from typing import Dict, List, Tuple

from .base import (ArchConfig, MlaConfig, MoeConfig, ShapeConfig,
                   SsmConfig, STANDARD_SHAPES, reduced)

from . import (deepseek_coder_33b, deepseek_v3_671b, h2o_danube3_4b,
               jamba15_large_398b, llava_next_mistral_7b, mamba2_780m,
               olmoe_1b_7b, phi3_medium_14b, phi4_mini_3_8b,
               whisper_small)

__all__ = ["ARCHS", "get_arch", "valid_cells", "cell_skip_reason",
           "ArchConfig", "ShapeConfig", "STANDARD_SHAPES", "reduced",
           "MoeConfig", "MlaConfig", "SsmConfig"]

_MODULES = [
    phi3_medium_14b, deepseek_coder_33b, h2o_danube3_4b, phi4_mini_3_8b,
    mamba2_780m, whisper_small, jamba15_large_398b, deepseek_v3_671b,
    olmoe_1b_7b, llava_next_mistral_7b,
]

ARCHS: Dict[str, ArchConfig] = {m.ARCH.name: m.ARCH for m in _MODULES}


def get_arch(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}") \
            from None


def cell_skip_reason(cfg: ArchConfig, shape: ShapeConfig) -> str:
    """Empty string when the (arch x shape) cell runs; else why not."""
    if shape.kind == "long_decode" and not cfg.subquadratic:
        return ("full quadratic attention: long_500k needs sub-quadratic "
                "attention (DESIGN.md §3)")
    return ""


def valid_cells() -> List[Tuple[ArchConfig, ShapeConfig]]:
    out = []
    for cfg in ARCHS.values():
        for shape in STANDARD_SHAPES.values():
            if not cell_skip_reason(cfg, shape):
                out.append((cfg, shape))
    return out

"""Phi-3-medium-14B [arXiv:2404.14219] — dense GQA decoder."""
from .base import ArchConfig

ARCH = ArchConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
    d_ff=17920, vocab=100352, head_dim=128,
    norm="rmsnorm", act="swiglu", rope_theta=10_000.0,
    notes="RoPE SwiGLU GQA; full attention -> long_500k skipped",
)

"""Phi-4-mini-3.8B [arXiv:2412.08905] — dense GQA, 200k vocab."""
from .base import ArchConfig

ARCH = ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=200064, head_dim=128,
    norm="rmsnorm", act="swiglu", rope_theta=10_000.0,
    tie_embeddings=True,
    notes="RoPE SwiGLU GQA; full attention -> long_500k skipped",
)

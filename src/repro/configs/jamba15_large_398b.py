"""Jamba-1.5-Large-398B [arXiv:2403.19887] — hybrid Mamba+attention 1:7
interleave with MoE every other sublayer (16 experts, top-2).
Mamba sublayers use Mamba-2 SSD geometry (documented adaptation)."""
from .base import ArchConfig, MoeConfig, SsmConfig

ARCH = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536, head_dim=128,
    norm="rmsnorm", act="swiglu",
    block_pattern="MMMMMMMA",
    moe=MoeConfig(n_experts=16, experts_per_tok=2, d_ff=24576,
                  moe_stride=2),
    ssm=SsmConfig(d_state=128, d_conv=4, expand=2, head_dim=128,
                  n_groups=8, chunk=256),
    notes="hybrid: modest KV (1 attn per 8) + SSM state -> "
          "long_500k runs",
)

"""DeepSeek-V3-671B [arXiv:2412.19437] — MLA + 256-expert top-8 MoE
(1 shared expert), sigmoid routing, MTP head.  The paper's first-3-dense-
layers exception is folded into the uniform MoE stack for scan-over-
layers (documented adaptation)."""
from .base import ArchConfig, MlaConfig, MoeConfig

ARCH = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab=129280, head_dim=128,
    norm="rmsnorm", act="swiglu", rope_theta=10_000.0,
    moe=MoeConfig(n_experts=256, experts_per_tok=8, d_ff=2048,
                  n_shared_experts=1, shared_d_ff=2048,
                  router_score="sigmoid"),
    mla=MlaConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    mtp=True,
    notes="MLA latent KV shrinks cache; attention still quadratic -> "
          "long_500k skipped",
)

"""OLMoE-1B-7B [arXiv:2409.02060] — 64-expert top-8 MoE, 1B active."""
from .base import ArchConfig, MoeConfig

ARCH = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304, head_dim=128,
    norm="rmsnorm", act="swiglu",
    moe=MoeConfig(n_experts=64, experts_per_tok=8, d_ff=1024),
    notes="full attention -> long_500k skipped",
)

"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b].
The anyres vision frontend is a STUB: input_specs supplies precomputed
patch embeddings (576 tokens / image tile) prepended to the text."""
from .base import ArchConfig

ARCH = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128,
    norm="rmsnorm", act="swiglu", rope_theta=1_000_000.0,
    vision_tokens=576,
    notes="mistral backbone; full attention -> long_500k skipped",
)

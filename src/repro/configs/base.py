"""Architecture + shape configuration system.

Every assigned architecture is one :class:`ArchConfig` (exact public
hyper-parameters) in its own ``configs/<id>.py``, plus the standard shape
set (``train_4k`` / ``prefill_32k`` / ``decode_32k`` / ``long_500k``).
``reduced()`` derives the CPU-smoke-test configuration of the same family.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

__all__ = ["MlaConfig", "SsmConfig", "MoeConfig", "ArchConfig",
           "ShapeConfig", "STANDARD_SHAPES", "reduced"]


@dataclass(frozen=True)
class MlaConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SsmConfig:
    """Mamba-2 SSD block geometry."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256            # SSD chunk length


@dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    experts_per_tok: int
    d_ff: int                   # per-expert hidden dim
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    router_score: str = "softmax"     # softmax | sigmoid (dsv3)
    capacity_factor: float = 1.25
    moe_stride: int = 1         # MoE every Nth sublayer (Jamba: 2)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None      # default d_model // n_heads
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    act: str = "swiglu"                 # swiglu | gelu
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None
    tie_embeddings: bool = False
    moe: Optional[MoeConfig] = None
    mla: Optional[MlaConfig] = None
    ssm: Optional[SsmConfig] = None
    # hybrid interleave: sublayer pattern per scan block, e.g. "MMMMMMMA"
    # (M = Mamba-2, A = attention); dense transformers use "A", pure SSM "M"
    block_pattern: str = "A"
    # encoder-decoder (whisper): encoder layer count; frontend is a stub
    encoder_layers: int = 0
    encoder_seq: int = 0                # precomputed frame/patch positions
    # vision-language (llava): patch embeddings prepended to text
    vision_tokens: int = 0
    mtp: bool = False                   # multi-token-prediction head (dsv3)
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    notes: str = ""

    # -- derived ---------------------------------------------------------------

    def __post_init__(self):
        if self.n_layers % len(self.block_pattern):
            raise ValueError(
                f"{self.name}: {self.n_layers} layers not divisible by "
                f"pattern {self.block_pattern!r}")

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_blocks(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def attention_free(self) -> bool:
        return "A" not in self.block_pattern

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k decode?"""
        return self.attention_free or self.sliding_window is not None \
            or self.family == "hybrid"

    def param_count(self) -> int:
        """Analytic parameter count, mirroring the model structure:
        every sublayer gets an FFN (MoE on ``moe_stride`` sublayers)
        except in pure-SSM stacks."""
        d = self.d_model
        n = 0
        n += self.vocab * d                       # embed
        if not self.tie_embeddings:
            n += self.vocab * d                   # lm head
        for _ in range(self.n_blocks):
            for i, ch in enumerate(self.block_pattern):
                n += d                            # sublayer norm
                if ch == "A":
                    n += self._attn_params()
                    if self.encoder_layers:       # cross-attention block
                        n += 4 * d * self.n_heads * self.hd + d
                else:
                    n += self._ssm_params()
                if self.family != "ssm":
                    use_moe = (self.moe is not None
                               and i % max(self.moe.moe_stride, 1) == 0)
                    n += d + self._ffn_params(use_moe)
        n += d                                    # final norm
        if self.encoder_layers:
            n += self.encoder_layers * (
                4 * d * self.n_heads * self.hd + self._ffn_params(False)
                + 2 * d) + d
        if self.mtp:
            n += 2 * d * d + d
        return n

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.hd
        if self.mla is not None:
            m = self.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            n = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
            n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            n += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim
                                                  + m.v_head_dim)
            n += self.n_heads * m.v_head_dim * d
            return n
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        return q + kv + o

    def _ffn_params(self, use_moe: bool = True) -> int:
        d = self.d_model
        if self.moe is not None and use_moe:
            m = self.moe
            per = 3 * d * m.d_ff if self.act == "swiglu" else 2 * d * m.d_ff
            n = m.n_experts * per + d * m.n_experts       # router
            if m.n_shared_experts:
                sf = m.shared_d_ff or m.d_ff
                n += m.n_shared_experts * 3 * d * sf
            return n
        if self.act == "swiglu":
            return 3 * d * self.d_ff
        return 2 * d * self.d_ff

    def _ssm_params(self) -> int:
        s = self.ssm
        assert s is not None
        d = self.d_model
        d_in = s.expand * d
        n_heads = d_in // s.head_dim
        conv_dim = d_in + 2 * s.n_groups * s.d_state
        n = d * (2 * d_in + 2 * s.n_groups * s.d_state + n_heads)  # in_proj
        n += conv_dim * s.d_conv                                   # conv1d
        n += n_heads * 2                                           # A, D
        n += d_in * d                                              # out_proj
        return n


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # train | prefill | decode | long_decode

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")


STANDARD_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "long_decode"),
}


def depth_variant(cfg: ArchConfig, k: int) -> ArchConfig:
    """Same architecture at ``k`` scan blocks (full width) — the roofline
    cost probes reconstruct per-step totals from depth-1/-2 compiles."""
    return dataclasses.replace(
        cfg, name=f"{cfg.name}-d{k}",
        n_layers=k * len(cfg.block_pattern),
        encoder_layers=k if cfg.encoder_layers else 0)


def reduced(cfg: ArchConfig, *, layers_per_kind: int = 1,
            d_model: int = 64, vocab: int = 256) -> ArchConfig:
    """Small same-family config for CPU smoke tests."""
    pat = cfg.block_pattern
    d = d_model
    n_heads = max(2, min(cfg.n_heads, 4))
    hd = d // n_heads
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=len(pat) * layers_per_kind,
        d_model=d, n_heads=n_heads,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=d * 2, vocab=vocab, head_dim=hd,
        sliding_window=16 if cfg.sliding_window else None,
        moe=None if cfg.moe is None else MoeConfig(
            n_experts=4, experts_per_tok=min(2, cfg.moe.experts_per_tok),
            d_ff=d, n_shared_experts=min(1, cfg.moe.n_shared_experts),
            shared_d_ff=d if cfg.moe.n_shared_experts else 0,
            router_score=cfg.moe.router_score),
        mla=None if cfg.mla is None else MlaConfig(
            q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=hd,
            qk_rope_head_dim=8, v_head_dim=hd),
        ssm=None if cfg.ssm is None else SsmConfig(
            d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1,
            chunk=16),
        encoder_layers=layers_per_kind if cfg.encoder_layers else 0,
        encoder_seq=32 if cfg.encoder_layers else 0,
        vision_tokens=8 if cfg.vision_tokens else 0,
        param_dtype="float32", compute_dtype="float32",
    )
    return dataclasses.replace(cfg, **kw)

"""Checkpoint manager: atomic manifests, async saves, keep-last-k GC.

Layout per step::

    <dir>/step_<N>.tmp/        (written first)
        shard_<i>.npz          one npz per host shard (flat path -> array)
        manifest.json          pytree structure + dtypes + metadata
    <dir>/step_<N>/            (atomic rename once complete)
    <dir>/LATEST               text file naming the newest complete step

Restart safety: a crash mid-save leaves only ``*.tmp`` directories, which
restore ignores and the next save garbage-collects.  Restores validate
the manifest against the expected tree structure before loading bytes.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_pytree", "load_pytree", "CheckpointManager"]


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_pytree(tree, directory: str, *, metadata: Optional[Dict] = None,
                n_shards: int = 1) -> None:
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    items = _flatten_with_paths(tree)
    manifest = {
        "keys": [k for k, _ in items],
        "dtypes": [str(np.asarray(v).dtype) for _, v in items],
        "shapes": [list(np.asarray(v).shape) for _, v in items],
        "n_shards": n_shards,
        "metadata": metadata or {},
        "time": time.time(),
    }
    for s in range(n_shards):
        blob = {k.replace("/", "__"): np.asarray(v)
                for i, (k, v) in enumerate(items)
                if i % n_shards == s}
        np.savez(os.path.join(tmp, f"shard_{s}.npz"), **blob)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)          # atomic commit


def load_pytree(template, directory: str) -> Tuple[Any, Dict]:
    """Load into the structure of ``template`` (validated)."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    items = _flatten_with_paths(template)
    want = [k for k, _ in items]
    if manifest["keys"] != want:
        missing = set(want) - set(manifest["keys"])
        extra = set(manifest["keys"]) - set(want)
        raise ValueError(f"checkpoint structure mismatch: missing="
                         f"{sorted(missing)[:5]} extra={sorted(extra)[:5]}")
    blobs: Dict[str, np.ndarray] = {}
    for s in range(manifest["n_shards"]):
        with np.load(os.path.join(directory, f"shard_{s}.npz")) as z:
            for k in z.files:
                blobs[k.replace("__", "/")] = z[k]
    leaves = [blobs[k] for k, _ in items]
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves), \
        manifest["metadata"]


class CheckpointManager:
    """Step-indexed checkpoints with async save and keep-last-k."""

    def __init__(self, root: str, *, keep: int = 3,
                 n_shards: int = 1) -> None:
        self.root = root
        self.keep = keep
        self.n_shards = n_shards
        os.makedirs(root, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # -- paths -----------------------------------------------------------------

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:010d}")

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.isdir(os.path.join(self.root, name)):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save / restore ----------------------------------------------------------

    def save(self, step: int, tree, *, metadata: Optional[Dict] = None,
             blocking: bool = False) -> None:
        self.wait()                        # one in flight at a time
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot off-device

        def work():
            save_pytree(host_tree, self._dir(step), metadata=metadata,
                        n_shards=self.n_shards)
            with open(os.path.join(self.root, "LATEST"), "w") as f:
                f.write(str(step))
            self._gc()

        if blocking:
            work()
        else:
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()

    def restore(self, template, step: Optional[int] = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None, None
        tree, meta = load_pytree(template, self._dir(step))
        return step, tree, meta

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._dir(s), ignore_errors=True)
        for name in os.listdir(self.root):        # crashed partial saves
            if name.endswith(".tmp"):
                full = os.path.join(self.root, name)
                if time.time() - os.path.getmtime(full) > 60:
                    shutil.rmtree(full, ignore_errors=True)

"""CLI for the serving simulator.

Examples::

    python -m repro.serve --trace poisson --rate 8 --requests 200 \\
        --fidelity trace
    python -m repro.serve --trace bursty --rate 6 --requests 100 \\
        --policy static
    python -m repro.serve --trace file --trace-file t.json \\
        --policy both --json out.json
"""
from __future__ import annotations

import argparse
import sys
from typing import Any, Dict, List

from ..core.machine import LINK_TIERS
from .metrics import metrics_json
from .policy import POLICIES, make_policy
from .trace_replay import (Request, ServeSim, bursty_trace, load_trace,
                           poisson_trace)
from .workload import ServeModelCfg, StepCostTable


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Request-level CIM LM serving simulator")
    p.add_argument("--trace", choices=("poisson", "bursty", "file"),
                   default="poisson")
    p.add_argument("--trace-file", help="JSON trace for --trace file")
    p.add_argument("--rate", type=float, default=8.0,
                   help="mean arrival rate, req/s")
    p.add_argument("--requests", type=int, default=200)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--burst", type=float, default=3.0,
                   help="bursty: on-phase rate multiplier")
    p.add_argument("--fidelity",
                   choices=("analytic", "trace", "simulate"),
                   default="trace")
    p.add_argument("--policy",
                   choices=tuple(sorted(POLICIES)) + ("both",),
                   default="continuous")
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--engine", choices=("event", "array"),
                   default="array",
                   help="replay engine: the array-batched engine "
                        "(default; orders of magnitude faster) or the "
                        "reference discrete-event loop — both produce "
                        "byte-identical metrics JSON")
    p.add_argument("--prefill-policy",
                   choices=("fifo", "batched", "chunked"),
                   default="fifo",
                   help="fifo: batch-1 prompts back to back; batched: "
                        "FCFS prefill batches up to --prefill-max-batch; "
                        "chunked: prompt chunks co-scheduled into decode "
                        "iterations under a --chunk-tokens budget "
                        "(batched/chunked need --engine array)")
    p.add_argument("--prefill-max-batch", type=int, default=8,
                   help="batch cap for --prefill-policy batched")
    p.add_argument("--chunk-tokens", type=int, default=32,
                   help="per-iteration token budget for "
                        "--prefill-policy chunked")
    p.add_argument("--streaming-percentiles", action="store_true",
                   help="estimate latency percentiles with the P2 "
                        "streaming algorithm (O(1) memory; approximate) "
                        "instead of the exact sorted sample")
    p.add_argument("--kv-frac", type=float, default=0.5,
                   help="fraction of global memory reserved for KV")
    p.add_argument("--deadline-s", type=float, default=None,
                   help="per-request SLO (s) from arrival; late "
                        "completions count as timeouts and drop out "
                        "of goodput")
    p.add_argument("--max-queue", type=int, default=None,
                   help="prefill admission cap: arrivals that find "
                        "this many requests waiting are shed (with "
                        "--max-retries retry attempts)")
    p.add_argument("--max-retries", type=int, default=0,
                   help="retry attempts for shed requests "
                        "(exponential backoff)")
    p.add_argument("--retry-backoff-s", type=float, default=0.05,
                   help="base backoff before a shed request retries")
    p.add_argument("--max-sim-s", type=float, default=None,
                   help="abort the replay with a diagnostic if "
                        "simulated time passes this cap (guards "
                        "against over-capacity traces running "
                        "unboundedly long)")
    p.add_argument("--n-layers", type=int, default=2)
    p.add_argument("--d-model", type=int, default=128)
    p.add_argument("--n-heads", type=int, default=4)
    p.add_argument("--vocab", type=int, default=256)
    p.add_argument("--max-prompt", type=int, default=64)
    p.add_argument("--max-new", type=int, default=64)
    p.add_argument("--no-incremental", action="store_true",
                   help="price decode with full KV re-staging")
    p.add_argument("--chips", default="1",
                   help="chip mesh, e.g. '2x2', '1x4' or a count "
                        "('4' picks the squarest mesh); '1' = classic "
                        "single-chip serving")
    p.add_argument("--link", default="pcb",
                   choices=tuple(sorted(LINK_TIERS)),
                   help="inter-chip link tier for --chips > 1")
    p.add_argument("--flow-cache",
                   help="flow pass/table disk cache directory "
                        "(also honors $REPRO_FLOW_CACHE); a second "
                        "run with the same knobs skips compilation")
    p.add_argument("--calibration",
                   help="saved calibration preset name (see "
                        "flow.calibrate(..., save=...))")
    p.add_argument("--json", help="write metrics JSON here")
    return p


def _system(args: argparse.Namespace):
    """``--chips``/``--link`` -> SystemConfig (None for one chip)."""
    from ..system import SystemConfig
    t = str(args.chips).lower().replace("×", "x")
    try:
        if "x" in t:
            cx, cy = (int(v) for v in t.split("x", 1))
            sysc = SystemConfig(chips_x=cx, chips_y=cy, link=args.link)
        else:
            sysc = SystemConfig.mesh(int(t), link=args.link)
    except ValueError as e:
        raise SystemExit(f"bad --chips {args.chips!r}: {e}") from None
    return sysc if sysc.n_chips > 1 else None


def _trace(args: argparse.Namespace) -> List[Request]:
    if args.trace == "file":
        if not args.trace_file:
            raise SystemExit("--trace file requires --trace-file")
        return load_trace(args.trace_file)
    kw = dict(rate=args.rate, n=args.requests, seed=args.seed,
              max_prompt=args.max_prompt, max_new=args.max_new)
    if args.trace == "bursty":
        return bursty_trace(burst=args.burst, **kw)
    return poisson_trace(**kw)


def _report(m: Dict[str, Any]) -> str:
    t, p = m["ttft_s"], m["tpot_s"]
    s = (
        f"policy={m['policy']:<11s} engine={m['engine']}/"
        f"{m['prefill_policy']} req={m['requests']} "
        f"tok/s={m['throughput_tok_s']:8.1f} "
        f"ttft p50={t['p50'] * 1e3:7.2f}ms p95={t['p95'] * 1e3:7.2f}ms "
        f"p99={t['p99'] * 1e3:7.2f}ms  "
        f"tpot p50={p['p50'] * 1e6:6.1f}us p99={p['p99'] * 1e6:6.1f}us")
    if "goodput_tok_s" in m:
        s += (f"  goodput={m['goodput_tok_s']:8.1f} "
              f"shed={m['shed_requests']} "
              f"timeout={m['timeout_requests']} "
              f"retries={m['retries']}")
    return s


def main(argv: List[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    cfg = ServeModelCfg(
        n_layers=args.n_layers, d_model=args.d_model,
        n_heads=args.n_heads, vocab=args.vocab,
        max_prompt=args.max_prompt, max_new=args.max_new)
    system = _system(args)
    mesh = (f", mesh {system.chips_x}x{system.chips_y} "
            f"'{system.link.name}'" if system is not None else "")
    print(f"compiling step costs (fidelity={args.fidelity}{mesh}) ...",
          flush=True)
    table = StepCostTable(cfg, fidelity=args.fidelity,
                          incremental=not args.no_incremental,
                          system=system,
                          calibration=args.calibration,
                          flow_cache=args.flow_cache)
    if table.cache_hit:
        print("step-cost table loaded from flow cache "
              "(compilation skipped)")
    requests = _trace(args)
    policies = sorted(POLICIES) if args.policy == "both" \
        else [args.policy]
    results: Dict[str, Any] = {}
    for name in policies:
        try:
            sim = ServeSim(table, make_policy(name, args.max_batch),
                           kv_frac=args.kv_frac,
                           deadline_s=args.deadline_s,
                           max_queue=args.max_queue,
                           max_retries=args.max_retries,
                           retry_backoff_s=args.retry_backoff_s,
                           engine=args.engine,
                           prefill_policy=args.prefill_policy,
                           prefill_max_batch=args.prefill_max_batch,
                           chunk_tokens=args.chunk_tokens,
                           percentile_mode=(
                               "streaming" if args.streaming_percentiles
                               else "exact"))
        except ValueError as e:
            raise SystemExit(f"error: {e}") from None
        try:
            m = sim.run(requests, max_sim_s=args.max_sim_s)
        except RuntimeError as e:
            raise SystemExit(f"error: {e}") from None
        results[name] = m
        print(_report(m))
    if args.json:
        payload = results if len(results) > 1 \
            else results[policies[0]]
        with open(args.json, "w") as f:
            f.write(metrics_json(payload))
            f.write("\n")
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Batching policies and KV-cache admission control.

Two schedulers, mirroring the serving-systems literature:

* :class:`StaticBatcher` — request-level batching: a batch is formed
  only when the decode engine is idle and then runs until *every*
  member finishes (early finishers leave dead slots, new arrivals wait
  behind the whole batch — classic head-of-line blocking);
* :class:`ContinuousBatcher` — iteration-level (ORCA-style) batching:
  at every decode-iteration boundary, finished requests leave and
  queued requests join, so slots never idle while work is waiting.

Both admit under a KV-cache budget: a request reserves its *final*
footprint (prompt + all generated tokens) at admission, so a running
request can never be evicted mid-generation.  Admission is strict
FCFS — the scan stops at the first request that does not fit, which
trades a little utilisation for freedom from starvation.

Contract: :meth:`Batcher.admit` must be **pure** — it returns the
prefix of ``queue`` to admit without mutating ``active``, ``queue``,
or itself.  The array replay engine (:mod:`repro.serve.engine`)
relies on this: during horizon planning it calls ``admit``
speculatively at simulated boundaries and discards the result when a
tail arrival invalidates the horizon.  A stateful policy would
double-count those probe calls.
"""
from __future__ import annotations

from typing import Callable, List, Protocol, Sequence

__all__ = ["Batcher", "StaticBatcher", "ContinuousBatcher",
           "make_policy", "POLICIES"]


class _HasFootprint(Protocol):
    kv_reserved: int


class Batcher:
    """Decides which queued requests join the decode batch."""

    name = "base"

    def __init__(self, max_batch: int = 8) -> None:
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch

    def admit(self, active: Sequence[object],
              queue: Sequence[_HasFootprint],
              kv_free: int) -> List[_HasFootprint]:
        """Return the prefix of ``queue`` to admit.  Must be pure —
        the array engine probes boundaries speculatively and may
        discard the returned admission without applying it."""
        raise NotImplementedError

    def _take_fcfs(self, queue: Sequence[_HasFootprint], slots: int,
                   kv_free: int) -> List[_HasFootprint]:
        out: List[_HasFootprint] = []
        for r in queue:
            if len(out) >= slots:
                break
            if r.kv_reserved > kv_free:
                break  # strict FCFS: do not jump the queue
            out.append(r)
            kv_free -= r.kv_reserved
        return out


class StaticBatcher(Batcher):
    """Admit only into an idle engine; drain the batch to completion."""

    name = "static"

    def admit(self, active: Sequence[object],
              queue: Sequence[_HasFootprint],
              kv_free: int) -> List[_HasFootprint]:
        if active:
            return []
        return self._take_fcfs(queue, self.max_batch, kv_free)


class ContinuousBatcher(Batcher):
    """Top up the batch at every iteration boundary (iteration-level)."""

    name = "continuous"

    def admit(self, active: Sequence[object],
              queue: Sequence[_HasFootprint],
              kv_free: int) -> List[_HasFootprint]:
        slots = self.max_batch - len(active)
        if slots <= 0:
            return []
        return self._take_fcfs(queue, slots, kv_free)


POLICIES: dict[str, Callable[[int], Batcher]] = {
    "static": StaticBatcher,
    "continuous": ContinuousBatcher,
}


def make_policy(name: str, max_batch: int = 8) -> Batcher:
    try:
        return POLICIES[name](max_batch)
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; choose from {sorted(POLICIES)}"
        ) from None

"""repro.serve: request-level LM serving simulator on the CIM stack.

Replays arrival traces (Poisson / bursty / file) against compiled CIM
artifacts with prefill/decode disaggregation, static or continuous
(iteration-level) batching, and KV-cache admission control.  Step
costs come from the fidelity ladder — the decode path uses the
append-row (``kv_append``) incremental weight staging so a decode
step is O(1) in KV length.

Two replay engines share one semantics: the reference discrete-event
loop (``engine="event"``) and the array-batched engine
(``engine="array"``, the default) that prices whole scheduling
horizons with numpy slice adds and ``cumsum`` clock chains — byte-
identical metrics JSON, orders of magnitude faster, and the only
engine for ``prefill_policy="batched"``/``"chunked"`` (FCFS batched
prefill and Sarathi-style chunked prefill co-scheduled with decode).
Trace generation is vectorized through a CPython-bit-identical
MT19937 (:class:`~repro.serve.rng.VecMT`), so million-request traces
draw in numpy batches without changing a byte of any committed
trace.

Quick start::

    python -m repro.serve --trace poisson --rate 8 --requests 200 \\
        --fidelity trace

or programmatically::

    from repro.serve import (ServeModelCfg, StepCostTable, ServeSim,
                             make_policy, poisson_trace)
    table = StepCostTable(ServeModelCfg(), fidelity="trace")
    sim = ServeSim(table, make_policy("continuous", max_batch=8))
    metrics = sim.run(poisson_trace(rate=8.0, n=200, seed=0))
"""
from .bucketing import (bucket_batch_sizes, bucket_boundaries,
                        bucket_for, group_by_bucket)
from .engine import run_array
from .metrics import (RequestRecord, StreamingPercentiles, metrics_json,
                      percentile, summarize, summarize_soa)
from .policy import (POLICIES, Batcher, ContinuousBatcher,
                     StaticBatcher, make_policy)
from .rng import VecMT
from .trace_replay import (Request, ServeSim, bursty_trace, load_trace,
                           poisson_trace, poisson_trace_arrays,
                           save_trace)
from .workload import ServeModelCfg, StepCostTable

__all__ = [
    "Request", "ServeSim", "poisson_trace", "poisson_trace_arrays",
    "bursty_trace", "load_trace", "save_trace",
    "ServeModelCfg", "StepCostTable",
    "Batcher", "StaticBatcher", "ContinuousBatcher", "make_policy",
    "POLICIES", "run_array", "VecMT",
    "RequestRecord", "percentile", "summarize", "summarize_soa",
    "StreamingPercentiles", "metrics_json",
    "bucket_boundaries", "bucket_for", "bucket_batch_sizes",
    "group_by_bucket",
]

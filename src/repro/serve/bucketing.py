"""Length-bucketed admission for the serving simulator.

Requests with similar sequence lengths are grouped into exponentially
spaced buckets (the ``data_reader`` batching idiom from tensor2tensor):
step costs are compiled once per *bucket* rather than once per length,
and admission/batching decisions quantise a request's KV length to its
bucket boundary.  The boundary is always an upper bound, so bucketed
costs are conservative.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

__all__ = [
    "bucket_boundaries",
    "bucket_for",
    "bucket_batch_sizes",
    "group_by_bucket",
]


def bucket_boundaries(max_length: int, min_length: int = 8,
                      step: float = 1.25) -> List[int]:
    """Exponentially spaced inclusive upper bounds covering
    ``[1, max_length]``.

    Consecutive boundaries grow by at least one and at most ``step``×;
    the final boundary is exactly ``max_length``.
    """
    if max_length < 1:
        raise ValueError("max_length must be >= 1")
    if step <= 1.0:
        raise ValueError("step must be > 1.0")
    x = max(1, min(min_length, max_length))
    out: List[int] = []
    while x < max_length:
        out.append(x)
        x = max(x + 1, int(x * step))
    out.append(max_length)
    return out


def bucket_for(length: int, boundaries: Sequence[int]) -> int:
    """Smallest boundary that admits ``length``.

    Raises ``ValueError`` when the length exceeds every boundary —
    the caller sized its buckets wrong, which should be loud.
    """
    if length < 0:
        raise ValueError("length must be >= 0")
    for b in boundaries:
        if length <= b:
            return b
    raise ValueError(
        f"length {length} exceeds largest bucket {boundaries[-1]}")


def bucket_batch_sizes(boundaries: Sequence[int], tokens_per_batch: int,
                       max_batch: int) -> Dict[int, int]:
    """Per-bucket batch-size caps under a token budget.

    Longer buckets admit fewer requests per batch so that
    ``batch × boundary`` stays within ``tokens_per_batch`` (at least one
    request per bucket, at most ``max_batch``).
    """
    if tokens_per_batch < 1 or max_batch < 1:
        raise ValueError("budgets must be >= 1")
    return {b: max(1, min(max_batch, tokens_per_batch // b))
            for b in boundaries}


def group_by_bucket(lengths: Sequence[int],
                    boundaries: Sequence[int]) -> Dict[int, List[int]]:
    """Indices of ``lengths`` grouped by their admitting bucket."""
    out: Dict[int, List[int]] = {}
    for i, n in enumerate(lengths):
        out.setdefault(bucket_for(n, boundaries), []).append(i)
    return out

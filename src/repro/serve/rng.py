"""Vectorized, CPython-compatible MT19937 word stream.

``random.Random`` is the committed definition of every serving trace
(the 200-request golden trace was drawn from it), so the vectorized
trace generators cannot switch RNGs without changing bytes.  Instead
:class:`VecMT` reproduces CPython's Mersenne Twister *exactly* — it
seeds itself from ``random.Random(seed).getstate()`` (so seeding
semantics are CPython's by construction) and then regenerates the
624-word state blocks with numpy array ops instead of one C call per
draw.

The in-place twist reads a mix of old and already-updated state words,
which vectorizes as four slice passes (the classic reference loop's
``mt[kk+(M-N)]`` reads new words, so the middle section is split where
its reads would overlap its own writes):

* ``kk in [0, N-M)``      — sources entirely old state;
* ``kk in [N-M, 2(N-M))`` — sources pass-1 output;
* ``kk in [2(N-M), N-1)`` — sources pass-2 output;
* ``kk = N-1``            — reads the *new* ``mt[0]``.

Tempering is elementwise.  The result is a bit-identical uint32 stream
to ``Random.getrandbits(32)`` at ~10x the throughput, and — more
importantly — a stream the trace generators can slice into arrays.

Consumption helpers mirror the two CPython primitives the trace
generators use:

* ``random()``  — two words: ``(a >> 5) * 2**26 + (b >> 6)`` scaled by
  ``2**-53``;
* ``_randbelow(n)`` — ``getrandbits(k)`` (= one word ``>> (32 - k)``
  for ``k <= 32``) redrawn while the value is ``>= n``.

The rejection loop makes word consumption data-dependent, so batch
extraction first walks the op layout over a prefetched word buffer
(cheap integer scan), then gathers all values with numpy fancy
indexing.
"""
from __future__ import annotations

import bisect
import random
from typing import List, Tuple

import numpy as np

__all__ = ["VecMT", "uniform_randbelow_batch", "uniform_at"]

_N, _M = 624, 397
_UPPER = np.uint32(0x80000000)
_LOWER = np.uint32(0x7FFFFFFF)
_MAG = np.uint32(0x9908B0DF)
_ZERO = np.uint32(0)


class VecMT:
    """CPython-bit-identical MT19937 emitting numpy word blocks."""

    def __init__(self, seed: int) -> None:
        state = random.Random(seed).getstate()[1]
        # a freshly seeded Random has consumed nothing: index == N
        assert state[_N] == _N, "VecMT requires a fresh seed state"
        self._mt = np.array(state[:_N], dtype=np.uint32)
        self._buf = np.empty(0, dtype=np.uint32)
        self._consumed = 0

    # -- block generation ---------------------------------------------

    def _twist(self) -> np.ndarray:
        mt = self._mt
        nxt = np.empty(_N, dtype=np.uint32)
        one = np.uint32(1)

        def tw(y: np.ndarray, src: np.ndarray) -> np.ndarray:
            return src ^ (y >> one) ^ np.where(y & one, _MAG, _ZERO)

        k = _N - _M                                      # 227
        y = (mt[0:k] & _UPPER) | (mt[1:k + 1] & _LOWER)
        nxt[0:k] = tw(y, mt[_M:_N])
        y = (mt[k:2 * k] & _UPPER) | (mt[k + 1:2 * k + 1] & _LOWER)
        nxt[k:2 * k] = tw(y, nxt[0:k])
        y = (mt[2 * k:_N - 1] & _UPPER) | (mt[2 * k + 1:_N] & _LOWER)
        nxt[2 * k:_N - 1] = tw(y, nxt[k:_M - 1])
        y = (mt[_N - 1] & _UPPER) | (nxt[0] & _LOWER)    # new mt[0]
        nxt[_N - 1] = tw(y, nxt[_M - 1])

        self._mt = nxt
        x = nxt.copy()
        x ^= x >> np.uint32(11)
        x ^= (x << np.uint32(7)) & np.uint32(0x9D2C5680)
        x ^= (x << np.uint32(15)) & np.uint32(0xEFC60000)
        x ^= x >> np.uint32(18)
        return x

    # -- stream access ------------------------------------------------

    def peek(self, n: int) -> np.ndarray:
        """First ``n`` unconsumed words, without consuming them."""
        if len(self._buf) < n:
            blocks = [self._buf]
            have = len(self._buf)
            while have < n:
                blocks.append(self._twist())
                have += _N
            self._buf = np.concatenate(blocks)
        return self._buf[:n]

    def consume(self, n: int) -> None:
        assert n <= len(self._buf), "consume past peeked buffer"
        self._buf = self._buf[n:]
        self._consumed += n

    @property
    def consumed(self) -> int:
        """Total words consumed — equals ``getrandbits(32)`` calls."""
        return self._consumed


_INV53 = 1.0 / 9007199254740992.0     # CPython's random() scaling


def uniform_randbelow_batch(
        mt: VecMT, n: int,
        spans: Tuple[int, ...]) -> Tuple[np.ndarray, List[np.ndarray]]:
    """Draw ``n`` repetitions of one ``random()`` double followed by
    one ``_randbelow(span)`` per span, mirroring CPython's word
    consumption order exactly (each rejected ``getrandbits`` draw
    burns one word).

    Returns ``(uniforms, [randbelow values per span])`` as numpy
    arrays.  The layout walk is an integer pointer chase over a
    prefetched accept mask (rejection makes consumption
    data-dependent); all values are then gathered with vectorized
    fancy indexing.
    """
    if n == 0:
        return (np.empty(0, dtype=np.float64),
                [np.empty(0, dtype=np.int64) for _ in spans])
    # _randbelow(s) draws k = s.bit_length() bits, i.e. one word
    # shifted right by 32-k, and redraws while the value is >= s.
    shifts = [np.uint32(32 - s.bit_length()) for s in spans]
    nspan = len(spans)
    stride = 2 + nspan                # words/request with zero rejects

    def masks(w: np.ndarray):
        return [(w >> sh) < s for sh, s in zip(shifts, spans)]

    # expected words per _randbelow(s) is 2^k / s (geometric redraw);
    # provision the expectation plus slack so re-peeking stays rare
    exp_words = 2.0 + sum((1 << s.bit_length()) / s for s in spans)
    words = mt.peek(int(n * exp_words) + 4096)
    accept = masks(words)

    # A request starting at w is "clean" (consumes exactly `stride`
    # words, span j accepted at w+2+j) iff every span's first draw
    # accepts.  Rejects are sparse (k-bit acceptance > 1/2, typically
    # ~0.95), so the walk is a run-jump scan: bisect to the next dirty
    # start in this residue class mod `stride`, emit the clean run as
    # one segment, resolve the single dirty request scalar.
    def dirty_lists(lo: int, hi: int):
        w = hi - (stride - 1)
        clean = accept[0][lo + 2:w + 2].copy()
        for j in range(1, nspan):
            clean &= accept[j][lo + 2 + j:w + 2 + j]
        bad = np.flatnonzero(~clean) + lo
        return [bad[bad % stride == r].tolist() for r in range(stride)]

    dirty = dirty_lists(0, len(words))

    def extend() -> None:
        nonlocal words, accept, dirty
        old = len(words)
        words = mt.peek(old + max(4096, old >> 1))
        tail = masks(words[old:])
        accept = [np.concatenate([a, t]) for a, t in zip(accept, tail)]
        seam = old - (stride - 1)     # clean[] near the seam was cut off
        for r, lst in zip(range(stride), dirty_lists(seam, len(words))):
            dirty[r].extend(x for x in lst if x >= seam)

    seg_i: List[int] = []             # first request index of segment
    seg_cnt: List[int] = []           # requests in segment
    seg_s: List[int] = []             # word position of first request
    fix_i = [[] for _ in spans]       # dirty request -> true position
    fix_p = [[] for _ in spans]
    s = 0
    i = 0
    while i < n:
        lst = dirty[s % stride]
        k = bisect.bisect_left(lst, s)
        b = lst[k] if k < len(lst) else None
        if b is None or (b - s) // stride >= n - i:
            seg_i.append(i)
            seg_cnt.append(n - i)
            seg_s.append(s)
            s += stride * (n - i)
            i = n
            break
        run = (b - s) // stride       # clean requests before the dirty
        seg_i.append(i)
        seg_cnt.append(run + 1)
        seg_s.append(s)
        i += run + 1
        pos = b + 2
        for j in range(nspan):
            while pos + 1 >= len(words) or not accept[j][pos]:
                if pos + 1 >= len(words):
                    extend()
                    continue
                pos += 1
            fix_i[j].append(i - 1)
            fix_p[j].append(pos)
            pos += 1
        s = pos
    while s + 64 > len(words):
        extend()

    base = np.array(seg_s, dtype=np.int64) - \
        np.array(seg_i, dtype=np.int64) * stride
    u_pos = np.repeat(base, seg_cnt) + \
        np.arange(n, dtype=np.int64) * stride
    rb_pos = [u_pos + (2 + j) for j in range(nspan)]
    for j in range(nspan):
        if fix_i[j]:
            rb_pos[j][np.array(fix_i[j])] = np.array(fix_p[j])

    a = (words[u_pos] >> np.uint32(5)).astype(np.float64)
    b = (words[u_pos + 1] >> np.uint32(6)).astype(np.float64)
    uniforms = (a * 67108864.0 + b) * _INV53
    values = [(words[p] >> sh).astype(np.int64)
              for p, sh in zip(rb_pos, shifts)]
    mt.consume(s)
    return uniforms, values


def uniform_at(words: np.ndarray, pos: int) -> float:
    """CPython ``random()`` double from two stream words at ``pos``."""
    return (float(words[pos] >> np.uint32(5)) * 67108864.0
            + float(words[pos + 1] >> np.uint32(6))) * _INV53

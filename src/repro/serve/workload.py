"""Serving-side cost model: compiled step-latency tables.

The serving simulator never prices a token by running the compiler in
its event loop.  Instead, :class:`StepCostTable` compiles the prefill
workload (``transformer_lm``) and the decode workload
(``transformer_decode``, incremental KV append) once per *length
bucket* on the chosen fidelity rung, and memoises the results:

* prefill: seconds to process a prompt of each bucketed length
  (batch 1 — the prefill engine runs prompts back to back);
* decode: an affine fit ``base + per_seq × batch`` per KV bucket,
  obtained from a batch-1 and a batch-K evaluation of the same
  artifact.  An iteration over a mixed batch is then priced in O(batch)
  as ``base(max bucket) + Σ per_seq(bucket_i)``.

Because the decode workload uses the append-row (``kv_append``)
weight path, ``per_seq`` stays O(1) in the KV length — the property
the regression test in ``tests/test_serve.py`` pins.

Tables are **disk-cacheable**: with a flow pass cache attached
(``flow_cache=`` or the ``REPRO_FLOW_CACHE`` environment variable),
the finished bucket tables are stored under a digest of everything
that shaped them — chip, mesh, fidelity, bucket grid, calibration —
so a second ``python -m repro.serve`` run with the same knobs skips
compilation entirely.  A ``system=`` :class:`repro.system.SystemConfig`
prices every bucket on the multi-chip plan instead of one chip.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Union

from ..core.arch import ChipConfig, default_chip
from ..core.machine import Calibration
from ..flow import (CompileOptions, PassDiskCache, compile as flow_compile,
                    default_pipeline, load_calibration)
from ..flow.diskcache import ENV_VAR as _FLOW_CACHE_ENV
from .bucketing import bucket_boundaries, bucket_for

__all__ = ["ServeModelCfg", "StepCostTable"]

_TABLE_VERSION = 2


@dataclass(frozen=True)
class ServeModelCfg:
    """Model served by the simulator (mirrors the workload builders)."""
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    d_ff: Optional[int] = None
    vocab: int = 256
    max_prompt: int = 64
    max_new: int = 64

    @property
    def max_seq(self) -> int:
        return self.max_prompt + self.max_new

    def kv_bytes(self, kv_len: int) -> int:
        """Resident KV-cache footprint at ``kv_len`` tokens (int8 K+V)."""
        return 2 * self.n_layers * kv_len * self.d_model

    def to_dict(self) -> Dict[str, Any]:
        return {
            "n_layers": self.n_layers, "d_model": self.d_model,
            "n_heads": self.n_heads, "d_ff": self.d_ff,
            "vocab": self.vocab, "max_prompt": self.max_prompt,
            "max_new": self.max_new,
        }


class StepCostTable:
    """Bucketed prefill/decode step costs from compiled artifacts."""

    def __init__(self, cfg: ServeModelCfg,
                 chip: Optional[ChipConfig] = None,
                 fidelity: str = "trace",
                 bucket_step: float = 2.0,
                 fit_batch: int = 8,
                 incremental: bool = True,
                 system: Optional[Any] = None,
                 calibration: Union[Calibration, str, None] = None,
                 flow_cache: Optional[str] = None) -> None:
        if fit_batch < 2:
            raise ValueError("fit_batch must be >= 2 for an affine fit")
        self.cfg = cfg
        self.chip = chip if chip is not None else default_chip()
        self.fidelity = fidelity
        self.fit_batch = fit_batch
        self.incremental = incremental
        self.system = system
        if isinstance(calibration, str):
            calibration = load_calibration(calibration)
        self.calibration = calibration
        self._hz = self.chip.clock_ghz * 1e9
        self.prefill_buckets = bucket_boundaries(
            cfg.max_prompt, step=bucket_step)
        self.decode_buckets = bucket_boundaries(
            cfg.max_seq, step=bucket_step)
        self._prefill_s: Dict[int, float] = {}
        self._prefill_base_s: Dict[int, float] = {}
        self._prefill_per_seq_s: Dict[int, float] = {}
        self._decode_base_s: Dict[int, float] = {}
        self._decode_per_seq_s: Dict[int, float] = {}
        self.cache_hit = False
        disk = self._attach_flow_cache(flow_cache)
        key = self._table_key() if disk is not None else None
        if disk is not None:
            hit, val = disk.get(key)
            if hit and isinstance(val, dict) \
                    and val.get("v") == _TABLE_VERSION:
                for name in ("prefill_s", "prefill_base_s",
                             "prefill_per_seq_s", "decode_base_s",
                             "decode_per_seq_s"):
                    setattr(self, "_" + name,
                            {int(k): float(v)
                             for k, v in val[name].items()})
                self.cache_hit = True
        if not self.cache_hit:
            self._build()
            if disk is not None:
                disk.put(key, {
                    "v": _TABLE_VERSION,
                    "prefill_s": dict(self._prefill_s),
                    "prefill_base_s": dict(self._prefill_base_s),
                    "prefill_per_seq_s": dict(self._prefill_per_seq_s),
                    "decode_base_s": dict(self._decode_base_s),
                    "decode_per_seq_s": dict(self._decode_per_seq_s)})

    # -- construction -------------------------------------------------

    @staticmethod
    def _attach_flow_cache(flow_cache: Optional[str]
                           ) -> Optional[PassDiskCache]:
        """Bind the flow pass disk cache (same discipline as
        ``explore.ExplorationEngine``) and return whichever disk tier
        ends up active — the whole-table cache rides in it too, so one
        directory serves both pass outputs and finished tables."""
        if flow_cache:
            os.environ[_FLOW_CACHE_ENV] = flow_cache
            pipe = default_pipeline()
            if pipe.disk is None or pipe.disk.root != flow_cache:
                pipe.disk = PassDiskCache(flow_cache)
        return default_pipeline().disk

    def _table_key(self) -> str:
        payload = {
            "v": _TABLE_VERSION,
            "chip": dataclasses.asdict(self.chip),
            "fidelity": self.fidelity,
            "fit_batch": self.fit_batch,
            "incremental": self.incremental,
            "model": self.cfg.to_dict(),
            "prefill_buckets": list(self.prefill_buckets),
            "decode_buckets": list(self.decode_buckets),
            "system": (self.system.to_dict()
                       if self.system is not None else None),
            "calibration": (self.calibration.to_dict()
                            if self.calibration is not None else None),
        }
        blob = json.dumps(payload, sort_keys=True, default=str)
        return ("servetable-"
                + hashlib.sha256(blob.encode("utf-8")).hexdigest())

    def _compile(self, workload: str, kw: Dict[str, Any]):
        return flow_compile(workload, self.chip, CompileOptions(
            workload_kw=kw, fidelity=self.fidelity, batch=1,
            system=self.system, calibration=self.calibration))

    def _build(self) -> None:
        c = self.cfg
        k = self.fit_batch
        for b in self.prefill_buckets:
            kw = dict(n_layers=c.n_layers, d_model=c.d_model,
                      n_heads=c.n_heads, d_ff=c.d_ff, seq=b,
                      vocab=c.vocab)
            art = self._compile("transformer", kw)
            c1 = float(art.evaluate().cycles)
            # batch-1 cost stays the FIFO path's price verbatim: the
            # affine fit is for *batched* prefill, and base + per_seq
            # does not round-trip to c1 in float
            self._prefill_s[b] = c1 / self._hz
            ck = float(art.replace_options(batch=k).evaluate().cycles)
            per = max((ck - c1) / (k - 1), 0.0)
            self._prefill_per_seq_s[b] = per / self._hz
            self._prefill_base_s[b] = max(c1 - per, 0.0) / self._hz
        for b in self.decode_buckets:
            kw = dict(n_layers=c.n_layers, d_model=c.d_model,
                      n_heads=c.n_heads, d_ff=c.d_ff, kv_len=b,
                      vocab=c.vocab, incremental=self.incremental)
            art = self._compile("transformer_decode", kw)
            c1 = float(art.evaluate().cycles)
            # batch-K rides the same partition: replace_options keeps
            # the compiled plan and only re-prices the sample loop
            ck = float(art.replace_options(batch=k).evaluate().cycles)
            per = max((ck - c1) / (k - 1), 0.0)
            self._decode_per_seq_s[b] = per / self._hz
            self._decode_base_s[b] = max(c1 - per, 0.0) / self._hz

    # -- queries ------------------------------------------------------

    def prefill_s(self, prompt_len: int) -> float:
        return self._prefill_s[bucket_for(prompt_len,
                                          self.prefill_buckets)]

    def prefill_base_s(self, prompt_len: int) -> float:
        return self._prefill_base_s[bucket_for(prompt_len,
                                               self.prefill_buckets)]

    def prefill_per_seq_s(self, prompt_len: int) -> float:
        return self._prefill_per_seq_s[bucket_for(prompt_len,
                                                  self.prefill_buckets)]

    def prefill_batch_s(self, prompt_lens: Sequence[int]) -> float:
        """Price one batched prefill over mixed prompts, O(batch) —
        the same affine shape as :meth:`iteration_s`."""
        if not prompt_lens:
            return 0.0
        return (self.prefill_base_s(max(prompt_lens))
                + sum(self.prefill_per_seq_s(n) for n in prompt_lens))

    def decode_base_s(self, kv_len: int) -> float:
        return self._decode_base_s[bucket_for(kv_len,
                                              self.decode_buckets)]

    def decode_per_seq_s(self, kv_len: int) -> float:
        return self._decode_per_seq_s[bucket_for(kv_len,
                                                 self.decode_buckets)]

    def iteration_s(self, kv_lens: Sequence[int]) -> float:
        """Price one decode iteration over a mixed batch, O(batch)."""
        if not kv_lens:
            return 0.0
        return (self.decode_base_s(max(kv_lens))
                + sum(self.decode_per_seq_s(n) for n in kv_lens))

    def kv_bytes(self, kv_len: int) -> int:
        return self.cfg.kv_bytes(kv_len)

    # -- synthetic tables / dense views -------------------------------

    @classmethod
    def from_costs(cls, cfg: ServeModelCfg,
                   prefill_s: Dict[int, float],
                   decode_base_s: Dict[int, float],
                   decode_per_seq_s: Dict[int, float],
                   prefill_base_s: Optional[Dict[int, float]] = None,
                   prefill_per_seq_s: Optional[Dict[int, float]] = None,
                   fit_batch: int = 8) -> "StepCostTable":
        """Build a table from explicit per-bucket costs, skipping the
        compiler entirely — for tests and benchmarks that need a cheap
        deterministic table (e.g. million-request replays where the
        analytic build would dominate).  Bucket grids are taken from
        the dict keys.  Without an explicit prefill fit, batched
        prefill degenerates to ``base = batch-1 cost, per_seq = 0``.
        """
        t = cls.__new__(cls)
        t.cfg = cfg
        t.chip = default_chip()
        t.fidelity = "synthetic"
        t.fit_batch = fit_batch
        t.incremental = True
        t.system = None
        t.calibration = None
        t._hz = t.chip.clock_ghz * 1e9
        t.prefill_buckets = sorted(int(k) for k in prefill_s)
        t.decode_buckets = sorted(int(k) for k in decode_base_s)
        if sorted(int(k) for k in decode_per_seq_s) != t.decode_buckets:
            raise ValueError("decode cost dicts must share buckets")
        t._prefill_s = {int(k): float(v) for k, v in prefill_s.items()}
        t._prefill_base_s = (
            {int(k): float(v) for k, v in prefill_base_s.items()}
            if prefill_base_s is not None else dict(t._prefill_s))
        t._prefill_per_seq_s = (
            {int(k): float(v) for k, v in prefill_per_seq_s.items()}
            if prefill_per_seq_s is not None
            else {b: 0.0 for b in t.prefill_buckets})
        t._decode_base_s = {int(k): float(v)
                            for k, v in decode_base_s.items()}
        t._decode_per_seq_s = {int(k): float(v)
                               for k, v in decode_per_seq_s.items()}
        t.cache_hit = False
        return t

    def dense_decode(self):
        """``(base_s, per_seq_s)`` numpy arrays indexed by KV length
        (0..max bucket) — the array engine's O(1) bucket lookup."""
        import numpy as np
        hi = self.decode_buckets[-1]
        base = np.empty(hi + 1, dtype=np.float64)
        per = np.empty(hi + 1, dtype=np.float64)
        for n in range(hi + 1):
            b = bucket_for(n, self.decode_buckets)
            base[n] = self._decode_base_s[b]
            per[n] = self._decode_per_seq_s[b]
        return base, per

    def dense_prefill(self):
        """``(batch1_s, base_s, per_seq_s)`` numpy arrays indexed by
        prompt length (0..max bucket)."""
        import numpy as np
        hi = self.prefill_buckets[-1]
        c1 = np.empty(hi + 1, dtype=np.float64)
        base = np.empty(hi + 1, dtype=np.float64)
        per = np.empty(hi + 1, dtype=np.float64)
        for n in range(hi + 1):
            b = bucket_for(n, self.prefill_buckets)
            c1[n] = self._prefill_s[b]
            base[n] = self._prefill_base_s[b]
            per[n] = self._prefill_per_seq_s[b]
        return c1, base, per

    def to_dict(self) -> Dict[str, Any]:
        return {
            "fidelity": self.fidelity,
            "fit_batch": self.fit_batch,
            "incremental": self.incremental,
            "system": (self.system.to_dict()
                       if self.system is not None else None),
            "model": self.cfg.to_dict(),
            "prefill_s": {str(k): v
                          for k, v in sorted(self._prefill_s.items())},
            "prefill_base_s": {
                str(k): v
                for k, v in sorted(self._prefill_base_s.items())},
            "prefill_per_seq_s": {
                str(k): v
                for k, v in sorted(self._prefill_per_seq_s.items())},
            "decode_base_s": {
                str(k): v
                for k, v in sorted(self._decode_base_s.items())},
            "decode_per_seq_s": {
                str(k): v
                for k, v in sorted(self._decode_per_seq_s.items())},
        }

"""Array-batched serving replay engine.

The reference simulator (``ServeSim._run_event``) advances one decode
iteration per Python loop pass — ~4 µs of interpreter work per
iteration, which caps replay at a few hundred thousand iterations per
second and makes million-request traces impractical.  This module
replays the *same* simulation as masked/sliced numpy array operations,
byte-identical metrics JSON included, by exploiting two structural
facts about the event loop:

* **Backlog horizons (regime A).**  While the decode queue is
  non-empty, admission is strict FCFS from the queue *head*, so
  arrivals joining the tail cannot change any scheduling decision
  until the current queue would drain.  Everything that happens over
  such a horizon — admissions, completions, KV occupancy — is a pure
  integer event structure (a member admitted with ``kv0`` at iteration
  ``a`` runs ``gen_len - 1`` iterations, its KV growing by one each),
  simulated in Python with *no float work*, then priced in one shot:
  per-member ``per_seq`` slice-adds into a horizon cost array (in
  admission order — replaying ``sum()``'s left fold bit-for-bit),
  per-segment ``base`` slice assignments (the batch-max KV grows by
  exactly one per iteration between admission/completion events), and
  a seeded ``np.cumsum`` for the clock chain (``cumsum`` *is* the
  sequential left fold, unlike pairwise ``np.sum``).

* **Arrival-coupled runs (regime B).**  With an empty queue the active
  batch is fixed until the next completion or until a new arrival
  becomes visible.  The next completion is an integer; the arrival cut
  is found by ``searchsorted``-ing the arrival time into the priced
  boundary-clock array.  A cut is only needed when the policy could
  actually admit (continuous batching with free slots) — otherwise a
  mid-segment pop is unobservable and the segment runs to the next
  completion.

Request timelines land in preallocated SoA arrays (no
:class:`RequestRecord` objects, no per-token timestamp lists) and are
aggregated by :func:`repro.serve.metrics.summarize_soa`.

Prefill policies on top of the array engine:

* ``fifo`` — batch-1 back-to-back prompts, byte-identical to the
  event engine (the ``max(free, arrive) + cost`` recurrence is a
  sequential float chain, so it stays a scalar loop over vectorized
  gathered costs);
* ``batched`` — work-conserving FCFS batches of up to
  ``prefill_max_batch`` prompts arrived by batch-formation time,
  priced with the table's prefill affine fit
  (``base + per_seq × batch``);
* ``chunked`` — Sarathi-style chunked prefill: no separate prefill
  engine at all; prompt chunks are co-scheduled into decode iterations
  under a ``chunk_tokens`` token budget (decode members cost one token
  each, the remainder goes to prompt chunks FCFS head-first), with the
  KV footprint and a decode slot reserved at first-chunk admission.
  Chunk pricing amortises the bucketed batch-1 prefill cost per
  *actual* token (``prefill_s(bucket)/bucket``), so mid-bucket prompts
  do not pay the bucket padding the batch-1 path pays.
"""
from __future__ import annotations

import heapq
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .bucketing import bucket_for
from .metrics import summarize_soa
from .policy import StaticBatcher

__all__ = ["run_array"]


class _AMem:
    """Decode-batch member in the array engine (integer state only)."""

    __slots__ = ("idx", "kv_len", "rem", "kv_reserved", "a", "c", "off")

    def __init__(self, idx: int, kv_len: int, rem: int,
                 kv_reserved: int) -> None:
        self.idx = idx                # row in the SoA timeline arrays
        self.kv_len = kv_len          # KV tokens at next iteration
        self.rem = rem                # decode iterations left
        self.kv_reserved = kv_reserved
        self.a = 0                    # horizon-local admission boundary
        self.c = 0                    # horizon-local completion boundary
        self.off = 0                  # kv_len - a (kv at iter j = off+j)


class _SoA:
    """Per-request timeline arrays, in record (prefill) order."""

    __slots__ = ("rid", "t_arrive", "prompt_len", "gen_len",
                 "t_prefill_start", "t_first", "t_complete")

    def __init__(self, n: int) -> None:
        self.rid = np.zeros(n, dtype=np.int64)
        self.t_arrive = np.zeros(n, dtype=np.float64)
        self.prompt_len = np.zeros(n, dtype=np.int64)
        self.gen_len = np.zeros(n, dtype=np.int64)
        self.t_prefill_start = np.zeros(n, dtype=np.float64)
        self.t_first = np.zeros(n, dtype=np.float64)
        self.t_complete = np.zeros(n, dtype=np.float64)


def _sorted_trace(requests: Sequence[Any]
                  ) -> Tuple[List[int], List[float], List[int], List[int]]:
    reqs = sorted(requests, key=lambda r: (r.t_arrive, r.rid))
    return ([r.rid for r in reqs], [r.t_arrive for r in reqs],
            [r.prompt_len for r in reqs], [r.gen_len for r in reqs])


# --------------------------------------------------------------------
# Prefill drivers
# --------------------------------------------------------------------

def _prefill_fifo(sim, rid, ta, plen, glen) -> _SoA:
    """Batch-1 FIFO prefill — the ``max(free, arrive) + cost`` chain is
    sequential in float, so it stays a scalar loop; the cost lookups
    are one vectorized gather."""
    n = len(rid)
    soa = _SoA(n)
    soa.rid[:] = rid
    soa.t_arrive[:] = ta
    soa.prompt_len[:] = plen
    soa.gen_len[:] = glen
    c1, _, _ = sim.table.dense_prefill()
    costs = c1[np.asarray(plen, dtype=np.int64)].tolist()
    starts = soa.t_prefill_start
    ends = soa.t_first
    free = 0.0
    for i in range(n):
        start = free if free > ta[i] else ta[i]
        end = start + costs[i]
        starts[i] = start
        ends[i] = end
        free = end
    soa.t_complete[:] = soa.t_first
    return soa


def _prefill_batched(sim, rid, ta, plen, glen) -> _SoA:
    """Work-conserving FCFS batched prefill: a batch forms at
    ``start = max(free, head arrival)`` from up to ``prefill_max_batch``
    requests already arrived by ``start``, priced with the affine
    prefill fit."""
    n = len(rid)
    soa = _SoA(n)
    soa.rid[:] = rid
    soa.t_arrive[:] = ta
    soa.prompt_len[:] = plen
    soa.gen_len[:] = glen
    _, base_d, per_d = sim.table.dense_prefill()
    bases = base_d[np.asarray(plen, dtype=np.int64)].tolist()
    pers = per_d[np.asarray(plen, dtype=np.int64)].tolist()
    cap = sim.prefill_max_batch
    starts = soa.t_prefill_start
    ends = soa.t_first
    free = 0.0
    i = 0
    while i < n:
        start = max(free, ta[i])
        j = i + 1
        while j < n and j - i < cap and ta[j] <= start:
            j += 1
        # base of the largest prompt bucket + per-seq of every member
        mx = i
        s = 0.0
        for k in range(i, j):
            if plen[k] > plen[mx]:
                mx = k
            s += pers[k]
        end = start + (bases[mx] + s)
        for k in range(i, j):
            starts[k] = start
            ends[k] = end
        free = end
        i = j
    soa.t_complete[:] = soa.t_first
    return soa


def _prefill_shedding(sim, rid, ta, plen, glen
                      ) -> Tuple[_SoA, int, int]:
    """FIFO prefill with queue-pressure admission control — mirrors
    ``ServeSim._run_prefill_shedding`` float-op for float-op, writing
    SoA rows in admission order."""
    cap = sim.max_queue
    c1, _, _ = sim.table.dense_prefill()
    costs = c1[np.asarray(plen, dtype=np.int64)].tolist()
    pend = [(ta[i], rid[i], 0, i) for i in range(len(rid))]
    heapq.heapify(pend)
    free = 0.0
    starts_q: List[float] = []
    rows: List[Tuple[int, float, float]] = []  # (trace idx, start, end)
    shed = 0
    retries = 0
    while pend:
        eff_ta, _, attempt, i = heapq.heappop(pend)
        while starts_q and starts_q[0] <= eff_ta:
            starts_q.pop(0)
        if cap is not None and len(starts_q) >= cap:
            if attempt < sim.max_retries:
                retries += 1
                t_retry = eff_ta + sim.retry_backoff_s * (2 ** attempt)
                heapq.heappush(pend, (t_retry, rid[i], attempt + 1, i))
            else:
                shed += 1
            continue
        start = max(free, eff_ta)
        end = start + costs[i]
        free = end
        if start > eff_ta:
            starts_q.append(start)
        rows.append((i, start, end))
    soa = _SoA(len(rows))
    for r, (i, start, end) in enumerate(rows):
        soa.rid[r] = rid[i]
        soa.t_arrive[r] = ta[i]
        soa.prompt_len[r] = plen[i]
        soa.gen_len[r] = glen[i]
        soa.t_prefill_start[r] = start
        soa.t_first[r] = end
        soa.t_complete[r] = end
    return soa, shed, retries


# --------------------------------------------------------------------
# Array decode engine
# --------------------------------------------------------------------

class _Decode:
    """Array decode replay over a prefill-ready SoA timeline."""

    def __init__(self, sim, soa: _SoA,
                 max_sim_s: Optional[float]) -> None:
        self.sim = sim
        self.soa = soa
        self.max_sim_s = max_sim_s
        self.base_d, self.per_d = sim.table.dense_decode()
        # decode candidates: gen_len > 1, ordered like the event heap
        # pops — (prefill end, rid) lexicographic
        gl = soa.gen_len
        cand = np.flatnonzero(gl > 1)
        order = np.lexsort((soa.rid[cand], soa.t_first[cand]))
        self.cand = cand[order]
        self.ends = soa.t_first[self.cand].tolist()
        self.ptr = 0
        self.queue: List[_AMem] = []
        self.active: List[_AMem] = []
        self.kv_used = 0
        self.t = 0.0
        self.busy = 0.0
        self.iterations = 0
        self.peak_kv = 0
        self.peak_batch = 0
        self._static = isinstance(sim.policy, StaticBatcher)

    # -- shared helpers -----------------------------------------------

    def _mem(self, ci: int) -> _AMem:
        i = int(self.cand[ci])
        p = int(self.soa.prompt_len[i])
        g = int(self.soa.gen_len[i])
        return _AMem(i, p + 1, g - 1,
                     self.sim.table.kv_bytes(p + g))

    def _pops(self) -> None:
        while self.ptr < len(self.ends) and \
                self.ends[self.ptr] <= self.t:
            self.queue.append(self._mem(self.ptr))
            self.ptr += 1

    def _raise_overload(self, t_cross: float) -> None:
        raise RuntimeError(self.sim._overload_msg(
            float(np.min(self.soa.t_arrive)) if len(self.soa.rid)
            else 0.0, self.max_sim_s, t=t_cross))

    def _chain(self, dts: np.ndarray, j: int) -> np.ndarray:
        """Boundary clock: seeded cumsum == the event loop's chained
        ``t += dt`` fold.  Returns boundaries [0..len(dts)]; also
        advances ``t``/``busy``/``iterations`` through boundary j."""
        t_bound = np.cumsum(np.concatenate(([self.t], dts)))
        busy_bound = np.cumsum(np.concatenate(([self.busy], dts)))
        self.t = float(t_bound[j])
        self.busy = float(busy_bound[j])
        self.iterations += j
        if self.max_sim_s is not None and self.t > self.max_sim_s:
            cross = int(np.argmax(t_bound[:j + 1] > self.max_sim_s))
            self._raise_overload(float(t_bound[cross]))
        return t_bound

    # -- regime A: backlog horizon ------------------------------------

    def _horizon(self) -> None:
        """Queue non-empty: simulate the integer event structure until
        the initial queue would drain, then price in one shot."""
        sim = self.sim
        adds: List[_AMem] = []
        for m in self.active:              # already-running members
            m.a = 0
            m.c = m.rem
            m.off = m.kv_len
            adds.append(m)
        segs: List[Tuple[int, int, int, int, int]] = []
        compl: List[Tuple[int, _AMem]] = []
        i = 0
        while True:
            done = [m for m in self.active if m.c == i]
            for m in done:
                self.active.remove(m)
                self.kv_used -= m.kv_reserved
                compl.append((i, m))
            if not self.queue:
                break
            admitted = sim.policy.admit(
                self.active, self.queue,
                sim.kv_capacity_bytes - self.kv_used)
            if i > 0 and len(admitted) == len(self.queue):
                # the take ran off the end of the *known* queue — at a
                # future boundary, tail arrivals could extend it, so
                # roll back and reprocess with full information
                break
            for m in admitted:
                self.queue.remove(m)
                self.kv_used += m.kv_reserved
                m.a = i
                m.c = i + m.rem
                m.off = m.kv_len - i
                self.active.append(m)
                adds.append(m)
            if not self.queue:
                break
            if not self.active:
                raise RuntimeError(
                    "deadlock: queued work cannot admit")
            e = min(m.c for m in self.active)
            segs.append((i, e, max(m.off for m in self.active),
                         len(self.active), self.kv_used))
            i = e
        L = i
        if L == 0:
            return                         # regime B prices this boundary
        # price the horizon
        S = np.zeros(L, dtype=np.float64)
        for m in adds:                     # admission order == fold order
            hi = m.c if m.c < L else L
            kv0 = m.off + m.a
            S[m.a:hi] += self.per_d[kv0:kv0 + (hi - m.a)]
        B = np.empty(L, dtype=np.float64)
        for s, e, M, nb, kv in segs:
            B[s:e] = self.base_d[M + s:M + e]
            if nb > self.peak_batch:
                self.peak_batch = nb
            if kv > self.peak_kv:
                self.peak_kv = kv
        t_bound = self._chain(B + S, L)
        if compl:
            idxs = np.array([m.idx for _, m in compl], dtype=np.int64)
            bidx = np.array([b for b, _ in compl], dtype=np.int64)
            self.soa.t_complete[idxs] = t_bound[bidx]
        for m in self.active:              # survivors carry into next
            m.kv_len = m.off + L
            m.rem = m.c - L

    # -- regime B: arrival-coupled run --------------------------------

    def _segment(self) -> None:
        """Queue empty, batch active: run to the next completion, or
        cut at the first boundary where a new arrival becomes visible
        (only when the policy could actually admit it)."""
        sim = self.sim
        e = min(m.rem for m in self.active)
        S = np.zeros(e, dtype=np.float64)
        for m in self.active:
            S[0:e] += self.per_d[m.kv_len:m.kv_len + e]
        M = max(m.kv_len for m in self.active)
        dts = self.base_d[M:M + e] + S
        t_bound = np.cumsum(np.concatenate(([self.t], dts)))
        j = e
        cut = (not self._static
               and len(self.active) < sim.policy.max_batch)
        if cut and self.ptr < len(self.ends):
            nxt = self.ends[self.ptr]
            j = int(np.searchsorted(t_bound, nxt, side="left"))
            if j > e:
                j = e
        busy_bound = np.cumsum(np.concatenate(([self.busy], dts)))
        self.t = float(t_bound[j])
        self.busy = float(busy_bound[j])
        self.iterations += j
        if self.max_sim_s is not None and self.t > self.max_sim_s:
            cross = int(np.argmax(t_bound[:j + 1] > self.max_sim_s))
            self._raise_overload(float(t_bound[cross]))
        if len(self.active) > self.peak_batch:
            self.peak_batch = len(self.active)
        if self.kv_used > self.peak_kv:
            self.peak_kv = self.kv_used
        for m in self.active:
            m.kv_len += j
            m.rem -= j
        if j == e:
            done = [m for m in self.active if m.rem == 0]
            for m in done:
                self.active.remove(m)
                self.kv_used -= m.kv_reserved
                self.soa.t_complete[m.idx] = self.t

    # -- main loop ----------------------------------------------------

    def run(self) -> None:
        if self.max_sim_s is not None and len(self.soa.rid) and \
                float(np.max(self.soa.t_first)) > self.max_sim_s:
            # prefill backlog alone exceeds the cap — match the event
            # engine's early diagnostic
            raise RuntimeError(self.sim._overload_msg(
                float(np.min(self.soa.t_arrive)), self.max_sim_s,
                prefill_end=float(np.max(self.soa.t_first))))
        while self.ptr < len(self.ends) or self.queue or self.active:
            self._pops()
            if not self.active and not self.queue:
                self.t = self.ends[self.ptr]
                continue
            if self.queue:
                self._horizon()
            else:
                self._segment()


def _chunked_decode(sim, rid, ta, plen, glen,
                    max_sim_s: Optional[float]
                    ) -> Tuple[_SoA, Dict[str, int], float, float]:
    """Chunked-prefill interleaving on the decode engine.

    Scalar by necessity (the per-iteration token-budget split is data
    dependent); chunked mode trades replay speed for modeled latency,
    not the other way round.
    """
    n = len(rid)
    soa = _SoA(n)
    soa.rid[:] = rid
    soa.t_arrive[:] = ta
    soa.prompt_len[:] = plen
    soa.gen_len[:] = glen
    table = sim.table
    base_d, per_d = table.dense_decode()
    base_l = base_d.tolist()
    per_l = per_d.tolist()
    c1, _, _ = table.dense_prefill()
    pb = table.prefill_buckets
    # per-token prefill rate: bucketed batch-1 cost amortised over the
    # *bucket* — chunked kernels run exact token counts, so a chunk of
    # k tokens costs k × s(bucket)/bucket (no bucket padding)
    rate = [c1[p] / bucket_for(p, pb) if p > 0 else 0.0
            for p in range(len(c1))]
    budget = sim.chunk_tokens
    max_batch = sim.policy.max_batch
    kv_cap = sim.kv_capacity_bytes

    # prefill queue entries: [trace idx, tokens left, started flag]
    pq: List[List[int]] = []
    active: List[_AMem] = []
    started = 0
    ptr = 0
    kv_used = 0
    t = 0.0
    busy = 0.0
    iterations = 0
    peak_kv = 0
    peak_batch = 0
    while ptr < n or pq or active:
        while ptr < n and ta[ptr] <= t:
            pq.append([ptr, plen[ptr], 0])
            ptr += 1
        if not pq and not active:
            t = ta[ptr]
            continue
        # split the token budget: decode members first, remainder to
        # prompt chunks FCFS head-first
        left = budget - len(active)
        chunks: List[Tuple[List[int], int]] = []
        for entry in pq:
            if left <= 0:
                break
            if not entry[2]:
                i = entry[0]
                reserve = table.kv_bytes(plen[i] + glen[i])
                if len(active) + started >= max_batch or \
                        kv_used + reserve > kv_cap:
                    break              # strict FCFS: no queue jumping
                entry[2] = 1
                started += 1
                kv_used += reserve
                if kv_used > peak_kv:
                    peak_kv = kv_used
                soa.t_prefill_start[i] = t
            k = entry[1] if entry[1] < left else left
            left -= k
            chunks.append((entry, k))
        if not chunks and not active:
            raise RuntimeError("deadlock: queued work cannot admit")
        dt = 0.0
        if active:
            mx = 0
            s = 0.0
            for m in active:
                if m.kv_len > mx:
                    mx = m.kv_len
                s += per_l[m.kv_len]
            dt = base_l[mx] + s
        for entry, k in chunks:
            dt += k * rate[plen[entry[0]]]
        t += dt
        busy += dt
        iterations += 1
        if max_sim_s is not None and t > max_sim_s:
            raise RuntimeError(sim._overload_msg(
                float(np.min(soa.t_arrive)) if n else 0.0,
                max_sim_s, t=t))
        if len(active) > peak_batch:
            peak_batch = len(active)
        if kv_used > peak_kv:
            peak_kv = kv_used
        done = []
        for m in active:
            m.kv_len += 1
            m.rem -= 1
            if m.rem == 0:
                done.append(m)
        for m in done:
            active.remove(m)
            kv_used -= m.kv_reserved
            soa.t_complete[m.idx] = t
        for entry, k in chunks:
            entry[1] -= k
            if entry[1] == 0:
                i = entry[0]
                pq.remove(entry)
                started -= 1
                soa.t_first[i] = t     # last chunk emits first token
                soa.t_complete[i] = t
                if glen[i] > 1:
                    m = _AMem(i, plen[i] + 1, glen[i] - 1,
                              table.kv_bytes(plen[i] + glen[i]))
                    active.append(m)   # decodes from next iteration
                else:
                    kv_used -= table.kv_bytes(plen[i] + glen[i])
    stats = {"kv_peak_bytes": peak_kv, "decode_iterations": iterations,
             "peak_decode_batch": peak_batch}
    return soa, stats, busy, t


# --------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------

def run_array(sim, requests: Sequence[Any],
              max_sim_s: Optional[float] = None) -> Dict[str, Any]:
    """Replay ``requests`` on the array engine; returns the same
    metrics dict as the event engine (byte-identical JSON for
    ``prefill_policy="fifo"``)."""
    rid, ta, plen, glen = _sorted_trace(requests)
    shed = 0
    retries = 0
    if sim.max_queue is not None:
        soa, shed, retries = _prefill_shedding(sim, rid, ta, plen, glen)
    elif sim.prefill_policy == "batched":
        soa = _prefill_batched(sim, rid, ta, plen, glen)
    elif sim.prefill_policy == "chunked":
        soa, stats, busy, t_end = _chunked_decode(
            sim, rid, ta, plen, glen, max_sim_s)
        return _finish(sim, soa, stats, busy, t_end, shed, retries)
    else:
        soa = _prefill_fifo(sim, rid, ta, plen, glen)
    dec = _Decode(sim, soa, max_sim_s)
    dec.run()
    stats = {"kv_peak_bytes": dec.peak_kv,
             "decode_iterations": dec.iterations,
             "peak_decode_batch": dec.peak_batch}
    return _finish(sim, soa, stats, dec.busy, dec.t, shed, retries)


def _finish(sim, soa: _SoA, stats: Dict[str, int], busy: float,
            t_end: float, shed: int, retries: int) -> Dict[str, Any]:
    extra = {
        "policy": sim.policy.name,
        "max_batch": sim.policy.max_batch,
        "fidelity": sim.table.fidelity,
        "kv_capacity_bytes": sim.kv_capacity_bytes,
        "kv_peak_bytes": stats["kv_peak_bytes"],
        "decode_iterations": stats["decode_iterations"],
        "peak_decode_batch": stats["peak_decode_batch"],
        "engine": "array",
        "prefill_policy": sim.prefill_policy,
    }
    _warn_if_saturated_soa(sim, soa, busy, t_end)
    if sim.degraded:
        extra.update(_degradation_extra_soa(sim, soa, shed, retries))
    return summarize_soa(soa.t_arrive, soa.gen_len, soa.t_first,
                         soa.t_complete, extra,
                         percentile_mode=sim.percentile_mode)


def _warn_if_saturated_soa(sim, soa: _SoA, decode_busy: float,
                           t_end: float) -> None:
    """``t_end`` is the final *decode clock* (0.0 when nothing ever
    decoded) — the event engine's utilization span, not
    ``max(t_complete)``."""
    n = len(soa.rid)
    if n == 0:
        return
    t0 = float(np.min(soa.t_arrive))
    # left-fold sum (cumsum) to match the event path bit-for-bit
    prefill_busy = float(
        np.cumsum(soa.t_first - soa.t_prefill_start)[-1])
    prefill_span = float(np.max(soa.t_first)) - t0
    decode_span = t_end - t0
    u_pre = prefill_busy / prefill_span if prefill_span > 0 else 0.0
    u_dec = decode_busy / decode_span if decode_span > 0 else 0.0
    sim._emit_saturation_warning(u_pre, u_dec)


def _degradation_extra_soa(sim, soa: _SoA, shed: int,
                           retries: int) -> Dict[str, Any]:
    n = len(soa.rid)
    e2e = soa.t_complete - soa.t_arrive
    if sim.deadline_s is not None:
        late = e2e > sim.deadline_s
        timeouts = int(np.sum(late))
        good_toks = int(np.sum(soa.gen_len[~late]))
    else:
        timeouts = 0
        good_toks = int(np.sum(soa.gen_len))
    if n:
        makespan = max(float(np.max(soa.t_complete))
                       - float(np.min(soa.t_arrive)), 1e-12)
    else:
        makespan = 0.0
    return {
        "shed_requests": shed,
        "retries": retries,
        "timeout_requests": timeouts,
        "goodput_tok_s": good_toks / makespan if makespan else 0.0,
    }

"""Latency/throughput metrics for the serving simulator.

Everything here is deterministic: percentiles use linear interpolation
on the sorted sample, and the JSON serialisation sorts keys and rounds
floats so the same simulation produces the same bytes on every run —
the property the determinism test and the CI golden gate rely on.

Two aggregation paths produce byte-identical output:

* :func:`summarize` over :class:`RequestRecord` lists (the event
  engine's native shape);
* :func:`summarize_soa` over preallocated numpy timeline arrays (the
  array engine's native shape) — means are chained ``cumsum`` (the
  same left-fold as Python ``sum``), percentiles interpolate on
  ``np.sort`` output, and every value is converted back to a Python
  float before rounding.

For ≥100k-request runs where holding and sorting full latency samples
is unwanted, :class:`StreamingPercentiles` estimates quantiles with
the P² algorithm (Jain & Chlamtac 1985) in O(1) memory per quantile;
pass ``percentile_mode="streaming"`` to either summarizer.  Exact
sorted-sample percentiles stay the default so existing goldens remain
byte-stable.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Sequence

import numpy as np

__all__ = ["RequestRecord", "percentile", "summarize", "summarize_soa",
           "StreamingPercentiles", "metrics_json"]

_ROUND = 9  # digits kept when serialising floats


@dataclass
class RequestRecord:
    """Per-request timeline collected by the simulator (seconds)."""
    rid: int
    t_arrive: float
    prompt_len: int
    gen_len: int
    t_prefill_start: float = 0.0
    t_first_token: float = 0.0
    t_complete: float = 0.0

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_arrive

    @property
    def tpot(self) -> float:
        """Mean inter-token latency after the first token."""
        if self.gen_len <= 1:
            return 0.0
        return (self.t_complete - self.t_first_token) / (self.gen_len - 1)


def _percentile_sorted(xs: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile of an already-sorted sample."""
    if not 0.0 <= q <= 100.0:
        raise ValueError("q must be in [0, 100]")
    n = len(xs)
    if n == 0:
        return 0.0
    if n == 1:
        return xs[0]
    pos = q / 100.0 * (n - 1)
    lo = int(pos)
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile of ``values`` (``q`` in [0,100])."""
    if not 0.0 <= q <= 100.0:
        raise ValueError("q must be in [0, 100]")
    return _percentile_sorted(sorted(values), q)


class StreamingPercentiles:
    """P² quantile estimation in O(1) memory per tracked quantile.

    Maintains five markers per quantile whose heights converge on the
    true quantile via piecewise-parabolic adjustment — no sample is
    retained.  Estimates are approximate (they converge as the stream
    grows), so goldens gated on exact percentiles must not use this
    mode; it exists for million-request runs where the exact sample
    would dominate memory.
    """

    def __init__(self, qs: Sequence[float] = (50.0, 95.0, 99.0)) -> None:
        self.qs = [float(q) for q in qs]
        for q in self.qs:
            if not 0.0 < q < 100.0:
                raise ValueError("streaming quantiles must be in (0,100)")
        self._init: List[float] = []
        # per-quantile: marker heights (5), positions (5), desired
        self._h: List[List[float]] = []
        self._pos: List[List[float]] = []
        self._count = 0

    def update(self, x: float) -> None:
        self._count += 1
        if self._count <= 5:
            self._init.append(x)
            if self._count == 5:
                self._init.sort()
                for _ in self.qs:
                    self._h.append(list(self._init))
                    self._pos.append([1.0, 2.0, 3.0, 4.0, 5.0])
            return
        for qi, q in enumerate(self.qs):
            p = q / 100.0
            h = self._h[qi]
            pos = self._pos[qi]
            if x < h[0]:
                h[0] = x
                k = 0
            elif x >= h[4]:
                h[4] = x
                k = 3
            else:
                k = 0
                while x >= h[k + 1]:
                    k += 1
            for j in range(k + 1, 5):
                pos[j] += 1.0
            n = float(self._count)
            desired = [1.0, 1.0 + (n - 1.0) * p / 2.0,
                       1.0 + (n - 1.0) * p,
                       1.0 + (n - 1.0) * (1.0 + p) / 2.0, n]
            for j in (1, 2, 3):
                d = desired[j] - pos[j]
                if (d >= 1.0 and pos[j + 1] - pos[j] > 1.0) or \
                        (d <= -1.0 and pos[j - 1] - pos[j] < -1.0):
                    sgn = 1.0 if d >= 1.0 else -1.0
                    # piecewise-parabolic marker move
                    hp = h[j] + sgn / (pos[j + 1] - pos[j - 1]) * (
                        (pos[j] - pos[j - 1] + sgn)
                        * (h[j + 1] - h[j]) / (pos[j + 1] - pos[j])
                        + (pos[j + 1] - pos[j] - sgn)
                        * (h[j] - h[j - 1]) / (pos[j] - pos[j - 1]))
                    if not h[j - 1] < hp < h[j + 1]:
                        # parabolic left the bracket: linear fallback
                        k2 = j + (1 if sgn > 0 else -1)
                        hp = h[j] + sgn * (h[k2] - h[j]) \
                            / (pos[k2] - pos[j])
                    h[j] = hp
                    pos[j] += sgn

    def extend(self, xs: Sequence[float]) -> None:
        for x in xs:
            self.update(float(x))

    def get(self, q: float) -> float:
        qi = self.qs.index(float(q))
        if self._count == 0:
            return 0.0
        if self._count <= 5 or not self._h:
            return _percentile_sorted(sorted(self._init), q)
        return self._h[qi][2]

    @property
    def count(self) -> int:
        return self._count


def _family_exact(values: Sequence[float]) -> Dict[str, float]:
    """p50/p95/p99/mean of a latency sample, sorting it exactly once.

    The mean folds in the *original* order — re-associating the sum
    over the sorted sample could change the last ulp.
    """
    mean = sum(values) / len(values) if values else 0.0
    xs = sorted(values)
    return {
        "p50": _percentile_sorted(xs, 50),
        "p95": _percentile_sorted(xs, 95),
        "p99": _percentile_sorted(xs, 99),
        "mean": mean,
    }


def _family_streaming(values: Sequence[float]) -> Dict[str, float]:
    sp = StreamingPercentiles()
    sp.extend(values)
    mean = sum(values) / len(values) if values else 0.0
    return {"p50": sp.get(50), "p95": sp.get(95), "p99": sp.get(99),
            "mean": mean}


def summarize(records: Sequence[RequestRecord],
              extra: Mapping[str, Any] | None = None,
              percentile_mode: str = "exact") -> Dict[str, Any]:
    """Aggregate request records into the canonical metrics dict."""
    if percentile_mode not in ("exact", "streaming"):
        raise ValueError("percentile_mode must be exact|streaming")
    family = _family_exact if percentile_mode == "exact" \
        else _family_streaming
    ttfts = [r.ttft for r in records]
    tpots = [r.tpot for r in records if r.gen_len > 1]
    e2es = [r.t_complete - r.t_arrive for r in records]
    toks = sum(r.gen_len for r in records)
    if records:
        t0 = min(r.t_arrive for r in records)
        t1 = max(r.t_complete for r in records)
        makespan = max(t1 - t0, 1e-12)
    else:
        makespan = 0.0
    out: Dict[str, Any] = {
        "requests": len(records),
        "tokens": toks,
        "makespan_s": makespan,
        "throughput_tok_s": toks / makespan if makespan else 0.0,
        "throughput_req_s": len(records) / makespan if makespan else 0.0,
        "ttft_s": family(ttfts),
        "tpot_s": family(tpots),
        "e2e_s": family(e2es),
    }
    if extra:
        out.update(extra)
    return out


def _np_mean(xs: np.ndarray) -> float:
    """Left-fold mean matching Python ``sum(list)/len`` bit-for-bit —
    ``np.sum`` is pairwise, ``np.cumsum`` is sequential."""
    if len(xs) == 0:
        return 0.0
    return float(np.cumsum(xs)[-1]) / len(xs)


def _family_soa(xs: np.ndarray, percentile_mode: str) -> Dict[str, float]:
    if percentile_mode == "streaming":
        return _family_streaming(xs.tolist())
    mean = _np_mean(xs)
    s = np.sort(xs)
    # float() everywhere: np.float64 would not JSON-serialise
    return {
        "p50": float(_percentile_sorted(s, 50)),
        "p95": float(_percentile_sorted(s, 95)),
        "p99": float(_percentile_sorted(s, 99)),
        "mean": mean,
    }


def summarize_soa(t_arrive: np.ndarray, gen_len: np.ndarray,
                  t_first_token: np.ndarray, t_complete: np.ndarray,
                  extra: Mapping[str, Any] | None = None,
                  percentile_mode: str = "exact") -> Dict[str, Any]:
    """:func:`summarize` over SoA timeline arrays — byte-identical
    output for the same per-request values, no record objects built.
    """
    if percentile_mode not in ("exact", "streaming"):
        raise ValueError("percentile_mode must be exact|streaming")
    n = len(t_arrive)
    ttfts = t_first_token - t_arrive
    multi = gen_len > 1
    tpots = (t_complete[multi] - t_first_token[multi]) \
        / (gen_len[multi] - 1)
    e2es = t_complete - t_arrive
    toks = int(np.sum(gen_len))
    if n:
        makespan = max(float(np.max(t_complete))
                       - float(np.min(t_arrive)), 1e-12)
    else:
        makespan = 0.0
    fam = _family_soa
    out: Dict[str, Any] = {
        "requests": n,
        "tokens": toks,
        "makespan_s": makespan,
        "throughput_tok_s": toks / makespan if makespan else 0.0,
        "throughput_req_s": n / makespan if makespan else 0.0,
        "ttft_s": fam(ttfts, percentile_mode),
        "tpot_s": fam(tpots, percentile_mode),
        "e2e_s": fam(e2es, percentile_mode),
    }
    if extra:
        out.update(extra)
    return out


def _rounded(obj: Any) -> Any:
    if isinstance(obj, float):
        return round(obj, _ROUND)
    if isinstance(obj, dict):
        return {k: _rounded(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_rounded(v) for v in obj]
    return obj


def metrics_json(metrics: Mapping[str, Any]) -> str:
    """Canonical (sorted, rounded) JSON — byte-stable across runs."""
    return json.dumps(_rounded(dict(metrics)), sort_keys=True, indent=2)

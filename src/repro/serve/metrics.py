"""Latency/throughput metrics for the serving simulator.

Everything here is deterministic: percentiles use linear interpolation
on the sorted sample (no RNG, no numpy), and the JSON serialisation
sorts keys and rounds floats so the same simulation produces the same
bytes on every run — the property the determinism test and the CI
golden gate rely on.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Sequence

__all__ = ["RequestRecord", "percentile", "summarize", "metrics_json"]

_ROUND = 9  # digits kept when serialising floats


@dataclass
class RequestRecord:
    """Per-request timeline collected by the simulator (seconds)."""
    rid: int
    t_arrive: float
    prompt_len: int
    gen_len: int
    t_prefill_start: float = 0.0
    t_first_token: float = 0.0
    t_complete: float = 0.0
    token_times: List[float] = field(default_factory=list)

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_arrive

    @property
    def tpot(self) -> float:
        """Mean inter-token latency after the first token."""
        if self.gen_len <= 1:
            return 0.0
        return (self.t_complete - self.t_first_token) / (self.gen_len - 1)


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile of ``values`` (``q`` in [0,100])."""
    if not values:
        return 0.0
    if not 0.0 <= q <= 100.0:
        raise ValueError("q must be in [0, 100]")
    xs = sorted(values)
    if len(xs) == 1:
        return xs[0]
    pos = q / 100.0 * (len(xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def summarize(records: Sequence[RequestRecord],
              extra: Mapping[str, Any] | None = None) -> Dict[str, Any]:
    """Aggregate request records into the canonical metrics dict."""
    ttfts = [r.ttft for r in records]
    tpots = [r.tpot for r in records if r.gen_len > 1]
    e2es = [r.t_complete - r.t_arrive for r in records]
    toks = sum(r.gen_len for r in records)
    if records:
        t0 = min(r.t_arrive for r in records)
        t1 = max(r.t_complete for r in records)
        makespan = max(t1 - t0, 1e-12)
    else:
        makespan = 0.0
    out: Dict[str, Any] = {
        "requests": len(records),
        "tokens": toks,
        "makespan_s": makespan,
        "throughput_tok_s": toks / makespan if makespan else 0.0,
        "throughput_req_s": len(records) / makespan if makespan else 0.0,
        "ttft_s": {
            "p50": percentile(ttfts, 50),
            "p95": percentile(ttfts, 95),
            "p99": percentile(ttfts, 99),
            "mean": sum(ttfts) / len(ttfts) if ttfts else 0.0,
        },
        "tpot_s": {
            "p50": percentile(tpots, 50),
            "p95": percentile(tpots, 95),
            "p99": percentile(tpots, 99),
            "mean": sum(tpots) / len(tpots) if tpots else 0.0,
        },
        "e2e_s": {
            "p50": percentile(e2es, 50),
            "p95": percentile(e2es, 95),
            "p99": percentile(e2es, 99),
            "mean": sum(e2es) / len(e2es) if e2es else 0.0,
        },
    }
    if extra:
        out.update(extra)
    return out


def _rounded(obj: Any) -> Any:
    if isinstance(obj, float):
        return round(obj, _ROUND)
    if isinstance(obj, dict):
        return {k: _rounded(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_rounded(v) for v in obj]
    return obj


def metrics_json(metrics: Mapping[str, Any]) -> str:
    """Canonical (sorted, rounded) JSON — byte-stable across runs."""
    return json.dumps(_rounded(dict(metrics)), sort_keys=True, indent=2)

"""Arrival traces and the discrete-event serving simulator.

A trace is a list of :class:`Request` (arrival time, prompt length,
generation length).  Traces come from a seeded Poisson process, a
bursty on/off-modulated Poisson process, or a JSON file — all three
are bit-for-bit reproducible from their seed.

:class:`ServeSim` replays a trace against a :class:`~repro.serve.
workload.StepCostTable` with prefill/decode disaggregation:

* the **prefill engine** runs prompts back to back in arrival order;
  the first token of a request is produced when its prefill finishes
  (TTFT = prefill completion − arrival);
* the **decode engine** generates the remaining tokens.  At every
  iteration boundary the batching policy admits queued requests under
  the KV-cache budget, the iteration is priced in O(batch) from the
  step table, every member's KV grows by one, and finished members
  release their reservation.

The simulator touches no wall clock and no global RNG — identical
trace + table + policy produce identical metrics JSON.
"""
from __future__ import annotations

import heapq
import json
import random
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .metrics import RequestRecord, summarize
from .policy import Batcher
from .workload import StepCostTable

__all__ = ["Request", "poisson_trace", "bursty_trace", "load_trace",
           "save_trace", "ServeSim"]


@dataclass(frozen=True)
class Request:
    rid: int
    t_arrive: float
    prompt_len: int
    gen_len: int


def poisson_trace(rate: float, n: int, seed: int = 0,
                  min_prompt: int = 4, max_prompt: int = 64,
                  min_new: int = 4, max_new: int = 64) -> List[Request]:
    """Poisson arrivals at ``rate`` req/s with uniform length draws."""
    if rate <= 0 or n < 1:
        raise ValueError("rate must be > 0 and n >= 1")
    rng = random.Random(seed)
    t = 0.0
    out: List[Request] = []
    for i in range(n):
        t += rng.expovariate(rate)
        out.append(Request(
            rid=i, t_arrive=t,
            prompt_len=rng.randint(min_prompt, max_prompt),
            gen_len=rng.randint(min_new, max_new)))
    return out


def bursty_trace(rate: float, n: int, seed: int = 0,
                 burst: float = 4.0, period_s: float = 2.0,
                 duty: float = 0.3, min_prompt: int = 4,
                 max_prompt: int = 64, min_new: int = 4,
                 max_new: int = 64) -> List[Request]:
    """On/off-modulated Poisson arrivals with the same mean ``rate``.

    During the on-phase (fraction ``duty`` of each ``period_s`` cycle)
    arrivals run ``burst``× hotter; the off-phase rate is scaled down
    so the long-run average stays at ``rate``.
    """
    if not 0.0 < duty < 1.0:
        raise ValueError("duty must be in (0, 1)")
    if burst * duty >= 1.0 + duty:
        # keep the off-phase rate positive
        raise ValueError("burst too high for this duty cycle")
    on_rate = rate * burst
    off_rate = rate * (1.0 - burst * duty) / (1.0 - duty)
    rng = random.Random(seed)
    t = 0.0
    out: List[Request] = []
    for i in range(n):
        while True:
            phase = (t / period_s) % 1.0
            r = on_rate if phase < duty else off_rate
            dt = rng.expovariate(r)
            # step at most to the next phase edge so the rate switch
            # lands where it should (thinning would also work; this
            # keeps the draw count deterministic per accepted arrival)
            edge = (duty if phase < duty else 1.0) * period_s \
                - (t % period_s)
            if dt <= edge or edge <= 0:
                t += dt
                break
            t += edge
        out.append(Request(
            rid=i, t_arrive=t,
            prompt_len=rng.randint(min_prompt, max_prompt),
            gen_len=rng.randint(min_new, max_new)))
    return out


def save_trace(path: str, requests: Sequence[Request]) -> None:
    with open(path, "w") as f:
        json.dump([{"rid": r.rid, "t_arrive": r.t_arrive,
                    "prompt_len": r.prompt_len, "gen_len": r.gen_len}
                   for r in requests], f, indent=2)
        f.write("\n")


def load_trace(path: str) -> List[Request]:
    with open(path) as f:
        rows = json.load(f)
    return [Request(rid=int(r["rid"]), t_arrive=float(r["t_arrive"]),
                    prompt_len=int(r["prompt_len"]),
                    gen_len=int(r["gen_len"])) for r in rows]


# --------------------------------------------------------------------
# Simulator
# --------------------------------------------------------------------

class _Live:
    """A request in flight on the decode engine."""

    __slots__ = ("req", "rec", "t_ready", "kv_len", "emitted",
                 "kv_reserved")

    def __init__(self, req: Request, rec: RequestRecord,
                 t_ready: float, kv_reserved: int) -> None:
        self.req = req
        self.rec = rec
        self.t_ready = t_ready
        self.kv_len = req.prompt_len + 1  # prefill emitted token 1
        self.emitted = 1
        self.kv_reserved = kv_reserved


class ServeSim:
    """Replay an arrival trace against a compiled step-cost table."""

    def __init__(self, table: StepCostTable, policy: Batcher,
                 kv_capacity_bytes: Optional[int] = None,
                 kv_frac: float = 0.5) -> None:
        self.table = table
        self.policy = policy
        if kv_capacity_bytes is None:
            kv_capacity_bytes = int(
                table.chip.global_mem_bytes * kv_frac)
        one = table.cfg.kv_bytes(table.cfg.max_seq)
        if kv_capacity_bytes < one:
            raise ValueError(
                f"KV budget {kv_capacity_bytes}B cannot hold one "
                f"max-length request ({one}B)")
        self.kv_capacity_bytes = kv_capacity_bytes

    # -- prefill engine ----------------------------------------------

    def _run_prefill(self, requests: Sequence[Request]
                     ) -> List[Tuple[float, Request, RequestRecord]]:
        """FIFO prefill; returns (decode-ready time, req, record)."""
        free = 0.0
        out: List[Tuple[float, Request, RequestRecord]] = []
        for req in sorted(requests, key=lambda r: (r.t_arrive, r.rid)):
            start = max(free, req.t_arrive)
            end = start + self.table.prefill_s(req.prompt_len)
            free = end
            rec = RequestRecord(
                rid=req.rid, t_arrive=req.t_arrive,
                prompt_len=req.prompt_len, gen_len=req.gen_len,
                t_prefill_start=start, t_first_token=end,
                t_complete=end, token_times=[end])
            out.append((end, req, rec))
        return out

    # -- decode engine -----------------------------------------------

    def run(self, requests: Sequence[Request]) -> Dict[str, Any]:
        ready = self._run_prefill(requests)
        records: List[RequestRecord] = [rec for _, _, rec in ready]

        # single-token requests never enter the decode engine
        heap: List[Tuple[float, int, Request, RequestRecord]] = []
        for end, req, rec in ready:
            if req.gen_len > 1:
                heapq.heappush(heap, (end, req.rid, req, rec))

        active: List[_Live] = []
        queue: List[_Live] = []
        kv_used = 0
        peak_kv = 0
        peak_batch = 0
        iterations = 0
        t = 0.0
        while heap or queue or active:
            # surface everything that has finished prefill by now
            while heap and heap[0][0] <= t:
                end, _, req, rec = heapq.heappop(heap)
                queue.append(_Live(
                    req, rec, end,
                    self.table.kv_bytes(req.prompt_len + req.gen_len)))
            if not active and not queue and heap:
                t = heap[0][0]
                continue

            admitted = self.policy.admit(
                active, queue, self.kv_capacity_bytes - kv_used)
            for live in admitted:
                queue.remove(live)
                kv_used += live.kv_reserved
                active.append(live)
            if not active:
                # queue blocked on KV/slots: wait for in-flight work,
                # or (static policy with empty engine) nothing can
                # block, so this only happens via the heap above
                if heap:
                    t = max(t, heap[0][0])
                    continue
                raise RuntimeError("deadlock: queued work cannot admit")

            dt = self.table.iteration_s([l.kv_len for l in active])
            t += dt
            iterations += 1
            peak_batch = max(peak_batch, len(active))
            peak_kv = max(peak_kv, kv_used)
            done: List[_Live] = []
            for live in active:
                live.kv_len += 1
                live.emitted += 1
                live.rec.token_times.append(t)
                live.rec.t_complete = t
                if live.emitted >= live.req.gen_len:
                    done.append(live)
            for live in done:
                active.remove(live)
                kv_used -= live.kv_reserved

        extra = {
            "policy": self.policy.name,
            "max_batch": self.policy.max_batch,
            "fidelity": self.table.fidelity,
            "kv_capacity_bytes": self.kv_capacity_bytes,
            "kv_peak_bytes": peak_kv,
            "decode_iterations": iterations,
            "peak_decode_batch": peak_batch,
        }
        return summarize(records, extra)

"""Arrival traces and the discrete-event serving simulator.

A trace is a list of :class:`Request` (arrival time, prompt length,
generation length).  Traces come from a seeded Poisson process, a
bursty on/off-modulated Poisson process, or a JSON file — all three
are bit-for-bit reproducible from their seed.

:class:`ServeSim` replays a trace against a :class:`~repro.serve.
workload.StepCostTable` with prefill/decode disaggregation:

* the **prefill engine** runs prompts back to back in arrival order;
  the first token of a request is produced when its prefill finishes
  (TTFT = prefill completion − arrival);
* the **decode engine** generates the remaining tokens.  At every
  iteration boundary the batching policy admits queued requests under
  the KV-cache budget, the iteration is priced in O(batch) from the
  step table, every member's KV grows by one, and finished members
  release their reservation.

The simulator touches no wall clock and no global RNG — identical
trace + table + policy produce identical metrics JSON.

Degraded operation is opt-in: pass ``deadline_s`` and/or ``max_queue``
to :class:`ServeSim` and the simulator adds request deadlines, load
shedding on queue pressure (with bounded retry-and-backoff), and
*goodput* — tokens from requests that met their deadline — to the
metrics.  With neither set, the simulation and its metrics JSON are
byte-identical to the fault-free simulator.
"""
from __future__ import annotations

import heapq
import json
import math
import random
import warnings
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .engine import run_array
from .metrics import RequestRecord, summarize
from .policy import Batcher
from .rng import VecMT, uniform_randbelow_batch
from .workload import StepCostTable

__all__ = ["Request", "poisson_trace", "poisson_trace_arrays",
           "bursty_trace", "load_trace", "save_trace", "ServeSim"]

_ENGINES = ("event", "array")
_PREFILL_POLICIES = ("fifo", "batched", "chunked")


@dataclass(frozen=True)
class Request:
    rid: int
    t_arrive: float
    prompt_len: int
    gen_len: int


def _poisson_trace_scalar(rate: float, n: int, seed: int = 0,
                          min_prompt: int = 4, max_prompt: int = 64,
                          min_new: int = 4,
                          max_new: int = 64) -> List[Request]:
    """Reference per-request loop (the committed traces' definition).

    :func:`poisson_trace` must match this bit-for-bit; the equivalence
    suite pins it.
    """
    if rate <= 0 or n < 1:
        raise ValueError("rate must be > 0 and n >= 1")
    rng = random.Random(seed)
    t = 0.0
    out: List[Request] = []
    for i in range(n):
        t += rng.expovariate(rate)
        out.append(Request(
            rid=i, t_arrive=t,
            prompt_len=rng.randint(min_prompt, max_prompt),
            gen_len=rng.randint(min_new, max_new)))
    return out


def poisson_trace_arrays(
        rate: float, n: int, seed: int = 0,
        min_prompt: int = 4, max_prompt: int = 64,
        min_new: int = 4,
        max_new: int = 64) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """SoA form of :func:`poisson_trace`: ``(t_arrive, prompt_len,
    gen_len)`` numpy arrays, skipping :class:`Request` materialization
    (which dominates at million-request scale).  Values are
    bit-identical to the :class:`Request` list.
    """
    if rate <= 0 or n < 1:
        raise ValueError("rate must be > 0 and n >= 1")
    mt = VecMT(seed)
    u, (p, g) = uniform_randbelow_batch(
        mt, n, (max_prompt - min_prompt + 1, max_new - min_new + 1))
    gaps = [-math.log(1.0 - x) / rate for x in u.tolist()]
    t = np.cumsum(np.asarray(gaps))
    return t, p + min_prompt, g + min_new


def poisson_trace(rate: float, n: int, seed: int = 0,
                  min_prompt: int = 4, max_prompt: int = 64,
                  min_new: int = 4, max_new: int = 64) -> List[Request]:
    """Poisson arrivals at ``rate`` req/s with uniform length draws.

    Bit-identical to :func:`_poisson_trace_scalar` (same seed, same
    bytes on disk) but draws the whole word stream through
    :class:`~repro.serve.rng.VecMT` in numpy batches.  The only scalar
    stage left is the ``math.log`` map for the exponential gaps —
    numpy's SIMD ``log`` differs from libm by ~1 ulp on a fraction of
    inputs, which would change trace bytes.
    """
    t, p, g = poisson_trace_arrays(rate, n, seed, min_prompt,
                                   max_prompt, min_new, max_new)
    return [Request(rid=i, t_arrive=ti, prompt_len=pi, gen_len=gi)
            for i, (ti, pi, gi) in enumerate(zip(
                t.tolist(), p.tolist(), g.tolist()))]


def _bursty_trace_scalar(rate: float, n: int, seed: int = 0,
                         burst: float = 4.0, period_s: float = 2.0,
                         duty: float = 0.3, min_prompt: int = 4,
                         max_prompt: int = 64, min_new: int = 4,
                         max_new: int = 64) -> List[Request]:
    """Reference per-request loop for :func:`bursty_trace`."""
    if not 0.0 < duty < 1.0:
        raise ValueError("duty must be in (0, 1)")
    if burst * duty >= 1.0 + duty:
        # keep the off-phase rate positive
        raise ValueError("burst too high for this duty cycle")
    on_rate = rate * burst
    off_rate = rate * (1.0 - burst * duty) / (1.0 - duty)
    rng = random.Random(seed)
    t = 0.0
    out: List[Request] = []
    for i in range(n):
        while True:
            phase = (t / period_s) % 1.0
            r = on_rate if phase < duty else off_rate
            dt = rng.expovariate(r)
            # step at most to the next phase edge so the rate switch
            # lands where it should (thinning would also work; this
            # keeps the draw count deterministic per accepted arrival)
            edge = (duty if phase < duty else 1.0) * period_s \
                - (t % period_s)
            # t + edge == t: t sits within one ulp of the phase edge,
            # so stepping to the edge cannot advance the clock — accept
            # the draw at the boundary rate or the walk spins forever
            if dt <= edge or edge <= 0 or t + edge == t:
                t += dt
                break
            t += edge
        out.append(Request(
            rid=i, t_arrive=t,
            prompt_len=rng.randint(min_prompt, max_prompt),
            gen_len=rng.randint(min_new, max_new)))
    return out


def bursty_trace(rate: float, n: int, seed: int = 0,
                 burst: float = 4.0, period_s: float = 2.0,
                 duty: float = 0.3, min_prompt: int = 4,
                 max_prompt: int = 64, min_new: int = 4,
                 max_new: int = 64) -> List[Request]:
    """On/off-modulated Poisson arrivals with the same mean ``rate``.

    During the on-phase (fraction ``duty`` of each ``period_s`` cycle)
    arrivals run ``burst``× hotter; the off-phase rate is scaled down
    so the long-run average stays at ``rate``.

    Bit-identical to :func:`_bursty_trace_scalar`.  The phase walk is
    sequential by construction (each arrival's rate depends on the
    previous arrival time), so this draws the MT19937 word stream in
    numpy batches via :class:`~repro.serve.rng.VecMT` and walks it
    with scalar pointer arithmetic instead of one ``random.Random``
    call per draw.
    """
    if not 0.0 < duty < 1.0:
        raise ValueError("duty must be in (0, 1)")
    if burst * duty >= 1.0 + duty:
        # keep the off-phase rate positive
        raise ValueError("burst too high for this duty cycle")
    on_rate = rate * burst
    off_rate = rate * (1.0 - burst * duty) / (1.0 - duty)
    span_p = max_prompt - min_prompt + 1
    span_g = max_new - min_new + 1
    sh_p = 32 - span_p.bit_length()
    sh_g = 32 - span_g.bit_length()
    mt = VecMT(seed)
    # walk the batch-generated stream as a plain int list — Python int
    # shifts beat numpy scalar indexing in a data-dependent loop
    words = mt.peek(8 * n + 4096).tolist()
    nw = len(words)
    inv53 = 1.0 / 9007199254740992.0
    log = math.log
    pos = 0
    t = 0.0
    out: List[Request] = []
    append = out.append
    for i in range(n):
        while True:
            phase = (t / period_s) % 1.0
            on = phase < duty
            if pos + 4 > nw:
                words = mt.peek(nw + max(4096, nw >> 1)).tolist()
                nw = len(words)
            u = ((words[pos] >> 5) * 67108864.0
                 + (words[pos + 1] >> 6)) * inv53
            dt = -log(1.0 - u) / (on_rate if on else off_rate)
            pos += 2
            edge = (duty if on else 1.0) * period_s - (t % period_s)
            # mirror the scalar loop's ulp guard: a degenerate edge
            # step (t + edge == t) cannot advance the clock
            if dt <= edge or edge <= 0 or t + edge == t:
                t += dt
                break
            t += edge
        while True:
            if pos >= nw:
                words = mt.peek(nw + max(4096, nw >> 1)).tolist()
                nw = len(words)
            v = words[pos] >> sh_p
            pos += 1
            if v < span_p:
                break
        p_len = min_prompt + v
        while True:
            if pos >= nw:
                words = mt.peek(nw + max(4096, nw >> 1)).tolist()
                nw = len(words)
            v = words[pos] >> sh_g
            pos += 1
            if v < span_g:
                break
        append(Request(rid=i, t_arrive=t, prompt_len=p_len,
                       gen_len=min_new + v))
    mt.consume(pos)
    return out


def save_trace(path: str, requests: Sequence[Request]) -> None:
    with open(path, "w") as f:
        json.dump([{"rid": r.rid, "t_arrive": r.t_arrive,
                    "prompt_len": r.prompt_len, "gen_len": r.gen_len}
                   for r in requests], f, indent=2)
        f.write("\n")


def load_trace(path: str) -> List[Request]:
    with open(path) as f:
        rows = json.load(f)
    return [Request(rid=int(r["rid"]), t_arrive=float(r["t_arrive"]),
                    prompt_len=int(r["prompt_len"]),
                    gen_len=int(r["gen_len"])) for r in rows]


# --------------------------------------------------------------------
# Simulator
# --------------------------------------------------------------------

class _Live:
    """A request in flight on the decode engine."""

    __slots__ = ("req", "rec", "t_ready", "kv_len", "emitted",
                 "kv_reserved")

    def __init__(self, req: Request, rec: RequestRecord,
                 t_ready: float, kv_reserved: int) -> None:
        self.req = req
        self.rec = rec
        self.t_ready = t_ready
        self.kv_len = req.prompt_len + 1  # prefill emitted token 1
        self.emitted = 1
        self.kv_reserved = kv_reserved


class ServeSim:
    """Replay an arrival trace against a compiled step-cost table.

    Two replay engines produce byte-identical metrics JSON (modulo the
    self-describing ``engine`` key):

    * ``engine="array"`` (default) — the array-batched engine in
      :mod:`repro.serve.engine`: per-request timelines in preallocated
      numpy arrays, decode priced horizon-at-a-time with slice adds
      and ``cumsum`` clock chains.  Orders of magnitude faster on long
      traces; required for ``prefill_policy="batched"``/``"chunked"``.
    * ``engine="event"`` — the reference discrete-event loop below,
      one Python pass per decode iteration.  Kept as the semantic
      oracle the equivalence suite diffs the array engine against.

    ``prefill_policy`` picks how prompts reach the decode engine:
    ``fifo`` (batch-1 back-to-back, both engines), ``batched`` (FCFS
    batches up to ``prefill_max_batch``, priced with the table's
    prefill affine fit), or ``chunked`` (Sarathi-style chunked prefill
    co-scheduled into decode iterations under a ``chunk_tokens``
    budget).

    ``deadline_s``/``max_queue`` switch on degraded-mode machinery:

    * ``max_queue`` — admission control at the prefill engine.  A
      request arriving while ``max_queue`` requests already wait is
      *shed*; while it has retries left it re-arrives after an
      exponential backoff (``retry_backoff_s * 2**attempt``), keeping
      its original arrival time for latency accounting, otherwise it
      is dropped and counted in ``shed_requests``.
    * ``deadline_s`` — per-request SLO from the *original* arrival.  A
      request finishing late still completes (no mid-flight cancel —
      the engine already spent the cycles) but counts as a timeout and
      contributes nothing to goodput.

    With both unset (the default) every code path, record and metrics
    key is identical to the pre-degradation simulator.
    """

    def __init__(self, table: StepCostTable, policy: Batcher,
                 kv_capacity_bytes: Optional[int] = None,
                 kv_frac: float = 0.5,
                 deadline_s: Optional[float] = None,
                 max_queue: Optional[int] = None,
                 max_retries: int = 0,
                 retry_backoff_s: float = 0.05,
                 engine: str = "array",
                 prefill_policy: str = "fifo",
                 prefill_max_batch: int = 8,
                 chunk_tokens: int = 32,
                 percentile_mode: str = "exact") -> None:
        self.table = table
        self.policy = policy
        if engine not in _ENGINES:
            raise ValueError(f"engine must be one of {_ENGINES}")
        if prefill_policy not in _PREFILL_POLICIES:
            raise ValueError(
                f"prefill_policy must be one of {_PREFILL_POLICIES}")
        if engine == "event" and prefill_policy != "fifo":
            raise ValueError(
                "the event engine only supports prefill_policy='fifo' "
                "— batched/chunked prefill need engine='array'")
        if max_queue is not None and prefill_policy != "fifo":
            raise ValueError(
                "max_queue admission control models the FIFO prefill "
                "queue; it composes with prefill_policy='fifo' only")
        if prefill_max_batch < 1:
            raise ValueError("prefill_max_batch must be >= 1")
        if chunk_tokens < 1:
            raise ValueError("chunk_tokens must be >= 1")
        if percentile_mode not in ("exact", "streaming"):
            raise ValueError("percentile_mode must be exact|streaming")
        self.engine = engine
        self.prefill_policy = prefill_policy
        self.prefill_max_batch = prefill_max_batch
        self.chunk_tokens = chunk_tokens
        self.percentile_mode = percentile_mode
        if kv_capacity_bytes is None:
            kv_capacity_bytes = int(
                table.chip.global_mem_bytes * kv_frac)
        one = table.cfg.kv_bytes(table.cfg.max_seq)
        if kv_capacity_bytes < one:
            raise ValueError(
                f"KV budget {kv_capacity_bytes}B cannot hold one "
                f"max-length request ({one}B)")
        self.kv_capacity_bytes = kv_capacity_bytes
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError("deadline_s must be > 0")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if max_retries < 0 or retry_backoff_s < 0:
            raise ValueError("max_retries and retry_backoff_s must "
                             "be non-negative")
        self.deadline_s = deadline_s
        self.max_queue = max_queue
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s

    @property
    def degraded(self) -> bool:
        """True when any degradation feature is switched on."""
        return self.deadline_s is not None or self.max_queue is not None

    # -- prefill engine ----------------------------------------------

    def _run_prefill(self, requests: Sequence[Request]
                     ) -> List[Tuple[float, Request, RequestRecord]]:
        """FIFO prefill; returns (decode-ready time, req, record)."""
        free = 0.0
        out: List[Tuple[float, Request, RequestRecord]] = []
        for req in sorted(requests, key=lambda r: (r.t_arrive, r.rid)):
            start = max(free, req.t_arrive)
            end = start + self.table.prefill_s(req.prompt_len)
            free = end
            rec = RequestRecord(
                rid=req.rid, t_arrive=req.t_arrive,
                prompt_len=req.prompt_len, gen_len=req.gen_len,
                t_prefill_start=start, t_first_token=end,
                t_complete=end)
            out.append((end, req, rec))
        return out

    def _run_prefill_shedding(self, requests: Sequence[Request]
                              ) -> Tuple[
                                  List[Tuple[float, Request,
                                             RequestRecord]],
                                  int, int]:
        """FIFO prefill with queue-pressure admission control.

        Returns ``(ready, shed, retries)``.  A request whose (re-)
        arrival finds ``max_queue`` requests already waiting for the
        prefill engine is bounced: retried after backoff while
        attempts remain, shed for good otherwise.  Records keep the
        *original* arrival time, so retry delay shows up in TTFT/e2e
        exactly as a client would measure it.
        """
        cap = self.max_queue if self.max_queue is not None else None
        # (effective arrival, rid, attempt, request)
        pend = [(r.t_arrive, r.rid, 0, r) for r in requests]
        heapq.heapify(pend)
        free = 0.0
        starts: List[float] = []   # admitted-but-not-started, FIFO
        out: List[Tuple[float, Request, RequestRecord]] = []
        shed = 0
        retries = 0
        while pend:
            ta, _, attempt, req = heapq.heappop(pend)
            # drain the wait queue of everything that started by ta
            while starts and starts[0] <= ta:
                starts.pop(0)
            if cap is not None and len(starts) >= cap:
                if attempt < self.max_retries:
                    retries += 1
                    t_retry = ta + self.retry_backoff_s * (2 ** attempt)
                    heapq.heappush(
                        pend, (t_retry, req.rid, attempt + 1, req))
                else:
                    shed += 1
                continue
            start = max(free, ta)
            end = start + self.table.prefill_s(req.prompt_len)
            free = end
            if start > ta:
                starts.append(start)
            rec = RequestRecord(
                rid=req.rid, t_arrive=req.t_arrive,
                prompt_len=req.prompt_len, gen_len=req.gen_len,
                t_prefill_start=start, t_first_token=end,
                t_complete=end)
            out.append((end, req, rec))
        return out, shed, retries

    # -- decode engine -----------------------------------------------

    def run(self, requests: Sequence[Request],
            max_sim_s: Optional[float] = None) -> Dict[str, Any]:
        if self.engine == "array":
            return run_array(self, requests, max_sim_s)
        return self._run_event(requests, max_sim_s)

    def _run_event(self, requests: Sequence[Request],
                   max_sim_s: Optional[float] = None) -> Dict[str, Any]:
        if self.max_queue is not None:
            ready, shed, retries = self._run_prefill_shedding(requests)
        else:
            ready = self._run_prefill(requests)
            shed, retries = 0, 0
        records: List[RequestRecord] = [rec for _, _, rec in ready]
        if max_sim_s is not None and ready and \
                max(end for end, _, _ in ready) > max_sim_s:
            raise RuntimeError(self._overload_diag(ready, max_sim_s))

        # single-token requests never enter the decode engine
        heap: List[Tuple[float, int, Request, RequestRecord]] = []
        for end, req, rec in ready:
            if req.gen_len > 1:
                heapq.heappush(heap, (end, req.rid, req, rec))

        active: List[_Live] = []
        queue: List[_Live] = []
        kv_used = 0
        peak_kv = 0
        peak_batch = 0
        iterations = 0
        decode_busy = 0.0
        t = 0.0
        while heap or queue or active:
            # surface everything that has finished prefill by now
            while heap and heap[0][0] <= t:
                end, _, req, rec = heapq.heappop(heap)
                queue.append(_Live(
                    req, rec, end,
                    self.table.kv_bytes(req.prompt_len + req.gen_len)))
            if not active and not queue and heap:
                t = heap[0][0]
                continue

            admitted = self.policy.admit(
                active, queue, self.kv_capacity_bytes - kv_used)
            for live in admitted:
                queue.remove(live)
                kv_used += live.kv_reserved
                active.append(live)
            if not active:
                # queue blocked on KV/slots: wait for in-flight work,
                # or (static policy with empty engine) nothing can
                # block, so this only happens via the heap above
                if heap:
                    t = max(t, heap[0][0])
                    continue
                raise RuntimeError("deadlock: queued work cannot admit")

            dt = self.table.iteration_s([l.kv_len for l in active])
            t += dt
            decode_busy += dt
            iterations += 1
            if max_sim_s is not None and t > max_sim_s:
                raise RuntimeError(self._overload_diag(ready, max_sim_s,
                                                       t=t))
            peak_batch = max(peak_batch, len(active))
            peak_kv = max(peak_kv, kv_used)
            done: List[_Live] = []
            for live in active:
                live.kv_len += 1
                live.emitted += 1
                live.rec.t_complete = t
                if live.emitted >= live.req.gen_len:
                    done.append(live)
            for live in done:
                active.remove(live)
                kv_used -= live.kv_reserved

        extra = {
            "policy": self.policy.name,
            "max_batch": self.policy.max_batch,
            "fidelity": self.table.fidelity,
            "kv_capacity_bytes": self.kv_capacity_bytes,
            "kv_peak_bytes": peak_kv,
            "decode_iterations": iterations,
            "peak_decode_batch": peak_batch,
            "engine": "event",
            "prefill_policy": self.prefill_policy,
        }
        self._warn_if_saturated(records, decode_busy, t)
        if self.degraded:
            extra.update(self._degradation_extra(records, shed,
                                                 retries))
        return summarize(records, extra,
                         percentile_mode=self.percentile_mode)

    # -- degraded-mode accounting ------------------------------------

    def _degradation_extra(self, records: Sequence[RequestRecord],
                           shed: int, retries: int) -> Dict[str, Any]:
        """shed/timeout/retry counters and goodput (gated keys)."""
        timeouts = 0
        good_toks = 0
        for rec in records:
            late = (self.deadline_s is not None and
                    rec.t_complete - rec.t_arrive > self.deadline_s)
            if late:
                timeouts += 1
            else:
                good_toks += rec.gen_len
        if records:
            t0 = min(r.t_arrive for r in records)
            t1 = max(r.t_complete for r in records)
            makespan = max(t1 - t0, 1e-12)
        else:
            makespan = 0.0
        return {
            "shed_requests": shed,
            "retries": retries,
            "timeout_requests": timeouts,
            # tokens that arrived in time, per second — under overload
            # this drops below throughput_tok_s even as the engine
            # stays busy, which is the whole point of measuring it
            "goodput_tok_s": good_toks / makespan if makespan else 0.0,
        }

    # -- overload diagnostics ----------------------------------------

    def _utilization(self, records: Sequence[RequestRecord],
                     decode_busy: float,
                     t_end: float) -> Tuple[float, float]:
        """(prefill, decode) busy fractions over their active spans."""
        if not records:
            return 0.0, 0.0
        t0 = min(r.t_arrive for r in records)
        prefill_busy = sum(r.t_first_token - r.t_prefill_start
                           for r in records)
        prefill_span = max(r.t_first_token for r in records) - t0
        decode_span = t_end - t0
        u_pre = prefill_busy / prefill_span if prefill_span > 0 else 0.0
        u_dec = decode_busy / decode_span if decode_span > 0 else 0.0
        return u_pre, u_dec

    def _warn_if_saturated(self, records: Sequence[RequestRecord],
                           decode_busy: float, t_end: float) -> None:
        u_pre, u_dec = self._utilization(records, decode_busy, t_end)
        self._emit_saturation_warning(u_pre, u_dec)

    def _emit_saturation_warning(self, u_pre: float, u_dec: float,
                                 threshold: float = 0.95) -> None:
        """Shared by both engines so the warning text stays identical."""
        if max(u_pre, u_dec) < threshold:
            return
        stage = "prefill" if u_pre >= u_dec else "decode"
        warnings.warn(
            f"serving replay saturated: {stage} engine utilization "
            f"{max(u_pre, u_dec):.3f} (prefill {u_pre:.3f}, decode "
            f"{u_dec:.3f}) — offered load is at or beyond capacity, "
            f"so queueing delay grows with trace length and latency "
            f"percentiles reflect the trace, not the system; lower "
            f"the arrival rate or enable load shedding (max_queue=)",
            RuntimeWarning, stacklevel=4)

    def _overload_msg(self, t0: float, max_sim_s: float,
                      t: Optional[float] = None,
                      prefill_end: Optional[float] = None) -> str:
        """Shared by both engines so the diagnostic stays identical."""
        where = (f"decode clock reached {t:.3f}s" if t is not None
                 else f"prefill backlog extends past "
                      f"{prefill_end:.3f}s")
        return (f"serving replay exceeded max_sim_s={max_sim_s:g}s: "
                f"{where} for a trace starting at {t0:.3f}s — the "
                f"offered load exceeds sustainable capacity and the "
                f"replay would run (almost) unboundedly long; lower "
                f"the arrival rate, shrink the trace, enable load "
                f"shedding (max_queue=), or raise max_sim_s")

    def _overload_diag(self, ready: Sequence[Tuple[float, Request,
                                                   RequestRecord]],
                       max_sim_s: float,
                       t: Optional[float] = None) -> str:
        recs = [rec for _, _, rec in ready]
        t0 = min(r.t_arrive for r in recs) if recs else 0.0
        prefill_end = (max(e for e, _, _ in ready)
                       if t is None else None)
        return self._overload_msg(t0, max_sim_s, t=t,
                                  prefill_end=prefill_end)

"""Substrate subsystems: optimizer, data pipeline, quantization,
checkpointing, fault tolerance, elastic re-meshing, stragglers."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis",
                    reason="property tests need the optional "
                           "hypothesis dev dependency")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.configs import ARCHS, reduced
from repro.data import SyntheticStream, make_batch
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_warmup, global_norm)
from repro.quant import dequantize, fake_quant, quantize_tensor
from repro.runtime import (FailureDetector, HeartbeatRegistry,
                           StragglerDetector, plan_remesh)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    cfg = AdamWConfig(weight_decay=0.0)
    state = adamw_init(params, cfg)
    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, m = adamw_update(g, state, params,
                                        jnp.float32(0.05), cfg)
    assert float(loss(params)) < 1e-2 * l0
    assert np.isfinite(float(m["grad_norm"]))


def test_adamw_clip_and_bf16_moments():
    params = {"w": jnp.ones((4,))}
    cfg = AdamWConfig(clip_norm=0.5, moment_dtype="bfloat16")
    state = adamw_init(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4,), 100.0)}
    _, state, m = adamw_update(g, state, params, jnp.float32(0.1), cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_cosine_warmup_shape():
    lr0 = float(cosine_warmup(0, peak=1e-3, warmup=10, total=100))
    lrw = float(cosine_warmup(10, peak=1e-3, warmup=10, total=100))
    lre = float(cosine_warmup(100, peak=1e-3, warmup=10, total=100))
    assert lr0 == 0.0 and lrw == pytest.approx(1e-3)
    assert lre == pytest.approx(1e-4, rel=1e-3)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_batches_deterministic_per_step():
    cfg = reduced(ARCHS["phi3-medium-14b"])
    a = make_batch(cfg, 8, 16, seed=3, step=7)
    b = make_batch(cfg, 8, 16, seed=3, step=7)
    c = make_batch(cfg, 8, 16, seed=3, step=8)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].min() >= 1 and a["tokens"].max() < cfg.vocab


def test_stream_resume_reproduces_sequence():
    cfg = reduced(ARCHS["olmoe-1b-7b"])
    s1 = SyntheticStream(cfg, 4, 8, seed=5)
    first = [next(s1)["tokens"] for _ in range(3)]
    state = s1.state_dict()
    nxt = next(s1)["tokens"]
    s1.close()
    s2 = SyntheticStream.restore(cfg, 4, 8, state)
    assert np.array_equal(next(s2)["tokens"], nxt)
    s2.close()


def test_modality_extras_present():
    wcfg = reduced(ARCHS["whisper-small"])
    b = make_batch(wcfg, 2, 8, seed=0, step=0)
    assert b["frames"].shape == (2, wcfg.encoder_seq, wcfg.d_model)
    vcfg = reduced(ARCHS["llava-next-mistral-7b"])
    b = make_batch(vcfg, 2, 8, seed=0, step=0)
    assert b["patches"].shape == (2, vcfg.vision_tokens, vcfg.d_model)


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_error_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, (16, 8)).astype(np.float32))
    t = quantize_tensor(x)
    err = np.abs(np.asarray(dequantize(t) - x))
    assert err.max() <= float(t.scale) * 0.5 + 1e-7


def test_per_channel_beats_per_tensor():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (64, 8)).astype(np.float32)
    x[:, 0] *= 100.0                      # one loud channel
    xt = jnp.asarray(x)
    e_tensor = np.abs(np.asarray(dequantize(quantize_tensor(xt)) - x)).mean()
    e_chan = np.abs(np.asarray(
        dequantize(quantize_tensor(xt, axis=1)) - x)).mean()
    assert e_chan < e_tensor


def test_fake_quant_straight_through_grad():
    x = jnp.linspace(-1, 1, 32)
    g = jax.grad(lambda v: jnp.sum(fake_quant(v) ** 2))(x)
    assert np.isfinite(np.asarray(g)).all()


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def _tree():
    return {"layer": {"w": jnp.arange(6.0).reshape(2, 3),
                      "b": jnp.zeros(3)},
            "step": jnp.int32(7)}


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"), metadata={"step": 7}, n_shards=2)
    loaded, meta = load_pytree(t, str(tmp_path / "ck"))
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_structure_mismatch_rejected(tmp_path):
    save_pytree(_tree(), str(tmp_path / "ck"))
    bad = {"other": jnp.zeros(3)}
    with pytest.raises(ValueError, match="structure mismatch"):
        load_pytree(bad, str(tmp_path / "ck"))


def test_manager_async_keep_and_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for step in (1, 2, 3):
        t = jax.tree.map(lambda a: a + 1 if a.dtype.kind == "f" else a, t)
        mgr.save(step, t, metadata={"step": step})
    mgr.wait()
    assert mgr.all_steps() == [2, 3]      # keep-last-2
    step, loaded, meta = mgr.restore(_tree())
    assert step == 3 and meta["step"] == 3


def test_crash_safe_tmp_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(), blocking=True)
    os.makedirs(str(tmp_path / "step_0000000002.tmp"))  # simulated crash
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------------------
# Fault tolerance / elastic / stragglers
# ---------------------------------------------------------------------------


def test_failure_detector_flags_silent_node():
    clock = [0.0]
    reg = HeartbeatRegistry(clock=lambda: clock[0])
    det = FailureDetector(reg, min_timeout=5.0)
    for t in range(5):
        clock[0] = float(t)
        reg.beat("a")
        reg.beat("b")
    for t in range(5, 12):                # b goes silent
        clock[0] = float(t)
        reg.beat("a")
    assert det.check() == ["b"]
    assert det.alive() == ["a"]
    det.revive("b")
    assert "b" not in det.failed


def test_elastic_remesh_keeps_model_axis():
    plan = plan_remesh(500, model_parallel=16, target_data_parallel=32)
    assert plan.mesh_shape == (31, 16)
    assert plan.chips_idle == 500 - 31 * 16
    assert plan.grad_accum == 2           # 31 dp vs target 32 -> accum 2


def test_elastic_remesh_shrinks_when_needed():
    plan = plan_remesh(12, model_parallel=16, target_data_parallel=8,
                       min_model_parallel=4)
    assert plan.mesh_shape[1] in (4, 8)
    assert plan.chips_used <= 12


def test_elastic_impossible_raises():
    with pytest.raises(ValueError):
        plan_remesh(3, model_parallel=16, target_data_parallel=4,
                    min_model_parallel=8)


def test_straggler_detector_persistent_slow_host():
    det = StragglerDetector(k=4.0, min_hits=3)
    flagged = []
    for step in range(6):
        times = {f"h{i}": 1.0 + 0.01 * i for i in range(8)}
        times["h7"] = 3.0                 # persistently slow
        flagged = det.record_step(times)
    assert flagged == ["h7"]


def test_straggler_one_off_not_flagged():
    det = StragglerDetector(min_hits=3)
    for step in range(6):
        times = {f"h{i}": 1.0 for i in range(8)}
        if step == 2:
            times["h3"] = 9.0             # transient hiccup
        assert det.record_step(times) == []

"""Pallas CIM kernel vs pure-jnp oracle: shape/dtype sweep + properties.

Digital CIM arithmetic is exact, so every comparison is integer equality,
not allclose-with-tolerance.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis",
                    reason="property tests need the optional "
                           "hypothesis dev dependency")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.bitserial_mvm import bitserial_mvm_pallas

RNG = np.random.default_rng(7)


def _rand(m, k, n, lo=-128, hi=128):
    x = RNG.integers(lo, hi, (m, k)).astype(np.int8)
    w = RNG.integers(-128, 128, (k, n)).astype(np.int8)
    return jnp.asarray(x), jnp.asarray(w)


# ---------------------------------------------------------------------------
# Decomposition math
# ---------------------------------------------------------------------------


def test_bitplane_reference_equals_matmul():
    x, w = _rand(64, 96, 32)
    assert np.array_equal(kref.bitserial_mvm_ref(x, w), kref.mvm_ref(x, w))


def test_unsigned_bitplanes():
    x = jnp.asarray(RNG.integers(0, 128, (32, 64)).astype(np.int8))
    w = jnp.asarray(RNG.integers(-128, 128, (64, 16)).astype(np.int8))
    # 7 planes suffice for non-negative activations
    out = kref.bitserial_mvm_ref(x, w, act_bits=7, signed=False)
    assert np.array_equal(out, kref.mvm_ref(x, w))


# ---------------------------------------------------------------------------
# Pallas kernel (interpret mode on CPU)
# ---------------------------------------------------------------------------

ALIGNED = [(128, 128, 128), (256, 128, 384), (128, 512, 128)]


@pytest.mark.parametrize("m,k,n", ALIGNED)
def test_pallas_kernel_aligned(m, k, n):
    x, w = _rand(m, k, n)
    out = bitserial_mvm_pallas(x, w, interpret=True)
    assert out.dtype == jnp.int32
    assert np.array_equal(out, kref.mvm_ref(x, w))


RAGGED = [(1, 1, 1), (37, 100, 59), (128, 129, 130), (200, 64, 1000),
          (5, 4096, 8), (511, 27, 64)]


@pytest.mark.parametrize("m,k,n", RAGGED)
def test_cim_mvm_ragged(m, k, n):
    x, w = _rand(m, k, n)
    out = ops.cim_mvm(x, w, interpret=True)
    assert out.shape == (m, n)
    assert np.array_equal(out, kref.mvm_ref(x, w))


@pytest.mark.parametrize("act_bits", [4, 6, 8])
def test_cim_mvm_reduced_precision(act_bits):
    """act_bits < 8 is exact when activations fit act_bits bits."""
    lo, hi = -(1 << (act_bits - 1)), 1 << (act_bits - 1)
    x = jnp.asarray(RNG.integers(lo, hi, (64, 128)).astype(np.int8))
    w = jnp.asarray(RNG.integers(-128, 128, (128, 64)).astype(np.int8))
    out = ops.cim_mvm(x, w, act_bits=act_bits, interpret=True)
    # sign bit position differs: mask to act_bits two's complement first
    xm = ((x.astype(jnp.int32) + hi) % (2 * hi)) - hi
    want = kref.mvm_ref(xm.astype(jnp.int8), w)
    assert np.array_equal(out, want)


def test_blocks_affect_nothing():
    x, w = _rand(160, 192, 96)
    a = ops.cim_mvm(x, w, block_m=128, block_n=128, block_k=128,
                    interpret=True)
    b = ops.cim_mvm(x, w, block_m=32, block_n=64, block_k=96,
                    interpret=True)
    assert np.array_equal(a, b)


def test_int8_matmul_identical_to_kernel():
    x, w = _rand(96, 160, 72)
    assert np.array_equal(ops.int8_matmul(x, w),
                          ops.cim_mvm(x, w, interpret=True))


@given(st.integers(1, 64), st.integers(1, 96), st.integers(1, 64),
       st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_cim_mvm_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-128, 128, (m, k)).astype(np.int8))
    w = jnp.asarray(rng.integers(-128, 128, (k, n)).astype(np.int8))
    out = ops.cim_mvm(x, w, block_m=32, block_n=32, block_k=32,
                      interpret=True)
    assert np.array_equal(out, kref.mvm_ref(x, w))


# ---------------------------------------------------------------------------
# Requant + fake-quant linear
# ---------------------------------------------------------------------------


def test_requant_matches_iss_semantics():
    """kernels.ref.requant_ref == the compiled V_QUANT semantics."""
    from repro.core.codegen import QuantParams
    from repro.core.ref import quantize as iss_quant
    acc = RNG.integers(-100000, 100000, (64,)).astype(np.int32)
    for scale, shift, div in [(1, 8, 1), (3, 12, 1), (1, 4, 49)]:
        got = kref.requant_ref(jnp.asarray(acc), scale, shift, div)
        want = iss_quant(acc, QuantParams(scale=scale, shift=shift),
                         div=div)
        assert np.array_equal(np.asarray(got), want)


def test_quantized_linear_forward_and_grad():
    x = jnp.asarray(RNG.normal(0, 1, (8, 32)).astype(np.float32))
    w = jnp.asarray(RNG.integers(-128, 128, (32, 16)).astype(np.int8))
    scales = (jnp.float32(0.02), jnp.float32(0.01))
    y = ops.quantized_linear(x, w, scales)
    want = kref.quantized_linear_ref(x, w, 0.01, 0.02)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-6)
    # straight-through gradient exists and is finite
    g = jax.grad(lambda xx: ops.quantized_linear(xx, w, scales).sum())(x)
    assert np.isfinite(np.asarray(g)).all()
    # and matches the dequantized-weight linear gradient
    w_deq = w.astype(jnp.float32) * 0.01
    g_ref = jax.grad(lambda xx: (xx @ w_deq).sum())(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-5)

"""Unit tests for the shared machine model (repro.core.machine)."""

import math

import pytest

from repro.core.arch import default_chip
from repro.core.isa import default_isa
from repro.core.machine import (Calibration, IDENTITY_CALIBRATION,
                                MachineModel, VECTOR_MUL_FNS,
                                VECTOR_SPECIAL_FNS, machine_for)
from repro.core.simulator import Simulator


@pytest.fixture(scope="module")
def chip():
    return default_chip()


@pytest.fixture(scope="module")
def m(chip):
    return machine_for(chip)


def test_machine_memoized(chip, m):
    # equal chip descriptions share one model instance
    assert machine_for(default_chip()) is m
    assert chip.machine() is m
    assert machine_for(chip, Calibration(cim=2.0)) is not m


def test_mvm_timing_matches_macro(chip, m):
    macro = chip.core.cim.macro
    assert m.mvm_interval_beats == macro.act_bits
    assert m.mvm_fill_beats == macro.adder_tree_depth
    assert m.mvm_pass_beats == macro.mvm_beats()
    assert m.mvm_cycles(10) == 10 * macro.act_bits \
        + macro.adder_tree_depth


def test_weight_load(chip, m):
    rate = chip.core.cim.weight_load_rows_per_cycle
    assert m.weight_load_cycles(512) == 512 / rate
    assert m.group_load_cycles() == chip.core.cim.macro.rows / rate


def test_vector_latency_classes(chip, m):
    v = chip.core.vector
    n = v.lanes * 3
    assert m.vector_cycles("add", n) == 3 + v.alu_latency
    for fn in VECTOR_MUL_FNS:
        assert m.vector_cycles(fn, n) == 3 + v.mul_latency
    for fn in VECTOR_SPECIAL_FNS:
        assert m.vector_cycles(fn, n) == 3 * v.special_latency
    # sub-lane ops still cost one beat
    assert m.vector_cycles("add", 1) == 1 + v.alu_latency


def test_noc_rules(chip, m):
    noc = chip.noc
    assert m.link_bytes_per_cycle == noc.link_bytes_per_cycle
    assert m.router_hop_cycles == noc.router_latency
    assert m.link_occupancy_cycles(noc.flit_bytes * 4) \
        == 4 / noc.flits_per_cycle
    assert m.link_occupancy_cycles(1) == 1 / noc.flits_per_cycle
    assert m.send_issue_cycles(1) == 1.0          # floor of one cycle
    assert m.avg_hops == (chip.mesh_rows + chip.mesh_cols) / 3.0
    assert m.hops(0, 9) == chip.hops(0, 9)


def test_gmem_rules(chip, m):
    per_port = chip.global_mem_bytes_per_cycle
    ports = chip.global_mem_ports
    assert m.gmem_total_bytes_per_cycle == ports * per_port
    assert m.gmem_stream_cycles(per_port) == 1 / ports
    assert m.gmem_stream_cycles(per_port, ports=1) == 1.0
    # ports clamp to the chip's count
    assert m.gmem_stream_cycles(per_port, ports=99) == 1 / ports


def test_scalar_rules(chip, m):
    s = chip.core.scalar
    assert m.scalar_alu_cycles == s.alu_latency
    assert m.scalar_mul_cycles == s.mul_latency
    assert m.scalar_ldst_cycles == s.ldst_latency
    assert m.branch_cycles(False) == 1
    assert m.branch_cycles(True) == 1 + s.branch_penalty


def test_simulator_shares_machine(chip, m):
    sim = Simulator(chip, default_isa())
    assert sim.m is m


def test_energy_pricing(chip, m):
    out = m.price_events({"gmem_bytes": 1000.0})
    assert out["gmem"] == pytest.approx(
        1000.0 * m.energy_table.gmem_byte)
    assert out["total"] == out["gmem"]


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------


def test_calibration_identity():
    assert Calibration().is_identity
    assert IDENTITY_CALIBRATION.is_identity
    assert not Calibration(vector=2.0).is_identity


def test_calibration_rejects_nonpositive():
    with pytest.raises(ValueError):
        Calibration(cim=0.0)
    with pytest.raises(ValueError):
        Calibration(makespan=-1.0)
    with pytest.raises(ValueError):
        Calibration(noc=float("inf"))


def test_calibration_dict_roundtrip():
    c = Calibration(cim=1.5, vector=9.0, noc=3.0, gmem=3.0,
                    load=1.2, makespan=2.5)
    assert Calibration.from_dict(c.to_dict()) == c


def test_calibration_combine_geomean():
    a = Calibration(vector=2.0)
    b = Calibration(vector=8.0)
    comb = Calibration.combine([a, b])
    assert comb.vector == pytest.approx(4.0)
    assert comb.cim == pytest.approx(1.0)
    assert Calibration.combine([]) == Calibration()


def test_calibrated_stage_costs(chip):
    """Calibration scales the analytic stage arithmetic predictably."""
    from repro import flow
    from repro.core.mapping import CostParams

    art = flow.compile("tiny_cnn", chip,
                       flow.CompileOptions(strategy="dp",
                                           params=CostParams(batch=4)))
    res = art.partition
    base = res.latency_cycles(4)
    doubled = res.latency_cycles(4, Calibration(makespan=2.0))
    assert doubled == pytest.approx(2 * base)
    # scaling every unit by k scales the whole latency by k
    k = 3.0
    allk = Calibration(cim=k, vector=k, noc=k, gmem=k, load=k)
    assert res.latency_cycles(4, allk) == pytest.approx(k * base)
    # the dominant-unit max still rules the interval
    sp = res.stages[0]
    assert sp.interval_c(Calibration(vector=100.0)) >= sp.interval_c()

"""repro.flow pipeline: golden equivalence against the legacy
partition+compile_model chain, pass-output caching across fidelities,
backend parity, deprecation shims, and the strict_lmem warning."""

import warnings

import numpy as np
import pytest

from repro import flow
from repro.core import ref, workloads
from repro.core.arch import default_chip
from repro.core.codegen import CodegenError, compile_model
from repro.core.graph import Graph
from repro.core.mapping import CostParams
from repro.core.partition import partition
from repro.flow import (AnalyticBackend, CompileOptions, PartitionPass,
                        Pipeline, register_pass)

CHIP = default_chip(n_cores=8, mesh_cols=4)
PARAMS = CostParams(batch=2)


def _mlp() -> Graph:
    g = Graph("mlp")
    x = g.input("x", (64,))
    h = g.linear("fc1", x, cout=48, act="relu")
    g.linear("fc2", h, cout=10)
    return g


def _resnet_style() -> Graph:
    """conv -> conv -> residual add -> relu -> GAP -> fc (ResNet idiom)."""
    g = Graph("res_style")
    x = g.input("x", (8, 8, 8))
    c1 = g.conv("c1", x, cout=8, k=3, act="relu", use_bn=False)
    c2 = g.conv("c2", c1, cout=8, k=3, use_bn=False)
    a = g.eltwise("add", "add", c2, c1)
    r = g.unary("relu", "relu", a)
    g.linear("fc", g.globalpool("gap", r), cout=4)
    return g


def _isa_streams(model):
    """Encoded per-core ISA words: [(stage, core, uint32-words), ...]."""
    return [(si, cid, prog.encode(model.isa).tolist())
            for si, st in enumerate(model.stages)
            for cid, prog in sorted(st.programs.items())]


def _legacy_model(cg, strategy="dp", batch=2):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        res = partition(cg, CHIP, strategy, PARAMS)
        return compile_model(res, batch=batch)


# ---------------------------------------------------------------------------
# golden equivalence: new API == legacy chain, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("build", [_mlp, _resnet_style],
                         ids=["mlp", "resnet_style"])
@pytest.mark.parametrize("strategy", ["dp", "generic"])
def test_golden_isa_streams_bit_identical(build, strategy):
    cg = build().condense()
    legacy = _legacy_model(cg, strategy=strategy)
    art = flow.compile(cg, CHIP, CompileOptions(
        strategy=strategy, params=PARAMS, batch=2, fidelity="simulate"),
        pipeline=Pipeline())
    assert _isa_streams(art.model) == _isa_streams(legacy)
    assert art.model.layout.weights == legacy.layout.weights
    assert art.model.layout.acts == legacy.layout.acts


def test_golden_simulated_cycles_match_legacy():
    cg = _mlp().condense()
    legacy = _legacy_model(cg)
    from repro.core.simulator import Simulator
    want = Simulator(CHIP, legacy.isa, mode="perf").run_model(legacy)
    art = flow.compile(cg, CHIP, strategy="dp", params=PARAMS, batch=2,
                       pipeline=Pipeline())
    rep = art.evaluate("simulate")
    assert rep.cycles == want.cycles
    assert rep.sim.instrs == want.instrs


def test_analytic_backend_matches_partition_result():
    cg = _resnet_style().condense()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        res = partition(cg, CHIP, "dp", PARAMS)
    art = flow.compile(cg, CHIP, strategy="dp", params=PARAMS,
                       pipeline=Pipeline())
    rep = art.evaluate(AnalyticBackend())
    assert rep.cycles == pytest.approx(res.latency_cycles())
    assert rep.batch == PARAMS.batch
    # no codegen happened for the analytic fidelity
    assert art.model is None


# ---------------------------------------------------------------------------
# pass-output caching across fidelities
# ---------------------------------------------------------------------------


def test_partition_pass_reused_across_fidelities():
    pipe = Pipeline()
    cg = _mlp().condense()
    a1 = pipe.compile(cg, CHIP, CompileOptions(
        strategy="dp", params=PARAMS, fidelity="analytic"))
    r1 = a1.pass_record("partition")
    assert r1 is not None and not r1.cached
    # second fidelity: the partition pass must be skipped (cache hit)
    a2 = pipe.compile(cg, CHIP, CompileOptions(
        strategy="dp", params=PARAMS, fidelity="simulate"))
    r2 = a2.pass_record("partition")
    assert r2 is not None and r2.cached
    assert a2.partition is a1.partition        # same object, no rework
    assert a2.pass_record("condense").cached
    assert not a2.pass_record("codegen").cached


def test_cache_key_isolates_strategy_and_params():
    pipe = Pipeline()
    cg = _mlp().condense()
    a_dp = pipe.compile(cg, CHIP, strategy="dp", params=PARAMS)
    a_gen = pipe.compile(cg, CHIP, strategy="generic", params=PARAMS)
    assert not a_gen.pass_record("partition").cached
    assert a_gen.partition is not a_dp.partition
    a_b4 = pipe.compile(cg, CHIP, strategy="dp",
                        params=CostParams(batch=4))
    assert not a_b4.pass_record("partition").cached


def test_condense_cache_shared_across_chips():
    """Condense is chip-independent: a second chip must reuse it while
    re-running the (chip-dependent) partition pass."""
    pipe = Pipeline()
    cg = _mlp().condense()
    other = default_chip(n_cores=4, mesh_cols=2)
    pipe.compile(cg, CHIP, strategy="dp", params=PARAMS)
    a2 = pipe.compile(cg, other, strategy="dp", params=PARAMS)
    assert a2.pass_record("condense").cached
    assert not a2.pass_record("partition").cached


def test_dump_dir_writes_ir_even_on_cache_hit(tmp_path):
    import os
    pipe = Pipeline()
    cg = _mlp().condense()
    pipe.compile(cg, CHIP, strategy="dp", params=PARAMS)   # warm cache
    d = str(tmp_path / "ir")
    art = pipe.compile(cg, CHIP, strategy="dp", params=PARAMS,
                       dump_dir=d)
    assert art.pass_record("partition").cached
    dumps = os.listdir(d)
    assert any(f.startswith("condense-") for f in dumps)
    assert any(f.startswith("partition_dp-") for f in dumps)


def test_structurally_identical_graphs_share_cache():
    pipe = Pipeline()
    a1 = pipe.compile(_mlp().condense(), CHIP, strategy="dp",
                      params=PARAMS)
    a2 = pipe.compile(_mlp().condense(), CHIP, strategy="dp",
                      params=PARAMS)
    assert a2.pass_record("partition").cached
    assert a2.partition is a1.partition


def test_quant_and_strict_do_not_invalidate_partition():
    pipe = Pipeline()
    cg = _mlp().condense()
    a1 = pipe.compile(cg, CHIP, strategy="dp", params=PARAMS)
    a2 = pipe.compile(cg, CHIP, strategy="dp", params=PARAMS,
                      strict_lmem=True, fidelity="simulate")
    assert a2.pass_record("partition").cached
    # but codegen does key on strict_lmem/quant
    a3 = pipe.compile(cg, CHIP, strategy="dp", params=PARAMS,
                      fidelity="simulate")
    assert not a3.pass_record("codegen").cached


# ---------------------------------------------------------------------------
# registry pluggability
# ---------------------------------------------------------------------------


def test_custom_partition_strategy_plugs_in():
    from repro.core.partition import greedy_partition
    from repro.core.mapping import generic_mapping

    def fn(cg, chip, params):
        res = greedy_partition(cg, chip, params, generic_mapping,
                               "custom-greedy")
        return res

    register_pass(PartitionPass("custom-greedy", fn=fn), replace=True)
    art = flow.compile(_mlp().condense(), CHIP,
                       strategy="custom-greedy", params=PARAMS,
                       pipeline=Pipeline())
    assert art.partition.strategy == "custom-greedy"
    assert art.evaluate("analytic").cycles > 0


def test_unknown_strategy_raises():
    with pytest.raises(KeyError, match="no-such-strategy"):
        flow.compile(_mlp().condense(), CHIP,
                     strategy="no-such-strategy", pipeline=Pipeline())


# ---------------------------------------------------------------------------
# deprecation shims + strict_lmem warning
# ---------------------------------------------------------------------------


def test_legacy_partition_warns_but_works():
    cg = _mlp().condense()
    with pytest.warns(DeprecationWarning, match="repro.flow.compile"):
        res = partition(cg, CHIP, "dp", PARAMS)
    assert res.n_stages >= 1
    with pytest.warns(DeprecationWarning, match="repro.flow.compile"):
        model = compile_model(res, batch=1)
    assert model.total_instrs > 0


def test_perf_mode_lmem_overflow_warns():
    """The silent strict_lmem footgun: perf mode must announce
    out-of-bounds segments (one line, with segment + group id)."""
    g = Graph("big")
    x = g.input("x", (24, 24, 16))
    g.conv("c1", x, cout=64, k=3, act="relu", use_bn=False)
    cg = g.condense()
    tiny = default_chip(n_cores=1, mesh_cols=1, local_mem_kb=16)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        res = partition(cg, tiny, "generic", CostParams(batch=1))
        with pytest.warns(RuntimeWarning,
                          match=r"lmem overflow: segment \d+.*group \d+"):
            compile_model(res, batch=1)
        # strict mode still raises instead
        with pytest.raises(CodegenError, match="overflow"):
            compile_model(res, batch=1, strict_lmem=True)


# ---------------------------------------------------------------------------
# options + func fidelity end-to-end
# ---------------------------------------------------------------------------


def test_options_validation():
    from repro.core.codegen import QuantParams
    with pytest.raises(ValueError, match="fidelity"):
        CompileOptions(fidelity="nope")
    with pytest.raises(ValueError, match="batch"):
        CompileOptions(batch=0)
    # quant normalizes to a sorted tuple: equal options hash equal
    a = CompileOptions(quant={2: QuantParams(3, 8), 1: QuantParams()})
    b = CompileOptions(quant={1: QuantParams(), 2: QuantParams(3, 8)})
    assert a == b and hash(a) == hash(b)
    assert a.subset_key(("quant",)) == b.subset_key(("quant",))
    assert a.quant_dict()[2] == QuantParams(3, 8)


def test_func_backend_matches_oracle():
    g = workloads.tiny_cnn(res=8, c=8)
    cg = g.condense()
    rng = np.random.default_rng(1)
    weights, biases = {}, {}
    for grp in cg:
        if grp.anchor is None:
            continue
        op = g.ops[grp.anchor]
        if op.kind == "conv":
            k = op.attrs["k"]
            cin = g.ops[op.inputs[0]].out_shape[-1]
            ker = rng.integers(-6, 7, (k, k, cin, op.gemm_n), np.int8)
            weights[grp.idx] = ref.conv_weight_matrix(ker)
        elif op.kind == "linear":
            weights[grp.idx] = rng.integers(
                -6, 7, (grp.gemm_k, grp.gemm_n), dtype=np.int8)
        if any(g.ops[i].kind == "bias" for i in grp.op_ids):
            biases[grp.idx] = rng.integers(-40, 40, grp.gemm_n,
                                           np.int32)
    inputs = rng.integers(-8, 8, (2, 8, 8, 3)).astype(np.int8)
    qp = ref.auto_quant(cg, weights, biases, inputs)
    art = flow.compile(cg, CHIP, strategy="dp", params=PARAMS, batch=2,
                       quant=qp, strict_lmem=True, fidelity="func",
                       pipeline=Pipeline())
    img = art.build_gmem_image(weights, biases, inputs)
    rep = art.evaluate(gmem_image=img)          # default backend: func
    oracle = ref.run_reference(cg, weights, biases, qp, inputs)
    last = len(cg) - 1
    for s in range(2):
        addr, nb = art.output_addr(last, s)
        got = rep.sim.gmem[addr - 0x10000000: addr - 0x10000000 + nb]
        np.testing.assert_array_equal(got, oracle[last][s].reshape(-1))

"""Mesh-of-chips scale-out: plan conservation, 1x1 identity, func
bit-exactness across pipeline cuts, the capacity wall, multi-chip DSE
and the cached serving cost table.

The invariants pinned here are the ones that make the system layer
trustworthy rather than merely plausible:

* splitting a model across chips must conserve work exactly (MACs and
  output bytes are partition-invariant);
* a 1x1 "mesh" must be the identity — same cycles, same ISA streams
  as the classic single-chip compile;
* a pipeline-cut functional run (chips executing sequentially, blobs
  harvested over the wire) must be bit-exact with the single-chip
  numpy oracle;
* a model whose resident weights exceed one chip's gmem must be
  rejected single-chip and accepted multi-chip (capacity, not speed,
  is what the mesh buys first).
"""

import numpy as np
import pytest

from repro import flow
from repro.core import ref, workloads
from repro.core.arch import default_chip
from repro.core.mapping import gmem_footprint_bytes
from repro.flow import CompileOptions
from repro.core.partition import InfeasibleModel
from repro.system import SystemConfig, split_pipeline, shard_tensor

RNG = np.random.default_rng(7)

# the func-ladder transformer config used across the suite (full-size
# transformer never func-compiles single-chip under strict lmem)
SMALL_TF = dict(n_layers=1, d_model=128, n_heads=4, seq=16, vocab=64)


def _weights_for(cg):
    """Random int8 weights/biases in the (K, N) matrix layout."""
    src = cg.source
    weights, biases = {}, {}
    for g in cg:
        if g.anchor is None:
            continue
        op = src.ops[g.anchor]
        lo, hi = -6, 7
        if op.kind == "conv":
            k = op.attrs["k"]
            cin = src.ops[op.inputs[0]].out_shape[-1]
            ker = RNG.integers(lo, hi, (k, k, cin, op.gemm_n),
                               dtype=np.int8)
            weights[g.idx] = ref.conv_weight_matrix(ker)
        elif op.kind == "dwconv":
            k = op.attrs["k"]
            ker = RNG.integers(lo, hi, (k, k, op.groups), dtype=np.int8)
            weights[g.idx] = ref.dwconv_weight_matrix(ker)
        elif op.kind == "linear":
            weights[g.idx] = RNG.integers(lo, hi, (g.gemm_k, g.gemm_n),
                                          dtype=np.int8)
        if "bias" in ref._vops(cg, g):
            biases[g.idx] = RNG.integers(-40, 40, g.gemm_n
                                         * (g.groups if g.groups > 1
                                            else 1)).astype(np.int32)
    return weights, biases


def _func_vs_oracle(workload, chip, n_chips, batch=2, workload_kw=None):
    """Compile a pipeline mesh, run func, compare to the numpy oracle."""
    art = flow.compile(workload, chip, CompileOptions(
        fidelity="func", batch=batch, workload_kw=workload_kw or {},
        system=SystemConfig.mesh(n_chips)))
    cg = art.cg
    weights, biases = _weights_for(cg)
    inputs = RNG.integers(-8, 8, (batch,) + cg.source.ops[0].out_shape
                          ).astype(np.int8)
    qp = ref.auto_quant(cg, weights, biases, inputs)
    got = art.run_func(weights, biases, inputs, quant=qp)
    oracle = ref.run_reference(cg, weights, biases, qp, inputs)
    last = len(cg) - 1
    for s in range(batch):
        np.testing.assert_array_equal(
            got.final[s], oracle[last][s].reshape(-1),
            err_msg=f"sample {s} mismatch on {n_chips} chips")
    return art


# ---------------------------------------------------------------------------
# conservation: splitting never creates or destroys work
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 4])
def test_pipeline_conserves_work(n):
    cg = workloads.build("transformer").condense()
    chip = default_chip()
    plan = split_pipeline(cg, chip, SystemConfig.mesh(n))
    assert plan.total_macs() == cg.total_macs
    assert sum(s.out_bytes for s in plan.slices) == \
        sum(g.out_bytes for g in cg)
    # contiguous, disjoint, complete coverage
    covered = [g for s in plan.slices for g in s.gids]
    assert covered == list(range(len(cg)))


@pytest.mark.parametrize("n", [2, 4])
def test_tensor_conserves_work(n):
    cg = workloads.build("transformer").condense()
    chip = default_chip()
    plan = shard_tensor(cg, chip, SystemConfig.mesh(
        n, parallel="tensor"))
    assert plan.total_macs() == cg.total_macs


# ---------------------------------------------------------------------------
# 1x1 mesh == single chip, bit for bit
# ---------------------------------------------------------------------------


def test_1x1_mesh_is_identity():
    chip = default_chip()
    solo = flow.compile("tiny_cnn", chip,
                        CompileOptions(fidelity="simulate"))
    mesh = flow.compile("tiny_cnn", chip, CompileOptions(
        fidelity="simulate", system=SystemConfig(chips_x=1, chips_y=1)))
    assert mesh.n_chips == 1
    rep_solo = solo.evaluate()
    rep_mesh = mesh.evaluate()
    assert rep_mesh.cycles == rep_solo.cycles
    assert rep_mesh.comm_cycles == 0
    # the inner artifact is a real single-chip compile of the original
    # workload: identical ISA streams, not merely identical totals
    inner = mesh.chips[0]
    assert len(inner.model.stages) == len(solo.model.stages)
    for st_a, st_b in zip(inner.model.stages, solo.model.stages):
        assert sorted(st_a.programs) == sorted(st_b.programs)
        for core in st_a.programs:
            assert str(st_a.programs[core]) == str(st_b.programs[core])


# ---------------------------------------------------------------------------
# func bit-exactness across pipeline cuts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 4])
def test_pipeline_func_tiny_cnn(n):
    art = _func_vs_oracle("tiny_cnn", default_chip(), n)
    assert art.n_chips >= 2
    assert art.plan.transfers        # at least one cut crossed


def test_pipeline_func_transformer():
    """Residual taps crossing a cut (side operand arrives as a slice
    input) stay bit-exact — the codegen side-input path."""
    art = _func_vs_oracle("transformer", default_chip(), 2,
                          workload_kw=SMALL_TF)
    assert art.n_chips == 2


def test_pipeline_energy_has_interchip_key():
    art = flow.compile("transformer", default_chip(), CompileOptions(
        fidelity="analytic", system=SystemConfig.mesh(2)))
    rep = art.evaluate()
    assert rep.n_chips == 2
    assert rep.comm_cycles > 0
    assert rep.energy.get("interchip", 0) > 0
    assert rep.energy["total"] >= rep.energy["interchip"]


# ---------------------------------------------------------------------------
# the capacity wall: multi-chip extends reach, not just speed
# ---------------------------------------------------------------------------


def test_deepseek_proxy_needs_a_mesh():
    chip = default_chip()
    cg = workloads.build("deepseek_proxy").condense()
    assert gmem_footprint_bytes(cg.groups) > chip.global_mem_bytes
    for n in (1, 2):
        with pytest.raises(InfeasibleModel):
            flow.compile("deepseek_proxy", chip, CompileOptions(
                fidelity="analytic", system=SystemConfig.mesh(n)))
    art = flow.compile("deepseek_proxy", chip, CompileOptions(
        fidelity="analytic", system=SystemConfig.mesh(4)))
    assert art.n_chips == 4
    assert art.evaluate().cycles > 0
    # tensor-parallel sharding also clears the wall at 4 chips
    art_t = flow.compile("deepseek_proxy", chip, CompileOptions(
        fidelity="analytic",
        system=SystemConfig.mesh(4, parallel="tensor")))
    assert art_t.evaluate().cycles > 0


# ---------------------------------------------------------------------------
# multi-chip DSE
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fidelity", ["analytic", "trace"])
def test_mesh_dse_sweep(fidelity, tmp_path):
    from repro.explore import ExplorationEngine, mesh_space
    space = mesh_space(chips=(1, 2, 4), links=("interposer", "pcb"))
    pts = space.points()
    assert len(pts) == 6
    eng = ExplorationEngine("transformer", cache=str(tmp_path))
    recs = eng.evaluate(pts, fidelity=fidelity)
    assert all(r.error is None for r in recs)
    by = {(r.point.chips, r.point.link): r for r in recs}
    # scale-out helps throughput; a slower link tier can't be faster
    assert by[(2, "interposer")].throughput_sps > \
        by[(1, "interposer")].throughput_sps
    assert by[(2, "interposer")].cycles <= by[(2, "pcb")].cycles
    # second pass is pure cache
    again = eng.evaluate(pts, fidelity=fidelity)
    assert all(r.cache_hit for r in again)
    assert [r.cycles for r in again] == [r.cycles for r in recs]


def test_design_point_system_axes_default_off():
    """chips=1 points build no SystemConfig and keep legacy dict/keys."""
    from repro.explore import DesignPoint
    pt = DesignPoint()
    assert pt.system() is None
    # old serialized points (pre-scale-out) still round-trip
    legacy = {"macros_per_group": 8, "n_macro_groups": 16,
              "n_cores": 64, "flit_bytes": 8, "local_mem_kb": 512,
              "strategy": "generic"}
    assert DesignPoint.from_dict(legacy) == pt
    assert DesignPoint.from_dict(pt.to_dict()) == pt
    pt4 = pt.replace(chips=4, link="interposer")
    assert pt4.system().n_chips == 4
    assert pt4.system().link.name == "interposer"


# ---------------------------------------------------------------------------
# serving: multi-chip tables + whole-table disk cache
# ---------------------------------------------------------------------------


def test_serve_table_disk_cache(tmp_path):
    from repro.serve import ServeModelCfg, StepCostTable
    cfg = ServeModelCfg(n_layers=1, d_model=64, n_heads=2, vocab=64,
                        max_prompt=16, max_new=16)
    kw = dict(fidelity="analytic", flow_cache=str(tmp_path))
    t1 = StepCostTable(cfg, **kw)
    assert not t1.cache_hit
    t2 = StepCostTable(cfg, **kw)
    assert t2.cache_hit
    assert t2.to_dict() == t1.to_dict()
    # a different mesh is a different table, not a stale hit
    t3 = StepCostTable(cfg, system=SystemConfig.mesh(2), **kw)
    assert not t3.cache_hit
    assert t3.to_dict()["system"] is not None


def test_serve_cli_chips_parsing():
    from repro.serve.__main__ import _system, build_parser
    p = build_parser()
    assert _system(p.parse_args(["--chips", "1"])) is None
    sysc = _system(p.parse_args(["--chips", "2x2",
                                 "--link", "interposer"]))
    assert (sysc.chips_x, sysc.chips_y) == (2, 2)
    assert sysc.link.name == "interposer"
    assert _system(p.parse_args(["--chips", "4"])).n_chips == 4
    with pytest.raises(SystemExit):
        _system(p.parse_args(["--chips", "zero"]))
